"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes + finiteness. Full configs are exercised only
through launch.dryrun (ShapeDtypeStruct, no allocation)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_arch, list_archs
from repro.models import transformer as T
from repro.train.optim import AdamWConfig
from repro.train.train_step import TrainConfig, init_train_state, make_train_step

pytestmark = pytest.mark.slow

ALL_ARCHS = [
    "granite-20b",
    "mistral-nemo-12b",
    "nemotron-4-340b",
    "h2o-danube3-4b",
    "jamba-v0.1-52b",
    "granite-moe-3b-a800m",
    "moonshot-v1-16b-a3b",
    "llava-next-34b",
    "whisper-base",
    "mamba2-130m",
]


def test_registry_complete():
    assert sorted(ALL_ARCHS) == list_archs()


def batch_for(cfg, B=2, S=32):
    rng = np.random.default_rng(0)
    b = {
        "tokens": jnp.asarray(rng.integers(4, cfg.vocab_size, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(4, cfg.vocab_size, (B, S)), jnp.int32),
    }
    if cfg.family == "vlm":
        b["patch_embeds"] = 0.01 * jnp.ones((B, cfg.n_patches, cfg.d_model), jnp.bfloat16)
    if cfg.enc_dec:
        b["frames"] = 0.01 * jnp.ones((B, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
    return b


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_arch(arch).reduced()
    tc = TrainConfig(optimizer=AdamWConfig(lr=1e-3), n_microbatches=1,
                     warmup_steps=1, total_steps=10)
    state = init_train_state(cfg, tc, jax.random.PRNGKey(0))
    batch = batch_for(cfg)

    logits, aux = T.forward(state.params, batch, cfg)
    expect_s = batch["tokens"].shape[1] + (cfg.n_patches if cfg.family == "vlm" else 0)
    assert logits.shape == (2, expect_s, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    step = jax.jit(make_train_step(cfg, tc))
    state2, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["loss"]) < 1.2 * np.log(cfg.vocab_size)
    # params actually change (step 1: warmup lr is 0 by construction)
    state3, _ = step(state2, batch)
    d0 = jax.tree.leaves(state.params)[0]
    d1 = jax.tree.leaves(state3.params)[0]
    assert not np.array_equal(np.asarray(d0), np.asarray(d1))


@pytest.mark.parametrize("arch", ["granite-20b", "jamba-v0.1-52b", "whisper-base", "mamba2-130m"])
def test_smoke_serve_roundtrip(arch):
    cfg = get_arch(arch).reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    batch = batch_for(cfg)
    batch.pop("labels")
    logits, caches = T.prefill(params, batch, cfg, max_seq=64)
    assert logits.shape == (2, cfg.vocab_size)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    pos0 = batch["tokens"].shape[1] + (cfg.n_patches if cfg.family == "vlm" else 0)
    pos = jnp.full((2,), pos0, jnp.int32)
    for i in range(3):
        logits, caches = T.decode_step(params, tok, caches, pos + i, cfg)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        assert np.isfinite(np.asarray(logits)).all()
