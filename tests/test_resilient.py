"""Resilient multi-replica access: TransferPlan striping, hedging,
retry/backoff, circuit breakers + GRIS feedback, and the unified
SelectionResult / TransferRequest→TransferResult API."""

import math

import pytest

from repro.core.broker import SelectionResult, default_read_request
from repro.core.transferplan import (
    TransferFailure,
    TransferPlan,
    TransferRequest,
)
from repro.storage.breaker import CLOSED, HALF_OPEN, OPEN, CircuitBreaker
from repro.storage.endpoint import build_demo_grid
from repro.storage.faults import FaultEvent, FaultInjector
from repro.storage.resilient import ResilienceConfig
from repro.storage.transfer import stream_utilization

DATA = b"q" * (8 << 20)
REPLICA_EPS = ["gsiftp://ep000", "gsiftp://ep002", "gsiftp://ep005", "gsiftp://ep007"]


@pytest.fixture
def grid():
    g = build_demo_grid(8, 4, seed=11)
    g.add_client("client://app", zone="zone1")
    g.replicate("bulk", DATA, REPLICA_EPS)
    return g


def make_service(g, **res_kw):
    broker = g.broker_for("client://app")
    svc = g.resilient_transfer_service(
        broker, resilience=ResilienceConfig(**res_kw) if res_kw else None
    )
    return broker, svc


def mirror_grid():
    """Four comparable replicas (one zone): the setting where striping
    actually pays and fault-inflation bounds are meaningful."""
    from repro.storage.endpoint import DataGrid

    g = DataGrid(seed=5)
    eps = [f"gsiftp://acc{i}" for i in range(4)]
    for url in eps:
        g.add_endpoint(url, zone="zoneA")
    g.add_client("client://app", zone="zoneA")
    g.replicate("bulk", DATA, eps)
    return g


# ---------------------------------------------------------------- breaker unit
class TestCircuitBreaker:
    def test_trips_after_threshold(self):
        br = CircuitBreaker("ep", failure_threshold=3, reset_s=10.0)
        assert br.state == CLOSED and br.allows(0.0)
        br.record_failure(1.0)
        br.record_failure(2.0)
        assert br.state == CLOSED  # two of three
        br.record_failure(3.0)
        assert br.state == OPEN and br.trips == 1
        assert not br.allows(4.0)

    def test_success_resets_consecutive_count(self):
        br = CircuitBreaker("ep", failure_threshold=2)
        br.record_failure(1.0)
        br.record_success(2.0)
        br.record_failure(3.0)
        assert br.state == CLOSED  # never two *consecutive* failures

    def test_half_open_probe_cycle(self):
        br = CircuitBreaker("ep", failure_threshold=1, reset_s=10.0)
        br.record_failure(5.0)
        assert br.state == OPEN
        assert not br.allows(14.0)  # still inside the reset window
        assert br.allows(15.1)  # reset elapsed → half-open probe admitted
        assert br.state == HALF_OPEN and br.value == 0.5
        br.record_failure(16.0)  # probe failed → straight back to open
        assert br.state == OPEN and br.trips == 2
        assert br.allows(26.1)
        br.record_success(27.0)  # probe succeeded → closed
        assert br.state == CLOSED and br.value == 0.0


# ------------------------------------------------------------ selection shape
class TestSelectionResult:
    def test_select_returns_plan_and_audit_handle(self, grid):
        b = grid.broker_for("client://app")
        sel = b.select("bulk")
        assert isinstance(sel, SelectionResult)
        # still quacks like the old ranked list
        assert sel[0].pfn.endpoint in REPLICA_EPS
        assert len(sel) == len(REPLICA_EPS)
        assert [rr.rank for rr in sel] == sorted(
            (rr.rank for rr in sel), reverse=True
        )
        # plus the executable plan + decision record
        assert sel.plan.primary.endpoint == sel[0].pfn.endpoint
        assert len(sel.plan.replicas) == len(sel)
        assert sel.plan.request_id == sel.request_id
        assert b.explain(sel.request_id).chosen == sel[0].pfn.endpoint
        assert sel.scores and any(s.matched for s in sel.scores)

    def test_select_many_and_placements_share_the_shape(self, grid):
        b = grid.broker_for("client://app")
        (out,) = b.select_many([("bulk", None)])
        assert isinstance(out, SelectionResult)
        assert out.plan is not None and out.request_id
        place = b.select_placements(1 << 20, grid.alive_endpoints(), k=2)
        assert isinstance(place, SelectionResult) and len(place) == 2

    def test_stripe_map_weighted_and_complete(self):
        from repro.core.catalog import PhysicalFile

        plan = TransferPlan(
            lfn="f",
            replicas=[PhysicalFile(f"ep{i}", "/f", 100) for i in range(3)],
            ranks=[3.0, 2.0, 1.0],
            predicted=[200.0, 100.0, None],  # third replica is cold
            stripe_k=3,
        )
        smap = plan.stripe_map(12)
        assert len(smap) == 12 and set(smap) <= {0, 1, 2}
        counts = [smap.count(s) for s in range(3)]
        assert counts[0] > counts[1] >= counts[2] > 0  # 2x source owns more
        # contiguous runs: each stripe reads one consecutive range
        assert smap == sorted(smap)


# ------------------------------------------------- per-endpoint stream shares
class TestStreamAccounting:
    def test_concurrent_stripes_share_one_pipe(self, grid, monkeypatch):
        """k stripes of n streams on ONE endpoint must charge time
        consistent with a single k*n-stream transfer — utilization is a
        function of the endpoint's total streams, not per-service."""
        monkeypatch.setattr(grid.net, "noise", lambda *a: 1.0)  # pin draws
        svc = grid.transfer_service()
        ep = grid.endpoints["gsiftp://ep000"]
        nb = 1 << 20
        # one transfer holding all 8 streams
        ep.active_streams = 8
        t_one8 = svc.chunk_seconds(ep, "client://app", nb, 0.0, 8)
        # two concurrent stripes of 4 (total 8): each gets U(8)*4/8
        t_stripe4 = svc.chunk_seconds(ep, "client://app", nb, 0.0, 4)
        ep.active_streams = 0
        assert t_stripe4 == pytest.approx(2 * t_one8)
        # and two 4-stream stripes move 2*nb in t_stripe4 — the same
        # aggregate U(8) rate, NOT 2*U(4) (the old per-service overcommit)
        assert stream_utilization(8) < 2 * stream_utilization(4)

    def test_serial_reads_numerically_unchanged(self, grid, monkeypatch):
        """A lone transfer's share is U(n)*n/n = U(n) — the legacy value."""
        monkeypatch.setattr(grid.net, "noise", lambda *a: 1.0)
        svc = grid.transfer_service()
        ep = grid.endpoints["gsiftp://ep000"]
        ep.active_streams = 4
        t = svc.chunk_seconds(ep, "client://app", 1 << 20, 0.0, 4)
        ep.active_streams = 0
        bw = grid.net.effective_bandwidth(
            ep.url, "client://app", 0.0, load_factor=0, disk_rate=ep.disk_rate
        )
        assert t == pytest.approx((1 << 20) / (bw * stream_utilization(4)))

    def test_request_n_streams_override(self, grid):
        pfn = grid.catalog.lookup("bulk")[0]
        svc = grid.transfer_service()
        r8 = svc.transfer(TransferRequest(pfn, "client://app", n_streams=8))
        r4 = svc.transfer(TransferRequest(pfn, "client://app", n_streams=4))
        assert r8.seconds < r4.seconds


# ------------------------------------------------------------------- striping
class TestStripedExecution:
    def test_striped_bytes_and_makespan(self, grid):
        b, svc = make_service(grid)
        t0 = grid.clock.now()
        res = svc.fetch("bulk")
        assert res.payload == DATA and res.nbytes == len(DATA)
        assert res.stripes == 3  # default stripe_k over 4 replicas
        # a cold fetch may hedge its slowest stripe onto the 4th replica
        assert 3 <= len(res.per_replica) <= 4
        assert set(res.per_replica) <= set(REPLICA_EPS)
        assert sum(res.per_replica.values()) == len(DATA)
        # wall time charged is the stripe makespan, not the sum
        assert res.seconds == pytest.approx(grid.clock.now() - t0)

    def test_striping_beats_single_source(self, grid):
        b, svc = make_service(grid)
        warm = svc.fetch("bulk")  # warm per-source history
        striped = svc.fetch("bulk")
        twin = build_demo_grid(8, 4, seed=11)
        twin.add_client("client://app", zone="zone1")
        twin.replicate("bulk", DATA, REPLICA_EPS)
        single = twin.transfer_service()
        pfn = twin.catalog.lookup("bulk")[0]
        alone = single.transfer(TransferRequest(pfn, "client://app"))
        assert striped.seconds < alone.seconds

    def test_single_replica_plan_degenerates_to_one_stripe(self, grid):
        grid.replicate("solo", b"s" * (1 << 20), ["gsiftp://ep001"])
        b, svc = make_service(grid)
        res = svc.fetch("solo")
        assert res.stripes == 1 and res.payload == b"s" * (1 << 20)

    def test_audit_record_annotated(self, grid):
        b, svc = make_service(grid)
        res = svc.fetch("bulk")
        rec = b.explain(b.last_request_id)
        assert rec.accessed and rec.fetched_from in res.per_replica
        assert rec.nbytes == len(DATA)


# ---------------------------------------------------------- retry and hedging
class TestRetryAndHedging:
    def test_flaky_endpoint_retries_with_backoff(self, grid):
        b, svc = make_service(grid, max_retries=8)
        for ep in REPLICA_EPS:
            grid.endpoints[ep].flaky_rate = 0.10
        res = svc.fetch("bulk")
        assert res.payload == DATA
        assert res.retries > 0
        assert svc._c_retries.value == res.retries

    def test_hedge_rescues_degraded_stripe(self):
        """Mild degradation (observed < hedge_factor x predicted) while
        the peers are still busy with their own long queues is hedging's
        regime — the hedge opens the unused 4th replica, which work
        stealing (redistribution among *open* stripes) cannot reach."""
        g = mirror_grid()
        big = b"h" * (64 << 20)  # work >> per-stripe connection latency
        g.replicate("big", big, [f"gsiftp://acc{i}" for i in range(4)])
        b, svc = make_service(g)
        svc.fetch("bulk")  # warm history → predictions exist
        slow_ep = b.select("big").plan.primary.endpoint
        g.endpoints[slow_ep].degradation = 0.3  # below the 0.4 hedge factor
        res = svc.fetch("big")
        assert res.payload == big
        assert res.hedges >= 1 and res.hedge_wins > 0

    def test_retries_exhausted_trips_breaker_and_fails_over(self, grid):
        b, svc = make_service(grid, max_retries=1, breaker_failures=1)
        svc.fetch("bulk")
        sel = b.select("bulk")
        dead_ep = sel.plan.replicas[1].endpoint
        grid.endpoints[dead_ep].flaky_rate = 1.0  # every chunk faults
        res = svc.fetch("bulk")
        assert res.payload == DATA
        assert res.failovers >= 1
        assert svc.breakers.state(dead_ep) == OPEN


# --------------------------------------------------- breaker → GRIS feedback
class TestBreakerFeedback:
    def test_open_breaker_excluded_from_matchmaking(self, grid):
        b, svc = make_service(grid, max_retries=0, breaker_failures=1,
                              breaker_reset_s=500.0)
        svc.fetch("bulk")
        target = b.select("bulk").plan.replicas[1].endpoint
        grid.endpoints[target].flaky_rate = 1.0
        svc.fetch("bulk")  # trips the breaker on `target`
        assert svc.breakers.state(target) == OPEN
        # the endpoint's GRIS now carries our per-source health attr...
        view = grid.endpoints[target].gris.flattened_view(source="client://app")
        assert view["breakerOpenToSource"] == 1.0
        # ...and the default request's requirements gate excludes it while
        # the endpoint itself is alive and reachable
        sel = b.select("bulk")
        assert target not in [rr.pfn.endpoint for rr in sel]
        assert grid.endpoints[target].alive

    def test_half_open_probe_reenters_matchmaking(self, grid):
        b, svc = make_service(grid, max_retries=0, breaker_failures=1,
                              breaker_reset_s=50.0)
        svc.fetch("bulk")
        target = b.select("bulk").plan.replicas[1].endpoint
        grid.endpoints[target].flaky_rate = 1.0
        svc.fetch("bulk")
        assert svc.breakers.state(target) == OPEN
        grid.endpoints[target].flaky_rate = 0.0  # healed
        grid.clock.advance(60.0)  # past breaker_reset_s
        b.invalidate_snapshot()
        res = svc.fetch("bulk")  # republishes 0.5 → selectable probe
        assert res.payload == DATA
        # probe succeeded → breaker closed again and GRIS attr cleared
        assert svc.breakers.state(target) == CLOSED
        view = grid.endpoints[target].gris.flattened_view(source="client://app")
        assert view["breakerOpenToSource"] == 0.0

    def test_bandwidth_publish_does_not_wipe_health(self, grid):
        ep = grid.endpoints["gsiftp://ep000"]
        ep.gris.publish_source_health("client://app", {"breakerOpenToSource": 1.0})
        ep.monitor.observe_transfer("read", "client://app", 1 << 20, 1.0, 0.0)
        view = ep.gris.flattened_view(source="client://app")
        assert view["breakerOpenToSource"] == 1.0
        assert view["lastRDBandwidth"] > 0


# ------------------------------------------------------- faults & acceptance
class TestFaultScenarios:
    def _twin(self):
        g = build_demo_grid(8, 4, seed=11)
        g.add_client("client://app", zone="zone1")
        g.replicate("bulk", DATA, REPLICA_EPS)
        return g

    def test_kill_mid_transfer_plus_degraded_source(self):
        """The acceptance scenario: one stripe source killed mid-transfer
        (via the on_advance fault hook) and another degraded 4x. The
        striped+hedged read completes with correct bytes within 1.5x the
        fault-free simulated wall time; the legacy single-source path
        raises TransferFailure for the killed endpoint."""
        # fault-free baseline on a twin grid (identical seed/state)
        base = mirror_grid()
        bb, bsvc = make_service(base)
        bsvc.fetch("bulk")  # warm
        baseline = bsvc.fetch("bulk")
        assert baseline.payload == DATA
        s_free = baseline.seconds

        # faulted run: degrade the biggest warm contributor (the broker
        # has a bandwidth prediction for it → hedging is prediction-driven)
        # and kill the second-biggest mid-transfer
        g = mirror_grid()
        b, svc = make_service(g)
        inj = FaultInjector(g)
        svc.on_advance = inj.tick
        warm = svc.fetch("bulk")  # warm identically
        contrib = sorted(
            warm.per_replica, key=lambda u: (warm.per_replica[u], u), reverse=True
        )
        slow_ep, kill_ep = contrib[0], contrib[1]
        g.endpoints[slow_ep].degradation = 0.25  # 4x slow
        inj.schedule_event(
            FaultEvent(g.clock.now() + 0.25 * s_free, "kill", kill_ep)
        )
        res = svc.fetch("bulk")
        assert res.payload == DATA  # correct bytes despite both faults
        assert res.failovers >= 1  # the killed stripe was reassigned
        assert res.seconds <= 1.5 * s_free
        assert not g.endpoints[kill_ep].alive  # fault landed mid-transfer

        # legacy single-source against the same faults: dies outright
        g2 = mirror_grid()
        inj2 = FaultInjector(g2)
        xfer = g2.transfer_service()
        pfn = next(p for p in g2.catalog.lookup("bulk") if p.endpoint == kill_ep)
        inj2.schedule_event(FaultEvent(g2.clock.now() + 0.05, "kill", kill_ep))
        with pytest.raises(TransferFailure):
            for ev in xfer.transfer_chunks(TransferRequest(pfn, "client://app")):
                inj2.tick()  # the injector fires as the clock advances

    def test_chaos_integrity_and_bounded_inflation(self):
        """Property test: under a deterministic chaos schedule (degrade +
        flaky + heal), every striped read returns the exact bytes and
        total simulated wall time stays within a bounded factor of the
        fault-free run."""
        n_fetches = 8

        base = self._twin()
        _, bsvc = make_service(base)
        t0 = base.clock.now()
        for _ in range(n_fetches):
            assert bsvc.fetch("bulk").payload == DATA
        s_free = base.clock.now() - t0

        g = self._twin()
        b, svc = make_service(g, max_retries=6)
        inj = FaultInjector(g)
        svc.on_advance = inj.tick
        inj.chaos(horizon=600.0, mtbf=40.0, mttr=10.0, seed=5,
                  kinds=("degrade", "flaky"))
        t0 = g.clock.now()
        for _ in range(n_fetches):
            inj.tick()
            res = svc.fetch("bulk")
            assert res.payload == DATA  # byte integrity under chaos
        s_chaos = g.clock.now() - t0
        assert s_chaos <= 4.0 * s_free  # bounded inflation

    def test_all_replicas_dead_raises(self, grid):
        b, svc = make_service(grid)
        sel = b.select("bulk")
        for ep in REPLICA_EPS:
            grid.drop_endpoint(ep)
        with pytest.raises(TransferFailure):
            svc.execute(sel.plan)


# ------------------------------------------------------------ the only shims
class TestDeprecatedShims:
    """The ONE place the tuple-returning surface is still exercised."""

    def test_read_and_read_chunks_shims(self, grid):
        xfer = grid.transfer_service()
        pfn = grid.catalog.lookup("bulk")[0]
        with pytest.warns(DeprecationWarning):
            payload, n, secs = xfer.read(pfn, "client://app")
        assert payload == DATA and n == len(DATA) and secs > 0
        with pytest.warns(DeprecationWarning):
            chunks = list(xfer.read_chunks(pfn, "client://app"))
        assert b"".join(c for c, _, _ in chunks) == DATA
