"""End-to-end system test: the paper's full pipeline as one scenario.

Builds a heterogeneous grid, replicates a dataset, trains a reduced model
with broker-selected shard fetches under injected faults, checkpoints
with write-side matchmaking, kills the best endpoints, and verifies that
(a) training completes, (b) selection adapted (history-driven re-ranking
actually changed decisions), (c) the checkpoint restores bit-exact from
the surviving replicas."""

import jax
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import get_arch
from repro.core.broker import default_read_request
from repro.data.datasets import ShardManifest, SyntheticCorpus, materialize_on_grid
from repro.data.pipeline import BatchSpec, DataPipeline
from repro.storage.endpoint import build_demo_grid
from repro.storage.faults import FaultEvent, FaultInjector
from repro.train.loop import LoopConfig, TrainLoop
from repro.train.optim import AdamWConfig
from repro.train.train_step import TrainConfig

pytestmark = pytest.mark.slow


def test_end_to_end_grid_training_with_faults():
    cfg = get_arch("granite-moe-3b-a800m").reduced()
    grid = build_demo_grid(8, 4, seed=42)
    grid.add_client("client://host0", zone="zone1")

    man = ShardManifest("e2e", 8, tokens_per_shard=25_000, vocab_size=cfg.vocab_size, seed=5)
    materialize_on_grid(SyntheticCorpus(man), grid, replication=2)

    pipe = DataPipeline("client://host0", 0, 1, grid, man, BatchSpec(8, 64), cache_shards=2)
    broker = grid.broker_for("client://host0")
    ckpt = CheckpointManager("e2e", grid, broker, replication=2, chunk_bytes=1 << 20)

    inj = FaultInjector(grid)
    inj.schedule_event(FaultEvent(0.2, "kill", "gsiftp://ep002"))
    inj.schedule_event(FaultEvent(0.4, "degrade", "gsiftp://ep005", 0.05))

    tc = TrainConfig(optimizer=AdamWConfig(lr=3e-3), n_microbatches=2,
                     warmup_steps=2, total_steps=50)
    loop = TrainLoop(cfg, tc, LoopConfig(total_steps=35, checkpoint_every=15),
                     pipe, ckpt, faults=inj)
    state = loop.run()

    # (a) completed, loss went down despite faults
    losses = loop.losses()
    assert len(losses) == 35
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2

    # (b) the paper's loop is live: GRIS per-source stats exist for us
    served = [
        ep for ep, e in grid.endpoints.items()
        if "client://host0" in e.monitor.per_source
    ]
    assert served, "no endpoint instrumented our transfers"
    # and ranking is history-driven now (rank values are observed B/s)
    ranked = broker.select(man.lfn(0), default_read_request("client://host0"))
    assert ranked[0].rank > 0

    # (c) checkpoint survives losing its top-ranked replica holder
    ckpt.save(999, state)  # snapshot the exact final state
    step = ckpt.latest_step()
    assert step == 999
    manifest = ckpt.load_manifest(step)
    holder = grid.catalog.lookup(manifest["leaves"][0]["chunks"][0]["lfn"])[0].endpoint
    grid.drop_endpoint(holder)
    template = jax.eval_shape(lambda: state)
    restored = ckpt.restore(step, template)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_decentralized_selection_identical_across_clients():
    """Two same-zone clients with identical published state make identical
    decisions with zero shared broker state (§5.1.1)."""
    grid = build_demo_grid(6, 3, seed=9)
    grid.add_client("client://a", zone="zone0")
    grid.add_client("client://b", zone="zone0")
    grid.replicate("f", b"q" * (1 << 20), grid.alive_endpoints()[:4])
    ra = [r.pfn.endpoint for r in grid.broker_for("client://a").select("f")]
    rb = [r.pfn.endpoint for r in grid.broker_for("client://b").select("f")]
    assert ra == rb
