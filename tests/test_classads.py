"""ClassAd language semantics: units, tri-state logic, scoping, builtins."""

import math

import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.classads import (
    ClassAd,
    Error,
    Undefined,
    evaluate,
    parse,
    parse_classad,
    ClassAdSyntaxError,
)


def ev(src, ad=None, other=None, env=None):
    return evaluate(parse(src), ad, other, env)


class TestLiterals:
    def test_numbers(self):
        assert ev("42") == 42
        assert ev("3.5") == 3.5
        assert ev("1e3") == 1000.0

    def test_unit_suffixes_match_paper_ads(self):
        # the paper's §4 storage ad uses 50G / 75K
        assert ev("50G") == 50 * 1024**3
        assert ev("75K") == 75 * 1024
        assert ev("2M") == 2 * 1024**2
        assert ev("1.5K") == 1536.0

    def test_strings_and_bools(self):
        assert ev('"hello"') == "hello"
        assert ev("true") is True
        assert ev("FALSE") is False
        assert ev("undefined") is Undefined
        assert ev("error") is Error

    def test_syntax_errors(self):
        for bad in ("1 +", "(1", "a .", "{1,", "foo(1,"):
            with pytest.raises(ClassAdSyntaxError):
                parse(bad)


class TestArithmetic:
    def test_precedence(self):
        assert ev("7 % 2 + 2 * 3") == 7
        assert ev("2 + 3 * 4 == 14") is True
        assert ev("(2 + 3) * 4") == 20

    def test_integer_division_truncates_toward_zero(self):
        assert ev("7 / 2") == 3
        assert ev("-7 / 2") == -3
        assert ev("7 / 2.0") == 3.5

    def test_division_by_zero_is_error(self):
        assert ev("5 / 0") is Error
        assert ev("5 % 0") is Error

    def test_string_concat_via_plus(self):
        assert ev('"a" + "b"') == "ab"

    def test_type_mismatch_is_error(self):
        assert ev('1 + "a"') is Error
        assert ev('"a" < 1') is Error


class TestTriState:
    """Condor's three-valued logic with absorption."""

    def test_and_absorption(self):
        assert ev("false && undefined") is False
        assert ev("undefined && false") is False
        assert ev("true && undefined") is Undefined
        assert ev("undefined && undefined") is Undefined
        assert ev("false && error") is False
        assert ev("true && error") is Error

    def test_or_absorption(self):
        assert ev("true || undefined") is True
        assert ev("undefined || true") is True
        assert ev("false || undefined") is Undefined
        assert ev("false || error") is Error

    def test_not(self):
        assert ev("!undefined") is Undefined
        assert ev("!error") is Error
        assert ev("!true") is False

    def test_comparisons_propagate(self):
        assert ev("undefined < 5") is Undefined
        assert ev("error == error") is Error  # strict ops propagate Error
        assert ev("undefined + 1") is Undefined

    def test_identity_comparison_is_total(self):
        assert ev("undefined =?= undefined") is True
        assert ev("error =?= error") is True
        assert ev("undefined =?= 5") is False
        assert ev("undefined =!= 5") is True
        assert ev('"a" =?= "A"') is False  # case-sensitive
        assert ev('"a" == "A"') is True  # == is case-insensitive

    def test_ternary(self):
        assert ev("(1 < 2) ? 10 : 20") == 10
        assert ev("undefined ? 10 : 20") is Undefined
        assert ev("error ? 10 : 20") is Error


class TestScoping:
    def test_other_and_my(self):
        a = parse_classad("x = 1; y = other.x + 10")
        b = parse_classad("x = 5")
        assert a.eval_attr("y", b) == 15
        a2 = parse_classad("x = 1; y = my.x + 10")
        assert a2.eval_attr("y", b) == 11

    def test_unqualified_lookup_order_self_then_other(self):
        a = parse_classad("y = x + 1")
        b = parse_classad("x = 7")
        assert a.eval_attr("y", b) == 8  # falls through to other
        a2 = parse_classad("x = 2; y = x + 1")
        assert a2.eval_attr("y", b) == 3  # self wins

    def test_missing_is_undefined(self):
        a = parse_classad("y = other.nosuch")
        assert a.eval_attr("y", ClassAd()) is Undefined

    def test_cycle_guard(self):
        a = parse_classad("x = y; y = x")
        assert a.eval_attr("x") is Error

    def test_case_insensitive_attrs(self):
        a = parse_classad("FooBar = 3")
        assert a.eval_attr("foobar") == 3
        assert "FOOBAR" in a


class TestRecordsAndLists:
    def test_nested_record(self):
        assert ev("[a=1; b=a+1].b") == 2

    def test_list_index_and_member(self):
        assert ev("{10,20,30}[1]") == 20
        assert ev("{10,20,30}[5]") is Error
        assert ev("member(2, {1,2,3})") is True
        assert ev('member("B", {"a","b"})') is True  # case-insensitive


class TestBuiltins:
    def test_numeric(self):
        assert ev("floor(3.7)") == 3
        assert ev("ceiling(3.2)") == 4
        assert ev("round(2.5)") == 3
        assert ev("round(-2.5)") == -3
        assert ev("abs(-4)") == 4
        assert ev("pow(2, 10)") == 1024
        assert ev("sqrt(-1)") is Error
        assert ev("min(3, 1, 2)") == 1
        assert ev("max({3, 1, 2})") == 3
        assert ev("avg({2, 4})") == 3

    def test_strings(self):
        assert ev('strcat("a", 1, "b")') == "a1b"
        assert ev('toUpper("ab")') == "AB"
        assert ev('substr("hello", 1, 3)') == "ell"
        assert ev('regexp("^h.*o$", "hello")') is True

    def test_introspection(self):
        assert ev("isUndefined(nosuch)") is True
        assert ev("isError(1/0)") is True
        assert ev("ifThenElse(1 < 2, 5, 6)") == 5

    def test_time_uses_injected_clock(self):
        assert ev("time()", env={"now": 1234.0}) == 1234
        assert ev("time()") is Error  # no clock injected

    def test_strict_builtins_propagate(self):
        assert ev("floor(undefined)") is Undefined
        assert ev("pow(error, 2)") is Error


# ---------------------------------------------------------------------------
# properties
# ---------------------------------------------------------------------------

nums = st.one_of(
    st.integers(min_value=-(2**31), max_value=2**31),
    st.floats(allow_nan=False, allow_infinity=False, width=32),
)


@given(nums, nums)
@settings(max_examples=200, deadline=None)
def test_prop_arithmetic_matches_python(a, b):
    ad = ClassAd({"a": a, "b": b})
    got = ad.copy()
    got.set_expr("s", "a + b")
    assert got.eval_attr("s") == pytest.approx(a + b, rel=1e-6, abs=1e-6)


@given(nums, nums)
@settings(max_examples=200, deadline=None)
def test_prop_comparison_total_order(a, b):
    ad = ClassAd({"a": a, "b": b})
    lt = evaluate(parse("a < b"), ad)
    ge = evaluate(parse("a >= b"), ad)
    assert lt != ge  # exactly one holds for defined numerics


@given(st.booleans() | st.none(), st.booleans() | st.none())
@settings(max_examples=100, deadline=None)
def test_prop_kleene_and_or_duality(x, y):
    """De Morgan holds in the tri-state logic (None ⇒ undefined)."""
    ad = ClassAd({"x": x, "y": y})
    lhs = evaluate(parse("!(x && y)"), ad)
    rhs = evaluate(parse("(!x) || (!y)"), ad)
    assert lhs is rhs or lhs == rhs


@given(st.integers(-1000, 1000))
@settings(max_examples=50, deadline=None)
def test_prop_parse_repr_roundtrip(n):
    expr = parse(f"(a + {n}) * 2 - abs(b)")
    again = parse(repr(expr))
    ad = ClassAd({"a": 7, "b": -3})
    assert evaluate(expr, ad) == evaluate(again, ad)
