"""LDIF serialization, LDAP filters, and the LDIF↔ClassAd conversion."""

import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.classads import ClassAd, parse_classad
from repro.core.ldif import (
    FilterSyntaxError,
    classad_to_entry,
    dumps,
    entry_to_classad,
    loads,
    parse_filter,
)


class TestLdifRoundtrip:
    def test_basic(self):
        entries = [
            {
                "dn": "gss=vol0, o=grid",
                "objectClass": "Grid::Storage::ServerVolume",
                "totalSpace": 1000,
                "availableSpace": 412.5,
                "mountPoint": "/data",
                "readonly": True,
            }
        ]
        text = dumps(entries)
        back = loads(text)
        assert back == entries

    def test_multivalued(self):
        entries = [{"dn": "x", "filesystem": ["ext4", "xfs"]}]
        back = loads(dumps(entries))
        assert back[0]["filesystem"] == ["ext4", "xfs"]

    def test_continuation_and_comments(self):
        text = "dn: a\n# comment\nfoo: hello\n world\n"
        assert loads(text)[0]["foo"] == "helloworld"


class TestFilters:
    ENTRY = {
        "objectClass": "Grid::Storage::ServerVolume",
        "availableSpace": 5 * 1024**3,
        "hostname": "ep001.grid",
        "zone": "zone3",
    }

    def test_comparisons(self):
        assert parse_filter("(availableSpace>=1000)").matches(self.ENTRY)
        assert not parse_filter("(availableSpace<=1000)").matches(self.ENTRY)
        assert parse_filter("(zone=zone3)").matches(self.ENTRY)
        assert parse_filter("(zone=ZONE3)").matches(self.ENTRY)  # case-insensitive

    def test_composite(self):
        f = parse_filter("(&(availableSpace>=1)(|(zone=zone1)(zone=zone3)))")
        assert f.matches(self.ENTRY)
        assert not parse_filter("(!(zone=zone3))").matches(self.ENTRY)

    def test_presence_and_substring(self):
        assert parse_filter("(hostname=*)").matches(self.ENTRY)
        assert not parse_filter("(nosuch=*)").matches(self.ENTRY)
        assert parse_filter("(hostname=ep*)").matches(self.ENTRY)
        assert parse_filter("(hostname=*grid)").matches(self.ENTRY)
        assert parse_filter("(hostname=ep*grid)").matches(self.ENTRY)
        assert not parse_filter("(hostname=xp*)").matches(self.ENTRY)

    def test_objectclass_query(self):
        # "the broker uses LDAP searches to query GRIS servers"
        f = parse_filter("(objectClass=Grid::Storage::ServerVolume)")
        assert f.matches(self.ENTRY)

    def test_attributes_projection_list(self):
        f = parse_filter("(&(a>=1)(!(b=2)))")
        assert sorted(f.attributes()) == ["a", "b"]

    def test_syntax_errors(self):
        for bad in ("", "(", "(a>5)", "(&)", "(a=1"):
            with pytest.raises(FilterSyntaxError):
                parse_filter(bad)


class TestClassAdConversion:
    """§6: 'the process of converting data, represented in LDAP format,
    into ClassAds is not cumbersome and is worth the effort.'"""

    def test_entry_to_classad_values(self):
        entry = {"dn": "x", "availableSpace": 100, "hostname": "h"}
        ad = entry_to_classad(entry)
        assert ad.eval_attr("availableSpace") == 100
        assert ad.eval_attr("hostname") == "h"

    def test_requirements_string_becomes_expression(self):
        entry = {"requirements": "other.reqdSpace < 10G"}
        ad = entry_to_classad(entry)
        req = parse_classad("reqdSpace = 1024")
        assert ad.eval_attr("requirements", req) is True
        req["reqdSpace"] = 20 * 1024**3
        assert ad.eval_attr("requirements", req) is False

    def test_roundtrip(self):
        ad = parse_classad('a = 5; b = "x"; requirements = a > 3')
        entry = classad_to_entry(ad, dn="gss=t")
        ad2 = entry_to_classad(entry)
        assert ad2.eval_attr("a") == 5
        assert ad2.eval_attr("requirements") is True


@given(
    st.dictionaries(
        st.from_regex(r"[A-Za-z][A-Za-z0-9]{0,10}", fullmatch=True),
        st.one_of(
            st.integers(-(10**9), 10**9),
            st.booleans(),
            st.from_regex(r"[A-Za-z0-9_./:-]{1,20}", fullmatch=True),
        ),
        min_size=1,
        max_size=8,
    )
)
@settings(max_examples=100, deadline=None)
def test_prop_ldif_roundtrip(attrs):
    attrs = {k: v for k, v in attrs.items() if k.lower() != "dn"}
    if not attrs:
        return
    back = loads(dumps([attrs]))
    assert len(back) == 1
    got = back[0]
    for k, v in attrs.items():
        if isinstance(v, str) and (v in ("TRUE", "FALSE") or _looks_numeric(v)):
            continue  # typed re-parse is lossy for number-like strings, by design
        assert got[k] == v


def _looks_numeric(s: str) -> bool:
    try:
        float(s)
        return True
    except ValueError:
        return False
