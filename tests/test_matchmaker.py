"""Matchmaking semantics, including the paper's §4/§5.2 worked example."""

import pytest

from repro.core.classads import ClassAd, parse_classad
from repro.core.matchmaker import Matchmaker, match, rank_value

STORAGE_AD = """
hostname = "hugo.mcs.anl.gov";
volume = "/dev/sandbox";
availableSpace = 50G;
MaxRDBandwidth = 75K;
requirements = other.reqdSpace < 10G && other.reqdRDBandwidth < 75K;
"""

REQUEST_AD = """
hostname = "comet.xyz.com";
reqdSpace = 5G;
reqdRDBandwidth = 50K;
rank = other.availableSpace;
requirements = other.availableSpace > 5G && other.MaxRDBandwidth > 50K;
"""


class TestPaperExample:
    """The exact ads from the paper, §4 (storage) and §5.2 (request)."""

    def test_match_succeeds(self):
        storage = parse_classad(STORAGE_AD)
        request = parse_classad(REQUEST_AD)
        res = match(request, [storage])
        assert len(res) == 1
        assert res[0].name == "hugo.mcs.anl.gov"
        # "we rank the replica servers based on their available space"
        assert res[0].rank == 50 * 1024**3

    def test_policy_rejects_oversized_request(self):
        storage = parse_classad(STORAGE_AD)
        req = parse_classad(REQUEST_AD)
        req["reqdSpace"] = 20 * 1024**3  # > 10G site policy
        assert match(req, [storage]) == []

    def test_request_rejects_slow_storage(self):
        storage = parse_classad(STORAGE_AD)
        storage["MaxRDBandwidth"] = 10 * 1024  # below the 50K requirement
        assert match(parse_classad(REQUEST_AD), [storage]) == []


class TestTwoSided:
    def test_undefined_requirements_fail_closed(self):
        res = parse_classad("requirements = other.nosuchattr > 5")
        req = parse_classad("requirements = true; rank = 1")
        assert match(req, [res]) == []

    def test_resource_without_requirements_one_sided(self):
        res = parse_classad('name = "a"; x = 3')
        req = parse_classad("requirements = other.x > 2")
        assert len(match(req, [res])) == 1

    def test_ranking_order_and_tiebreak(self):
        ads = [
            parse_classad(f'name = "ep{i}"; bw = {bw}')
            for i, bw in enumerate([30, 50, 50, 10])
        ]
        req = parse_classad("requirements = true; rank = other.bw")
        res = match(req, ads)
        assert [m.name for m in res] == ["ep1", "ep2", "ep0", "ep3"]  # ties by name

    def test_rank_undefined_is_zero(self):
        res = parse_classad('name = "a"')
        req = parse_classad("requirements = true; rank = other.nosuch")
        assert match(req, [res])[0].rank == 0.0

    def test_boolean_rank(self):
        a = parse_classad('name = "a"; fast = true')
        b = parse_classad('name = "b"; fast = false')
        req = parse_classad("requirements = true; rank = other.fast")
        res = match(req, [a, b])
        assert res[0].name == "a" and res[0].rank == 1.0

    def test_top_k(self):
        ads = [parse_classad(f'name = "e{i}"; bw = {i}') for i in range(10)]
        req = parse_classad("requirements = true; rank = other.bw")
        res = match(req, ads, top_k=3)
        assert [m.rank for m in res] == [9.0, 8.0, 7.0]


class TestDeterminism:
    def test_independent_matchmakers_agree(self):
        """Decentralization invariant: same published state ⇒ same decision."""
        ads = [parse_classad(f'name = "e{i}"; bw = {(i * 37) % 11}') for i in range(20)]
        req = parse_classad("requirements = other.bw >= 3; rank = other.bw")
        r1 = Matchmaker().match(req, ads)
        r2 = Matchmaker().match(req, list(ads))
        assert [m.name for m in r1] == [m.name for m in r2]

    def test_env_time_deterministic(self):
        res = parse_classad('name = "a"; ts = 100')
        req = parse_classad("requirements = time() - other.ts < 50; rank = 0")
        assert Matchmaker({"now": 120}).match(req, [res])
        assert not Matchmaker({"now": 200}).match(req, [res])
