"""Model-stack numerics: flash==dense, chunked CE, decode==forward,
MoE dispatch invariants, SSD decode==scan."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_arch
from repro.models import transformer as T
from repro.models.attention import _dense_attention, _flash_attention
from repro.models.layers import chunked_cross_entropy, cross_entropy
from repro.models.moe import apply_moe, capacity, init_moe

pytestmark = pytest.mark.slow


class TestFlashAttention:
    @pytest.mark.parametrize("window", [None, 700])
    def test_matches_dense(self, window):
        rng = jax.random.PRNGKey(0)
        q = jax.random.normal(rng, (2, 4096, 8, 32), jnp.float32)
        k = jax.random.normal(jax.random.PRNGKey(1), (2, 4096, 2, 32), jnp.float32)
        v = jax.random.normal(jax.random.PRNGKey(2), (2, 4096, 2, 32), jnp.float32)
        ref = _dense_attention(q, k, v, causal=True, window=window)
        out = _flash_attention(q, k, v, causal=True, window=window,
                               q_chunk=512, kv_chunk=1024)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)

    def test_grad_matches_dense(self):
        rng = jax.random.PRNGKey(3)
        q = jax.random.normal(rng, (1, 2048, 4, 16), jnp.float32)
        k = jax.random.normal(jax.random.PRNGKey(4), (1, 2048, 4, 16), jnp.float32)
        v = jax.random.normal(jax.random.PRNGKey(5), (1, 2048, 4, 16), jnp.float32)
        g1 = jax.grad(lambda q: _flash_attention(q, k, v, causal=True, window=None).sum())(q)
        g2 = jax.grad(lambda q: _dense_attention(q, k, v, causal=True, window=None).sum())(q)
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-4, atol=1e-4)

    def test_noncausal(self):
        rng = jax.random.PRNGKey(6)
        q = jax.random.normal(rng, (1, 4096, 4, 16), jnp.float32)
        k = jax.random.normal(jax.random.PRNGKey(7), (1, 4096, 4, 16), jnp.float32)
        v = jax.random.normal(jax.random.PRNGKey(8), (1, 4096, 4, 16), jnp.float32)
        ref = _dense_attention(q, k, v, causal=False, window=None)
        out = _flash_attention(q, k, v, causal=False, window=None)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


class TestChunkedCE:
    def test_value_and_grads(self):
        B, S, D, V = 2, 64, 32, 977
        h = jax.random.normal(jax.random.PRNGKey(0), (B, S, D))
        w = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (D, V))
        lab = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, V)
        lab = lab.at[0, :5].set(-100)
        dense = lambda h, w: cross_entropy(h @ w, lab)[0]
        chunk = lambda h, w: chunked_cross_entropy(h, w, lab, chunk=16)[0]
        np.testing.assert_allclose(dense(h, w), chunk(h, w), rtol=1e-6)
        g1 = jax.grad(dense, argnums=(0, 1))(h, w)
        g2 = jax.grad(chunk, argnums=(0, 1))(h, w)
        for a, b in zip(g1, g2):
            np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-7)


class TestMoE:
    def test_output_shape_and_aux(self):
        cfg = get_arch("granite-moe-3b-a800m").reduced()
        params = init_moe(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model), jnp.float32)
        y, aux = apply_moe(params, x, cfg)
        assert y.shape == x.shape
        assert float(aux) > 0  # balance loss active

    def test_capacity_drops_bounded(self):
        assert capacity(1024, 2, 8, 1.25) >= 1024 * 2 * 1.25 / 8
        assert capacity(8, 1, 64, 1.0) == 8  # floor

    def test_gate_weighting_sums_to_one_effect(self):
        """With capacity ≫ tokens nothing drops: output is a convex
        combination of expert outputs (scale bounded by max expert)."""
        cfg = get_arch("granite-moe-3b-a800m").reduced()
        params = init_moe(jax.random.PRNGKey(0), cfg)
        x = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (1, 16, cfg.d_model))
        y, _ = apply_moe(params, x, cfg)
        assert np.isfinite(np.asarray(y)).all()


class TestDecodeConsistency:
    @pytest.mark.parametrize(
        "arch", ["mistral-nemo-12b", "h2o-danube3-4b", "mamba2-130m", "jamba-v0.1-52b", "whisper-base"]
    )
    def test_teacher_forced_decode_matches_forward(self, arch):
        cfg = get_arch(arch).reduced()
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        B, S = 1, 32
        toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 4, cfg.vocab_size).astype(jnp.int32)
        batch = {"tokens": toks, "labels": toks}
        if cfg.enc_dec:
            batch["frames"] = 0.01 * jnp.ones((B, cfg.enc_seq, cfg.d_model), jnp.float32)
        logits_full, _ = T.forward(params, batch, cfg)
        pre = dict(batch)
        pre["tokens"] = toks[:, : S - 1]
        pre.pop("labels")
        lp, caches = T.prefill(params, pre, cfg, max_seq=64)
        np.testing.assert_allclose(
            np.asarray(lp), np.asarray(logits_full[:, S - 2]), rtol=3e-2, atol=3e-2
        )
        ld, _ = T.decode_step(params, toks[:, S - 1 : S], caches,
                              jnp.full((B,), S - 1, jnp.int32), cfg)
        np.testing.assert_allclose(
            np.asarray(ld), np.asarray(logits_full[:, S - 1]), rtol=3e-2, atol=3e-2
        )

    def test_vlm_prefill_decode(self):
        cfg = get_arch("llava-next-34b").reduced()
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        B, S = 1, 24
        toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 4, cfg.vocab_size).astype(jnp.int32)
        patches = 0.01 * jnp.ones((B, cfg.n_patches, cfg.d_model), jnp.float32)
        lp, caches = T.prefill(params, {"tokens": toks, "patch_embeds": patches}, cfg, max_seq=64)
        assert np.isfinite(np.asarray(lp)).all()
        ld, _ = T.decode_step(params, toks[:, -1:], caches,
                              jnp.full((B,), cfg.n_patches + S, jnp.int32), cfg)
        assert np.isfinite(np.asarray(ld)).all()


class TestParamAccounting:
    @pytest.mark.parametrize("arch", ["granite-20b", "jamba-v0.1-52b", "mamba2-130m"])
    def test_reduced_param_count_matches_tree(self, arch):
        """param_counts() (used for MODEL_FLOPS) must track the real tree."""
        cfg = get_arch(arch).reduced()
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        actual = sum(x.size for x in jax.tree.leaves(params))
        pc = cfg.param_counts()
        predicted = pc["total"] + pc["embedding"]
        if cfg.positional == "learned":
            predicted += params["pos_embed"].size
        assert abs(actual - predicted) / predicted < 0.05, (actual, predicted)
