"""ShardingPolicy: divisibility fallbacks, axis-uniqueness, tree mapping.

Single-device process: policies are constructed against *abstract* meshes
(we only inspect the PartitionSpecs, never place arrays)."""

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import get_arch
from repro.models import transformer as T
from repro.parallel.sharding import ShardingPolicy, tree_specs


class FakeMesh:
    """Axis-name/size stand-in (ShardingPolicy only reads names+shape)."""

    def __init__(self, **axes):
        self.axis_names = tuple(axes)
        self.shape = dict(axes)


def mesh16():
    return FakeMesh(data=16, model=16)


def spec_entries(spec):
    out = []
    for e in spec:
        if isinstance(e, tuple):
            out += list(e)
        elif e is not None:
            out.append(e)
    return out


class TestParamRules:
    def test_tp_sharding_basics(self):
        p = ShardingPolicy(mesh=mesh16())
        assert p.param_spec("embedding", (49152, 6144)) == P("model", None)
        assert p.param_spec("head", (6144, 49152)) == P(None, "model")
        assert p.param_spec("slots/0/attn/wq", (52, 6144, 6144)) == P(None, None, "model")
        assert p.param_spec("slots/0/attn/wo", (52, 6144, 6144)) == P(None, "model", None)
        assert p.param_spec("slots/0/mlp/wi", (52, 6144, 24576)) == P(None, None, "model")

    def test_divisibility_fallback_replicates(self):
        p = ShardingPolicy(mesh=mesh16())
        # whisper-base: 8 heads × 64 = 512 !% 16 → replicate, recorded
        assert p.param_spec("slots/0/attn/wq", (6, 512, 520)) == P(None, None, None)
        assert any("wq" in f for f in p.explain())

    def test_no_duplicate_axes_with_zero3(self):
        cfg = get_arch("nemotron-4-340b")
        params = jax.eval_shape(lambda: T.init_params(cfg, jax.random.PRNGKey(0)))
        p = ShardingPolicy(mesh=mesh16(), zero3=True)
        specs = tree_specs(params, p.param_spec)
        for spec in jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)):
            entries = spec_entries(spec)
            assert len(entries) == len(set(entries)), spec

    def test_zero3_shards_over_data(self):
        p = ShardingPolicy(mesh=mesh16(), zero3=True)
        spec = p.param_spec("slots/0/mlp/wo", (96, 73728, 18432))
        assert "data" in spec_entries(spec) and "model" in spec_entries(spec)

    def test_moe_expert_parallel_toggle(self):
        p_tp = ShardingPolicy(mesh=mesh16(), expert_parallel=False)
        p_ep = ShardingPolicy(mesh=mesh16(), expert_parallel=True)
        shape = (32, 16, 4096, 14336)  # jamba: 16 experts
        assert p_tp.param_spec("slots/1/moe/wi", shape) == P(None, None, None, "model")
        assert p_ep.param_spec("slots/1/moe/wi", shape) == P(None, "model", None, None)
        # 40 experts don't divide 16 → EP falls back to TP
        p_ep2 = ShardingPolicy(mesh=mesh16(), expert_parallel=True)
        spec = p_ep2.param_spec("slots/0/moe/wi", (32, 40, 1536, 512))
        assert spec == P(None, None, None, "model")

    def test_ssm_head_parallel(self):
        p = ShardingPolicy(mesh=mesh16())
        # jamba: d_inner 8192 → shard; dt (nh=128) aligned
        assert p.param_spec("slots/0/ssm/x_proj", (4, 4096, 8192)) == P(None, None, "model")
        assert p.param_spec("slots/0/ssm/dt_proj", (4, 4096, 128)) == P(None, None, "model")
        assert p.param_spec("slots/0/ssm/bc_proj", (4, 4096, 32)) == P(None, None, None)


class TestOptAndCacheRules:
    def test_qtensor_blocks_spread_over_all_axes(self):
        p = ShardingPolicy(mesh=mesh16())
        spec = p.opt_spec("mu/slots/0/mlp/wi/q", (96, 5308416, 256))
        ents = spec_entries(spec)
        assert "data" in ents and "model" in ents

    def test_qtensor_falls_to_lead_dim(self):
        p = ShardingPolicy(mesh=mesh16())
        # blocks/row = 72 (!% 16) but lead (vocab) shards
        spec = p.opt_spec("mu/embedding/q", (256000, 72, 256))
        assert spec[0] is not None

    def test_cache_batch_sharded(self):
        p = ShardingPolicy(mesh=mesh16(), cache_kv_heads=8)
        spec = p.cache_spec("kv/0/k", (1, 128, 32768, 8, 128))
        assert spec[1] is not None  # batch over data
        # 8 kv heads !% 16 → heads replicated
        assert spec[3] is None

    def test_cache_seq_sharding_for_long_ctx(self):
        p = ShardingPolicy(mesh=mesh16(), cache_kv_heads=8, seq_shard_cache=True)
        k_spec = p.cache_spec("kv/0/k", (1, 1, 524288, 8, 128))
        pos_spec = p.cache_spec("kv/0/pos", (1, 1, 524288))
        assert k_spec[2] is not None  # sequence sharded
        assert pos_spec[2] == k_spec[2]  # masking stays aligned

    def test_kv_head_divisible_shards_heads(self):
        p = ShardingPolicy(mesh=mesh16(), cache_kv_heads=16)
        spec = p.cache_spec("kv/0/k", (1, 128, 32768, 16, 128))
        assert spec[3] == "model"


class TestBatchSpecs:
    def test_batch_over_dp_axes(self):
        p = ShardingPolicy(mesh=FakeMesh(pod=2, data=16, model=16))
        assert p.batch_spec((256, 4096)) == P(("pod", "data"), None)

    def test_indivisible_batch_replicates(self):
        p = ShardingPolicy(mesh=mesh16())
        assert p.batch_spec((1, 4096)) == P(None, None)
