"""Storage simulation + catalog + broker-backed data pipeline."""

import numpy as np
import pytest

from repro.core.catalog import CatalogError, PhysicalFile, ReplicaCatalog
from repro.core.transferplan import TransferRequest
from repro.data.datasets import ShardManifest, SyntheticCorpus, materialize_on_grid
from repro.data.pipeline import BatchSpec, DataPipeline
from repro.parallel.elastic import host_shard_assignment
from repro.storage.endpoint import build_demo_grid
from repro.storage.faults import FaultEvent, FaultInjector
from repro.storage.simnet import NetModel, ZoneTopology


class TestCatalog:
    def test_register_lookup_unregister(self):
        cat = ReplicaCatalog()
        pfn = PhysicalFile("ep://a", "/x", 100, "abcd")
        cat.register_replica("lfn1", pfn)
        assert cat.lookup("lfn1") == [pfn]
        cat.register_replica("lfn1", PhysicalFile("ep://b", "/x", 100))
        assert len(cat.lookup("lfn1")) == 2
        assert cat.unregister_endpoint("ep://a") == 1
        assert len(cat.lookup("lfn1")) == 1
        with pytest.raises(CatalogError):
            cat.lookup("missing")

    def test_idempotent_registration(self):
        cat = ReplicaCatalog()
        pfn = PhysicalFile("ep://a", "/x", 100)
        cat.register_replica("l", pfn)
        cat.register_replica("l", pfn)
        assert len(cat.lookup("l")) == 1

    def test_collections(self):
        cat = ReplicaCatalog()
        cat.create_collection("c", ["a", "b"])
        assert cat.collection("c") == ["a", "b"]


class TestSimNet:
    def test_deterministic(self):
        topo = ZoneTopology()
        topo.assign("s", "z0")
        topo.assign("d", "z1")
        n1, n2 = NetModel(topo, seed=3), NetModel(topo, seed=3)
        a = [n1.effective_bandwidth("s", "d", t * 10.0) for t in range(5)]
        b = [n2.effective_bandwidth("s", "d", t * 10.0) for t in range(5)]
        assert a == b

    def test_zone_hierarchy(self):
        topo = ZoneTopology()
        topo.assign("a", "z0", "r0")
        topo.assign("b", "z0", "r0")
        topo.assign("c", "z1", "r0")
        topo.assign("d", "z2", "r1")
        assert topo.base_bandwidth("a", "b") > topo.base_bandwidth("a", "c")
        assert topo.base_bandwidth("a", "c") > topo.base_bandwidth("a", "d")

    def test_load_reduces_bandwidth(self):
        topo = ZoneTopology()
        n = NetModel(topo, seed=0)
        free = n.expected_bandwidth("s", "d", 0.0, load_factor=0)
        busy = n.expected_bandwidth("s", "d", 0.0, load_factor=4)
        assert busy < free / 4


class TestTransfers:
    def test_bytes_move_and_instrumentation(self):
        grid = build_demo_grid(4, 2, seed=0)
        grid.add_client("client://c", zone="zone0")
        data = b"hello" * 1000
        grid.store_replica("f", "gsiftp://ep001", data)
        xfer = grid.transfer_service()
        pfn = grid.catalog.lookup("f")[0]
        res = xfer.transfer(TransferRequest(pfn, "client://c"))
        assert res.payload == data and res.nbytes == len(data) and res.seconds > 0
        assert res.per_replica == {"gsiftp://ep001": len(data)}
        # server-side per-source stats published (§3.2)
        ep = grid.endpoints["gsiftp://ep001"]
        assert ep.monitor.per_source["client://c"]["read"].n == 1
        view = ep.gris.flattened_view(source="client://c")
        assert view["lastRDBandwidth"] > 0

    def test_clock_advances(self):
        grid = build_demo_grid(4, 2, seed=0)
        grid.add_client("client://c", zone="zone0")
        grid.store_replica("f", "gsiftp://ep000", b"z" * (1 << 20))
        t0 = grid.clock.now()
        grid.transfer_service().transfer(
            TransferRequest(grid.catalog.lookup("f")[0], "client://c")
        )
        assert grid.clock.now() > t0

    def test_fault_schedule(self):
        grid = build_demo_grid(4, 2, seed=0)
        inj = FaultInjector(grid)
        inj.schedule_event(FaultEvent(10.0, "kill", "gsiftp://ep000"))
        inj.schedule_event(FaultEvent(20.0, "heal", "gsiftp://ep000"))
        grid.clock.advance(11)
        inj.tick()
        assert not grid.endpoints["gsiftp://ep000"].alive
        grid.clock.advance(10)
        inj.tick()
        assert grid.endpoints["gsiftp://ep000"].alive

    def test_capacity_enforced(self):
        grid = build_demo_grid(2, 1, seed=0, capacity=1000)
        with pytest.raises(IOError):
            grid.endpoints["gsiftp://ep000"].put("/big", b"x" * 2000)


class TestPipeline:
    @pytest.fixture
    def env(self):
        grid = build_demo_grid(6, 3, seed=2)
        for h in range(2):
            grid.add_client(f"client://h{h}", zone=f"zone{h}")
        man = ShardManifest("corpus", 8, tokens_per_shard=5000, vocab_size=512, seed=4)
        materialize_on_grid(SyntheticCorpus(man), grid, replication=2)
        return grid, man

    def test_shard_assignment_partition(self):
        """Every shard goes to exactly one host — with no coordinator."""
        for n_hosts in (1, 2, 4):
            seen = []
            for h in range(n_hosts):
                seen += host_shard_assignment(16, n_hosts, h, epoch=3)
            assert sorted(seen) == list(range(16))

    def test_batches_deterministic(self, env):
        grid, man = env
        spec = BatchSpec(4, 64)
        p1 = DataPipeline("client://h0", 0, 2, grid, man, spec)
        p2 = DataPipeline("client://h0", 0, 2, grid, man, spec)
        b1 = next(p1.batches(0))
        b2 = next(p2.batches(0))
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        np.testing.assert_array_equal(b1["labels"], b2["labels"])

    def test_labels_shifted(self, env):
        grid, man = env
        p = DataPipeline("client://h0", 0, 1, grid, man, BatchSpec(2, 32))
        b = next(p.batches(0))
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])

    def test_survives_endpoint_death(self, env):
        grid, man = env
        p = DataPipeline("client://h0", 0, 1, grid, man, BatchSpec(4, 64), cache_shards=0)
        it = p.batches(0)
        next(it)
        # kill every endpoint that served so far; replication saves us
        first = grid.catalog.lookup(man.lfn(0))[0].endpoint
        grid.drop_endpoint(first)
        count = sum(1 for _ in it)
        assert count > 0

    def test_corpus_deterministic_and_structured(self):
        man = ShardManifest("c", 2, 10000, 512, seed=9)
        c = SyntheticCorpus(man)
        a, b = c.shard_tokens(0), c.shard_tokens(0)
        np.testing.assert_array_equal(a, b)
        assert (a == 1).sum() > 5  # BOS structure present
        assert not np.array_equal(a, c.shard_tokens(1))
