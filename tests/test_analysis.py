"""Static analysis subsystem: ClassAd/schema analyzer, repo lint,
kernel BlockSpec checks, broker/GRIS wiring, and the CLI gate.

The seeded defect corpus pins the contract from the issue: every known-bad
ad produces exactly the expected diagnostic (rule-for-rule, no extras),
and the clean tree plus the exemplar ads produce zero findings.
"""

import json
import os

import pytest

from repro.analysis import (
    Report,
    Severity,
    build_report,
    check_ad_file,
    check_ad_text,
    check_kernel_source,
    check_policy_source,
    check_request_ad,
    check_resource_ad,
    lint_source,
    main,
)
from repro.core.broker import AdValidationError, default_read_request
from repro.core.classads import parse_classad
from repro.core.gris import Clock, StorageGRIS
from repro.core.schema import SchemaError
from repro.storage.endpoint import build_demo_grid

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO_ROOT, "src", "repro")
ADS_DIR = os.path.join(REPO_ROOT, "examples", "ads")


def rules(diags):
    return sorted(d.rule for d in diags)


# ---------------------------------------------------------------- bad corpus
# Each entry: (name, ad source, perspective, exact expected rule list).
BAD_ADS = [
    (
        "undefined-attr",
        "requirements = other.availabelSpace > 5G; rank = other.AvgRDBandwidth;",
        "request",
        ["AD101"],
    ),
    (
        "cis-compared-as-number",
        "requirements = other.mountPoint > 5; rank = other.AvgRDBandwidth;",
        "request",
        ["AD102"],
    ),
    (
        "contradictory-interval",
        "requirements = other.availableSpace > 10G && other.availableSpace < 1G;"
        " rank = other.AvgRDBandwidth;",
        "request",
        ["AD104"],
    ),
    (
        "trivially-false",
        "requirements = 1 > 2; rank = other.AvgRDBandwidth;",
        "request",
        ["AD104"],
    ),
    (
        "tautology",
        "requirements = 2 > 1; rank = other.AvgRDBandwidth;",
        "request",
        ["AD105"],
    ),
    (
        "constant-rank",
        "reqdSpace = 5G;"
        " requirements = other.availableSpace >= my.reqdSpace;"
        " rank = my.reqdSpace / 1G;",
        "request",
        ["AD106"],
    ),
    (
        "string-rank",
        "requirements = other.availableSpace > 1G; rank = other.mountPoint;",
        "request",
        ["AD108"],
    ),
    (
        "unknown-function",
        "requirements = other.availableSpace > 1G;"
        " rank = frobnicate(other.AvgRDBandwidth);",
        "request",
        ["AD103"],
    ),
    (
        "missing-requirements",
        "reqdSpace = 5G; rank = other.AvgRDBandwidth;",
        "request",
        ["AD107"],
    ),
    (
        "numeric-operand-to-and",
        "requirements = other.availableSpace && other.MaxRDBandwidth > 1;"
        " rank = other.AvgRDBandwidth;",
        "request",
        ["AD102"],
    ),
    (
        # the paper's §4 storage ad, mutated: availableSpace typo'd away
        # so the ServerVolume MUST set is violated
        "storage-ad-missing-must",
        'objectClass = "Grid::Storage::ServerVolume";'
        ' mountPoint = "/homes"; totalSpace = 50G; availabelSpace = 20G;'
        " diskTransferRate = 75K; drdTime = 10.5; dwrTime = 11.5;"
        " requirements = other.reqdSpace <= 10G;",
        "resource",
        ["ADS01"],
    ),
    (
        # site policy with a cis/cisfloat confusion: comparing the
        # requester's URL (a string) with a number
        "storage-ad-policy-type-confusion",
        'objectClass = "Grid::Storage::ServerVolume";'
        ' mountPoint = "/homes"; totalSpace = 50G; availableSpace = 20G;'
        " diskTransferRate = 75K; drdTime = 10.5; dwrTime = 11.5;"
        " requirements = other.clientUrl > 5;",
        "resource",
        ["AD102"],
    ),
    (
        "storage-ad-unknown-class",
        'objectClass = "Grid::Compute::Node"; totalSpace = 50G;',
        "resource",
        ["ADS03"],
    ),
]


class TestBadAdCorpus:
    @pytest.mark.parametrize(
        "name,src,perspective,expected",
        BAD_ADS,
        ids=[b[0] for b in BAD_ADS],
    )
    def test_exact_diagnostics(self, name, src, perspective, expected):
        diags = check_ad_text(src, name=name)
        assert rules(diags) == expected, [d.render() for d in diags]

    def test_corpus_is_large_enough(self):
        assert len(BAD_ADS) >= 10

    def test_syntax_error_ad(self):
        diags = check_ad_text("requirements = other.availableSpace >;")
        assert rules(diags) == ["ADS02"]
        assert diags[0].severity is Severity.ERROR
        assert diags[0].span is not None

    def test_spans_point_at_the_attribute(self):
        src = "reqdSpace = 5G;\nrank = other.AvgRDBandwidth;\n"
        diags = check_ad_text(src)
        assert rules(diags) == ["AD107"]  # located on the missing attr's ad
        src2 = "reqdSpace = 5G;\nrequirements = other.nope > 1;\nrank = other.AvgRDBandwidth;\n"
        (d,) = check_ad_text(src2)
        assert d.rule == "AD101" and d.span.line == 2

    def test_guarded_undefined_attr_downgrades(self):
        src = (
            "requirements = isUndefined(other.customHint) || other.customHint > 1;"
            " rank = other.AvgRDBandwidth;"
        )
        (d,) = check_request_ad(parse_classad(src))
        assert d.rule == "AD101" and d.severity is Severity.WARNING

    def test_attr_used_only_inside_guard_is_silent(self):
        src = (
            "requirements = !isUndefined(other.customHint)"
            " && other.availableSpace > 1G;"
            " rank = other.AvgRDBandwidth;"
        )
        assert check_request_ad(parse_classad(src)) == []


class TestCleanAds:
    def test_exemplar_ads_zero_findings(self):
        files = sorted(
            os.path.join(ADS_DIR, f)
            for f in os.listdir(ADS_DIR)
            if f.endswith(".ad")
        )
        assert len(files) >= 3
        for path in files:
            assert check_ad_file(path) == [], path

    def test_default_read_request_is_clean(self):
        assert check_request_ad(default_read_request("client://c")) == []

    def test_demo_policy_is_clean(self):
        assert check_policy_source("other.reqdSpace <= 10G") == []

    def test_resource_ad_perspective_detected(self):
        src = 'objectClass = "Grid::Storage::ServerVolume"; mountPoint = "/x";' \
              " totalSpace = 1G; availableSpace = 1G; diskTransferRate = 1K;" \
              " drdTime = 1.0; dwrTime = 1.0;"
        assert check_ad_text(src) == []


# -------------------------------------------------------------- injected lint
class TestInjectedLintViolations:
    def test_wallclock_leak_in_sim_path(self):
        src = "import time\n\ndef stamp():\n    return time.time()\n"
        diags = lint_source(src, "repro/storage/leak.py")
        assert rules(diags) == ["SIM001"]
        assert diags[0].severity is Severity.ERROR
        # same file outside a sim path: only a warning
        (d,) = lint_source(src, "repro/launch/tool.py")
        assert d.severity is Severity.WARNING

    def test_unseeded_random_in_sim_path(self):
        src = "import random\n\ndef jitter():\n    return random.random()\n"
        diags = lint_source(src, "repro/core/jitter.py")
        assert rules(diags) == ["SIM002"]
        src_np = (
            "import numpy as np\n\ndef jitter():\n    return np.random.rand(3)\n"
        )
        assert rules(lint_source(src_np, "repro/serve/x.py")) == ["SIM002"]
        # explicitly seeded constructions stay silent
        ok = "import numpy as np\nrng = np.random.default_rng(7)\n"
        assert lint_source(ok, "repro/core/ok.py") == []

    def test_unbounded_retry_and_bare_except(self):
        src = (
            "def fetch(svc):\n"
            "    while True:\n"
            "        try:\n"
            "            svc.poll()\n"
            "        except:\n"
            "            continue\n"
        )
        diags = lint_source(src, "repro/storage/retry.py")
        assert rules(diags) == ["TRF001", "TRF002"]
        # a bounded loop (break) with a concrete except is clean
        ok = (
            "def fetch(svc):\n"
            "    for _ in range(3):\n"
            "        try:\n"
            "            return svc.poll()\n"
            "        except TimeoutError:\n"
            "            continue\n"
        )
        assert lint_source(ok, "repro/storage/retry.py") == []

    def test_unbounded_metric_label(self):
        src = (
            "def track(metrics, lfn):\n"
            "    metrics.counter('reads_total', 'reads', lfn=lfn).inc()\n"
        )
        diags = lint_source(src, "repro/core/track.py")
        assert rules(diags) == ["OBS001"]
        # a literal label value is bounded by construction
        ok = "def track(m):\n    m.counter('reads_total', 'r', op='read').inc()\n"
        assert lint_source(ok, "repro/core/track.py") == []

    def test_deprecated_tuple_read_shims(self):
        src = (
            "def old(svc, replica, client):\n"
            "    data, nbytes, bw = svc.read(replica, client)\n"
            "    for c in svc.read_chunks(replica, client):\n"
            "        pass\n"
        )
        diags = lint_source(src, "repro/serve/old.py")
        assert rules(diags) == ["DEP001", "DEP001"]
        # ordinary file-object reads are not the shim
        ok = "def load(f):\n    return f.read()\n"
        assert lint_source(ok, "repro/serve/old.py") == []

    def test_allow_marker_suppresses(self):
        src = (
            "import time\n\n"
            "def stamp():\n"
            "    return time.time()  # lint: allow-wallclock\n"
        )
        assert lint_source(src, "repro/storage/leak.py") == []

    def test_kernel_blockspec_misalignment(self):
        src = (
            "import jax.experimental.pallas as pl\n"
            "def launch(x, *, block_s=7):\n"
            "    grid = (4, 2)\n"
            "    spec = pl.BlockSpec((block_s, 100), lambda i: (i, 0))\n"
        )
        diags = check_kernel_source(src, "repro/kernels/bad/kernel.py")
        assert rules(diags) == ["KRN001", "KRN002", "KRN003"]
        ok = (
            "import jax.experimental.pallas as pl\n"
            "def launch(x, *, block_s=512):\n"
            "    grid = (4,)\n"
            "    spec = pl.BlockSpec((block_s, 256), lambda i: (i, 0))\n"
        )
        assert check_kernel_source(ok, "repro/kernels/ok/kernel.py") == []

    def test_merge_kernel_blockspec_alignment(self):
        """The hierarchical merge stage (DESIGN.md §9) sizes its output
        blocks by a module-level constant; the checker must resolve it —
        both to keep the real kernel honest and to flag a bad edit."""
        from repro.analysis import check_kernel_file

        real = os.path.join(SRC, "kernels", "matchrank", "sharded.py")
        assert check_kernel_file(real) == []
        doctored = (
            "import jax.experimental.pallas as pl\n"
            "MERGE_K_PAD = 100\n"  # not 1 and not a lane multiple
            "def merge(b, c_pad=256):\n"
            "    grid = (b,)\n"
            "    out = pl.BlockSpec((1, MERGE_K_PAD), lambda bi: (bi, 0))\n"
        )
        diags = check_kernel_source(doctored, "repro/kernels/matchrank/bad.py")
        assert rules(diags) == ["KRN001"]


class TestCleanTree:
    def test_repo_sources_and_ads_have_zero_findings(self):
        report = build_report([SRC], [ADS_DIR])
        assert list(report) == [], report.render()
        assert report.checked_files > 50
        assert report.checked_ads >= 3
        assert report.ok

    def test_report_is_deterministic(self):
        a = build_report([SRC], [ADS_DIR]).to_dict()
        b = build_report([SRC], [ADS_DIR]).to_dict()
        assert a == b


# ------------------------------------------------------------- broker wiring
@pytest.fixture
def grid():
    g = build_demo_grid(4, 2, seed=3)
    g.add_client("client://c0", zone="zone1")
    g.replicate("f-0", b"z" * (1 << 20), ["gsiftp://ep000", "gsiftp://ep002"])
    return g


CONSTANT_RANK_AD = (
    "clientUrl = \"client://c0\"; reqdSpace = 1G;"
    " requirements = other.availableSpace >= 0; rank = 1.0;"
)


class TestBrokerAdCheck:
    def test_warn_mode_records_into_audit(self, grid):
        b = grid.broker_for("client://c0")  # ad_check defaults to "warn"
        res = b.select("f-0", parse_classad(CONSTANT_RANK_AD))
        assert len(res) == 2
        rec = b.explain(b.last_request_id)
        assert [d["rule"] for d in rec.ad_diagnostics] == ["AD106"]
        assert rec.ad_diagnostics[0]["severity"] == "warning"
        assert b.stats["ad_findings"] == 1

    def test_clean_request_records_nothing(self, grid):
        b = grid.broker_for("client://c0")
        b.select("f-0")
        rec = b.explain(b.last_request_id)
        assert rec.ad_diagnostics == []

    def test_strict_mode_refuses_error_ads(self, grid):
        b = grid.broker_for("client://c0", ad_check="strict")
        bad = parse_classad(
            "requirements = 1 > 2; rank = other.AvgRDBandwidth;"
        )
        with pytest.raises(AdValidationError, match="AD104"):
            b.select("f-0", bad)
        rec = b.explain(b.last_request_id)
        assert rec.error.startswith("AdValidationError")
        assert [d["rule"] for d in rec.ad_diagnostics] == ["AD104"]

    def test_strict_mode_passes_clean_ads(self, grid):
        b = grid.broker_for("client://c0", ad_check="strict")
        assert len(b.select("f-0")) == 2

    def test_off_mode_skips_analysis(self, grid):
        b = grid.broker_for("client://c0", ad_check="off")
        b.select("f-0", parse_classad(CONSTANT_RANK_AD))
        rec = b.explain(b.last_request_id)
        assert rec.ad_diagnostics == []
        assert len(b._ad_diag_cache) == 0

    def test_analysis_is_memoized_per_ad_source(self, grid):
        b = grid.broker_for("client://c0")
        b.select("f-0")
        b.select("f-0")
        assert len(b._ad_diag_cache) == 1

    def test_select_many_nonstrict_isolates_bad_ad(self, grid):
        b = grid.broker_for("client://c0", ad_check="strict")
        bad = parse_classad("requirements = 1 > 2; rank = other.AvgRDBandwidth;")
        results = b.select_many(
            [("f-0", None), ("f-0", bad)], strict=False
        )
        assert len(results[0]) == 2
        assert isinstance(results[1], AdValidationError)

    def test_invalid_mode_rejected(self, grid):
        with pytest.raises(ValueError):
            grid.broker_for("client://c0", ad_check="loud")


class TestGrisPolicyCheck:
    def test_error_policy_refused_at_registration(self):
        with pytest.raises(SchemaError, match="AD102"):
            StorageGRIS(
                "volume=/x", {"requirements": "other.clientUrl > 5"},
                clock=Clock(),
            )

    def test_warning_policy_registers_with_findings(self):
        g = StorageGRIS(
            "volume=/x", {"requirements": "other.reqdFoo <= 10G"},
            clock=Clock(),
        )
        assert [d.rule for d in g.policy_diagnostics] == ["AD101"]
        assert g.policy_diagnostics[0].severity is Severity.WARNING

    def test_validate_false_keeps_findings_without_raising(self):
        g = StorageGRIS(
            "volume=/x", {"requirements": "other.clientUrl > 5"},
            clock=Clock(), validate=False,
        )
        assert [d.rule for d in g.policy_diagnostics] == ["AD102"]

    def test_set_static_reanalyzes(self):
        g = StorageGRIS("volume=/x", {}, clock=Clock())
        assert g.policy_diagnostics == []
        with pytest.raises(SchemaError):
            g.set_static("requirements", "other.clientUrl > 5")

    def test_demo_grid_policies_are_clean(self, grid):
        for ep in grid.endpoints:
            g = grid.gris_for(ep)
            if g is not None:
                assert g.policy_diagnostics == []


# ----------------------------------------------------------------- CLI / JSON
class TestRunner:
    def test_gate_fails_on_bad_ad_and_writes_report(self, tmp_path, capsys):
        bad = tmp_path / "bad.ad"
        bad.write_text(
            "requirements = other.availabelSpace > 5G;"
            " rank = other.AvgRDBandwidth;\n"
        )
        out = tmp_path / "report.json"
        rc = main(["--ads", str(bad), "--json", str(out)])
        assert rc == 1
        payload = json.loads(out.read_text())
        assert payload["version"] == 1
        assert payload["tool"] == "repro.analysis"
        assert payload["ok"] is False
        assert payload["by_rule"] == {"AD101": 1}
        assert payload["checked_ads"] == 1
        (d,) = payload["diagnostics"]
        assert d["rule"] == "AD101" and d["severity"] == "error"
        assert "availabelSpace" in d["message"]
        listing = capsys.readouterr().out
        assert "AD101" in listing

    def test_gate_passes_on_clean_inputs(self, tmp_path):
        rc = main([os.path.join(SRC, "analysis"), "--ads", ADS_DIR,
                   "--json", str(tmp_path / "r.json")])
        assert rc == 0
        payload = json.loads((tmp_path / "r.json").read_text())
        assert payload["ok"] is True and payload["diagnostics"] == []

    def test_lint_flags_injected_file_on_disk(self, tmp_path):
        pkg = tmp_path / "repro" / "storage"
        pkg.mkdir(parents=True)
        (pkg / "leak.py").write_text(
            "import time\n\ndef stamp():\n    return time.time()\n"
        )
        rc = main([str(tmp_path)])
        assert rc == 1


class TestDiagnosticModel:
    def test_severity_ordering(self):
        assert Severity.INFO < Severity.WARNING < Severity.ERROR

    def test_report_counts_and_ok(self):
        report = Report()
        assert report.ok
        report.extend(check_ad_text("requirements = 2 > 1; rank = 1;"))
        assert report.counts()["warning"] == 2  # AD105 + AD106
        assert report.ok  # warnings do not fail the gate
        report.extend(check_ad_text("requirements = 1 > 2; rank = 1.0;"))
        assert not report.ok

    def test_render_one_line_per_finding(self):
        (d,) = check_ad_text("reqdSpace = 5G;\nrank = other.AvgRDBandwidth;\n",
                             name="x.ad")
        line = d.render()
        assert line.startswith("x.ad") and "AD107" in line and "warning" in line
