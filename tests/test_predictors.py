"""Bandwidth predictors: streaming correctness + NWS-style adaptation."""

import math

import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.predictors import (
    AdaptivePredictor,
    Ewma,
    LastValue,
    RunningMean,
    SlidingMean,
    SlidingMedian,
    make_predictor,
)


series = st.lists(
    st.floats(min_value=1.0, max_value=1e9, allow_nan=False), min_size=1, max_size=64
)


class TestBasics:
    def test_empty_predicts_none(self):
        for kind in ("last", "mean", "sliding_mean", "sliding_median", "ewma", "adaptive"):
            assert make_predictor(kind).predict() is None

    @given(series)
    @settings(max_examples=100, deadline=None)
    def test_last(self, xs):
        p = LastValue()
        p.update_many(xs)
        assert p.predict() == xs[-1]

    @given(series)
    @settings(max_examples=100, deadline=None)
    def test_running_mean_and_std(self, xs):
        p = RunningMean()
        p.update_many(xs)
        assert p.predict() == pytest.approx(np.mean(xs), rel=1e-9)
        assert p.std == pytest.approx(np.std(xs), rel=1e-6, abs=1e-6)

    @given(series, st.integers(1, 16))
    @settings(max_examples=100, deadline=None)
    def test_sliding_window(self, xs, w):
        pm = SlidingMean(w)
        pmed = SlidingMedian(w)
        pm.update_many(xs)
        pmed.update_many(xs)
        tail = xs[-w:]
        assert pm.predict() == pytest.approx(np.mean(tail), rel=1e-9)
        assert pmed.predict() == pytest.approx(np.median(tail), rel=1e-9)

    @given(series)
    @settings(max_examples=100, deadline=None)
    def test_ewma_recursion(self, xs):
        p = Ewma(0.25)
        p.update_many(xs)
        v = xs[0]
        for x in xs[1:]:
            v = 0.25 * x + 0.75 * v
        assert p.predict() == pytest.approx(v, rel=1e-9)


class TestAdaptive:
    def test_picks_last_on_trending_series(self):
        """On a monotone ramp, last-value beats the long-run mean."""
        p = AdaptivePredictor()
        for t in range(200):
            p.update(1000.0 + 10.0 * t)
        assert p.best_member().name in ("last", "ewma", "sliding_mean", "sliding_median")
        pred = p.predict()
        truth = 1000.0 + 10.0 * 200
        mean_err = abs(np.mean([1000 + 10 * t for t in range(200)]) - truth)
        assert abs(pred - truth) < mean_err / 2

    def test_picks_robust_on_noisy_stationary(self):
        rng = np.random.default_rng(0)
        xs = 1e6 + rng.normal(0, 1e5, 500)
        xs[::50] = 1e3  # outlier dropouts
        p = AdaptivePredictor()
        p.update_many(xs.tolist())
        # adaptive must not be fooled into predicting the outlier level
        assert p.predict() > 5e5

    def test_adaptive_beats_worst_member(self):
        rng = np.random.default_rng(1)
        xs = np.concatenate([
            np.full(100, 1e6) + rng.normal(0, 1e4, 100),
            np.full(100, 2e5) + rng.normal(0, 1e4, 100),  # regime change
        ])
        members = {
            "last": LastValue(), "mean": RunningMean(), "ewma": Ewma(0.25),
        }
        adaptive = AdaptivePredictor()
        errs = {k: [] for k in members}
        errs["adaptive"] = []
        for x in xs:
            for k, m in members.items():
                if m.predict() is not None:
                    errs[k].append(abs(m.predict() - x))
                m.update(x)
            if adaptive.predict() is not None:
                errs["adaptive"].append(abs(adaptive.predict() - x))
            adaptive.update(x)
        mae = {k: np.mean(v) for k, v in errs.items()}
        assert mae["adaptive"] <= max(mae["last"], mae["mean"], mae["ewma"])
        assert mae["adaptive"] < mae["mean"]  # mean is terrible across regimes


def test_make_predictor_rejects_unknown():
    with pytest.raises(ValueError):
        make_predictor("nope")
