"""bwstats Pallas kernel: shape sweeps vs jnp ref vs python recursion."""

import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.bandwidth import TransferMonitor
from repro.kernels.bwstats.ops import bwstats, publish_fleet_stats


def rand_hist(rng, n, w):
    hist = rng.uniform(1e3, 1e9, (n, w)).astype(np.float32)
    counts = rng.integers(0, w + 1, n).astype(np.int32)
    return hist, counts


class TestKernelVsRef:
    @pytest.mark.parametrize("n,w", [(1, 1), (3, 17), (50, 37), (256, 64), (300, 128), (1024, 200)])
    def test_shape_sweep(self, n, w):
        rng = np.random.default_rng(n * 1000 + w)
        hist, counts = rand_hist(rng, n, w)
        k = bwstats(hist, counts, use_kernel=True)
        r = bwstats(hist, counts, use_kernel=False)
        for name in k:
            np.testing.assert_allclose(k[name], r[name], rtol=1e-5, atol=1e-2, err_msg=name)

    @pytest.mark.parametrize("alpha", [0.1, 0.25, 0.9, 1.0])
    def test_alpha_sweep(self, alpha):
        rng = np.random.default_rng(int(alpha * 100))
        hist, counts = rand_hist(rng, 32, 48)
        k = bwstats(hist, counts, alpha=alpha, use_kernel=True)
        r = bwstats(hist, counts, alpha=alpha, use_kernel=False)
        np.testing.assert_allclose(k["ewma"], r["ewma"], rtol=2e-4, atol=1e-2)

    def test_empty_series_zero(self):
        hist = np.ones((4, 8), np.float32)
        counts = np.array([0, 3, 0, 8], np.int32)
        out = bwstats(hist, counts)
        assert out["mean"][0] == 0 and out["mean"][2] == 0
        assert out["mean"][1] > 0

    def test_zero_rows(self):
        out = bwstats(np.zeros((0, 8), np.float32), np.zeros((0,), np.int32))
        assert out["mean"].shape == (0,)


class TestVsPythonOracle:
    @given(st.integers(0, 10_000))
    @settings(max_examples=20, deadline=None)
    def test_recursive_ewma_and_stats(self, seed):
        rng = np.random.default_rng(seed)
        n, w = int(rng.integers(1, 20)), int(rng.integers(1, 40))
        hist, counts = rand_hist(rng, n, w)
        out = bwstats(hist, counts, alpha=0.25)
        for i in range(n):
            c = counts[i]
            if c == 0:
                continue
            xs = hist[i, :c]
            np.testing.assert_allclose(out["min"][i], xs.min(), rtol=1e-6)
            np.testing.assert_allclose(out["max"][i], xs.max(), rtol=1e-6)
            np.testing.assert_allclose(out["mean"][i], xs.mean(), rtol=1e-5)
            np.testing.assert_allclose(out["std"][i], xs.std(), rtol=1e-3, atol=1.0)
            assert out["last"][i] == xs[-1]
            v = xs[0]
            for x in xs[1:]:
                v = 0.25 * x + 0.75 * v
            np.testing.assert_allclose(out["ewma"][i], v, rtol=5e-4)


class TestMonitorIntegration:
    def test_fleet_publication_matches_streaming_monitor(self):
        mon = TransferMonitor(None, window=32)
        rng = np.random.default_rng(5)
        peers = [f"client://h{i}" for i in range(7)]
        for t in range(200):
            p = peers[int(rng.integers(0, len(peers)))]
            mon.observe_transfer("read", p, int(rng.integers(1 << 20, 64 << 20)), float(rng.uniform(0.5, 4.0)), t)
        mat, counts, got_peers = mon.history_matrix("read")
        stats = publish_fleet_stats(mat, counts, got_peers)
        for i, p in enumerate(got_peers):
            per = mon.per_source[p]["read"]
            np.testing.assert_allclose(
                stats[p]["AvgRDBandwidthToSource"],
                np.mean(per.as_array()),
                rtol=1e-5,
            )
            np.testing.assert_allclose(
                stats[p]["EwmaRDBandwidthToSource"], per.ewma.predict(), rtol=1e-4
            )
            np.testing.assert_allclose(stats[p]["lastRDBandwidth"], per.last, rtol=1e-6)
