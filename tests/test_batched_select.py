"""Batched device-resident matchmaking: ReplicaSnapshot, PlanCache,
DataBroker.select_many tier parity, and the coalescing BatchScheduler."""

import numpy as np
import pytest

from repro.core.broker import NoMatchError, NoReplicaError, SelectionResult
from repro.core.classads import parse_classad
from repro.core.compile import CompileError
from repro.core.plancache import PlanCache, request_cache_key
from repro.core.snapshot import ReplicaSnapshot, numeric_attr_names
from repro.kernels.matchrank.ops import matchrank
from repro.serve.scheduler import BatchScheduler
from repro.storage.endpoint import build_demo_grid


def make_entries(n, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        out.append(
            {
                "endpoint": f"ep{i:04d}",
                "availableSpace": float(rng.uniform(0, 20 * 1024**3)),
                "maxRDBandwidth": float(rng.uniform(0, 200 * 1024)),
                "avgRDBandwidth": float(rng.uniform(0, 100e6)),
                "loadFactor": float(rng.uniform(0, 8)),
            }
        )
    return out


REQ = parse_classad(
    "reqdSpace = 5G; rank = other.avgRDBandwidth;"
    "requirements = other.availableSpace > 5G && other.maxRDBandwidth >= 50K;"
)


class TestReplicaSnapshot:
    def test_padding_and_vocab(self):
        snap = ReplicaSnapshot(make_entries(37))
        assert snap.n == 37
        assert snap.s_pad % snap.block_s == 0 and snap.s_pad >= 37
        assert snap.a_pad % 128 == 0
        assert snap.attr_names == numeric_attr_names(snap.entries)
        attrs, valid, n = snap.device_columns()
        assert attrs.shape == (snap.s_pad, snap.a_pad)
        # padded rows are invalid everywhere
        host_attrs, host_valid, _ = snap.host_columns()
        assert not host_valid[n:].any()

    def test_matchrank_accepts_resident_columns(self):
        entries = make_entries(50, seed=1)
        snap = ReplicaSnapshot(entries)
        plan_vocab = snap.attr_names
        from repro.kernels.matchrank.ops import lower_request

        plan = lower_request(REQ, plan_vocab)
        attrs, valid, n = snap.device_columns()
        mk, sk, bs, bi = matchrank(attrs, valid, plan, n_rows=n, use_kernel=False)
        # vs the host-padded path over the same columns
        ha, hv, _ = snap.host_columns()
        cols = [snap.attr_names.index(a) for a in plan_vocab]
        mk2, sk2, bs2, bi2 = matchrank(
            ha[:n][:, : len(snap.attr_names)],
            hv[:n][:, : len(snap.attr_names)] > 0.5,
            lower_request(REQ, snap.attr_names),
            use_kernel=False,
        )
        np.testing.assert_array_equal(mk, mk2)
        assert bi == bi2

    def test_update_rows_incremental(self):
        snap = ReplicaSnapshot(make_entries(20, seed=2))
        v0 = snap.version
        snap.update_rows({3: {"loadFactor": 99.0}, 7: {"availableSpace": 0.0}})
        assert snap.version == v0 + 1
        j = snap.attr_names.index("loadfactor")
        attrs, valid, _ = snap.device_columns()
        assert float(np.asarray(attrs)[3, j]) == 99.0
        ha, _, _ = snap.host_columns()
        assert ha[3, j] == 99.0
        with pytest.raises(IndexError):
            snap.update_rows({99: {"loadFactor": 1.0}})

    def test_new_epoch(self):
        snap = ReplicaSnapshot(make_entries(10, seed=3))
        nxt = snap.new_epoch(make_entries(12, seed=4))
        assert nxt.epoch == snap.epoch + 1 and nxt.n == 12

    def test_table_matches_columns(self):
        snap = ReplicaSnapshot(make_entries(9, seed=5))
        tbl = snap.table()
        ha, hv, n = snap.host_columns()
        for name in snap.attr_names:
            j = snap.attr_names.index(name)
            np.testing.assert_allclose(tbl.cols[name], ha[:n, j], rtol=1e-6)


class TestPlanCache:
    def test_hit_and_canonical_key(self):
        pc = PlanCache()
        vocab = ("availablespace", "maxrdbandwidth", "avgrdbandwidth", "loadfactor")
        p1 = pc.kernel_plan(REQ, vocab)
        # a structurally identical but distinct ad hits the same entry
        req2 = parse_classad(
            "reqdSpace = 5G; rank = other.avgRDBandwidth;"
            "requirements = other.availableSpace > 5G && other.maxRDBandwidth >= 50K;"
        )
        p2 = pc.kernel_plan(req2, vocab)
        assert p1 is p2
        assert pc.stats["hits"] == 1 and pc.stats["misses"] == 1

    def test_constants_key_the_entry(self):
        vocab = ("availablespace",)
        a = parse_classad("reqdSpace = 1G; requirements = other.availableSpace >= my.reqdSpace;")
        b = parse_classad("reqdSpace = 9G; requirements = other.availableSpace >= my.reqdSpace;")
        assert request_cache_key(a, vocab) != request_cache_key(b, vocab)
        pc = PlanCache()
        pa = pc.kernel_plan(a, vocab)
        pb = pc.kernel_plan(b, vocab)
        assert pa.thresholds[0] != pb.thresholds[0]

    def test_negative_caching(self):
        pc = PlanCache()
        bad = parse_classad('requirements = other.hostname == "x";')
        for _ in range(3):
            with pytest.raises(CompileError):
                pc.kernel_plan(bad, ("hostname",))
        assert pc.stats["negative_hits"] == 2 and pc.stats["misses"] == 1

    def test_lru_eviction(self):
        pc = PlanCache(maxsize=2)
        vocab = ("loadfactor",)
        for i in range(4):
            pc.kernel_plan(
                parse_classad(f"requirements = other.loadFactor < {i + 1};"), vocab
            )
        assert len(pc) == 2 and pc.stats["evictions"] == 2


@pytest.fixture
def grid():
    g = build_demo_grid(8, 4, seed=7)
    g.add_client("client://host0", zone="zone1")
    g.replicate("shard-000", b"x" * (1 << 20), ["gsiftp://ep000", "gsiftp://ep003", "gsiftp://ep005"])
    g.replicate("shard-001", b"y" * (1 << 20), ["gsiftp://ep001", "gsiftp://ep004"])
    g.replicate("shard-002", b"z" * (1 << 19), ["gsiftp://ep002", "gsiftp://ep006", "gsiftp://ep007"])
    return g


def _urls(ranked):
    return [r.pfn.url for r in ranked]


class TestSelectMany:
    def test_default_request_parity(self, grid):
        b = grid.broker_for("client://host0")
        want = [b.select(f"shard-00{i}") for i in range(3)]
        got = b.select_many([(f"shard-00{i}", None) for i in range(3)])
        for g_, w in zip(got, want):
            assert _urls(g_) == _urls(w)
            for x, y in zip(g_, w):
                assert abs(x.rank - y.rank) <= 1e-6 * max(1.0, abs(y.rank))

    @pytest.mark.parametrize("use_kernel", [False, True])
    def test_kernel_tier_parity(self, grid, use_kernel):
        b = grid.broker_for("client://host0")
        req = parse_classad(
            "reqdSpace = 0; rank = other.diskTransferRate;"
            "requirements = other.availableSpace > 1M;"
        )
        want = [b.select(f"shard-00{i}", req) for i in range(3)]
        got = b.select_many(
            [(f"shard-00{i}", req) for i in range(3)], use_kernel=use_kernel
        )
        assert b.stats["batched_kernel_requests"] == 3
        for g_, w in zip(got, want):
            assert _urls(g_) == _urls(w)

    def test_mixed_tiers_one_batch(self, grid):
        b = grid.broker_for("client://host0")
        conj = parse_classad(
            "reqdSpace = 0; rank = other.diskTransferRate;"
            "requirements = other.availableSpace > 1M;"
        )
        # references a per-replica attribute ⇒ interpreter tier
        per_replica = parse_classad(
            "reqdSpace = 0; rank = other.diskTransferRate;"
            "requirements = other.replicaSize > 0;"
        )
        queries = [
            ("shard-000", conj),
            ("shard-001", None),  # columnar tier (isUndefined/ifThenElse)
            ("shard-002", per_replica),
        ]
        want = [b.select(lfn, req) for lfn, req in queries]
        got = b.select_many(queries)
        assert b.stats["batched_kernel_requests"] == 1
        assert b.stats["batched_columnar_requests"] == 1
        assert b.stats["batched_interp_requests"] == 1
        for g_, w in zip(got, want):
            assert _urls(g_) == _urls(w)

    def test_snapshot_reuse_and_ttl(self, grid):
        b = grid.broker_for("client://host0")
        b.select_many([("shard-000", None)])
        b.select_many([("shard-000", None), ("shard-001", None)])
        assert b.stats["snapshot_builds"] >= 1
        assert b.stats["snapshot_reuses"] >= 0
        builds = b.stats["snapshot_builds"]
        grid.clock.advance(b.snapshot_ttl + 1)
        b.select_many([("shard-000", None)])
        assert b.stats["snapshot_builds"] == builds + 1

    def test_strict_and_nonstrict_errors(self, grid):
        b = grid.broker_for("client://host0")
        out = b.select_many([("no-such", None), ("shard-000", None)], strict=False)
        assert isinstance(out[0], NoReplicaError)
        assert isinstance(out[1], SelectionResult) and out[1]
        assert out[1].plan is not None and out[1].request_id
        with pytest.raises(NoReplicaError):
            b.select_many([("no-such", None)])
        impossible = parse_classad("requirements = other.loadFactor > 1e30;")
        out = b.select_many([("shard-000", impossible)], strict=False)
        assert isinstance(out[0], NoMatchError)

    def test_top_k(self, grid):
        b = grid.broker_for("client://host0")
        (got,) = b.select_many([("shard-000", None)], top_k=2)
        assert len(got) == 2

    def test_plan_cache_warm_across_batches(self, grid):
        b = grid.broker_for("client://host0")
        req = parse_classad(
            "reqdSpace = 0; rank = other.diskTransferRate;"
            "requirements = other.availableSpace > 1M;"
        )
        b.select_many([("shard-000", req)])
        misses = b.plan_cache.stats["misses"]
        b.select_many([("shard-001", req), ("shard-002", req)])
        assert b.plan_cache.stats["misses"] == misses  # all hits
        assert b.plan_cache.stats["hits"] > 0


class TestBatchScheduler:
    def test_coalesces_and_fills(self, grid):
        b = grid.broker_for("client://host0")
        sch = BatchScheduler(b, max_batch=8)
        tickets = sch.submit_many([(f"shard-00{i % 3}", None) for i in range(6)])
        assert all(not t.done for t in tickets)
        sch.flush()
        assert all(t.done for t in tickets)
        assert sch.stats["batches"] == 1 and sch.coalescing_ratio() == 6.0
        want = b.select("shard-000")
        assert _urls(tickets[0].result()) == _urls(want)

    def test_size_flush(self, grid):
        b = grid.broker_for("client://host0")
        sch = BatchScheduler(b, max_batch=2)
        t1 = sch.submit("shard-000")
        assert not t1.done
        sch.submit("shard-001")  # hits max_batch → flush
        assert t1.done and sch.stats["size_flushes"] == 1

    def test_latency_flush(self, grid):
        b = grid.broker_for("client://host0")
        sch = BatchScheduler(b, max_batch=100, max_delay=2.0)
        t = sch.submit("shard-000")
        assert not sch.poll() and not t.done
        grid.clock.advance(2.5)
        assert sch.poll() and t.done
        assert sch.stats["latency_flushes"] == 1

    def test_result_forces_flush_and_errors(self, grid):
        b = grid.broker_for("client://host0")
        sch = BatchScheduler(b, max_batch=100)
        t_ok = sch.submit("shard-000")
        t_bad = sch.submit("no-such")
        assert _urls(t_ok.result()) == _urls(b.select("shard-000"))
        with pytest.raises(NoReplicaError):
            t_bad.result()


class TestRestoreWiring:
    def test_checkpoint_restore_batches_selections(self, grid):
        import jax
        import jax.numpy as jnp

        from repro.checkpoint.manager import CheckpointManager

        b = grid.broker_for("client://host0")
        mgr = CheckpointManager("t", grid, b, replication=2, chunk_bytes=1 << 16)
        state = {"w": np.arange(65536, dtype=np.float32), "b": np.ones(16, np.float32)}
        mgr.save(0, state)
        sch = BatchScheduler(b, max_batch=64)
        restored = mgr.restore(0, jax.eval_shape(lambda: {"w": jnp.zeros(65536, jnp.float32), "b": jnp.zeros(16, jnp.float32)}), scheduler=sch)
        np.testing.assert_array_equal(np.asarray(restored["w"]), state["w"])
        np.testing.assert_array_equal(np.asarray(restored["b"]), state["b"])
        assert sch.stats["submitted"] >= 2
        assert sch.stats["batches"] >= 1
        assert sch.coalescing_ratio() > 1.0
