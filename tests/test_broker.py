"""The decentralized broker: Search/Match/Access phases, failover,
straggler mitigation, vectorized-match parity, write placement."""

import numpy as np
import pytest

from repro.core.broker import (
    BrokerError,
    NoReplicaError,
    default_read_request,
    default_write_request,
)
from repro.core.classads import parse_classad
from repro.storage.endpoint import build_demo_grid
from repro.storage.faults import FaultInjector


@pytest.fixture
def grid():
    g = build_demo_grid(8, 4, seed=7)
    g.add_client("client://host0", zone="zone1")
    g.add_client("client://host1", zone="zone2")
    data = b"x" * (4 << 20)
    g.replicate("shard-000", data, ["gsiftp://ep000", "gsiftp://ep003", "gsiftp://ep005"])
    g.replicate("shard-001", b"y" * (1 << 20), ["gsiftp://ep001", "gsiftp://ep004"])
    return g


class TestSearchPhase:
    def test_views_carry_gris_state(self, grid):
        b = grid.broker_for("client://host0")
        views = b.search("shard-000")
        assert len(views) == 3
        for v in views:
            assert "availableSpace" in v.entry
            assert "diskTransferRate" in v.entry

    def test_missing_lfn(self, grid):
        with pytest.raises(Exception):
            grid.broker_for("client://host0").search("no-such-file")

    def test_dead_endpoint_excluded(self, grid):
        grid.drop_endpoint("gsiftp://ep000")
        views = grid.broker_for("client://host0").search("shard-000")
        assert {v.pfn.endpoint for v in views} == {"gsiftp://ep003", "gsiftp://ep005"}


class TestMatchPhase:
    def test_policy_gating(self, grid):
        # ep000/ep003 publish `other.reqdSpace <= 10G` (policy_every=3)
        b = grid.broker_for("client://host0")
        req = default_read_request("client://host0")
        req["reqdSpace"] = 20 * 1024**3  # violates the site policy
        ranked = b.match(req, b.search("shard-000"))
        assert {r.pfn.endpoint for r in ranked} == {"gsiftp://ep005"}

    def test_cold_rank_uses_static_attrs(self, grid):
        b = grid.broker_for("client://host0")
        ranked = b.select("shard-000")
        # disk rates: ep003=800MB/s > ep000=200MB/s = ep005(1000?) per build
        assert ranked[0].rank >= ranked[-1].rank

    def test_history_changes_ranking(self, grid):
        b = grid.broker_for("client://host0")
        xfer = grid.transfer_service()
        cold = [r.pfn.endpoint for r in b.select("shard-000")]
        for _ in range(8):
            b.fetch("shard-000", xfer)
        warm = b.select("shard-000")
        # warm ranks come from observed bandwidth (EWMA per-source), which
        # is bounded by simulated path bandwidth << static disk rate
        assert all(r.rank < 1e9 for r in warm)

    def test_vectorized_match_parity(self, grid):
        b_i = grid.broker_for("client://host0")
        b_v = grid.broker_for("client://host0", use_vectorized=True)
        xfer = grid.transfer_service()
        for _ in range(4):
            b_i.fetch("shard-000", xfer)
        r_i = [r.pfn.endpoint for r in b_i.select("shard-000")]
        r_v = [r.pfn.endpoint for r in b_v.select("shard-000")]
        assert r_i == r_v
        assert b_v.stats["vectorized_matches"] > 0


class TestAccessPhase:
    def test_fetch_returns_payload(self, grid):
        b = grid.broker_for("client://host0")
        out = b.fetch("shard-000", grid.transfer_service())
        assert out.nbytes == 4 << 20
        assert out.payload == b"x" * (4 << 20)

    def test_failover_on_death(self, grid):
        b = grid.broker_for("client://host0")
        xfer = grid.transfer_service()
        best = b.select("shard-000")[0].pfn.endpoint
        grid.drop_endpoint(best)
        out = b.fetch("shard-000", xfer)
        assert out.replica.endpoint != best

    def test_flaky_endpoint_failover(self, grid):
        b = grid.broker_for("client://host0")
        xfer = grid.transfer_service()
        inj = FaultInjector(grid)
        best = b.select("shard-000")[0].pfn.endpoint
        inj.flaky(best, 1.0)  # always drops
        out = b.fetch("shard-000", xfer)
        assert out.replica.endpoint != best
        assert b.stats["failovers"] >= 1

    def test_all_dead_raises(self, grid):
        b = grid.broker_for("client://host0")
        for ep in ("gsiftp://ep000", "gsiftp://ep003", "gsiftp://ep005"):
            grid.drop_endpoint(ep)
        with pytest.raises(Exception):
            b.fetch("shard-000", grid.transfer_service())

    def test_straggler_mid_transfer_switch(self, grid):
        b = grid.broker_for("client://host0")
        xfer = grid.transfer_service()
        for _ in range(6):  # build history so rank = predicted bandwidth
            b.fetch("shard-000", xfer)
        best = b.select("shard-000")[0].pfn.endpoint
        FaultInjector(grid).degrade(best, 0.02)  # alive but 50× slower
        out = b.fetch("shard-000", xfer)
        assert out.replica.endpoint != best
        assert b.stats["straggler_switches"] >= 1
        assert out.payload == b"x" * (4 << 20)


class TestDecentralization:
    def test_brokers_share_no_state_but_agree(self, grid):
        """§5.1.1: every client selects independently; same published
        state ⇒ same decision for same-zone clients."""
        grid.add_client("client://host0b", zone="zone1")
        b1 = grid.broker_for("client://host0")
        b2 = grid.broker_for("client://host0b")
        r1 = [r.pfn.endpoint for r in b1.select("shard-000")]
        r2 = [r.pfn.endpoint for r in b2.select("shard-000")]
        assert r1 == r2
        assert b1.local_monitor is not b2.local_monitor

    def test_different_zones_can_differ(self, grid):
        """Per-source history makes selection client-relative (§3.2)."""
        b0 = grid.broker_for("client://host0")
        b1 = grid.broker_for("client://host1")
        xfer = grid.transfer_service()
        for _ in range(6):
            b0.fetch("shard-000", xfer)
            b1.fetch("shard-000", xfer)
        # both selections are valid orderings of the same replica set
        s0 = {r.pfn.endpoint for r in b0.select("shard-000")}
        s1 = {r.pfn.endpoint for r in b1.select("shard-000")}
        assert s0 == s1


class TestPlacement:
    def test_write_placement_respects_space(self, grid):
        b = grid.broker_for("client://host0")
        placements = b.select_placements(1 << 20, grid.alive_endpoints(), k=3)
        assert len(placements) == 3
        # a request larger than every volume matches nothing
        with pytest.raises(Exception):
            b.select_placements(1 << 60, grid.alive_endpoints(), k=1)

    def test_placement_obeys_policy(self, grid):
        b = grid.broker_for("client://host0")
        big = 11 * 1024**3  # over the 10G limit of policy endpoints
        placements = b.select_placements(big, grid.alive_endpoints(), k=8)
        eps = {p.pfn.endpoint for p in placements}
        assert "gsiftp://ep000" not in eps  # policy endpoint refuses
        assert "gsiftp://ep003" not in eps
