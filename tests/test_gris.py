"""Storage GRIS + GIIS: dynamic attributes, TTL, schema, drill-down."""

import pytest

from repro.core.giis import GIIS
from repro.core.gris import Clock, StorageGRIS
from repro.core.schema import (
    SERVER_VOLUME,
    SOURCE_TRANSFER_BANDWIDTH,
    TRANSFER_BANDWIDTH,
    SchemaError,
    validate_entry,
)


def make_gris(clock=None):
    clock = clock or Clock()
    g = StorageGRIS(
        "gss=vol0, ou=mcs, o=anl, o=grid",
        {
            "hostname": "hugo.mcs.anl.gov",
            "mountPoint": "/dev/sandbox",
            "diskTransferRate": 800e6,
            "drdTime": 0.004,
            "dwrTime": 0.005,
            "requirements": "other.reqdSpace < 10G",
        },
        clock=clock,
    )
    state = {"avail": 50.0 * 1024**3, "calls": 0}

    def avail():
        state["calls"] += 1
        return state["avail"]

    g.register_dynamic("totalSpace", lambda: 100.0 * 1024**3, ttl=5)
    g.register_dynamic("availableSpace", avail, ttl=5)
    g.register_dynamic("loadFactor", lambda: 0.0, ttl=5)
    return g, state, clock


class TestSchema:
    def test_figures_2_4_5_attribute_sets(self):
        assert SERVER_VOLUME.must_names == [
            "totalSpace", "availableSpace", "mountPoint",
            "diskTransferRate", "drdTime", "dwrTime",
        ]
        assert "MaxRDBandwidth" in TRANSFER_BANDWIDTH.must_names
        assert "lastRDurl" in SOURCE_TRANSFER_BANDWIDTH.must_names

    def test_must_enforced(self):
        with pytest.raises(SchemaError):
            validate_entry({"totalSpace": 1}, SERVER_VOLUME)

    def test_syntax_enforced(self):
        entry = {
            "totalSpace": "not-a-number", "availableSpace": 1.0,
            "mountPoint": "/x", "diskTransferRate": 1.0,
            "drdTime": 1.0, "dwrTime": 1.0,
        }
        with pytest.raises(SchemaError):
            validate_entry(entry, SERVER_VOLUME)


class TestGRIS:
    def test_dynamic_ttl_caching(self):
        """Shell-backend semantics: providers run on query, cached per TTL."""
        g, state, clock = make_gris()
        g.volume_entry()
        g.volume_entry()
        assert state["calls"] == 1  # cached within TTL
        clock.advance(6)
        g.volume_entry()
        assert state["calls"] == 2  # TTL expired → provider re-ran

    def test_invalidate(self):
        g, state, clock = make_gris()
        g.volume_entry()
        g.invalidate("availableSpace")
        g.volume_entry()
        assert state["calls"] == 2

    def test_search_filter_and_projection(self):
        g, state, _ = make_gris()
        out = g.search("(objectClass=Grid::Storage::ServerVolume)",
                       attrs=["availableSpace"])
        assert len(out) == 1
        assert set(k.lower() for k in out[0]) <= {"dn", "objectclass", "availablespace"}

    def test_bandwidth_children(self):
        g, state, _ = make_gris()
        g.publish_bandwidth_summary({
            "MaxRDBandwidth": 5e6, "MinRDBandwidth": 1e6, "AvgRDBandwidth": 3e6,
            "MaxWRBandwidth": 4e6, "MinWRBandwidth": 1e6, "AvgWRBandwidth": 2e6,
        })
        g.publish_source_bandwidth("client://a", {
            "lastRDBandwidth": 2.5e6, "lastRDurl": "client://a",
            "lastWRBandwidth": 0.0, "lastWRurl": "",
        })
        entries = g.entries()
        ocs = [e["objectClass"] for e in entries]
        assert "Grid::Storage::TransferBandwidth" in ocs
        assert "Grid::Storage::SourceTransferBandwidth" in ocs
        # per-source narrowing flattens this client's end-to-end stats
        view = g.flattened_view(source="client://a")
        assert view["lastRDBandwidth"] == 2.5e6
        assert view["AvgRDBandwidth"] == 3e6

    def test_schema_violation_refused(self):
        g, state, _ = make_gris()
        with pytest.raises(SchemaError):
            g.publish_bandwidth_summary({"MaxRDBandwidth": 1.0})  # missing MUSTs

    def test_ldif_output(self):
        g, _, _ = make_gris()
        text = g.to_ldif()
        assert "dn: gss=vol0" in text
        assert "availableSpace:" in text


class TestGIIS:
    def test_register_search_drilldown(self):
        clock = Clock()
        giis = GIIS("o=grid", clock=clock, cache_ttl=30)
        grises = []
        for i in range(4):
            g, _, _ = make_gris(clock)
            g.set_static("hostname", f"ep{i}")
            giis.register(f"ep{i}", g)
            grises.append(g)
        # broad query to the index
        out = giis.search("(objectClass=Grid::Storage::ServerVolume)")
        assert len(out) == 4
        # discovery → drill-down pairs
        found = giis.discover("(hostname=ep2)")
        assert len(found) == 1 and found[0][0] == "ep2"

    def test_index_staleness_vs_gris_freshness(self):
        """GIIS serves cached snapshots; GRIS is authoritative."""
        clock = Clock()
        giis = GIIS("o=grid", clock=clock, cache_ttl=30)
        g, state, _ = make_gris(clock)
        giis.register("ep0", g)
        giis.search(None)  # snapshot taken
        state["avail"] = 1.0  # world changes
        g.invalidate("availableSpace")
        stale = giis.search(None)[0]["availableSpace"]
        assert stale == 50.0 * 1024**3  # index still stale
        fresh = g.volume_entry()["availableSpace"]
        assert fresh == 1.0  # drill-down sees truth
        clock.advance(31)
        refreshed = giis.search(None)[0]["availableSpace"]
        assert refreshed == 1.0  # snapshot refreshed after TTL

    def test_hierarchical(self):
        clock = Clock()
        root = GIIS("o=grid", clock=clock)
        child = GIIS("o=pod0", clock=clock)
        g, _, _ = make_gris(clock)
        child.register("ep0", g)
        root.register("pod0", child)
        assert len(root.search(None)) == 1
        assert root.discover(None)[0][0] == "ep0"
