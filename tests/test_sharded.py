"""Sharded GIIS-scale matchmaking (DESIGN.md §9): ShardedSnapshot layout
and delta refresh, hierarchical top-k parity vs the flat path (tie-break
included), per-shard result-cache invalidation, the GIIS bridge, and the
broker's sharded tier end to end."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # degrade: property tests skip, sweeps still run
    HAVE_HYPOTHESIS = False

    def given(*a, **k):
        return lambda f: f

    def settings(*a, **k):
        return lambda f: f

    class _St:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _St()

needs_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS, reason="hypothesis not installed (requirements-dev.txt)"
)

from repro.core.classads import parse_classad
from repro.core.giis import GIIS
from repro.core.gris import Clock, StorageGRIS
from repro.core.plancache import PlanCache, request_cache_key
from repro.core.snapshot_sharded import ShardedSnapshot, shard_by_hash
from repro.kernels.matchrank.ops import lower_request, matchrank_batched_topk
from repro.kernels.matchrank.ref import merge_topk_ref
from repro.kernels.matchrank.sharded import (
    MERGE_K_PAD,
    merge_topk_pallas,
    sharded_matchrank_topk,
    sharded_sparse_topk,
)
from repro.kernels.matchrank.sparse import canonicalize_plans
from repro.storage.endpoint import build_demo_grid

NAMES = ["availablespace", "maxrdbandwidth", "avgrdbandwidth", "loadfactor"]

REQ_SRCS = [
    "reqdSpace = 5G; rank = other.avgRDBandwidth;"
    "requirements = other.availableSpace > 5G && other.maxRDBandwidth >= 50K;",
    "reqdSpace = 2G; rank = other.maxRDBandwidth;"
    "requirements = other.availableSpace > 2G;",
    "rank = other.avgRDBandwidth - other.loadFactor;"
    "requirements = other.loadFactor < 6;",
    # impossible: exercises the all-(-inf) merge slots
    "rank = other.avgRDBandwidth; requirements = other.loadFactor > 1e30;",
]


def make_shard_entries(s, g, seed=0, ties=False, missing_frac=0.1):
    """Uneven shards over a shared vocabulary; ``ties=True`` quantizes the
    rank attribute so equal scores are common (tie-break coverage)."""
    rng = np.random.default_rng(seed)
    cols = np.stack(
        [
            rng.uniform(0, 20 * 1024**3, s),
            rng.uniform(0, 200 * 1024, s),
            rng.uniform(0, 100e6, s),
            rng.uniform(0, 8, s),
        ],
        axis=1,
    )
    if ties:
        cols[:, 2] = np.round(cols[:, 2] / 25e6) * 25e6  # ~5 distinct ranks
    drop = rng.random((s, len(NAMES))) < missing_frac
    # uneven split: every shard non-empty, sizes differ
    cuts = np.sort(rng.choice(np.arange(1, s), size=g - 1, replace=False)) if g > 1 else []
    bounds = [0, *map(int, cuts), s]
    out = {}
    for gi in range(g):
        rows = []
        for i in range(bounds[gi], bounds[gi + 1]):
            e = {"endpoint": f"gsiftp://site{gi}/ep{i:05d}"}
            for j, n in enumerate(NAMES):
                if not drop[i, j]:
                    e[n] = float(cols[i, j])
            rows.append(e)
        out[f"shard-{gi:03d}"] = rows
    return out


def make_plans(snap, srcs=REQ_SRCS):
    return [lower_request(parse_classad(src), snap.attr_names) for src in srcs]


def flat_topk(snap, plans, k, admit=None):
    """Flat dense reference (lax.top_k tie-break) over the global rows."""
    attrs, valid = snap.logical_columns()
    return matchrank_batched_topk(
        attrs, valid, plans, k=k, admit=admit, use_sparse=False, use_kernel=False
    )


def assert_topk_equal(got, want):
    ti_g, ts_g = got
    ti_w, ts_w = want
    np.testing.assert_allclose(ts_g, ts_w, rtol=1e-6)
    live = ~np.isneginf(np.asarray(ts_w))
    # exact — tie-break contract, not just score parity
    np.testing.assert_array_equal(np.asarray(ti_g)[live], np.asarray(ti_w)[live])
    assert (np.asarray(ti_g)[~live] == -1).all()


class TestShardedSnapshot:
    def test_layout_and_global_rows(self):
        se = make_shard_entries(123, 5, seed=1)
        snap = ShardedSnapshot(se, device=False)
        assert snap.g == 5 and snap.n == 123
        assert snap.shard_names == sorted(se)
        assert snap.offsets[0] == 0
        np.testing.assert_array_equal(np.diff(snap.offsets), snap.counts[:-1])
        # global rows are the shard-major concat of the per-shard views
        attrs, valid = snap.logical_columns()
        pos = 0
        for gi in range(snap.g):
            a_g, v_g = snap.shard_logical_columns(gi)
            c = int(snap.counts[gi])
            np.testing.assert_array_equal(attrs[pos : pos + c], a_g)
            np.testing.assert_array_equal(valid[pos : pos + c], v_g)
            for r in range(pos, pos + c):
                assert snap.shard_of_row(r) == gi
            pos += c
        with pytest.raises(IndexError):
            snap.shard_of_row(snap.n)
        # shared vocabulary is the lower-cased union across shards
        assert set(NAMES) <= set(snap.attr_names)

    def test_update_rows_delta_accounting(self):
        snap = ShardedSnapshot(make_shard_entries(200, 4, seed=2))
        assert snap.pushed_rows == snap.n
        eps0 = snap.shard_epochs.copy()
        # rows 0..4 live in shard 0 only
        changed = snap.update_rows({r: {"loadFactor": 1.5} for r in range(5)})
        assert changed == [0]
        assert snap.pushed_rows == snap.n + int(snap.counts[0])
        np.testing.assert_array_equal(snap.shard_epochs[1:], eps0[1:])
        assert snap.shard_epochs[0] == eps0[0] + 1
        j = snap.attr_names.index("loadfactor")
        attrs, _ = snap.shard_logical_columns(0)
        np.testing.assert_allclose(attrs[:5, j], 1.5)

    def test_update_rows_case_insensitive_merge(self):
        snap = ShardedSnapshot(make_shard_entries(20, 2, seed=3, missing_frac=0.0))
        name = snap.shard_names[0]
        entry = snap.entries_by_shard[name][0]
        keys_before = set(entry)
        snap.update_rows({0: {"LoadFactor": 7.25}})  # resident spelling differs
        assert set(entry) == keys_before  # merged, not duplicated
        assert entry["loadfactor"] == 7.25
        j = snap.attr_names.index("loadfactor")
        attrs, valid = snap.shard_logical_columns(0)
        assert attrs[0, j] == np.float32(7.25) and valid[0, j]

    def test_update_rows_new_attr_falls_back(self):
        """An update outside the vocabulary can't take the scalar fast
        path; the full row recompute must still be exact for the
        in-vocabulary cells."""
        snap = ShardedSnapshot(make_shard_entries(20, 2, seed=4))
        snap.update_rows({0: {"loadFactor": 2.5, "newAttr": 9.0}})
        j = snap.attr_names.index("loadfactor")
        attrs, valid = snap.shard_logical_columns(0)
        assert attrs[0, j] == np.float32(2.5) and valid[0, j]
        assert "newattr" not in snap.attr_names  # vocab is fixed per snapshot

    def test_update_rows_bounds(self):
        snap = ShardedSnapshot(make_shard_entries(10, 2, seed=5), device=False)
        with pytest.raises(IndexError):
            snap.update_rows({10: {"loadFactor": 1.0}})
        with pytest.raises(IndexError):
            snap.update_rows({-1: {"loadFactor": 1.0}})

    def test_refresh_delta_and_structural_errors(self):
        se = make_shard_entries(60, 3, seed=6)
        snap = ShardedSnapshot(se)
        pushed = snap.pushed_rows
        # identical content ⇒ no shard changes, epoch still rolls
        assert snap.refresh({k: [dict(e) for e in v] for k, v in se.items()}) == []
        assert snap.epoch == 1 and snap.pushed_rows == pushed
        # one changed shard ⇒ only it re-uploads
        name = snap.shard_names[1]
        se2 = {k: [dict(e) for e in v] for k, v in se.items()}
        se2[name][0]["loadfactor"] = 0.125
        assert snap.refresh(se2) == [name]
        assert snap.pushed_rows == pushed + int(snap.counts[1])
        # structural changes refuse the delta path
        with pytest.raises(ValueError):
            snap.refresh({k: v for k, v in se2.items() if k != name})
        grown = {k: [dict(e) for e in v] for k, v in se2.items()}
        grown[name] = grown[name] + [dict(grown[name][0])]
        with pytest.raises(ValueError):
            snap.refresh(grown)
        drift = {k: [dict(e) for e in v] for k, v in se2.items()}
        drift[name][0]["brandNew"] = 3.0
        with pytest.raises(ValueError):
            snap.refresh(drift)

    def test_rank_order_cache_per_shard(self):
        snap = ShardedSnapshot(make_shard_entries(80, 4, seed=7), device=False)
        w = np.zeros(len(snap.attr_names), np.float32)
        w[snap.attr_names.index("avgrdbandwidth")] = 1.0
        before = [snap.shard_rank_order(g, w) for g in range(4)]
        snap.update_rows({0: {"avgRDBandwidth": 1.0}})  # dirties shard 0 only
        after = [snap.shard_rank_order(g, w) for g in range(4)]
        assert after[0][0] is not before[0][0]
        for g in range(1, 4):
            assert after[g][0] is before[g][0]  # untouched shards stay cached

    def test_shard_by_hash(self):
        buckets = {shard_by_hash(f"gsiftp://ep{i}", 4) for i in range(64)}
        assert buckets <= set(range(4)) and len(buckets) > 1
        assert shard_by_hash("gsiftp://ep0", 4) == shard_by_hash("gsiftp://ep0", 4)


class TestHierarchicalTopKParity:
    @pytest.mark.parametrize("g", [1, 3, 8])
    @pytest.mark.parametrize("s", [100, 1000])
    def test_kernel_path_matches_flat(self, g, s):
        snap = ShardedSnapshot(make_shard_entries(s, g, seed=g * 31 + s))
        plans = make_plans(snap)
        attrs, valid, counts = snap.shard_device_columns()
        got = sharded_matchrank_topk(
            attrs, valid, plans, counts=counts, offsets=snap.offsets, k=5
        )
        assert_topk_equal(got, flat_topk(snap, plans, 5))

    @pytest.mark.parametrize("g", [1, 3, 8])
    def test_sparse_path_matches_flat_s10k(self, g):
        snap = ShardedSnapshot(make_shard_entries(10_000, g, seed=g), device=False)
        plans = make_plans(snap)
        iv = canonicalize_plans(plans, len(snap.attr_names))
        assert iv is not None
        shards = [snap.shard_logical_columns(gi) for gi in range(snap.g)]
        got = sharded_sparse_topk(
            shards, iv, k=3, offsets=snap.offsets, rank_order=snap.shard_rank_order
        )
        assert_topk_equal(got, flat_topk(snap, plans, 3))

    def test_tie_break_exact_on_equal_ranks(self):
        """Quantized ranks ⇒ many exact ties; both sharded paths must
        reproduce lax.top_k's lowest-global-row tie-break."""
        snap = ShardedSnapshot(make_shard_entries(600, 4, seed=11, ties=True))
        plans = make_plans(snap, REQ_SRCS[:1] * 3)
        want = flat_topk(snap, plans, 8)
        attrs, valid, counts = snap.shard_device_columns()
        assert_topk_equal(
            sharded_matchrank_topk(
                attrs, valid, plans, counts=counts, offsets=snap.offsets, k=8
            ),
            want,
        )
        iv = canonicalize_plans(plans, len(snap.attr_names))
        shards = [snap.shard_logical_columns(gi) for gi in range(snap.g)]
        assert_topk_equal(
            sharded_sparse_topk(
                shards, iv, k=8, offsets=snap.offsets,
                rank_order=snap.shard_rank_order,
            ),
            want,
        )

    def test_admit_mask_parity(self):
        snap = ShardedSnapshot(make_shard_entries(300, 3, seed=12))
        plans = make_plans(snap)
        rng = np.random.default_rng(0)
        admit = rng.random((len(plans), snap.n)) > 0.5
        attrs, valid, counts = snap.shard_device_columns()
        got = sharded_matchrank_topk(
            attrs, valid, plans, counts=counts, offsets=snap.offsets, k=4,
            admit=admit,
        )
        assert_topk_equal(got, flat_topk(snap, plans, 4, admit=admit))

    def test_merge_ref_parity_after_delta(self):
        """merge_kernel=False swaps stage 2 for the NumPy oracle; a delta
        refresh in between must not leak the previous epoch's rows."""
        snap = ShardedSnapshot(make_shard_entries(200, 4, seed=13))
        plans = make_plans(snap)
        snap.update_rows({r: {"avgRDBandwidth": 99e6} for r in range(3)})
        attrs, valid, counts = snap.shard_device_columns()
        got = sharded_matchrank_topk(
            attrs, valid, plans, counts=counts, offsets=snap.offsets, k=5,
            merge_kernel=False,
        )
        assert_topk_equal(got, flat_topk(snap, plans, 5))


class TestMergeKernel:
    def _random_candidates(self, b, g, k, seed=0, dead_rows=()):
        """Per-shard rank-desc candidate lists (ties → lowest index),
        flattened shard-major — the merge stage's input contract."""
        rng = np.random.default_rng(seed)
        scores = np.empty((b, g * k), np.float32)
        idx = np.empty((b, g * k), np.int32)
        for bi in range(b):
            for gi in range(g):
                s = np.sort(
                    rng.choice([0.0, 1.0, 2.5, 7.0, 9.0], size=k).astype(np.float32)
                )[::-1]
                n_dead = int(rng.integers(0, k + 1))
                if n_dead:
                    s[k - n_dead :] = -np.inf
                scores[bi, gi * k : (gi + 1) * k] = s
                idx[bi, gi * k : (gi + 1) * k] = gi * 1000 + np.arange(k)
        for bi in dead_rows:
            scores[bi, :] = -np.inf
        return scores, idx

    @pytest.mark.parametrize("k", [1, 3, 7])
    def test_kernel_matches_ref(self, k):
        scores, idx = self._random_candidates(6, 5, k, seed=k, dead_rows=(2,))
        ts_k, ti_k = merge_topk_pallas(scores, idx, k)
        ts_r, ti_r = merge_topk_ref(scores, idx, k)
        np.testing.assert_array_equal(ts_k, ts_r)
        live = ~np.isneginf(ts_r)
        np.testing.assert_array_equal(np.asarray(ti_k)[live], ti_r[live])

    def test_candidate_axis_padding(self):
        # C=10 is nowhere near the 128 lane width: padding must be inert
        scores, idx = self._random_candidates(3, 2, 5, seed=42)
        ts_k, ti_k = merge_topk_pallas(scores, idx, 5)
        ts_r, ti_r = merge_topk_ref(scores, idx, 5)
        np.testing.assert_array_equal(ts_k, ts_r)
        assert np.asarray(ts_k).shape == (3, 5)

    def test_k_bound(self):
        scores, idx = self._random_candidates(1, 1, 2)
        with pytest.raises(AssertionError):
            merge_topk_pallas(scores, idx, MERGE_K_PAD + 1)

    def test_merge_matches_flat_stable_topk_seeded(self):
        """Tie-break contract vs a stable flat sort, without hypothesis:
        shard-major position order == global row order."""
        for seed in range(20):
            self._check_against_stable_sort(seed)

    def _check_against_stable_sort(self, seed):
        rng = np.random.default_rng(seed)
        n = int(rng.integers(1, 40))
        g = int(rng.integers(1, 5))
        k = int(rng.integers(1, 6))
        flat = rng.choice([-np.inf, 0.0, 1.0, 2.0, 3.0], size=n).astype(np.float32)
        bounds = np.linspace(0, n, g + 1).astype(int)
        parts_s, parts_i = [], []
        for gi in range(g):
            seg = flat[bounds[gi] : bounds[gi + 1]]
            order = np.argsort(-seg, kind="stable")[:k]
            s = np.full(k, -np.inf, np.float32)
            i = np.zeros(k, np.int32)
            s[: len(order)] = seg[order]
            i[: len(order)] = order + bounds[gi]
            parts_s.append(s)
            parts_i.append(i)
        cand_s = np.concatenate(parts_s)[None, :]
        cand_i = np.concatenate(parts_i)[None, :]
        ts, ti = merge_topk_ref(cand_s, cand_i, k)
        want = np.argsort(-flat, kind="stable")[:k]
        live = ~np.isneginf(ts[0])
        np.testing.assert_array_equal(ti[0][live], want[live[: len(want)]])
        np.testing.assert_array_equal(ts[0][live], flat[want][live[: len(want)]])

    @needs_hypothesis
    @settings(max_examples=60, deadline=None)
    @given(st.data())
    def test_merge_tie_break_property(self, data):
        seed = data.draw(st.integers(min_value=0, max_value=2**31 - 1))
        self._check_against_stable_sort(seed)


class TestPlanCacheShardedInvalidation:
    def test_topk_epoch_keys(self):
        pc = PlanCache()
        pc.topk_put(("q1",), {0: 0, 2: 5}, "v1")
        pc.topk_put(("q2",), {1: 3}, "v2")
        hit, val = pc.topk_get(("q1",), [0, 3, 5])
        assert hit and val == "v1"
        # shard 1 moves: q2 (touching shard 1) goes stale, q1 survives
        hit, _ = pc.topk_get(("q2",), [0, 4, 5])
        assert not hit and pc.stats["topk_stale"] == 1
        hit, val = pc.topk_get(("q1",), [0, 4, 5])
        assert hit and val == "v1"
        # shard 0 moves: now q1 dies too, and is dropped eagerly
        hit, _ = pc.topk_get(("q1",), [1, 4, 5])
        assert not hit
        assert len(pc._topk) == 0

    def test_update_rows_invalidates_only_touched_shards(self):
        """The end-to-end contract on real snapshot epochs: a delta in one
        shard must not evict results whose candidates came entirely from
        other shards."""
        snap = ShardedSnapshot(make_shard_entries(100, 4, seed=21), device=False)
        pc = PlanCache()
        req = parse_classad(REQ_SRCS[0])
        key_a = ("sharded_topk", "lfnA") + request_cache_key(req, snap.vocab_key())
        key_b = ("sharded_topk", "lfnB") + request_cache_key(req, snap.vocab_key())
        pc.topk_put(key_a, {0: int(snap.shard_epochs[0])}, "from-shard-0")
        pc.topk_put(key_b, {2: int(snap.shard_epochs[2])}, "from-shard-2")
        row_in_2 = int(snap.offsets[2])
        assert snap.update_rows({row_in_2: {"loadFactor": 3.0}}) == [2]
        hit_a, val_a = pc.topk_get(key_a, snap.shard_epochs)
        hit_b, _ = pc.topk_get(key_b, snap.shard_epochs)
        assert hit_a and val_a == "from-shard-0"
        assert not hit_b and pc.stats["topk_stale"] == 1


class TestGIISBridge:
    def _make_giis(self):
        clock = Clock()
        giis = GIIS("o=grid", clock=clock, cache_ttl=5)
        states = []
        for i in range(3):
            g = StorageGRIS(
                f"gss=vol{i}, o=grid",
                {"hostname": f"ep{i}", "mountPoint": "/x",
                 "diskTransferRate": 800e6, "drdTime": 0.004, "dwrTime": 0.005},
                clock=clock,
            )
            state = {"avail": (i + 1) * 1024.0**3}
            g.register_dynamic("totalSpace", lambda: 100.0 * 1024**3, ttl=5)
            g.register_dynamic(
                "availableSpace", lambda st_=state: st_["avail"], ttl=5
            )
            g.register_dynamic("loadFactor", lambda: 0.5, ttl=5)
            giis.register(f"ep{i}", g)
            states.append((g, state))
        return clock, giis, states

    def test_from_giis_and_delta_refresh(self):
        clock, giis, states = self._make_giis()
        snap = ShardedSnapshot.from_giis(giis)
        assert snap.shard_names == ["ep0", "ep1", "ep2"]
        pushed = snap.pushed_rows
        # nothing moved ⇒ no shard re-uploads
        assert snap.refresh_from_giis(giis) == []
        assert snap.pushed_rows == pushed
        # one site's dynamic attribute changes after its TTL
        g1, state1 = states[1]
        state1["avail"] = 7.0 * 1024**3
        g1.invalidate("availableSpace")
        clock.advance(6)
        changed = snap.refresh_from_giis(giis)
        assert changed == ["ep1"]
        assert snap.pushed_rows == pushed + int(snap.counts[1])
        j = snap.attr_names.index("availablespace")
        attrs, _ = snap.shard_logical_columns(1)
        assert float(attrs[0, j]) == np.float32(7.0 * 1024**3)


REQ_KERNEL = parse_classad(
    "reqdSpace = 0; rank = other.diskTransferRate;"
    "requirements = other.availableSpace > 1M;"
)


@pytest.fixture
def grid():
    g = build_demo_grid(8, 4, seed=7)
    g.add_client("client://host0", zone="zone1")
    g.replicate("f-000", b"x" * (1 << 20),
                ["gsiftp://ep000", "gsiftp://ep003", "gsiftp://ep005"])
    g.replicate("f-001", b"y" * (1 << 20), ["gsiftp://ep001", "gsiftp://ep004"])
    g.replicate("f-002", b"z" * (1 << 19),
                ["gsiftp://ep002", "gsiftp://ep006", "gsiftp://ep007"])
    return g


def _urls(ranked):
    return [r.pfn.url for r in ranked]


class TestShardedBroker:
    def test_parity_with_flat_broker(self, grid):
        flat = grid.broker_for("client://host0")
        sh = grid.broker_for("client://host0", snapshot_shards=4)
        queries = [(f"f-00{i}", REQ_KERNEL) for i in range(3)]
        want = flat.select_many(queries, top_k=2)
        got = sh.select_many(queries, top_k=2)
        assert sh.stats["batched_sharded_requests"] == 3
        for g_, w in zip(got, want):
            assert _urls(g_) == _urls(w)
            for x, y in zip(g_, w):
                assert abs(x.rank - y.rank) <= 1e-6 * max(1.0, abs(y.rank))

    def test_audit_records_shards_and_path(self, grid):
        b = grid.broker_for("client://host0", snapshot_shards=4)
        (res,) = b.select_many([("f-000", REQ_KERNEL)], top_k=2)
        rec = b.audit.get(res.request_id)
        assert rec.kernel_path == "sharded_topk"
        assert rec.shards  # which corners of the federation answered
        snap = b._snap_state.snapshot
        assert rec.shards == sorted(set(rec.shards))
        assert all(0 <= s < snap.g for s in rec.shards)

    def test_select_delegates_to_sharded_tier(self, grid):
        b = grid.broker_for("client://host0", snapshot_shards=4)
        got = b.select("f-000", REQ_KERNEL, top_k=2)
        assert b.stats["batched_sharded_requests"] == 1
        flat = grid.broker_for("client://host0")
        want = flat.select("f-000", REQ_KERNEL, top_k=2)
        assert _urls(got) == _urls(want)

    def test_per_replica_request_skips_delegation(self, grid):
        b = grid.broker_for("client://host0", snapshot_shards=4)
        req = parse_classad(
            "reqdSpace = 0; rank = other.diskTransferRate;"
            "requirements = other.replicaSize > 0;"
        )
        got = b.select("f-000", req, top_k=2)
        assert b.stats["batched_sharded_requests"] == 0
        assert _urls(got)  # still answered (interpreter tier)

    def test_result_cache_hits_and_shard_invalidation(self, grid):
        b = grid.broker_for("client://host0", snapshot_shards=4)
        # prime with every lfn so the snapshot spans all 8 endpoints and
        # f-000's candidates occupy a strict subset of the shards
        b.select_many([(f"f-00{i}", REQ_KERNEL) for i in range(3)], top_k=2)
        misses = b.plan_cache.stats["topk_misses"]
        (res,) = b.select_many([("f-000", REQ_KERNEL)], top_k=2)
        assert b.plan_cache.stats["topk_hits"] >= 1
        assert b.plan_cache.stats["topk_misses"] == misses
        rec = b.audit.get(res.request_id)
        st = b._snap_state
        snap = st.snapshot
        # the cached entry is keyed by every shard holding a *candidate*
        # replica (a superset of the final contributors in rec.shards)
        cand_shards = sorted({snap.shard_of_row(st.row_of[u]) for u in rec.candidates})
        # dirty a shard holding no candidate: still a hit
        untouched = sorted(set(range(snap.g)) - set(cand_shards))
        assert untouched, "fixture should leave at least one candidate-free shard"
        snap.update_rows({int(snap.offsets[untouched[0]]): {"loadFactor": 1.0}})
        hits = b.plan_cache.stats["topk_hits"]
        b.select_many([("f-000", REQ_KERNEL)], top_k=2)
        assert b.plan_cache.stats["topk_hits"] == hits + 1
        # dirty a candidate shard: the cached result must die
        row = int(st.row_of[rec.candidates[0]])
        snap.update_rows({row: {"loadFactor": 1.0}})
        stale = b.plan_cache.stats["topk_stale"]
        b.select_many([("f-000", REQ_KERNEL)], top_k=2)
        assert b.plan_cache.stats["topk_stale"] == stale + 1

    def test_snapshot_delta_refresh_across_ttl(self, grid):
        b = grid.broker_for("client://host0", snapshot_shards=4)
        (r0,) = b.select_many([("f-000", REQ_KERNEL)], top_k=2)
        assert b.audit.get(r0.request_id).snapshot == "build"
        grid.clock.advance(b.snapshot_ttl + 1)
        (r1,) = b.select_many([("f-000", REQ_KERNEL)], top_k=2)
        assert b.stats["snapshot_delta_refreshes"] >= 1
        assert b.audit.get(r1.request_id).snapshot == "delta"
        (r2,) = b.select_many([("f-000", REQ_KERNEL)], top_k=2)
        assert b.audit.get(r2.request_id).snapshot == "reuse"
