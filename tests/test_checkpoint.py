"""Distributed checkpointing: roundtrip, placement, failover, repair, GC."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointError, CheckpointManager
from repro.checkpoint.placement import plan_placement
from repro.storage.endpoint import build_demo_grid
from repro.storage.faults import FaultInjector


def make_state(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {
            "w": jnp.asarray(rng.normal(size=(64, 128)).astype(np.float32)),
            "b": jnp.asarray(rng.normal(size=(128,)).astype(np.float32)),
        },
        "step": jnp.asarray(7, jnp.int32),
    }


@pytest.fixture
def env():
    grid = build_demo_grid(6, 3, seed=11, capacity=1 << 30)
    grid.add_client("client://trainer", zone="zone0")
    broker = grid.broker_for("client://trainer")
    mgr = CheckpointManager("testrun", grid, broker, replication=2, chunk_bytes=16 << 10)
    return grid, broker, mgr


class TestRoundtrip:
    def test_save_restore_exact(self, env):
        grid, broker, mgr = env
        state = make_state()
        mgr.save(10, state)
        restored = mgr.restore(10, jax.eval_shape(lambda: state))
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_latest_step(self, env):
        grid, broker, mgr = env
        assert mgr.latest_step() is None
        mgr.save(5, make_state())
        mgr.save(10, make_state(1))
        assert mgr.latest_step() == 10

    def test_replication_factor(self, env):
        grid, broker, mgr = env
        mgr.save(1, make_state())
        man = mgr.load_manifest(1)
        for leaf in man["leaves"]:
            for ch in leaf["chunks"]:
                assert len(grid.catalog.lookup(ch["lfn"])) >= 2

    def test_zone_anti_affinity(self, env):
        grid, broker, mgr = env
        plan = plan_placement(broker, grid, 1 << 20, k=2)
        zones = [grid.topology.zone_of(t) for t in plan.targets]
        assert len(set(zones)) == 2

    def test_async_save(self, env):
        grid, broker, mgr = env
        state = make_state()
        mgr.save(3, state, blocking=False)
        mgr.wait()
        restored = mgr.restore(3, jax.eval_shape(lambda: state))
        np.testing.assert_array_equal(
            np.asarray(state["params"]["w"]), np.asarray(restored["params"]["w"])
        )


class TestFaultTolerance:
    def test_restore_with_dead_endpoint(self, env):
        """Kill one replica holder of every chunk; restore must failover."""
        grid, broker, mgr = env
        state = make_state()
        mgr.save(10, state)
        man = mgr.load_manifest(10)
        first_ep = grid.catalog.lookup(man["leaves"][0]["chunks"][0]["lfn"])[0].endpoint
        grid.drop_endpoint(first_ep)
        restored = mgr.restore(10, jax.eval_shape(lambda: state))
        np.testing.assert_array_equal(
            np.asarray(state["params"]["w"]), np.asarray(restored["params"]["w"])
        )
        assert broker.stats["failovers"] >= 0  # path exercised

    def test_repair_restores_replication(self, env):
        grid, broker, mgr = env
        mgr.save(10, make_state())
        man = mgr.load_manifest(10)
        victim = grid.catalog.lookup(man["leaves"][0]["chunks"][0]["lfn"])[0].endpoint
        grid.drop_endpoint(victim)
        n = mgr.repair(10)
        assert n > 0
        for leaf in man["leaves"]:
            for ch in leaf["chunks"]:
                live = [
                    r for r in grid.catalog.lookup(ch["lfn"])
                    if grid.endpoints[r.endpoint].alive
                ]
                assert len(live) >= 2

    def test_checksum_detects_corruption(self, env):
        grid, broker, mgr = env
        state = make_state()
        mgr.save(10, state)
        man = mgr.load_manifest(10)
        # corrupt every replica of one chunk
        lfn = man["leaves"][0]["chunks"][0]["lfn"]
        for pfn in grid.catalog.lookup(lfn):
            grid.endpoints[pfn.endpoint].put(pfn.path, b"corrupted!")
        with pytest.raises(CheckpointError):
            mgr.restore(10, jax.eval_shape(lambda: state))

    def test_total_loss_raises(self, env):
        grid, broker, mgr = env
        mgr.save(10, make_state())
        man = mgr.load_manifest(10)
        lfn = man["leaves"][0]["chunks"][0]["lfn"]
        for pfn in grid.catalog.lookup(lfn):
            grid.drop_endpoint(pfn.endpoint)
        with pytest.raises(Exception):
            mgr.repair(10) or mgr.restore(10, jax.eval_shape(lambda: make_state()))


class TestGC:
    def test_keep_last_k(self, env):
        grid, broker, mgr = env
        for s in (1, 2, 3, 4, 5):
            mgr.save(s, make_state(s))
        steps = sorted(
            int(c.rsplit("/", 1)[1])
            for c in grid.catalog.collections()
            if c.startswith("ckpt/testrun/")
        )
        assert steps == [3, 4, 5]  # keep=3
        # old chunks physically deleted
        with pytest.raises(Exception):
            mgr.restore(1, jax.eval_shape(lambda: make_state()))


class TestCrashConsistency:
    def test_incomplete_checkpoint_invisible(self, env):
        """A save that died (or is still in flight) before writing its
        MANIFEST must not be offered by latest_step()."""
        grid, broker, mgr = env
        mgr.save(10, make_state())
        # simulate a crash mid-save of step 20: collection exists, no manifest
        grid.catalog.create_collection(mgr._collection(20))
        grid.catalog.add_to_collection(mgr._collection(20), mgr._chunk_lfn(20, 0, 0))
        assert mgr.latest_step() == 10

    def test_repair_during_async_save_window(self, env):
        grid, broker, mgr = env
        mgr.save(10, make_state())
        grid.catalog.create_collection(mgr._collection(20))  # in-flight save
        assert mgr.repair(mgr.latest_step()) == 0  # repairs step 10, no crash
