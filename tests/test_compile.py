"""ClassAd→columnar compiler: equivalence with the interpreter, fallback
behaviour, kernel-plan extraction."""

import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.broker import ReplicaView
from repro.core.catalog import PhysicalFile
from repro.core.classads import ClassAd, parse, parse_classad
from repro.core.compile import (
    CompileError,
    build_columns,
    compile_program,
    extract_conjunctive_terms,
    extract_linear_rank,
    vectorized_match,
)
from repro.core.ldif import entry_to_classad
from repro.core.matchmaker import Matchmaker


def make_views(rng, s, *, policy_frac=0.3):
    views = []
    for i in range(s):
        entry = {
            "endpoint": f"ep{i:04d}",
            "availableSpace": float(rng.uniform(0, 20 * 1024**3)),
            "MaxRDBandwidth": float(rng.uniform(0, 200 * 1024)),
            "loadFactor": float(rng.uniform(0, 8)),
        }
        if rng.random() < 0.15:
            del entry["MaxRDBandwidth"]  # Undefined column entries
        if rng.random() < policy_frac:
            entry["requirements"] = "other.reqdSpace <= 10G"
        ad = entry_to_classad(entry)
        views.append(ReplicaView(PhysicalFile(entry["endpoint"], "/p", 1), entry, ad))
    return views


REQS = [
    "other.availableSpace > 5G && other.MaxRDBandwidth >= 50K",
    "other.loadFactor <= 4 || other.availableSpace > 10G",
    "!(other.loadFactor > 6)",
    "ifThenElse(isUndefined(other.MaxRDBandwidth), false, other.MaxRDBandwidth > 10K)",
    "true",
]
RANKS = [
    "other.availableSpace",
    "other.availableSpace / 1M + 2 * other.MaxRDBandwidth",
    "min(other.loadFactor, 3) * -1.0",
    "ifThenElse(other.loadFactor < 2, 100.0, 1.0)",
]


class TestEquivalence:
    @pytest.mark.parametrize("req", REQS)
    @pytest.mark.parametrize("rank", RANKS)
    def test_matrix(self, req, rank):
        rng = np.random.default_rng(hash((req, rank)) % 2**32)
        views = make_views(rng, 40)
        request = ClassAd({"reqdSpace": 5 * 1024**3})
        request.set_expr("requirements", req)
        request.set_expr("rank", rank)
        interp = Matchmaker().match(request, [v.ad for v in views])
        vec = vectorized_match(request, views)
        assert vec is not None
        assert [m.ad.eval_attr("endpoint") for m in interp] == [
            r.view.entry["endpoint"] for r in vec
        ]

    @given(st.integers(0, 100000), st.integers(1, 60))
    @settings(max_examples=30, deadline=None)
    def test_prop_random_grids(self, seed, s):
        rng = np.random.default_rng(seed)
        views = make_views(rng, s)
        request = ClassAd({"reqdSpace": int(rng.uniform(0, 20 * 1024**3))})
        request.set_expr("requirements", REQS[seed % len(REQS)])
        request.set_expr("rank", RANKS[seed % len(RANKS)])
        interp = Matchmaker().match(request, [v.ad for v in views])
        vec = vectorized_match(request, views)
        assert [m.ad.eval_attr("endpoint") for m in interp] == [
            r.view.entry["endpoint"] for r in vec
        ]


class TestFallback:
    def test_string_ops_fall_back(self):
        request = ClassAd()
        request.set_expr("requirements", 'other.hostname == "a"')
        views = make_views(np.random.default_rng(0), 5)
        assert vectorized_match(request, views) is None

    def test_unknown_builtin_falls_back(self):
        request = ClassAd()
        request.set_expr("requirements", "regexp(\"x\", other.name)")
        views = make_views(np.random.default_rng(0), 5)
        assert vectorized_match(request, views) is None


class TestKernelExtraction:
    def test_conjunctive_terms(self):
        req = parse_classad("reqdSpace = 4K; requirements = other.a > 5 && my.reqdSpace <= other.b && 3 < other.c")
        terms = extract_conjunctive_terms(req["requirements"], req)
        assert {(t.attr, t.op) for t in terms} == {("a", ">"), ("b", ">="), ("c", ">")}

    def test_non_conjunctive_rejected(self):
        req = parse_classad("requirements = other.a > 5 || other.b > 2")
        assert extract_conjunctive_terms(req["requirements"], req) is None

    def test_linear_rank(self):
        req = parse_classad("rank = 2 * other.a + other.b / 4 - 3")
        w = extract_linear_rank(req["rank"], req)
        assert w["a"] == 2.0 and w["b"] == 0.25 and w[""] == -3.0

    def test_nonlinear_rank_rejected(self):
        req = parse_classad("rank = other.a * other.b")
        assert extract_linear_rank(req["rank"], req) is None
