"""matchrank Pallas kernel: shape/dtype sweeps vs the pure-jnp oracle,
plus end-to-end parity with the ClassAd interpreter."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.classads import parse_classad
from repro.core.matchmaker import Matchmaker
from repro.kernels.matchrank.ops import lower_request, matchrank, matchrank_topk

NAMES = ["availablespace", "maxrdbandwidth", "avgrdbandwidth", "loadfactor"]


def random_cols(rng, s, invalid_frac=0.1):
    attrs = np.stack(
        [
            rng.uniform(0, 20 * 1024**3, s),
            rng.uniform(0, 200 * 1024, s),
            rng.uniform(0, 100e6, s),
            rng.uniform(0, 8, s),
        ],
        axis=1,
    ).astype(np.float32)
    valid = rng.random((s, 4)) > invalid_frac
    return attrs, valid


REQUEST = parse_classad(
    """
reqdSpace = 5G;
rank = other.avgRDBandwidth + 0.5 * other.maxRDBandwidth;
requirements = other.availableSpace > 5G && other.maxRDBandwidth >= 50K
    && other.loadFactor <= 6;
"""
)


class TestKernelVsRef:
    @pytest.mark.parametrize("s", [1, 7, 64, 512, 513, 2048])
    @pytest.mark.parametrize("block_s", [256, 512])
    def test_shape_sweep(self, s, block_s):
        rng = np.random.default_rng(s * 1000 + block_s)
        attrs, valid = random_cols(rng, s)
        plan = lower_request(REQUEST, NAMES)
        mk, sk, bsk, bik = matchrank(attrs, valid, plan, block_s=block_s, use_kernel=True)
        mr, sr, bsr, bir = matchrank(attrs, valid, plan, block_s=block_s, use_kernel=False)
        np.testing.assert_array_equal(mk, mr)
        np.testing.assert_allclose(sk[mk], sr[mr], rtol=1e-6)
        assert bik == bir
        if mk.any():
            np.testing.assert_allclose(bsk, bsr, rtol=1e-6)

    @pytest.mark.parametrize("dtype", [np.float32, np.float64, np.int32])
    def test_dtype_coercion(self, dtype):
        rng = np.random.default_rng(0)
        attrs, valid = random_cols(rng, 128)
        attrs = attrs.astype(dtype)
        plan = lower_request(REQUEST, NAMES)
        mk, sk, _, bik = matchrank(np.asarray(attrs, np.float32), valid, plan)
        mr, sr, _, bir = matchrank(np.asarray(attrs, np.float32), valid, plan, use_kernel=False)
        np.testing.assert_array_equal(mk, mr)
        assert bik == bir

    def test_no_matches(self):
        rng = np.random.default_rng(1)
        attrs, valid = random_cols(rng, 100)
        req = parse_classad("requirements = other.loadFactor > 1000; rank = 1")
        plan = lower_request(req, NAMES)
        mk, sk, bs, bi = matchrank(attrs, valid, plan)
        assert not mk.any()
        assert bs == -np.inf

    def test_admit_premask(self):
        rng = np.random.default_rng(2)
        attrs, valid = random_cols(rng, 64, invalid_frac=0.0)
        plan = lower_request(parse_classad("requirements = true; rank = other.loadfactor"), NAMES)
        admit = np.zeros(64)
        admit[10] = 1
        mk, _, _, bi = matchrank(attrs, valid, plan, admit=admit)
        assert mk.sum() == 1 and bi == 10

    def test_topk(self):
        rng = np.random.default_rng(3)
        attrs, valid = random_cols(rng, 300, invalid_frac=0.0)
        plan = lower_request(parse_classad("requirements = true; rank = other.avgrdbandwidth"), NAMES)
        idx, vals = matchrank_topk(attrs, valid, plan, 5)
        order = np.argsort(-attrs[:, 2])
        np.testing.assert_array_equal(idx, order[:5])


class TestKernelVsInterpreter:
    """The kernel path must reproduce the interpreter's selections."""

    @given(st.integers(0, 10_000), st.integers(2, 40))
    @settings(max_examples=25, deadline=None)
    def test_best_matches_interpreter(self, seed, s):
        rng = np.random.default_rng(seed)
        attrs, valid = random_cols(rng, s, invalid_frac=0.2)
        plan = lower_request(REQUEST, NAMES)
        mk, sk, bs, bi = matchrank(attrs, valid, plan)

        ads = []
        for i in range(s):
            ad = parse_classad(f'name = "ep{i:04d}"')
            for j, n in enumerate(NAMES):
                if valid[i, j]:
                    ad[n] = float(attrs[i, j])
            ads.append(ad)
        res = Matchmaker().match(REQUEST, ads, require_symmetric=False)
        got = {int(m.name[2:]) for m in res}
        assert got == set(np.nonzero(mk)[0].tolist())
        if res:
            # f32 rank ties can reorder; best score must agree to f32 eps
            assert abs(res[0].rank - bs) <= 1e-6 * max(abs(res[0].rank), 1.0) + 1e-3
