"""matchrank Pallas kernel: shape/dtype sweeps vs the pure-jnp oracle,
plus end-to-end parity with the ClassAd interpreter."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # degrade: property tests skip, sweeps still run
    HAVE_HYPOTHESIS = False

    def given(*a, **k):
        return lambda f: f

    def settings(*a, **k):
        return lambda f: f

    class _St:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _St()

needs_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS, reason="hypothesis not installed (requirements-dev.txt)"
)

from repro.core.classads import parse_classad
from repro.core.matchmaker import Matchmaker
from repro.kernels.matchrank.ops import (
    lower_request,
    matchrank,
    matchrank_batched,
    matchrank_batched_topk,
    matchrank_topk,
    stack_plans,
)
from repro.kernels.matchrank.sparse import canonicalize_plans

NAMES = ["availablespace", "maxrdbandwidth", "avgrdbandwidth", "loadfactor"]


def random_cols(rng, s, invalid_frac=0.1):
    attrs = np.stack(
        [
            rng.uniform(0, 20 * 1024**3, s),
            rng.uniform(0, 200 * 1024, s),
            rng.uniform(0, 100e6, s),
            rng.uniform(0, 8, s),
        ],
        axis=1,
    ).astype(np.float32)
    valid = rng.random((s, 4)) > invalid_frac
    return attrs, valid


REQUEST = parse_classad(
    """
reqdSpace = 5G;
rank = other.avgRDBandwidth + 0.5 * other.maxRDBandwidth;
requirements = other.availableSpace > 5G && other.maxRDBandwidth >= 50K
    && other.loadFactor <= 6;
"""
)


class TestKernelVsRef:
    @pytest.mark.parametrize("s", [1, 7, 64, 512, 513, 2048])
    @pytest.mark.parametrize("block_s", [256, 512])
    def test_shape_sweep(self, s, block_s):
        rng = np.random.default_rng(s * 1000 + block_s)
        attrs, valid = random_cols(rng, s)
        plan = lower_request(REQUEST, NAMES)
        mk, sk, bsk, bik = matchrank(attrs, valid, plan, block_s=block_s, use_kernel=True)
        mr, sr, bsr, bir = matchrank(attrs, valid, plan, block_s=block_s, use_kernel=False)
        np.testing.assert_array_equal(mk, mr)
        np.testing.assert_allclose(sk[mk], sr[mr], rtol=1e-6)
        assert bik == bir
        if mk.any():
            np.testing.assert_allclose(bsk, bsr, rtol=1e-6)

    @pytest.mark.filterwarnings("error")
    @pytest.mark.parametrize("dtype", [np.float32, np.float64, np.int32])
    def test_dtype_coercion(self, dtype):
        rng = np.random.default_rng(0)
        attrs, valid = random_cols(rng, 128)
        if np.issubdtype(dtype, np.integer):
            # clip into the target's representable range before the cast;
            # float32 spacing at 2^31 is 256, so clipping to exactly
            # info.max would round back out of range — leave headroom
            info = np.iinfo(dtype)
            attrs = np.clip(attrs, info.min, info.max - 1024)
        attrs = attrs.astype(dtype)
        plan = lower_request(REQUEST, NAMES)
        mk, sk, _, bik = matchrank(np.asarray(attrs, np.float32), valid, plan)
        mr, sr, _, bir = matchrank(np.asarray(attrs, np.float32), valid, plan, use_kernel=False)
        np.testing.assert_array_equal(mk, mr)
        assert bik == bir

    def test_no_matches(self):
        rng = np.random.default_rng(1)
        attrs, valid = random_cols(rng, 100)
        req = parse_classad("requirements = other.loadFactor > 1000; rank = 1")
        plan = lower_request(req, NAMES)
        mk, sk, bs, bi = matchrank(attrs, valid, plan)
        assert not mk.any()
        assert bs == -np.inf

    def test_admit_premask(self):
        rng = np.random.default_rng(2)
        attrs, valid = random_cols(rng, 64, invalid_frac=0.0)
        plan = lower_request(parse_classad("requirements = true; rank = other.loadfactor"), NAMES)
        admit = np.zeros(64)
        admit[10] = 1
        mk, _, _, bi = matchrank(attrs, valid, plan, admit=admit)
        assert mk.sum() == 1 and bi == 10

    def test_topk(self):
        rng = np.random.default_rng(3)
        attrs, valid = random_cols(rng, 300, invalid_frac=0.0)
        plan = lower_request(parse_classad("requirements = true; rank = other.avgrdbandwidth"), NAMES)
        idx, vals = matchrank_topk(attrs, valid, plan, 5)
        order = np.argsort(-attrs[:, 2])
        np.testing.assert_array_equal(idx, order[:5])


@needs_hypothesis
class TestKernelVsInterpreter:
    """The kernel path must reproduce the interpreter's selections."""

    @given(st.integers(0, 10_000), st.integers(2, 40))
    @settings(max_examples=25, deadline=None)
    def test_best_matches_interpreter(self, seed, s):
        rng = np.random.default_rng(seed)
        attrs, valid = random_cols(rng, s, invalid_frac=0.2)
        plan = lower_request(REQUEST, NAMES)
        mk, sk, bs, bi = matchrank(attrs, valid, plan)

        ads = []
        for i in range(s):
            ad = parse_classad(f'name = "ep{i:04d}"')
            for j, n in enumerate(NAMES):
                if valid[i, j]:
                    ad[n] = float(attrs[i, j])
            ads.append(ad)
        res = Matchmaker().match(REQUEST, ads, require_symmetric=False)
        got = {int(m.name[2:]) for m in res}
        assert got == set(np.nonzero(mk)[0].tolist())
        if res:
            # f32 rank ties can reorder; best score must agree to f32 eps
            assert abs(res[0].rank - bs) <= 1e-6 * max(abs(res[0].rank), 1.0) + 1e-3


def _ads_from_cols(attrs, valid):
    ads = []
    for i in range(attrs.shape[0]):
        ad = parse_classad(f'name = "ep{i:04d}"')
        for j, n in enumerate(NAMES):
            if valid[i, j]:
                ad[n] = float(attrs[i, j])
        ads.append(ad)
    return ads


REQUEST_BATCH = [
    REQUEST,
    parse_classad("rank = other.avgRDBandwidth; requirements = other.loadFactor <= 4;"),
    parse_classad(
        "reqdSpace = 1G;"
        "rank = 2 * other.maxRDBandwidth - other.loadFactor;"
        "requirements = other.availableSpace >= my.reqdSpace && other.avgRDBandwidth > 1M;"
    ),
    parse_classad("rank = other.loadFactor; requirements = true;"),
]


class TestBatched:
    """Multi-request kernel: one launch must equal B sequential launches."""

    @pytest.mark.parametrize("s", [1, 63, 512, 700])
    @pytest.mark.parametrize("use_kernel", [True, False])
    def test_batched_vs_sequential(self, s, use_kernel):
        rng = np.random.default_rng(s + int(use_kernel))
        attrs, valid = random_cols(rng, s, invalid_frac=0.15)
        plans = [lower_request(r, NAMES) for r in REQUEST_BATCH]
        mask_b, score_b, topk_i, topk_s = matchrank_batched(
            attrs, valid, plans, k=3, block_s=256, use_kernel=use_kernel
        )
        assert mask_b.shape == (len(plans), s)
        for i, p in enumerate(plans):
            m, sc, bs, bi = matchrank(attrs, valid, p, block_s=256, use_kernel=False)
            np.testing.assert_array_equal(mask_b[i], m)
            np.testing.assert_allclose(score_b[i][m], sc[m], rtol=1e-6)
            if m.any():
                assert topk_i[i, 0] == bi
                np.testing.assert_allclose(topk_s[i, 0], bs, rtol=1e-6)
            else:
                assert topk_s[i, 0] == -np.inf

    @pytest.mark.parametrize("use_kernel", [True, False])
    def test_batched_topk_matches_unbatched(self, use_kernel):
        rng = np.random.default_rng(9)
        attrs, valid = random_cols(rng, 600, invalid_frac=0.0)
        plans = [lower_request(r, NAMES) for r in REQUEST_BATCH]
        _, _, topk_i, topk_s = matchrank_batched(
            attrs, valid, plans, k=5, block_s=256, use_kernel=use_kernel
        )
        for i, p in enumerate(plans):
            idx, vals = matchrank_topk(attrs, valid, p, 5, block_s=256, use_kernel=False)
            matched = vals > -np.inf
            np.testing.assert_array_equal(topk_i[i][matched], idx[matched])
            np.testing.assert_allclose(topk_s[i][matched], vals[matched], rtol=1e-6)

    def test_batched_admit_premask(self):
        rng = np.random.default_rng(4)
        attrs, valid = random_cols(rng, 64, invalid_frac=0.0)
        plan = lower_request(
            parse_classad("requirements = true; rank = other.loadfactor"), NAMES
        )
        admit = np.zeros((2, 64), np.float32)
        admit[0, 5] = 1
        admit[1, 40:44] = 1
        mask, _, topk_i, _ = matchrank_batched(
            attrs, valid, stack_plans([plan, plan]), admit=admit, k=1
        )
        assert mask[0].sum() == 1 and topk_i[0, 0] == 5
        assert mask[1].sum() == 4 and 40 <= topk_i[1, 0] < 44

    def test_stack_plans_mixed_t_pad(self):
        many_terms = parse_classad(
            "requirements = "
            + " && ".join(f"other.loadFactor < {i + 100}" for i in range(20))
            + "; rank = 1"
        )
        plans = [lower_request(REQUEST, NAMES), lower_request(many_terms, NAMES)]
        assert plans[0].t_pad != plans[1].t_pad
        bp = stack_plans(plans)
        assert bp.t_pad == max(p.t_pad for p in plans)
        rng = np.random.default_rng(0)
        attrs, valid = random_cols(rng, 100, invalid_frac=0.0)
        mask_b, _, _, _ = matchrank_batched(attrs, valid, bp, use_kernel=False)
        m0, _, _, _ = matchrank(attrs, valid, plans[0], use_kernel=False)
        m1, _, _, _ = matchrank(attrs, valid, plans[1], use_kernel=False)
        np.testing.assert_array_equal(mask_b[0], m0)
        np.testing.assert_array_equal(mask_b[1], m1)

    def test_vocab_mismatch_rejected(self):
        p1 = lower_request(REQUEST, NAMES)
        p2 = lower_request(REQUEST, NAMES[:2])
        with pytest.raises(ValueError):
            stack_plans([p1, p2])


class TestUnknownAttributeEncodings:
    """lower_request's encodings for attributes outside the vocabulary
    must agree with the interpreter: a requirements term on an absent
    attribute ⇒ no candidate matches; a rank weight on an unknown
    attribute ⇒ rank Undefined ⇒ 0.0 for all candidates."""

    def _check(self, request, attrs, valid, expect_rank_zero=False):
        plan = lower_request(request, NAMES)
        ads = _ads_from_cols(attrs, valid)
        res = Matchmaker().match(request, ads, require_symmetric=False)
        expected = {int(m.name[2:]) for m in res}

        for use_kernel in (True, False):
            mk, sk, bs, bi = matchrank(
                attrs, valid, plan, block_s=256, use_kernel=use_kernel
            )
            assert set(np.nonzero(mk)[0].tolist()) == expected
            if expect_rank_zero:
                assert np.all(sk[mk] == 0.0)
            # batched path must encode identically
            mb, sb, _, _ = matchrank_batched(
                attrs, valid, [plan, plan], block_s=256, use_kernel=use_kernel
            )
            np.testing.assert_array_equal(mb[0], mk)
            np.testing.assert_array_equal(mb[1], mk)
            np.testing.assert_allclose(sb[0][mk], sk[mk], rtol=1e-6)
        if res and expect_rank_zero:
            assert all(m.rank == 0.0 for m in res)

    def test_absent_requirement_attr_no_match(self):
        rng = np.random.default_rng(11)
        attrs, valid = random_cols(rng, 80, invalid_frac=0.1)
        req = parse_classad(
            "requirements = other.noSuchAttr > 1 && other.loadFactor < 6; rank = 1"
        )
        self._check(req, attrs, valid)
        plan = lower_request(req, NAMES)
        mk, _, _, _ = matchrank(attrs, valid, plan, use_kernel=False)
        assert not mk.any()

    def test_unknown_rank_attr_rank_zero(self):
        rng = np.random.default_rng(12)
        attrs, valid = random_cols(rng, 80, invalid_frac=0.1)
        req = parse_classad(
            "requirements = other.loadFactor < 6; rank = other.noSuchAttr * 3"
        )
        self._check(req, attrs, valid, expect_rank_zero=True)

    @needs_hypothesis
    @given(st.integers(0, 10_000), st.integers(1, 50), st.booleans())
    @settings(max_examples=25, deadline=None)
    def test_property_absent_attrs_match_interpreter(self, seed, s, in_rank):
        rng = np.random.default_rng(seed)
        attrs, valid = random_cols(rng, s, invalid_frac=0.25)
        if in_rank:
            req = parse_classad(
                "requirements = other.availableSpace > 2G;"
                "rank = other.ghostAttr + other.avgRDBandwidth * 0"
            )
            self._check(req, attrs, valid, expect_rank_zero=True)
        else:
            req = parse_classad(
                "requirements = other.ghostAttr >= 1 && other.loadFactor < 7; rank = 1"
            )
            self._check(req, attrs, valid)

class TestSparseTopK:
    """The rank-order sparse walk must be selection-identical to the
    dense batched launch (same scores, same lowest-index tie-break)."""

    @pytest.mark.parametrize("s", [5, 257, 1000])
    @pytest.mark.parametrize("k", [1, 3])
    def test_matches_dense(self, s, k):
        rng = np.random.default_rng(s * 10 + k)
        attrs, valid = random_cols(rng, s, invalid_frac=0.15)
        plans = [lower_request(r, NAMES) for r in REQUEST_BATCH]
        ti, ts = matchrank_batched_topk(attrs, valid, plans, k=k)
        _, _, di, ds = matchrank_batched(attrs, valid, plans, k=k, use_kernel=False)
        matched = ts > -np.inf
        np.testing.assert_array_equal(ti[matched], np.asarray(di, np.int64)[matched])
        np.testing.assert_allclose(ts[matched], np.asarray(ds)[matched], rtol=1e-5)
        # unmatched slots are explicit on the sparse path
        assert (ti[~matched] == -1).all()

    def test_admit_premask(self):
        rng = np.random.default_rng(7)
        attrs, valid = random_cols(rng, 400, invalid_frac=0.0)
        plans = [lower_request(r, NAMES) for r in REQUEST_BATCH]
        admit = rng.random((len(plans), 400)) > 0.7
        ti, ts = matchrank_batched_topk(attrs, valid, plans, k=2, admit=admit)
        _, _, di, ds = matchrank_batched(
            attrs, valid, plans, k=2, admit=admit.astype(np.float32), use_kernel=False
        )
        matched = ts > -np.inf
        np.testing.assert_array_equal(ti[matched], np.asarray(di, np.int64)[matched])
        for bi in range(len(plans)):
            got = ti[bi][ti[bi] >= 0]
            assert admit[bi][got].all()

    def test_ne_term_falls_back_to_dense(self):
        rng = np.random.default_rng(8)
        attrs, valid = random_cols(rng, 300, invalid_frac=0.0)
        ne = lower_request(
            parse_classad(
                "rank = other.avgrdbandwidth; requirements = other.loadfactor != 3;"
            ),
            NAMES,
        )
        assert canonicalize_plans([ne], len(NAMES)) is None
        ti, ts = matchrank_batched_topk(attrs, valid, [ne], k=1)
        _, _, di, ds = matchrank_batched(attrs, valid, [ne], k=1, use_kernel=False)
        np.testing.assert_array_equal(ti, np.asarray(di, np.int64))
        from repro.core.compile import CompileError

        with pytest.raises(CompileError):
            matchrank_batched_topk(attrs, valid, [ne], k=1, use_sparse=True)

    def test_absent_attr_never_matches(self):
        rng = np.random.default_rng(9)
        attrs, valid = random_cols(rng, 128, invalid_frac=0.0)
        bad = lower_request(
            parse_classad("requirements = other.noSuchAttr > 1;"), NAMES
        )
        ok = lower_request(
            parse_classad("rank = other.loadfactor; requirements = true;"), NAMES
        )
        ti, ts = matchrank_batched_topk(attrs, valid, [bad, ok], k=2)
        assert (ti[0] == -1).all() and np.isneginf(ts[0]).all()
        assert (ti[1] >= 0).all()

    def test_strict_op_boundaries(self):
        # x > 5 must exclude exactly 5.0; x >= 5 must include it
        attrs = np.array([[5.0], [np.nextafter(5.0, 6.0, dtype=np.float32)], [4.0]],
                         np.float32)
        valid = np.ones((3, 1), bool)
        names = ["x"]
        gt = lower_request(parse_classad("rank = other.x; requirements = other.x > 5;"), names)
        ge = lower_request(parse_classad("rank = other.x; requirements = other.x >= 5;"), names)
        ti, ts = matchrank_batched_topk(attrs, valid, [gt, ge], k=3)
        assert set(ti[0][ti[0] >= 0].tolist()) == {1}
        assert set(ti[1][ti[1] >= 0].tolist()) == {0, 1}

    def test_tie_break_is_lowest_index(self):
        # constant rank => every score ties; both paths must pick the
        # lowest candidate indices, in order
        attrs = np.ones((50, 4), np.float32)
        valid = np.ones((50, 4), bool)
        plan = lower_request(parse_classad("rank = 7; requirements = true;"), NAMES)
        ti, ts = matchrank_batched_topk(attrs, valid, [plan], k=4)
        _, _, di, _ = matchrank_batched(attrs, valid, [plan], k=4, use_kernel=False)
        np.testing.assert_array_equal(ti[0], [0, 1, 2, 3])
        np.testing.assert_array_equal(np.asarray(di, np.int64)[0], [0, 1, 2, 3])
        np.testing.assert_allclose(ts[0], 7.0)

    def test_snapshot_rank_order_cache(self):
        from repro.core.snapshot import ReplicaSnapshot

        rng = np.random.default_rng(11)
        attrs, valid = random_cols(rng, 300, invalid_frac=0.1)
        entries = []
        for i in range(300):
            e = {"endpoint": f"ep{i:04d}"}
            e.update({n: float(attrs[i, j]) for j, n in enumerate(NAMES) if valid[i, j]})
            entries.append(e)
        snap = ReplicaSnapshot(entries, NAMES)
        la, lv = snap.logical_columns()
        plans = [lower_request(r, snap.attr_names) for r in REQUEST_BATCH]
        ti1, ts1 = matchrank_batched_topk(la, lv, plans, k=2, rank_order=snap.rank_order)
        ti2, ts2 = matchrank_batched_topk(la, lv, plans, k=2)  # uncached order
        np.testing.assert_array_equal(ti1, ti2)
        np.testing.assert_allclose(ts1, ts2, rtol=1e-6)
        # a row update invalidates the cached order and logical columns
        snap.update_rows({0: {NAMES[2]: 1e12}})
        la2, lv2 = snap.logical_columns()
        assert la2[0, 2] == np.float32(1e12)
        order, svals = snap.rank_order(np.array([0, 0, 1, 0], np.float32))
        assert order[0] == 0 and svals[0] == np.float32(1e12)
