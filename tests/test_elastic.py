"""Elastic scaling: mesh planning + save-on-one-mesh / restore-on-another.

The resharding restore runs in a subprocess with 8 forced host devices —
the main test process must keep seeing exactly 1 device (the dry-run
rule), so multi-device behaviour is always exercised out of process.
"""

import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.parallel.elastic import host_shard_assignment, plan_mesh, revalidate_batch


class TestPlanMesh:
    def test_keeps_model_parallel(self):
        plan = plan_mesh(200, model_parallel=16)
        assert plan.axes[-1] == "model"
        assert plan.shape[-1] == 16
        assert plan.chips == 128  # 8×16 (largest pow2 data)
        assert plan.dropped_chips == 72

    def test_multi_pod_when_enough(self):
        plan = plan_mesh(512, model_parallel=16, pod_size=256)
        assert plan.axes == ("pod", "data", "model")
        assert plan.shape == (2, 16, 16)

    def test_shrink_to_single_pod(self):
        plan = plan_mesh(300, model_parallel=16, pod_size=256)
        assert plan.chips == 256
        assert plan.shape == (16, 16)

    def test_too_few_chips(self):
        with pytest.raises(ValueError):
            plan_mesh(8, model_parallel=16)

    def test_batch_revalidation(self):
        plan = plan_mesh(128, model_parallel=16)
        gb, per = revalidate_batch(256, plan)
        assert gb == 256 and per == 32
        gb, per = revalidate_batch(100, plan)  # not divisible by 8
        assert gb == 96 and per == 12

    def test_assignment_recomputed_after_resize(self):
        before = [host_shard_assignment(32, 8, h) for h in range(8)]
        after = [host_shard_assignment(32, 4, h) for h in range(4)]
        assert sorted(sum(after, [])) == list(range(32))
        assert sorted(sum(before, [])) == list(range(32))


RESHARD_SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint.manager import CheckpointManager
from repro.storage.endpoint import build_demo_grid

grid = build_demo_grid(4, 2, seed=0, capacity=1 << 30)
grid.add_client("client://t", zone="zone0")
broker = grid.broker_for("client://t")
mgr = CheckpointManager("elastic", grid, broker, replication=2, chunk_bytes=32 << 10)

# save from a (4, 2) mesh
mesh_a = jax.make_mesh((4, 2), ("data", "model"))
w = jnp.arange(64 * 32, dtype=jnp.float32).reshape(64, 32)
state = {"w": jax.device_put(w, NamedSharding(mesh_a, P("data", "model")))}
mgr.save(1, state)

# restore into a shrunken (2, 2) mesh (node loss: 8 -> 4 devices)
mesh_b = jax.make_mesh((2, 2), ("data", "model"))
def spec_fn(path, shape):
    return P("data", "model")
restored = mgr.restore(1, jax.eval_shape(lambda: {"w": w}), mesh=mesh_b, spec_fn=spec_fn)
ok = bool(np.array_equal(np.asarray(restored["w"]), np.asarray(w)))
n_shards = len(restored["w"].sharding.device_set)
print(json.dumps({"ok": ok, "devices": n_shards}))
"""


def test_reshard_restore_into_smaller_mesh():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [sys.executable, "-c", RESHARD_SCRIPT],
        capture_output=True, text=True, env=env, timeout=300,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    result = json.loads(out.stdout.strip().splitlines()[-1])
    assert result["ok"] is True
    assert result["devices"] == 4  # restored onto the shrunken mesh
