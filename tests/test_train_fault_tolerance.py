"""Training-loop fault tolerance: convergence, checkpoint/restart under
chaos, straggler detection, optimizer variants, gradient compression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import get_arch
from repro.data.datasets import ShardManifest, SyntheticCorpus, materialize_on_grid
from repro.data.pipeline import BatchSpec, DataPipeline
from repro.parallel.collectives import (
    compress_with_feedback,
    init_error_feedback,
    quantize_int8,
    dequantize_int8,
)
from repro.storage.endpoint import build_demo_grid
from repro.storage.faults import FaultEvent, FaultInjector
from repro.train.loop import LoopConfig, TrainLoop
from repro.train.optim import AdamWConfig, adamw_update, init_adamw, warmup_cosine
from repro.train.straggler import StragglerMonitor
from repro.train.train_step import TrainConfig

pytestmark = pytest.mark.slow


def build_env(seed=3, shards=8, tokens=30_000):
    cfg = get_arch("h2o-danube3-4b").reduced()
    grid = build_demo_grid(6, 3, seed=seed)
    grid.add_client("client://host0", zone="zone0")
    man = ShardManifest("toy", shards, tokens, cfg.vocab_size, seed=1)
    materialize_on_grid(SyntheticCorpus(man), grid, replication=2)
    pipe = DataPipeline("client://host0", 0, 1, grid, man, BatchSpec(8, 64))
    broker = grid.broker_for("client://host0")
    ckpt = CheckpointManager("run", grid, broker, replication=2, chunk_bytes=1 << 20)
    return cfg, grid, pipe, ckpt


class TestOptim:
    def test_adamw_quadratic_convergence(self):
        cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
        params = {"x": jnp.asarray([5.0, -3.0])}
        state = init_adamw(params, cfg)
        for _ in range(200):
            grads = {"x": 2 * params["x"]}  # d/dx x²
            params, state, _ = adamw_update(grads, state, params, cfg, jnp.float32(0.1))
        assert np.abs(np.asarray(params["x"])).max() < 1e-2

    def test_int8_moments_track_float32(self):
        cfgf = AdamWConfig(lr=0.05, weight_decay=0.0, moments_dtype="float32")
        cfgq = AdamWConfig(lr=0.05, weight_decay=0.0, moments_dtype="int8")
        pf = {"w": jnp.asarray(np.linspace(-2, 2, 512), jnp.float32).reshape(2, 256)}
        pq = jax.tree.map(jnp.copy, pf)
        sf, sq = init_adamw(pf, cfgf), init_adamw(pq, cfgq)
        for i in range(50):
            g = jax.tree.map(lambda w: 2 * w + 0.1 * np.sin(i), pf)
            pf, sf, _ = adamw_update(g, sf, pf, cfgf, jnp.float32(0.05))
            gq = jax.tree.map(lambda w: 2 * w + 0.1 * np.sin(i), pq)
            pq, sq, _ = adamw_update(gq, sq, pq, cfgq, jnp.float32(0.05))
        np.testing.assert_allclose(
            np.asarray(pf["w"]), np.asarray(pq["w"]), atol=0.05
        )

    def test_warmup_cosine_shape(self):
        lrs = [float(warmup_cosine(jnp.asarray(s), peak_lr=1.0, warmup=10, total=100)) for s in range(100)]
        assert lrs[0] == 0.0 and abs(lrs[10] - 1.0) < 1e-6
        assert lrs[99] < 0.2 and all(l >= 0 for l in lrs)


class TestCompression:
    def test_quantize_roundtrip_error_bounded(self):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(0, 1, 10_000), jnp.float32)
        q, s = quantize_int8(x)
        back = dequantize_int8(q, s, x.shape)
        rel = float(jnp.abs(back - x).max() / jnp.abs(x).max())
        assert rel < 0.02

    def test_error_feedback_unbiased_over_time(self):
        """With EF, the *sum* of compressed grads tracks the true sum."""
        rng = np.random.default_rng(1)
        grads = [{"w": jnp.asarray(rng.normal(0, 1, 256), jnp.float32)} for _ in range(50)]
        ef = init_error_feedback(grads[0])
        total_true = jnp.zeros(256)
        total_comp = jnp.zeros(256)
        for g in grads:
            cg, ef, _ = compress_with_feedback(g, ef)
            total_true += g["w"]
            total_comp += cg["w"]
        resid = float(jnp.abs(total_true - total_comp).max())
        assert resid < 0.05  # bounded by one step's quantization error

    def test_training_converges_with_compression(self):
        cfg, grid, pipe, ckpt = build_env()
        tc = TrainConfig(
            optimizer=AdamWConfig(lr=3e-3), n_microbatches=1,
            warmup_steps=2, total_steps=40, grad_compression=True,
        )
        loop = TrainLoop(cfg, tc, LoopConfig(total_steps=30, checkpoint_every=100), pipe, None)
        loop.run()
        losses = loop.losses()
        assert np.mean(losses[-5:]) < losses[0] - 0.5


class TestFaultTolerantLoop:
    def test_loss_decreases_and_resume(self):
        cfg, grid, pipe, ckpt = build_env()
        tc = TrainConfig(optimizer=AdamWConfig(lr=3e-3), n_microbatches=2,
                         warmup_steps=2, total_steps=60)
        loop = TrainLoop(cfg, tc, LoopConfig(total_steps=40, checkpoint_every=20), pipe, ckpt)
        loop.run()
        losses = loop.losses()
        assert np.mean(losses[-8:]) < np.mean(losses[:8]) - 0.3
        loop2 = TrainLoop(cfg, tc, LoopConfig(total_steps=40), pipe, ckpt)
        _, start = loop2.init_or_resume()
        assert start == 40

    def test_survives_scheduled_endpoint_kills(self):
        cfg, grid, pipe, ckpt = build_env()
        inj = FaultInjector(grid)
        # kill two endpoints mid-run (replication=2 keeps every shard alive)
        inj.schedule_event(FaultEvent(0.5, "kill", "gsiftp://ep001"))
        inj.schedule_event(FaultEvent(1.0, "degrade", "gsiftp://ep004", 0.05))
        tc = TrainConfig(optimizer=AdamWConfig(lr=1e-3), warmup_steps=2, total_steps=30)
        loop = TrainLoop(cfg, tc, LoopConfig(total_steps=25, checkpoint_every=10),
                         pipe, ckpt, faults=inj)
        loop.run()
        assert len(loop.losses()) == 25
        assert any("fault@" in e for e in loop.events)
        assert ckpt.latest_step() is not None


class TestStragglerMonitor:
    def test_detects_persistent_straggler(self):
        mon = StragglerMonitor(patience=3)
        actions = []
        for step in range(20):
            times = {f"h{i}": 1.0 + 0.01 * i for i in range(8)}
            times["h7"] = 1.0 if step < 5 else 4.0  # h7 degrades at step 5
            actions += mon.observe_step(step, times)
        assert any(a.host == "h7" for a in actions)
        kinds = {a.kind for a in actions if a.host == "h7"}
        assert kinds & {"rebalance", "exclude"}

    def test_no_false_positives_on_noise(self):
        rng = np.random.default_rng(0)
        mon = StragglerMonitor(patience=3)
        actions = []
        for step in range(50):
            times = {f"h{i}": float(1.0 + rng.normal(0, 0.02)) for i in range(8)}
            actions += mon.observe_step(step, times)
        assert actions == []

    def test_excluded_host_leaves_fleet_stats(self):
        mon = StragglerMonitor(patience=1, z_exclude=4.0)
        for step in range(10):
            times = {f"h{i}": 1.0 for i in range(7)}
            times["bad"] = 50.0
            mon.observe_step(step, times)
        assert "bad" in mon.excluded
        s = mon.fleet_summary()
        assert s["excluded_hosts"] == 1.0
        assert s["straggler_overhead"] < 0.1
