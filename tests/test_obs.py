"""Unified observability: metrics exposition, span nesting, audit-record
completeness on every execution tier, and GRIS-published broker telemetry."""

import io
import json
import math

import numpy as np
import pytest

from repro.core.broker import DataBroker, NoMatchError, default_read_request
from repro.core.classads import parse_classad
from repro.core.gris import Clock
from repro.obs import (
    AuditTrail,
    BROKER_METRIC,
    BROKER_TELEMETRY,
    BrokerTelemetryGRIS,
    MetricError,
    MetricsRegistry,
    Tracer,
)
from repro.storage.endpoint import build_demo_grid


# --------------------------------------------------------------------- metrics
class TestMetricsRegistry:
    def test_counter_gauge_histogram_basics(self):
        reg = MetricsRegistry()
        c = reg.counter("requests_total", "requests")
        c.inc()
        c.inc(2)
        assert reg.value("requests_total") == 3
        with pytest.raises(MetricError):
            c.inc(-1)

        g = reg.gauge("queue_depth", "depth")
        g.set(5)
        g.dec(2)
        assert reg.value("queue_depth") == 3
        g.set_max(1)
        assert reg.value("queue_depth") == 3

        h = reg.histogram("latency_seconds", "latency", buckets=(0.1, 1, math.inf))
        for v in (0.05, 0.5, 2.0):
            h.observe(v)
        assert h.count == 3
        assert h.sum == pytest.approx(2.55)
        assert [c for _, c in h.cumulative()] == [1, 2, 3]

    def test_labels_and_bounded_cardinality(self):
        reg = MetricsRegistry(max_label_sets=2)
        reg.counter("ops_total", "ops", op="read").inc()
        reg.counter("ops_total", "ops", op="write").inc()
        # third distinct label set collapses into the overflow series
        reg.counter("ops_total", "ops", op="delete").inc()
        reg.counter("ops_total", "ops", op="stat").inc()
        labels = {
            tuple(lbl.items())
            for name, lbl, _metric in reg.samples()
            if name == "ops_total"
        }
        assert (("op", "__other__"),) in labels
        assert reg.value("ops_total", op="__other__") == 2

    def test_kind_and_name_conflicts_rejected(self):
        reg = MetricsRegistry()
        reg.counter("x_total", "x")
        with pytest.raises(MetricError):
            reg.gauge("x_total", "x")
        with pytest.raises(MetricError):
            reg.counter("bad name!", "x")
        with pytest.raises(MetricError):
            reg.counter("y_total", "y", le="0.5")

    def test_prometheus_exposition_format(self):
        reg = MetricsRegistry()
        reg.counter("broker_searches_total", "searches").inc(3)
        reg.gauge("queue_depth", "depth", shard="a b\"c\\d").set(2.5)
        h = reg.histogram("lat_seconds", "lat", buckets=(0.5, math.inf))
        h.observe(0.1)
        h.observe(7.0)
        text = reg.expose_text()
        assert "# HELP broker_searches_total searches\n" in text
        assert "# TYPE broker_searches_total counter\n" in text
        assert "broker_searches_total 3\n" in text
        # label escaping: backslash, quote (Prometheus text format 0.0.4)
        assert 'queue_depth{shard="a b\\"c\\\\d"} 2.5' in text
        assert 'lat_seconds_bucket{le="0.5"} 1\n' in text
        assert 'lat_seconds_bucket{le="+Inf"} 2\n' in text
        assert "lat_seconds_sum 7.1\n" in text
        assert "lat_seconds_count 2\n" in text
        self._parse_exposition(text)

    @staticmethod
    def _parse_exposition(text: str):
        """Minimal format checker: every non-comment line must be
        ``name{labels} value`` with a float-parseable value."""
        import re

        sample = re.compile(
            r"^[a-zA-Z_:][a-zA-Z0-9_:]*(\{[^{}]*\})? [^ ]+$"
        )
        for line in text.strip().splitlines():
            if line.startswith("#"):
                assert line.startswith(("# HELP ", "# TYPE ")), line
                continue
            assert sample.match(line), f"bad exposition line: {line!r}"
            float(line.rsplit(" ", 1)[1])  # value parses

    def test_json_round_trip(self, tmp_path):
        reg = MetricsRegistry()
        reg.counter("a_total", "a", op="r").inc(4)
        reg.gauge("b", "b").set(-1.5)
        h = reg.histogram("c_seconds", "c", buckets=(1, math.inf))
        h.observe(0.5)
        h.observe(3.0)

        clone = MetricsRegistry.from_dict(reg.to_dict())
        assert clone.value("a_total", op="r") == 4
        assert clone.value("b") == -1.5
        assert clone.expose_text() == reg.expose_text()

        path = tmp_path / "metrics.json"
        reg.dump_json(str(path), extra={"run": "t"})
        payload = json.loads(path.read_text())
        assert payload["run"] == "t"
        assert "a_total" in payload["exposition"]
        again = MetricsRegistry.from_dict(payload)
        assert again.expose_text() == reg.expose_text()


# ----------------------------------------------------------------------- spans
class TestTracer:
    def test_nesting_and_chrome_export(self):
        t = [0.0]

        def clock():
            t[0] += 1.0
            return t[0]

        tr = Tracer(time_fn=clock)
        with tr.span("outer", phase="x") as outer:
            with tr.span("inner") as inner:
                pass
            assert tr.depth == 1
        assert tr.depth == 0
        assert inner.parent_id == outer.span_id
        assert inner.depth == 1 and outer.depth == 0
        assert outer.duration > inner.duration

        doc = tr.export_chrome()
        events = doc["traceEvents"]
        assert {e["name"] for e in events} == {"outer", "inner"}
        for e in events:
            assert e["ph"] == "X"
            assert e["dur"] >= 0
        by_name = {e["name"]: e for e in events}
        assert by_name["inner"]["args"]["parent_id"] == outer.span_id
        assert by_name["outer"]["args"]["phase"] == "x"
        json.dumps(doc)  # serializable as-is

    def test_decorator_and_bounded_buffer(self):
        tr = Tracer(max_spans=4)

        @tr.trace("work")
        def work(x):
            return x * 2

        assert work(3) == 6
        assert len(tr.spans("work")) == 1
        for _ in range(10):
            work(1)
        assert len(tr.spans()) == 4
        assert tr.dropped == 7

    def test_span_set_attaches_args(self):
        tr = Tracer()
        with tr.span("s") as s:
            s.set(batch=7)
        assert tr.spans("s")[0].args["batch"] == 7


# ----------------------------------------------------- broker audit trail
def _demo_broker(**kwargs):
    grid = build_demo_grid(4, 3, seed=7)
    grid.add_client("client://c0", zone="zone1")
    grid.replicate("shard-000", b"x" * (2 << 20), ["gsiftp://ep000", "gsiftp://ep002"])
    grid.replicate("shard-001", b"y" * (1 << 20), ["gsiftp://ep001", "gsiftp://ep003"])
    grid.replicate("shard-002", b"z" * (1 << 20), ["gsiftp://ep000", "gsiftp://ep001"])
    broker = grid.broker_for("client://c0", **kwargs)
    return grid, broker


class TestAuditTrail:
    def test_select_records_complete_decision(self):
        grid, b = _demo_broker()
        lfn = sorted(grid.catalog.logical_files())[0]
        ranked = b.select(lfn)
        rid = b.last_request_id
        rec = b.explain(rid)
        assert rec.request_id == rid
        assert rec.lfn == lfn and rec.mode == "select"
        assert rec.kernel_path in ("interpreter", "vectorized")
        assert rec.candidates and rec.chosen == ranked[0].pfn.endpoint
        assert len(rec.scores) == len(rec.candidates)
        winner = next(s for s in rec.scores if s.endpoint == rec.chosen)
        assert winner.matched and winner.rank == pytest.approx(ranked[0].rank)
        assert rec.error is None and not rec.accessed

    def test_select_failure_recorded(self):
        grid, b = _demo_broker()
        lfn = sorted(grid.catalog.logical_files())[0]
        req = parse_classad("requirements = other.loadFactor > 1e12; rank = 1")
        req["clientUrl"] = "client://c0"
        with pytest.raises(NoMatchError):
            b.select(lfn, req)
        rec = b.explain(b.last_request_id)
        assert rec.error == "NoMatchError"
        assert rec.chosen is None
        assert all(not s.matched for s in rec.scores)

    def test_select_many_dense_kernel_audit(self):
        grid, b = _demo_broker(batch_use_kernel=False)
        lfns = sorted(grid.catalog.logical_files())[:3]
        req = parse_classad(
            "reqdSpace = 0; rank = other.diskTransferRate;"
            "requirements = other.availableSpace > 1M;"
        )
        results = b.select_many([(l, req) for l in lfns])
        assert len(b.last_request_ids) == 3
        assert b.stats["batched_kernel_requests"] == 3
        for rid, lfn, res in zip(b.last_request_ids, lfns, results):
            rec = b.explain(rid)
            assert rec.mode == "select_many" and rec.lfn == lfn
            assert rec.kernel_path == "batched_kernel"
            assert rec.snapshot in ("build", "reuse")
            assert rec.plan_cache in ("hit", "miss")
            assert rec.chosen == res[0].pfn.endpoint
            assert any(s.matched for s in rec.scores)
        # first request lowered the plan, the rest hit the cache
        statuses = [b.explain(r).plan_cache for r in b.last_request_ids]
        assert statuses[0] == "miss" and set(statuses[1:]) == {"hit"}

    def test_select_many_sparse_topk_audit_and_parity(self):
        grid, b = _demo_broker()
        lfns = sorted(grid.catalog.logical_files())[:3]
        req = parse_classad(
            "reqdSpace = 0; rank = other.diskTransferRate;"
            "requirements = other.availableSpace > 1M;"
        )
        queries = [(l, req) for l in lfns]
        dense = b.select_many(queries, top_k=2)
        sparse = b.select_many(queries, top_k=2, use_sparse=True)
        assert b.stats["batched_sparse_requests"] == 3
        for d, s in zip(dense, sparse):
            assert [rr.pfn.endpoint for rr in d] == [rr.pfn.endpoint for rr in s]
            assert [rr.rank for rr in d] == pytest.approx([rr.rank for rr in s])
        for rid, res in zip(b.last_request_ids, sparse):
            rec = b.explain(rid)
            assert rec.kernel_path == "sparse_topk"
            assert rec.top_k == 2
            assert rec.chosen == res[0].pfn.endpoint
            matched = [s for s in rec.scores if s.matched]
            assert 0 < len(matched) <= 2  # sparse records the probed winners

    def test_select_many_interp_tier_audit(self):
        grid, b = _demo_broker()
        lfn = sorted(grid.catalog.logical_files())[0]
        # per-replica attribute forces the interpreter tier
        req = default_read_request("client://c0")
        req.set_expr("rank", "other.replicaSize")
        b.select_many([(lfn, req)])
        rec = b.explain(b.last_request_ids[0])
        assert rec.kernel_path == "batched_interp"
        assert rec.chosen is not None

    def test_access_annotates_record(self):
        grid, b = _demo_broker()
        transfer = grid.transfer_service(metrics=b.metrics)
        lfn = sorted(grid.catalog.logical_files())[0]
        out = b.fetch(lfn, transfer)
        rec = b.explain(b.last_request_id)
        assert rec.accessed
        assert rec.fetched_from == out.replica.endpoint
        assert rec.nbytes == out.nbytes
        assert rec.observed_bandwidth == pytest.approx(out.bandwidth)
        assert rec.attempts == out.attempts
        # transfer service shares the registry
        assert b.metrics.value("transfer_total", op="read") >= 1

    def test_trail_ring_eviction_and_dump(self, tmp_path):
        trail = AuditTrail(capacity=2)
        r1 = trail.begin("f1", mode="select", at=0.0)
        trail.begin("f2", mode="select", at=1.0)
        trail.begin("f3", mode="select", at=2.0)
        assert len(trail) == 2 and trail.evicted == 1
        assert r1.request_id not in trail
        with pytest.raises(KeyError):
            trail.get(r1.request_id)

        buf = io.StringIO()
        assert trail.dump_jsonl(buf) == 2
        lines = [json.loads(l) for l in buf.getvalue().splitlines()]
        assert [l["lfn"] for l in lines] == ["f2", "f3"]

    def test_stats_property_backed_by_registry(self):
        grid, b = _demo_broker()
        lfn = sorted(grid.catalog.logical_files())[0]
        b.select(lfn)
        assert b.stats["searches"] == 1 and b.stats["matches"] == 1
        assert isinstance(b.stats["searches"], int)
        assert b.metrics.value("broker_searches_total") == 1
        assert "broker_searches_total 1" in b.metrics.expose_text()


# ----------------------------------------------------- GRIS-published telemetry
class TestBrokerTelemetryGRIS:
    def test_telemetry_subtree_valid_and_searchable(self):
        grid, b = _demo_broker()
        lfns = sorted(grid.catalog.logical_files())[:2]
        b.select_many([(l, None) for l in lfns])
        pub = BrokerTelemetryGRIS("gbt=c0, o=grid", b)

        top = pub.telemetry_entry()
        assert top["objectClass"] == BROKER_TELEMETRY.name
        assert top["searchesTotal"] == float(b.stats["searches"])
        assert top["batchSelectsTotal"] == 1.0
        assert top["auditRecords"] == float(len(b.audit))

        entries = pub.entries()
        assert entries[0] is not top  # materialized per call
        kids = [e for e in entries if e["objectClass"] == BROKER_METRIC.name]
        assert kids, "registry series published as child entries"
        names = {e["metricName"] for e in kids}
        assert "broker_searches_total" in names
        for e in kids:
            assert e["dn"].endswith(pub.dn)

        # LDAP filter over the subtree, like a GIIS query would issue
        hits = pub.search(f"(objectClass={BROKER_TELEMETRY.name})")
        assert len(hits) == 1 and hits[0]["brokerUrl"] == "client://c0"
        proj = pub.search(
            f"(metricName=broker_searches_total)", attrs=["metricValue"]
        )
        assert proj and "metricValue" in proj[0] and "metricType" not in proj[0]

    def test_giis_aggregates_broker_health(self):
        from repro.core.giis import GIIS

        grid, b = _demo_broker()
        b.select(sorted(grid.catalog.logical_files())[0])
        giis = GIIS("o=grid", clock=Clock())
        giis.register("broker-c0", BrokerTelemetryGRIS("gbt=c0, o=grid", b))
        hits = giis.search(f"(objectClass={BROKER_TELEMETRY.name})")
        assert len(hits) == 1
        assert hits[0]["searchesTotal"] >= 1.0

    def test_ldif_dump(self):
        grid, b = _demo_broker()
        b.select(sorted(grid.catalog.logical_files())[0])
        pub = BrokerTelemetryGRIS("gbt=c0, o=grid", b)
        text = pub.to_ldif()
        assert "dn: gbt=c0, o=grid" in text
        assert "objectClass: Grid::Broker::Telemetry" in text


# ----------------------------------------------------------- GRIS ttl metrics
def test_gris_query_metrics_and_ttl_hit_rate():
    grid, b = _demo_broker()
    ep = next(iter(grid.endpoints.values()))
    ep.gris.metrics = b.metrics
    lfn = sorted(grid.catalog.logical_files())[0]
    b.select(lfn)  # same simulated instant: dynamic reads hit the TTL cache
    b.select(lfn)
    assert b.metrics.value("gris_queries_total") >= 1
    stats = ep.gris.ttl_cache_stats()
    assert stats["misses"] >= 1
    assert 0.0 <= b.metrics.value("gris_dynamic_ttl_hit_rate") <= 1.0
