"""Fault-tolerance showcase: chaos schedule vs the decentralized broker.

Runs a 10-endpoint grid under a generated kill/degrade/heal schedule while
a client continuously fetches a replicated file. Prints a timeline of
faults, failovers, and straggler-driven mid-transfer switches, then the
selection-quality summary (achieved vs oracle bandwidth).

    PYTHONPATH=src python examples/grid_failover.py
"""

import sys

sys.path.insert(0, "src")

import numpy as np

from repro.storage.endpoint import build_demo_grid
from repro.storage.faults import FaultInjector


def main():
    grid = build_demo_grid(10, 5, seed=13)
    grid.add_client("client://app", zone="zone2")
    data = b"r" * (16 << 20)
    eps = grid.alive_endpoints()
    grid.replicate("bulk", data, [eps[0], eps[2], eps[5], eps[8]])

    inj = FaultInjector(grid)
    n = inj.chaos(horizon=600.0, mtbf=120.0, mttr=45.0, seed=3,
                  kinds=("kill", "degrade"))
    print(f"chaos schedule: {n} fault windows over 600 s simulated")

    broker = grid.broker_for("client://app")
    xfer = grid.transfer_service()
    bws = []
    events = 0
    for i in range(40):
        fired = inj.tick()
        for ev in fired:
            print(f"  t={grid.clock.now():7.1f}s  FAULT {ev.kind:8s} {ev.endpoint}"
                  + (f" ×{ev.factor:.2f}" if ev.kind == "degrade" else ""))
        events += len(fired)
        out = broker.fetch("bulk", xfer)
        bws.append(out.bandwidth)
        flags = []
        if out.attempts > 1:
            flags.append(f"failover×{out.attempts - 1}")
        if out.switched:
            flags.append(f"straggler-switch×{out.switched}")
        tag = f"  [{', '.join(flags)}]" if flags else ""
        print(f"  t={grid.clock.now():7.1f}s  fetch {i:2d}: "
              f"{out.replica.endpoint:18s} {out.bandwidth/1e6:7.1f} MB/s{tag}")

    print(f"\n40/40 fetches succeeded through {events} fault events")
    print(f"mean bandwidth {np.mean(bws)/1e6:.1f} MB/s "
          f"(min {np.min(bws)/1e6:.1f}, max {np.max(bws)/1e6:.1f})")
    print(f"broker stats: {broker.stats}")
    assert len(bws) == 40
    print("OK")


if __name__ == "__main__":
    main()
