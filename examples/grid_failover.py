"""Fault-tolerance showcase: chaos schedule vs the decentralized broker.

Part 1 runs a 10-endpoint grid under a generated kill/degrade/heal
schedule while a client continuously fetches a replicated file through
the classic single-source Access Phase (failover + straggler switches).

Part 2 runs the same chaos through the resilient access layer: every
fetch executes the broker's TransferPlan striped over the top-ranked
replicas, hedges stripes that run below prediction, retries transient
faults with backoff, and trips per-endpoint circuit breakers whose state
feeds back into matchmaking via GRIS. Scheduled faults land *mid-transfer*
(the injector ticks on every simulated-clock advance).

    PYTHONPATH=src python examples/grid_failover.py
"""

import sys

sys.path.insert(0, "src")

import numpy as np

from repro.storage.endpoint import build_demo_grid
from repro.storage.faults import FaultInjector


def classic():
    grid = build_demo_grid(10, 5, seed=13)
    grid.add_client("client://app", zone="zone2")
    data = b"r" * (16 << 20)
    eps = grid.alive_endpoints()
    grid.replicate("bulk", data, [eps[0], eps[2], eps[5], eps[8]])

    inj = FaultInjector(grid)
    n = inj.chaos(horizon=600.0, mtbf=120.0, mttr=45.0, seed=3,
                  kinds=("kill", "degrade"))
    print(f"chaos schedule: {n} fault windows over 600 s simulated")

    broker = grid.broker_for("client://app")
    xfer = grid.transfer_service()
    bws = []
    events = 0
    for i in range(40):
        fired = inj.tick()
        for ev in fired:
            print(f"  t={grid.clock.now():7.1f}s  FAULT {ev.kind:8s} {ev.endpoint}"
                  + (f" ×{ev.factor:.2f}" if ev.kind == "degrade" else ""))
        events += len(fired)
        out = broker.fetch("bulk", xfer)
        bws.append(out.bandwidth)
        flags = []
        if out.attempts > 1:
            flags.append(f"failover×{out.attempts - 1}")
        if out.switched:
            flags.append(f"straggler-switch×{out.switched}")
        tag = f"  [{', '.join(flags)}]" if flags else ""
        print(f"  t={grid.clock.now():7.1f}s  fetch {i:2d}: "
              f"{out.replica.endpoint:18s} {out.bandwidth/1e6:7.1f} MB/s{tag}")

    print(f"\n40/40 fetches succeeded through {events} fault events")
    print(f"mean bandwidth {np.mean(bws)/1e6:.1f} MB/s "
          f"(min {np.min(bws)/1e6:.1f}, max {np.max(bws)/1e6:.1f})")
    print(f"broker stats: {broker.stats}")
    assert len(bws) == 40
    print("OK")


def resilient():
    grid = build_demo_grid(10, 5, seed=13)
    grid.add_client("client://app", zone="zone2")
    data = b"r" * (16 << 20)
    eps = grid.alive_endpoints()
    grid.replicate("bulk", data, [eps[0], eps[2], eps[5], eps[8]])

    inj = FaultInjector(grid)
    n = inj.chaos(horizon=600.0, mtbf=120.0, mttr=45.0, seed=3,
                  kinds=("kill", "degrade"))
    print(f"\n=== resilient access layer, same chaos ({n} fault windows) ===")

    broker = grid.broker_for("client://app")
    svc = grid.resilient_transfer_service(broker)
    svc.on_advance = inj.tick  # scheduled faults land mid-transfer
    bws = []
    for i in range(40):
        for ev in inj.tick():
            print(f"  t={grid.clock.now():7.1f}s  FAULT {ev.kind:8s} {ev.endpoint}"
                  + (f" ×{ev.factor:.2f}" if ev.kind == "degrade" else ""))
        res = svc.fetch("bulk")
        assert res.payload == data
        bws.append(res.bandwidth)
        flags = []
        if res.failovers:
            flags.append(f"failover×{res.failovers}")
        if res.hedges:
            flags.append(f"hedged×{res.hedges} (won {res.hedge_wins} chunks)")
        if res.retries:
            flags.append(f"retries×{res.retries}")
        tag = f"  [{', '.join(flags)}]" if flags else ""
        srcs = "+".join(u.rsplit("ep", 1)[-1] for u in sorted(res.per_replica))
        print(f"  t={grid.clock.now():7.1f}s  fetch {i:2d}: "
              f"{res.stripes} stripes (ep{srcs:12s}) {res.bandwidth/1e6:7.1f} MB/s{tag}")

    open_eps = sorted(
        (ep, br.state) for ep, br in svc.breakers.breakers.items()
        if br.state != "closed"
    )
    print(f"\n40/40 striped fetches returned correct bytes")
    print(f"mean bandwidth {np.mean(bws)/1e6:.1f} MB/s "
          f"(min {np.min(bws)/1e6:.1f}, max {np.max(bws)/1e6:.1f})")
    print(f"breakers not closed at end: {open_eps or 'none'}")
    print(f"resilient counters: stripes={int(svc._c_stripes.value)} "
          f"hedges={int(svc._c_hedges.value)} hedge_wins={int(svc._c_hedge_wins.value)} "
          f"retries={int(svc._c_retries.value)} "
          f"stripe_failovers={int(svc._c_stripe_failovers.value)} "
          f"breaker_skips={int(svc._c_breaker_skips.value)}")
    print("OK")


def main():
    classic()
    resilient()


if __name__ == "__main__":
    main()
