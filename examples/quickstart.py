"""Quickstart: the paper's replica selection flow in 60 lines.

Reproduces the §4/§5.2 scenario end to end: a storage resource publishes
capabilities + a usage policy through its GRIS; an application submits a
request ClassAd; the decentralized broker runs Search → Match → Access
and fetches from the best-ranked replica.

    PYTHONPATH=src python examples/quickstart.py
"""

import sys

sys.path.insert(0, "src")

from repro.core.classads import parse_classad
from repro.core.matchmaker import Matchmaker
from repro.storage.endpoint import DataGrid

# --- 1. the paper's two ads, verbatim semantics -------------------------
storage_ad = parse_classad("""
    hostname = "hugo.mcs.anl.gov";
    volume = "/dev/sandbox";
    availableSpace = 50G;
    MaxRDBandwidth = 75K;
    requirements = other.reqdSpace < 10G && other.reqdRDBandwidth < 75K;
""")
request_ad = parse_classad("""
    hostname = "comet.xyz.com";
    reqdSpace = 5G;
    reqdRDBandwidth = 50K;
    rank = other.availableSpace;
    requirements = other.availableSpace > 5G && other.MaxRDBandwidth > 50K;
""")
match = Matchmaker().match(request_ad, [storage_ad])
print(f"§5.2 worked example: matched={bool(match)} "
      f"rank(availableSpace)={match[0].rank/2**30:.0f} GiB")

# --- 2. a small grid: publish, select, fetch ------------------------------
grid = DataGrid(seed=1)
for i, (zone, rate) in enumerate([("mcs", 800e6), ("mcs", 200e6), ("isi", 600e6)]):
    grid.add_endpoint(
        f"gsiftp://ep{i}", zone=zone, disk_rate=rate,
        policy="other.reqdSpace <= 10G" if i == 0 else None,
    )
grid.add_client("client://app", zone="mcs")

payload = b"dataset-bytes" * 100_000
grid.replicate("lfn://physics/run7/chunk-42", payload,
               ["gsiftp://ep0", "gsiftp://ep1", "gsiftp://ep2"])

broker = grid.broker_for("client://app")
xfer = grid.transfer_service()

print("\nSearch+Match (cold — static attributes only):")
for r in broker.select("lfn://physics/run7/chunk-42"):
    print(f"  {r.pfn.endpoint:16s} rank={r.rank/1e6:8.1f}")

print("\nAccess ×5 (history accumulates in each endpoint's GRIS):")
for i in range(5):
    out = broker.fetch("lfn://physics/run7/chunk-42", xfer)
    print(f"  fetch {i}: {out.replica.endpoint} at {out.bandwidth/1e6:.1f} MB/s")

print("\nSearch+Match (warm — per-source history drives the rank):")
for r in broker.select("lfn://physics/run7/chunk-42"):
    print(f"  {r.pfn.endpoint:16s} rank={r.rank/1e6:8.1f}")

# --- 3. failover ---------------------------------------------------------
best = broker.select("lfn://physics/run7/chunk-42")[0].pfn.endpoint
grid.drop_endpoint(best)
out = broker.fetch("lfn://physics/run7/chunk-42", xfer)
print(f"\nkilled {best}; broker failed over to {out.replica.endpoint} "
      f"(attempts={out.attempts})")
assert out.payload == payload
print("payload intact — done.")
