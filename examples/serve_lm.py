"""Serving example: replica-selected weight loading + batched generation.

The serving replica pulls its weights from the data grid (each checkpoint
chunk brokered independently — rank by predicted bandwidth to THIS host),
then serves batched greedy generation with KV caches.

    PYTHONPATH=src python examples/serve_lm.py
"""

import dataclasses
import sys

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import get_arch
from repro.models import transformer
from repro.serve.engine import ServeEngine
from repro.storage.endpoint import build_demo_grid
from repro.storage.faults import FaultInjector


def main():
    base = get_arch("h2o-danube3-4b")
    cfg = dataclasses.replace(
        base.reduced(), name="danube-serve", n_layers=4, d_model=256,
        n_heads=8, n_kv_heads=4, head_dim=32, d_ff=768, vocab_size=32768,
        sliding_window=64, max_seq=1024,
    )
    params = transformer.init_params(cfg, jax.random.PRNGKey(7))

    grid = build_demo_grid(6, 3, seed=7)
    grid.add_client("client://replica-west", zone="zone1")
    broker = grid.broker_for("client://replica-west")
    mgr = CheckpointManager("weights", grid, broker, replication=2, chunk_bytes=2 << 20)
    mgr.save(0, params)
    print("weights published to the grid (2× replication, matchmade placement)")

    # a weight holder dies before loading — restore must failover
    man = mgr.load_manifest(0)
    victim = grid.catalog.lookup(man["leaves"][2]["chunks"][0]["lfn"])[0].endpoint
    FaultInjector(grid).kill(victim)
    params2 = mgr.restore(0, jax.eval_shape(lambda: params))
    print(f"loaded via broker despite losing {victim} "
          f"(fetches={broker.stats['fetches']}, failovers={broker.stats['failovers']})")
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    engine = ServeEngine(cfg, params2, max_seq=256)
    rng = np.random.default_rng(0)
    prompts = rng.integers(4, cfg.vocab_size, (8, 48)).astype(np.int32)
    result = engine.generate(prompts, max_new=32)
    print(f"batched generation: {int(result.n_generated.sum())} tokens, "
          f"prefill {result.prefill_s*1e3:.0f} ms, "
          f"decode {result.decode_tokens_per_s:.0f} tok/s (CPU)")
    print("OK")


if __name__ == "__main__":
    main()
