"""End-to-end training driver: a ~100M-param LM for a few hundred steps,
every input shard fetched through the decentralized broker, with periodic
grid-replicated checkpoints and fault injection mid-run.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

This is the deliverable-(b) driver. It uses mistral-nemo-12b's *family*
at width 512 / 8 layers (~100M params incl. embeddings) — the full
configs lower through `python -m repro.launch.dryrun`.
"""

import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import get_arch
from repro.data.datasets import ShardManifest, SyntheticCorpus, materialize_on_grid
from repro.data.pipeline import BatchSpec, DataPipeline
from repro.storage.endpoint import build_demo_grid
from repro.storage.faults import FaultEvent, FaultInjector
from repro.train.loop import LoopConfig, TrainLoop
from repro.train.optim import AdamWConfig
from repro.train.train_step import TrainConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-3)
    args = ap.parse_args()

    # ~100M params: d=512, 8 layers, GQA 8/4, vocab 32768
    base = get_arch("mistral-nemo-12b")
    cfg = dataclasses.replace(
        base, name="nemo-100m", n_layers=10, d_model=640, n_heads=10,
        n_kv_heads=5, head_dim=64, d_ff=2048, vocab_size=32768, max_seq=4096,
    )
    n_params = cfg.param_counts()["total_with_emb"]
    print(f"arch nemo-100m: {n_params/1e6:.1f}M params")

    grid = build_demo_grid(8, 4, seed=0)
    grid.add_client("client://trainer", zone="zone0")
    man = ShardManifest("lm-corpus", 16, tokens_per_shard=200_000,
                        vocab_size=cfg.vocab_size, seed=0)
    materialize_on_grid(SyntheticCorpus(man), grid, replication=2)
    print(f"materialized {man.n_shards} shards ×2 replicas on 8 endpoints")

    pipe = DataPipeline("client://trainer", 0, 1, grid, man,
                        BatchSpec(args.batch, args.seq))
    broker = grid.broker_for("client://trainer")
    ckpt = CheckpointManager("train-lm", grid, broker, replication=2,
                             chunk_bytes=8 << 20)

    inj = FaultInjector(grid)
    inj.schedule_event(FaultEvent(5.0, "kill", "gsiftp://ep002"))
    inj.schedule_event(FaultEvent(9.0, "degrade", "gsiftp://ep004", 0.05))
    inj.schedule_event(FaultEvent(15.0, "heal", "gsiftp://ep002"))

    tc = TrainConfig(
        optimizer=AdamWConfig(lr=args.lr),
        n_microbatches=2,
        warmup_steps=max(args.steps // 20, 5),
        total_steps=args.steps,
    )
    lc = LoopConfig(total_steps=args.steps, checkpoint_every=max(args.steps // 4, 25),
                    log_every=max(args.steps // 15, 10), async_checkpoint=True,
                    repair_every=max(args.steps // 2, 50))
    loop = TrainLoop(cfg, tc, lc, pipe, ckpt, faults=inj)
    loop.run()

    losses = loop.losses()
    print("\n".join(loop.events[-12:]))
    print(f"\nloss: {losses[0]:.3f} → {np.mean(losses[-10:]):.3f} over {len(losses)} steps")
    print(f"pipeline: {pipe.stats}")
    print(f"broker:   {broker.stats}")
    print(f"ckpt:     {ckpt.stats}; latest step {ckpt.latest_step()}")
    assert np.mean(losses[-10:]) < losses[0] - 0.5, "training must make progress"
    print("OK")


if __name__ == "__main__":
    main()
