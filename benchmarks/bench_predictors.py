"""Predictor accuracy on synthetic bandwidth traces (§3.2 / §7).

Traces mix the effects the NetModel produces: diurnal load waves,
lognormal noise, regime shifts (path regrades). Error = mean absolute
percentage error of one-step-ahead prediction.

Rows: (predictor_trace, µs/update+predict, derived = MAPE %).
"""

import math
import time

import numpy as np

from repro.core.predictors import make_predictor

KINDS = ("last", "mean", "sliding_mean", "sliding_median", "ewma", "adaptive")


def make_trace(kind: str, n=600, seed=0):
    rng = np.random.default_rng(seed)
    t = np.arange(n)
    if kind == "diurnal":
        base = 100e6 * (1 - 0.35 * 0.5 * (1 + np.sin(2 * np.pi * t / 200)))
        return base * np.exp(rng.normal(0, 0.15, n))
    if kind == "noisy_stationary":
        x = 50e6 * np.exp(rng.normal(0, 0.25, n))
        x[::37] *= 0.05  # dropout outliers
        return x
    if kind == "regime_shift":
        x = np.where(t < n // 2, 80e6, 15e6).astype(float)
        return x * np.exp(rng.normal(0, 0.1, n))
    raise ValueError(kind)


def run():
    rows = []
    best = {}
    for trace_kind in ("diurnal", "noisy_stationary", "regime_shift"):
        xs = make_trace(trace_kind)
        for kind in KINDS:
            p = make_predictor(kind)
            errs = []
            t0 = time.perf_counter()
            for x in xs:
                pred = p.predict()
                if pred is not None:
                    errs.append(abs(pred - x) / x)
                p.update(float(x))
            us = (time.perf_counter() - t0) / len(xs) * 1e6
            mape = float(np.mean(errs)) * 100
            rows.append((f"pred_{kind}_{trace_kind}", us, mape))
            best.setdefault(trace_kind, []).append((mape, kind))
    for trace_kind, entries in best.items():
        entries.sort()
        # adaptive should be at worst ~1.35× the per-trace best member
        adaptive = [m for m, k in entries if k == "adaptive"][0]
        rows.append((f"pred_adaptive_regret_{trace_kind}", 0.0, adaptive / entries[0][0]))
    return rows
