"""Kernel micro-benchmarks (interpret-mode wall time is NOT a TPU number —
it validates the call path; the roofline for the kernels is analytic:
matchrank moves 4·S·A_PAD bytes/pass, bwstats 4·N·W_PAD — both single-pass
memory-bound designs; derived = modeled v5e µs at 819 GB/s HBM)."""

import time

import numpy as np

from repro.core.classads import parse_classad
from repro.kernels.bwstats.ops import bwstats
from repro.kernels.matchrank.ops import lower_request, matchrank

HBM = 819e9

REQ = parse_classad(
    "reqdSpace = 5G; rank = other.avgrdbandwidth;"
    "requirements = other.availablespace > 5G && other.maxrdbandwidth >= 50K;"
)
NAMES = ["availablespace", "maxrdbandwidth", "avgrdbandwidth", "loadfactor"]


def run():
    rows = []
    rng = np.random.default_rng(0)
    for s in (4096, 65536):
        attrs = rng.uniform(0, 1e9, (s, 4)).astype(np.float32)
        valid = np.ones((s, 4), bool)
        plan = lower_request(REQ, NAMES)
        matchrank(attrs, valid, plan)  # warm/compile
        t0 = time.perf_counter()
        for _ in range(3):
            matchrank(attrs, valid, plan)
        us = (time.perf_counter() - t0) / 3 * 1e6
        model_us = (s * plan.a_pad * 4 * 2) / HBM * 1e6
        rows.append((f"matchrank_interp_s{s}", us, model_us))

    for n, w in ((1024, 64), (8192, 128)):
        hist = rng.uniform(1e3, 1e9, (n, w)).astype(np.float32)
        counts = rng.integers(1, w + 1, n).astype(np.int32)
        bwstats(hist, counts)
        t0 = time.perf_counter()
        for _ in range(3):
            bwstats(hist, counts)
        us = (time.perf_counter() - t0) / 3 * 1e6
        w_pad = max((w + 127) // 128 * 128, 128)
        model_us = (n * w_pad * 4) / HBM * 1e6
        rows.append((f"bwstats_interp_n{n}w{w}", us, model_us))
    return rows
