"""Matchmaking throughput: interpreter vs columnar vs Pallas kernel path.

The paper's §6 claims ClassAds are "an efficient environment for
matching, querying, and ranking". This benchmark quantifies the Match
Phase at fleet scale: one request matched+ranked against S replica ads,

  * interp    — the paper-faithful tree-walking matchmaker,
  * columnar  — the ClassAd→columnar compiler under numpy (f64),
  * kernel    — conjunctive-threshold lowering through the fused
                matchrank kernel (interpret-mode Pallas on CPU; on TPU the
                same call runs compiled — see DESIGN.md §3),
  * batched   — the multi-request engine (DESIGN.md §4): B requests vs one
                resident snapshot, rank-order sparse top-k on CPU
                (``match_batched_b{8,64}_s{1k,10k}`` rows, with a
                batched-vs-sequential speedup row).

Rows: (name, µs/call, derived = matches/sec per 1k candidates — for
batched rows, request·candidates/sec; for speedup rows, the ratio).
"""

import time

import numpy as np

from repro.core.broker import ReplicaView
from repro.core.catalog import PhysicalFile
from repro.core.classads import ClassAd, parse_classad
from repro.core.compile import vectorized_match
from repro.core.ldif import entry_to_classad
from repro.core.matchmaker import Matchmaker
from repro.kernels.matchrank.ops import lower_request, matchrank

REQUEST_SRC = """
reqdSpace = 5G;
rank = other.AvgRDBandwidth;
requirements = other.availableSpace > 5G && other.MaxRDBandwidth >= 50K;
"""

NAMES = ["availablespace", "maxrdbandwidth", "avgrdbandwidth", "loadfactor"]


def make_world(s, seed=0):
    rng = np.random.default_rng(seed)
    attrs = np.stack(
        [
            rng.uniform(0, 20 * 1024**3, s),
            rng.uniform(0, 200 * 1024, s),
            rng.uniform(0, 100e6, s),
            rng.uniform(0, 8, s),
        ],
        axis=1,
    ).astype(np.float32)
    valid = np.ones((s, 4), bool)
    views = []
    for i in range(s):
        entry = {"endpoint": f"ep{i:05d}"}
        entry.update({n: float(attrs[i, j]) for j, n in enumerate(NAMES)})
        views.append(ReplicaView(PhysicalFile(entry["endpoint"], "/p", 1), entry,
                                 entry_to_classad(entry)))
    return attrs, valid, views


def _time(fn, reps, *, tol=0.25, max_warm=8):
    """Warm until two consecutive calls agree within ``tol`` (relative),
    so jit compilation / cache-fill time can't leak into the first timed
    rep on fresh shapes; bounded by ``max_warm`` calls for noisy-fast fns."""
    prev = None
    for _ in range(max_warm):
        t0 = time.perf_counter()
        fn()
        dt = time.perf_counter() - t0
        if prev is not None and abs(dt - prev) <= tol * max(dt, prev):
            break
        prev = dt
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e6  # µs


def run():
    rows = []
    steady_us = {}
    request = parse_classad(REQUEST_SRC)
    for s in (100, 1000, 10000):
        attrs, valid, views = make_world(s)
        mm = Matchmaker()
        ads = [v.ad for v in views]
        reps = max(2, 2000 // s)

        us_i = _time(lambda: mm.match(request, ads, require_symmetric=False), reps)
        # cold columnar: compile + build columns + match, per call
        us_c = _time(lambda: vectorized_match(request, views), reps)
        # steady state: the fleet scenario — columns are built once per
        # GRIS/GIIS snapshot and the compiled program is reused across
        # many selections (one per shard fetch)
        from repro.core.compile import build_columns, compile_program
        present = {n for v in views for n in (k.lower() for k in v.entry)}
        prog = compile_program(request, column_names=lambda n: n in present)
        tbl = build_columns([v.entry for v in views], sorted(present))
        import numpy as _np

        def steady():
            mask, rank = prog.run(tbl, _np)
            return int(_np.argmax(_np.where(mask, rank, -_np.inf)))

        us_w = _time(steady, max(reps, 20))
        steady_us[s] = us_w
        plan = lower_request(request, NAMES)
        us_k = _time(lambda: matchrank(attrs, valid, plan), max(reps, 10))

        rows.append((f"match_interp_s{s}", us_i, s / us_i * 1e6))
        rows.append((f"match_columnar_cold_s{s}", us_c, s / us_c * 1e6))
        rows.append((f"match_columnar_steady_s{s}", us_w, s / us_w * 1e6))
        # kernel timing on CPU is interpret-mode (Python per-block) —
        # reported for completeness; the perf claim is the columnar path,
        # which is the same program the kernel runs compiled on TPU.
        rows.append((f"match_kernel_interpret_s{s}", us_k, s / us_k * 1e6))
        rows.append((f"match_speedup_steady_vs_interp_s{s}", 0.0, us_i / us_w))

    # ---- batched engine: snapshot + plan cache + rank-order top-k ----
    # The fleet scenario (DESIGN.md §4): B concurrent requests answered
    # against ONE device-resident snapshot. Snapshot build, plan lowering
    # and the per-(epoch, rank-weights) sort happen once per GRIS epoch /
    # request shape — exactly the amortization the engine exists for —
    # so they sit outside the timed region, like the steady columnar row.
    from repro.core.plancache import PlanCache
    from repro.core.snapshot import ReplicaSnapshot
    from repro.kernels.matchrank.ops import matchrank_batched, matchrank_batched_topk

    for s in (1000, 10000):
        tag = "1k" if s == 1000 else "10k"
        _, _, views = make_world(s)
        snap = ReplicaSnapshot([v.entry for v in views])
        attrs_l, valid_l = snap.logical_columns()
        pc = PlanCache()
        for b in (8, 64):
            batch = [
                parse_classad(REQUEST_SRC.replace("5G", f"{4 + i % 4}G"))
                for i in range(b)
            ]
            plans = [pc.kernel_plan(r, snap.vocab_key()) for r in batch]

            def batched():
                return matchrank_batched_topk(
                    attrs_l, valid_l, plans, k=1, rank_order=snap.rank_order
                )

            us_b = _time(batched, 50)
            rows.append((f"match_batched_b{b}_s{tag}", us_b, b * s / us_b * 1e6))
            if b == 64:
                rows.append(
                    (
                        f"match_batched_vs_sequential_b{b}_s{tag}",
                        0.0,
                        b * steady_us[s] / us_b,
                    )
                )
        if s == 10000:
            # the dense batched launch (what the same call runs on TPU;
            # interpret-free jnp ref on CPU) — kept for the trajectory,
            # it is why the CPU steady state takes the sparse walk
            plans64 = [
                pc.kernel_plan(
                    parse_classad(REQUEST_SRC.replace("5G", f"{4 + i % 4}G")),
                    snap.vocab_key(),
                )
                for i in range(64)
            ]
            da, dv, dn = snap.device_columns()

            def dense():
                return matchrank_batched(
                    da, dv, plans64, n_rows=dn, k=1, use_kernel=False
                )

            us_d = _time(dense, 2, max_warm=3)
            rows.append((f"match_batched_dense_b64_s{tag}", us_d, 64 * s / us_d * 1e6))
            # dense fallback must stay within 20x of the sparse walk —
            # the host path is the safety net when plans don't
            # canonicalize, so it can't be allowed to rot (us_b still
            # holds the b=64 sparse figure from the loop above)
            rows.append(("match_dense_vs_sparse_b64_s10k", 0.0, us_d / us_b))

    # LDIF→ClassAd conversion throughput (the §6 'not cumbersome' claim)
    _, _, views = make_world(1000, seed=1)
    entries = [v.entry for v in views]
    us = _time(lambda: [entry_to_classad(e) for e in entries], 5)
    rows.append(("ldif_to_classad_1k", us, 1000 / us * 1e6))
    return rows
