"""Resilient access-layer benchmark: striped+hedged plans under faults.

Replays the acceptance scenario for the resilient transfer service on a
four-replica single-zone grid (comparable paths — the setting where
striping pays):

  * fault-free striped fetch vs the legacy single-source read,
  * one stripe source killed *mid-transfer* (injector ticks on every
    simulated-clock advance) plus another degraded 4x: the striped read
    must complete correct bytes within 1.5x the fault-free simulated
    wall time (claim check in run.py), while the legacy single-source
    read of the killed replica raises TransferFailure.

derived = simulated MB/s for throughput rows, ratio for the inflation
row, 0/1 for the legacy-failure row.
"""

import time

from repro.core.transferplan import TransferFailure, TransferRequest
from repro.storage.endpoint import DataGrid
from repro.storage.faults import FaultEvent, FaultInjector

DATA = b"b" * (32 << 20)
EPS = [f"gsiftp://bench{i}" for i in range(4)]


def _build(seed=5):
    g = DataGrid(seed=seed)
    for url in EPS:
        g.add_endpoint(url, zone="zoneA")
    g.add_client("client://bench", zone="zoneA")
    g.replicate("bulk", DATA, EPS)
    broker = g.broker_for("client://bench")
    svc = g.resilient_transfer_service(broker)
    return g, broker, svc


def _timed_fetch(svc, lfn="bulk"):
    w0 = time.perf_counter()
    res = svc.fetch(lfn)
    return res, (time.perf_counter() - w0) * 1e6


def run():
    rows = []

    # -- fault-free: striped vs legacy single-source -------------------------
    g, broker, svc = _build()
    svc.fetch("bulk")  # warm per-source history → predictions
    res, us = _timed_fetch(svc)
    assert res.payload == DATA
    s_free = res.seconds
    rows.append(("transfer_striped_healthy_MBps", us, res.bandwidth / 1e6))

    g2, broker2, _ = _build()
    xfer = g2.transfer_service()
    pfn = g2.catalog.lookup("bulk")[0]
    xfer.transfer(TransferRequest(pfn, "client://bench"))  # same warm count
    single = xfer.transfer(TransferRequest(pfn, "client://bench"))
    rows.append(("transfer_single_source_MBps", 0.0, single.bandwidth / 1e6))
    rows.append(
        ("transfer_striped_vs_single_speedup", 0.0, single.seconds / s_free)
    )

    # -- faulted: kill one source mid-transfer, degrade another 4x ------------
    g3, broker3, svc3 = _build()
    inj = FaultInjector(g3)
    svc3.on_advance = inj.tick
    warm = svc3.fetch("bulk")
    contrib = sorted(
        warm.per_replica, key=lambda u: (warm.per_replica[u], u), reverse=True
    )
    slow_ep, kill_ep = contrib[0], contrib[1]
    g3.endpoints[slow_ep].degradation = 0.25
    inj.schedule_event(
        FaultEvent(g3.clock.now() + 0.25 * s_free, "kill", kill_ep)
    )
    faulted, us_f = _timed_fetch(svc3)
    assert faulted.payload == DATA, "striped read corrupted under faults"
    assert not g3.endpoints[kill_ep].alive, "kill did not land mid-transfer"
    rows.append(("transfer_faulted_MBps", us_f, faulted.bandwidth / 1e6))
    rows.append(("transfer_fault_inflation", 0.0, faulted.seconds / s_free))
    rows.append(
        (
            "transfer_fault_recovery_events",
            0.0,
            float(
                faulted.failovers
                + faulted.hedges
                + faulted.retries
                + int(svc3._c_steals.value)
            ),
        )
    )

    # -- legacy single-source under the same kill: must fail ------------------
    g4, _, _ = _build()
    inj4 = FaultInjector(g4)
    xfer4 = g4.transfer_service()
    pfn4 = next(p for p in g4.catalog.lookup("bulk") if p.endpoint == kill_ep)
    inj4.schedule_event(FaultEvent(g4.clock.now() + 0.05 * s_free, "kill", kill_ep))
    legacy_failed = 0.0
    try:
        for _ev in xfer4.transfer_chunks(TransferRequest(pfn4, "client://bench")):
            inj4.tick()
    except TransferFailure:
        legacy_failed = 1.0
    rows.append(("transfer_legacy_fails_under_kill", 0.0, legacy_failed))
    return rows
