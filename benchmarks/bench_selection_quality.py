"""Selection quality: broker policies vs naive baselines.

The paper's criterion is "access speed" (§2.2). On a heterogeneous grid
(zones, per-pair path fingerprints, diurnal load, noise), we fetch a
replicated file repeatedly from one client under five policies:

  random       — uniform replica choice (no information service)
  round_robin  — rotate replicas
  static       — rank by published diskTransferRate only (no history)
  last         — rank by lastRDBandwidth (the paper's Figure-5 heuristic)
  predicted    — rank by EWMA per-source history with static fallback
                 (GridSelect default; the paper's §3.2 + NWS direction)

Rows: (policy, µs/fetch *simulated*, derived = mean achieved MB/s).
The paper's qualitative claim — history beats static, static beats blind —
is checked by benchmarks/run.py (predicted ≥ random required).
"""

import numpy as np

from repro.core.broker import default_read_request
from repro.core.transferplan import TransferRequest
from repro.storage.endpoint import build_demo_grid

N_FETCH = 60
FILE_MB = 8


def _run_policy(policy: str, seed: int) -> float:
    grid = build_demo_grid(10, 5, seed=seed)
    grid.add_client("client://host", zone="zone1")
    data = b"x" * (FILE_MB << 20)
    eps = grid.alive_endpoints()
    grid.replicate("f", data, [eps[0], eps[3], eps[6], eps[9]])
    broker = grid.broker_for("client://host")
    xfer = grid.transfer_service()
    replicas = grid.catalog.lookup("f")

    bws = []
    for i in range(N_FETCH):
        if policy == "random":
            rng = np.random.default_rng(seed * 1000 + i)
            pfn = replicas[int(rng.integers(0, len(replicas)))]
            res = xfer.transfer(TransferRequest(pfn, "client://host"))
            bws.append(res.bandwidth)
        elif policy == "round_robin":
            pfn = replicas[i % len(replicas)]
            res = xfer.transfer(TransferRequest(pfn, "client://host"))
            bws.append(res.bandwidth)
        else:
            req = default_read_request("client://host", rank={
                "static": "static", "last": "last", "predicted": "predicted",
            }[policy])
            out = broker.fetch("f", xfer, req, monitor_stragglers=False)
            bws.append(out.bandwidth)
    return float(np.mean(bws))


def run():
    rows = []
    results = {}
    for policy in ("random", "round_robin", "static", "last", "predicted"):
        vals = [_run_policy(policy, seed) for seed in (1, 2, 3)]
        mbps = np.mean(vals) / 1e6
        results[policy] = mbps
        per_fetch_us = FILE_MB * 1024 * 1024 / (mbps * 1e6) * 1e6
        rows.append((f"selection_{policy}", per_fetch_us, mbps))
    rows.append((
        "selection_gain_predicted_vs_random",
        0.0,
        results["predicted"] / results["random"],
    ))
    rows.append((
        "selection_gain_predicted_vs_static",
        0.0,
        results["predicted"] / results["static"],
    ))
    return rows
