"""Benchmark harness: one module per experimental axis of the paper.

Prints ``name,us_per_call,derived`` CSV (one row per measurement) and
checks the paper's qualitative claims hold quantitatively:

  * ClassAd matchmaking scales (columnar/kernel >= 10x interpreter @10k ads),
  * LDIF->ClassAd conversion is cheap (§6),
  * history-based selection beats blind/static selection (§3.2),
  * the adaptive predictor has bounded regret vs the per-trace best (§7),
  * the information plane's TTL caching pays (§3.1),
  * the data plane survives failover/straggler injection.

Usage: PYTHONPATH=src python -m benchmarks.run [--only <prefix>]
"""

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="run only benches whose module name contains this")
    args = ap.parse_args()

    from . import (
        bench_gris,
        bench_kernels,
        bench_matchmaking,
        bench_pipeline,
        bench_predictors,
        bench_selection_quality,
    )

    modules = {
        "matchmaking": bench_matchmaking,
        "selection_quality": bench_selection_quality,
        "predictors": bench_predictors,
        "gris": bench_gris,
        "pipeline": bench_pipeline,
        "kernels": bench_kernels,
    }

    rows = []
    failures = []
    for name, mod in modules.items():
        if args.only and args.only not in name:
            continue
        try:
            rows.extend(mod.run())
        except Exception as e:  # pragma: no cover
            failures.append((name, e))
            traceback.print_exc()

    print("name,us_per_call,derived")
    derived = {}
    for name, us, d in rows:
        derived[name] = d
        print(f"{name},{us:.2f},{d:.4f}")

    # ---- claim checks (reported on stderr; nonzero exit on inversions) ----
    checks = []
    if "match_speedup_steady_vs_interp_s10000" in derived:
        checks.append(("steady-state columnar >=10x interpreter @10k ads",
                       derived["match_speedup_steady_vs_interp_s10000"] >= 10))
    if "selection_gain_predicted_vs_random" in derived:
        checks.append(("history-based selection beats random",
                       derived["selection_gain_predicted_vs_random"] >= 1.0))
    if "gris_ttl_cache_speedup" in derived:
        checks.append(("GRIS TTL caching pays", derived["gris_ttl_cache_speedup"] >= 1.0))
    for trace in ("diurnal", "noisy_stationary", "regime_shift"):
        k = f"pred_adaptive_regret_{trace}"
        if k in derived:
            checks.append((f"adaptive regret bounded ({trace})", derived[k] <= 1.5))
    if "pipeline_failovers" in derived:
        checks.append(("pipeline survives endpoint death", derived["pipeline_failovers"] >= 0))

    bad = [c for c, ok in checks if not ok]
    for c, ok in checks:
        print(f"# CHECK {'PASS' if ok else 'FAIL'}: {c}", file=sys.stderr)
    if failures or bad:
        sys.exit(1)


if __name__ == "__main__":
    main()
