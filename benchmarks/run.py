"""Benchmark harness: one module per experimental axis of the paper.

Prints ``name,us_per_call,derived`` CSV (one row per measurement) and
checks the paper's qualitative claims hold quantitatively:

  * ClassAd matchmaking scales (columnar/kernel >= 10x interpreter @10k ads),
  * LDIF->ClassAd conversion is cheap (§6),
  * history-based selection beats blind/static selection (§3.2),
  * the adaptive predictor has bounded regret vs the per-trace best (§7),
  * the information plane's TTL caching pays (§3.1),
  * the data plane survives failover/straggler injection,
  * striped+hedged TransferPlan execution holds <=1.5x fault-free wall
    time under a mid-transfer kill + 4x degrade, where the legacy
    single-source read fails outright.

Usage: PYTHONPATH=src python -m benchmarks.run [--only <prefix>] [--json [PATH]]

``--json`` additionally writes the rows + claim checks to
``BENCH_matchmaking.json`` (or PATH) so the perf trajectory accumulates
run over run instead of living only in CI logs.
"""

import argparse
import gc
import json
import sys
import time
import traceback


def main() -> None:
    # Allocation-heavy benches otherwise measure CPython's collector more
    # than the code under test: once jax is imported, its XLA gc callback
    # runs on EVERY collection (~170µs each), and the default 700-alloc
    # gen0 threshold fires one per ~17 converted LDIF entries. Rarer
    # collections, identical semantics.
    gc.set_threshold(100_000, 50, 50)
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="run only benches whose module name contains this")
    ap.add_argument(
        "--json",
        nargs="?",
        const="BENCH_matchmaking.json",
        default=None,
        metavar="PATH",
        help="write rows + checks as JSON (default: BENCH_matchmaking.json)",
    )
    ap.add_argument(
        "--metrics-out",
        default=None,
        metavar="PATH",
        help="write the bench results as an obs metrics registry snapshot "
             "(JSON + Prometheus exposition)",
    )
    ap.add_argument(
        "--trace-out",
        default=None,
        metavar="PATH",
        help="write a Chrome trace (one span per bench module; Perfetto)",
    )
    args = ap.parse_args()

    from . import (
        bench_analysis,
        bench_gris,
        bench_kernels,
        bench_matchmaking,
        bench_pipeline,
        bench_predictors,
        bench_selection_quality,
        bench_sharded,
        bench_transfer,
    )

    modules = {
        "matchmaking": bench_matchmaking,
        "selection_quality": bench_selection_quality,
        "predictors": bench_predictors,
        "gris": bench_gris,
        "pipeline": bench_pipeline,
        "kernels": bench_kernels,
        "transfer": bench_transfer,
        "analysis": bench_analysis,
        "sharded": bench_sharded,
    }

    from repro.obs import Tracer

    tracer = Tracer()
    rows = []
    failures = []
    for name, mod in modules.items():
        if args.only and args.only not in name:
            continue
        try:
            with tracer.span(f"bench.{name}"):
                rows.extend(mod.run())
        except Exception as e:  # pragma: no cover
            failures.append((name, e))
            traceback.print_exc()

    print("name,us_per_call,derived")
    derived = {}
    for name, us, d in rows:
        derived[name] = d
        print(f"{name},{us:.2f},{d:.4f}")

    # ---- claim checks (reported on stderr; nonzero exit on inversions) ----
    checks = []
    if "match_speedup_steady_vs_interp_s10000" in derived:
        checks.append(("steady-state columnar >=10x interpreter @10k ads",
                       derived["match_speedup_steady_vs_interp_s10000"] >= 10))
    if "match_batched_vs_sequential_b64_s10k" in derived:
        checks.append(("batched B=64 engine >=5x sequential columnar-steady @10k ads",
                       derived["match_batched_vs_sequential_b64_s10k"] >= 5))
    if "selection_gain_predicted_vs_random" in derived:
        checks.append(("history-based selection beats random",
                       derived["selection_gain_predicted_vs_random"] >= 1.0))
    if "gris_ttl_cache_speedup" in derived:
        checks.append(("GRIS TTL caching pays", derived["gris_ttl_cache_speedup"] >= 1.0))
    for trace in ("diurnal", "noisy_stationary", "regime_shift"):
        k = f"pred_adaptive_regret_{trace}"
        if k in derived:
            checks.append((f"adaptive regret bounded ({trace})", derived[k] <= 1.5))
    if "pipeline_failovers" in derived:
        checks.append(("pipeline survives endpoint death", derived["pipeline_failovers"] >= 0))
    if "transfer_fault_inflation" in derived:
        checks.append(("striped+hedged read <=1.5x fault-free time under kill+degrade",
                       derived["transfer_fault_inflation"] <= 1.5))
    if "transfer_legacy_fails_under_kill" in derived:
        checks.append(("legacy single-source read dies where striped read survives",
                       derived["transfer_legacy_fails_under_kill"] == 1.0))
    if "transfer_striped_vs_single_speedup" in derived:
        checks.append(("striping over comparable replicas beats single-source",
                       derived["transfer_striped_vs_single_speedup"] >= 1.0))
    if "analysis_select_overhead" in derived:
        checks.append(("broker ad_check adds <5% latency to select()",
                       derived["analysis_select_overhead"] <= 1.05))
    if "analysis_check_ad" in derived:
        checks.append(("ad analyzer checks >=1k ads/sec",
                       derived["analysis_check_ad"] >= 1000))
    if "match_dense_vs_sparse_b64_s10k" in derived:
        checks.append(("dense batched fallback <=20x sparse walk @B=64 S=10k",
                       derived["match_dense_vs_sparse_b64_s10k"] <= 20))
    if "gris_ldif_entries_per_sec" in derived:
        checks.append(("LDIF->ClassAd ingest >=100k entries/sec",
                       derived["gris_ldif_entries_per_sec"] >= 100_000))
    if "sharded_vs_flat_columnar_b64_s100k_g8" in derived:
        checks.append(("sharded steady state >=5x flat columnar-steady @S=100k G=8",
                       derived["sharded_vs_flat_columnar_b64_s100k_g8"] >= 5))
    if "sharded_delta_vs_full_repush_s100k" in derived:
        checks.append(("1% delta refresh >=10x faster than full epoch re-push @S=100k",
                       derived["sharded_delta_vs_full_repush_s100k"] >= 10))

    bad = [c for c, ok in checks if not ok]
    for c, ok in checks:
        print(f"# CHECK {'PASS' if ok else 'FAIL'}: {c}", file=sys.stderr)

    if args.json:
        payload = {
            "timestamp": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
            "only": args.only,
            "rows": [
                {"name": name, "us_per_call": round(us, 2), "derived": d}
                for name, us, d in rows
            ],
            "checks": [{"name": c, "pass": bool(ok)} for c, ok in checks],
            "failures": [name for name, _ in failures],
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
        print(f"# wrote {args.json}", file=sys.stderr)

    if args.metrics_out:
        from repro.obs import MetricsRegistry

        reg = MetricsRegistry(max_label_sets=1024)
        for name, us, d in rows:
            reg.gauge("bench_us_per_call", "microseconds per call",
                      bench=name).set(us)
            reg.gauge("bench_derived", "bench-specific derived figure",
                      bench=name).set(d)
        for c, ok in checks:
            reg.gauge("bench_check_pass", "1 if the paper-claim check held",
                      check=c).set(1.0 if ok else 0.0)
        reg.dump_json(args.metrics_out, extra={"only": args.only})
        print(f"# wrote {args.metrics_out}", file=sys.stderr)

    if args.trace_out:
        tracer.dump_json(args.trace_out)
        print(f"# wrote {args.trace_out}", file=sys.stderr)

    if failures or bad:
        sys.exit(1)


if __name__ == "__main__":
    main()
