"""End-to-end data-plane benchmark: broker-driven shard delivery.

Measures simulated delivered bandwidth of the training input pipeline
(fetch + decode + batch) under three conditions: healthy grid, one dead
endpoint (failover), and a degraded top replica (straggler re-selection).
derived = delivered MB/s of simulated transfer time.
"""

import numpy as np

from repro.data.datasets import ShardManifest, SyntheticCorpus, materialize_on_grid
from repro.data.pipeline import BatchSpec, DataPipeline
from repro.storage.endpoint import build_demo_grid
from repro.storage.faults import FaultInjector


def _build(seed=0):
    grid = build_demo_grid(8, 4, seed=seed)
    grid.add_client("client://h0", zone="zone0")
    man = ShardManifest("bench", 12, tokens_per_shard=100_000, vocab_size=50257, seed=seed)
    materialize_on_grid(SyntheticCorpus(man), grid, replication=2)
    pipe = DataPipeline("client://h0", 0, 1, grid, man, BatchSpec(8, 512), cache_shards=0)
    # shards are ~400 KB; straggler detection watches per-chunk bandwidth,
    # so use 64 KB chunks (≥6 chunks/transfer) like a WAN-tuned GridFTP
    pipe.transfer.config.chunk_bytes = 64 << 10
    return grid, man, pipe


def _drain(pipe, n_batches=40):
    it = pipe.batches(0)
    for i, _ in enumerate(it):
        if i >= n_batches:
            break
    secs = max(pipe.stats["fetch_seconds"], 1e-9)
    return pipe.stats["bytes"] / secs / 1e6, pipe.stats


def run():
    rows = []

    grid, man, pipe = _build()
    mbps, stats = _drain(pipe)
    rows.append(("pipeline_healthy_MBps", stats["fetch_seconds"] * 1e6 / max(stats["fetches"], 1), mbps))

    # find the endpoint the broker actually prefers, then kill it
    grid, man, pipe = _build()
    used = pipe.broker.select(man.lfn(0))[0].pfn.endpoint
    # flaky=1.0: alive at search time, fails at transfer time ⇒ true
    # Access-Phase failover (a dead endpoint is filtered in Search)
    FaultInjector(grid).flaky(used, 1.0)
    mbps_f, stats_f = _drain(pipe)
    rows.append(("pipeline_with_flaky_best_MBps", 0.0, mbps_f))
    rows.append(("pipeline_failovers", 0.0, float(pipe.broker.stats["failovers"])))

    grid, man, pipe = _build()
    # warm local history first (≥3 observed transfers) so the broker can
    # predict a baseline bandwidth, then degrade the preferred endpoint
    # ⇒ observed ≪ predicted ⇒ mid-transfer switch
    for s in range(4):
        pipe.broker.fetch(man.lfn(s), pipe.transfer)
    used = pipe.broker.select(man.lfn(1))[0].pfn.endpoint
    FaultInjector(grid).degrade(used, 0.02)
    pipe._cache.clear()
    mbps_s, stats_s = _drain(pipe)
    rows.append(("pipeline_with_straggler_best_MBps", 0.0, mbps_s))
    rows.append(("pipeline_straggler_switches", 0.0, float(pipe.broker.stats["straggler_switches"])))
    return rows
