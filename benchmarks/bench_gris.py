"""Information-plane performance: GRIS query latency, GIIS fan-out,
TTL-cache effectiveness (§3.1's shell-backend/caching trade-off)."""

import time

import numpy as np

from repro.core.giis import GIIS
from repro.core.gris import Clock
from repro.core.transferplan import TransferRequest
from repro.storage.endpoint import build_demo_grid


def _time(fn, reps):
    fn()
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e6


def run():
    rows = []
    grid = build_demo_grid(64, 8, seed=0)
    grid.add_client("client://c", zone="zone0")
    # warm bandwidth children so the DIT has all three object classes
    data = b"z" * (1 << 20)
    for i, ep in enumerate(grid.alive_endpoints()[:16]):
        grid.store_replica(f"warm-{i}", ep, data)
        pfn = grid.catalog.lookup(f"warm-{i}")[0]
        grid.transfer_service().transfer(TransferRequest(pfn, "client://c"))

    ep0 = grid.endpoints[grid.alive_endpoints()[0]]
    # Model the paper's shell-backend cost: the OpenLDAP backends exec'd
    # scripts (statvfs / df) per query. Simulated endpoint providers are
    # trivial lambdas, so attach one realistically-priced provider.
    _work = np.arange(20000)

    def statvfs_like():
        return float(_work.sum() % (1 << 40))  # ~10s of µs of syscall-ish work

    ep0.gris.register_dynamic("availableSpace", statvfs_like, ttl=5.0)

    # GRIS direct query (drill-down), dynamic attrs cached within TTL
    us = _time(lambda: ep0.gris.search("(objectClass=Grid::Storage::ServerVolume)"), 200)
    rows.append(("gris_query_cached", us, 1e6 / us))

    # TTL expiry forces provider re-execution every query (worst case)
    def cold():
        grid.clock.advance(10)
        return ep0.gris.search("(objectClass=Grid::Storage::ServerVolume)")

    us_cold = _time(cold, 200)
    rows.append(("gris_query_cold", us_cold, 1e6 / us_cold))
    rows.append(("gris_ttl_cache_speedup", 0.0, us_cold / us))

    # GIIS broad search across 64 registrants (cached snapshots)
    us = _time(lambda: grid.giis.search("(availableSpace>=1)"), 20)
    rows.append(("giis_broad_64ep", us, 64 / us * 1e6))

    # discovery (broad → drill-down handles)
    us = _time(lambda: grid.giis.discover("(zone=zone3)"), 20)
    rows.append(("giis_discover_64ep", us, 64 / us * 1e6))

    # flattened-view construction (what the broker converts per replica)
    us = _time(lambda: ep0.gris.flattened_view(source="client://c"), 200)
    rows.append(("gris_flattened_view", us, 1e6 / us))

    # LDIF entry → ClassAd ingest over realistic flattened views (the
    # broker's per-row snapshot cost; derived = entries/sec)
    from repro.core.ldif import entry_to_classad

    entries = [
        grid.endpoints[ep].gris.flattened_view(source="client://c")
        for ep in grid.alive_endpoints()
    ]
    us = _time(lambda: [entry_to_classad(e) for e in entries], 50)
    rows.append(("gris_ldif_entries_per_sec", us, len(entries) / us * 1e6))
    return rows
