"""Static-analysis throughput + broker-side validation overhead.

Two questions the analysis subsystem must answer to ship as an always-on
gate:

  * how fast does the ClassAd analyzer check ads? (``analysis_ads_per_sec``
    — a GIIS-scale sweep revalidating thousands of capability ads must be
    interactive), and
  * what does ``ad_check="warn"`` cost a broker ``select()``? The analyzer
    memoizes per distinct ad source, so the steady state is one dict
    lookup; ``analysis_select_overhead`` is the warn/off latency ratio on
    the bench_matchmaking request, gated at <= 1.05 (the <5% claim).

Rows: (name, µs/call, derived).
"""

from repro.analysis import build_report, check_ad_text, lint_source
from repro.core.classads import parse_classad
from repro.storage.endpoint import build_demo_grid

from .bench_matchmaking import REQUEST_SRC, _time

RESOURCE_SRC = """
objectClass = "Grid::Storage::ServerVolume";
mountPoint = "/homes";
totalSpace = 50G;
availableSpace = 20G;
diskTransferRate = 75K;
drdTime = 10.5;
dwrTime = 11.5;
requirements = other.reqdSpace <= 10G;
"""

LINT_SRC = '''
import math

def backoff(attempt, base=0.25):
    """Bounded, jitter-free: the analyzer walks this in microseconds."""
    for i in range(attempt):
        base = min(base * 2, 8.0)
    return base
'''


def _grid():
    g = build_demo_grid(8, 4, seed=11)
    g.add_client("client://bench", zone="zone1")
    g.replicate(
        "blob-0", b"b" * (1 << 20),
        ["gsiftp://ep000", "gsiftp://ep003", "gsiftp://ep005"],
    )
    return g


#: bench_matchmaking's request shape, grounded on attributes the demo
#: grid publishes before any transfer history exists
SELECT_SRC = """
reqdSpace = 1G;
rank = other.diskTransferRate;
requirements = other.availableSpace >= my.reqdSpace;
"""


def _select_us(g, ad_check, reps=200):
    b = g.broker_for("client://bench", ad_check=ad_check)
    req = parse_classad(SELECT_SRC)
    # min-of-3 timed batches: the overhead claim compares two ~100µs paths,
    # so a single noisy batch must not decide the gate
    return min(_time(lambda: b.select("blob-0", req), reps) for _ in range(3))


def run():
    rows = []

    # ---- analyzer throughput: mixed request + resource ads ----
    n = 200
    sources = [
        REQUEST_SRC.replace("5G", f"{4 + i % 4}G") if i % 2 == 0
        else RESOURCE_SRC.replace("20G", f"{16 + i % 8}G")
        for i in range(n)
    ]
    us_batch = _time(lambda: [check_ad_text(s) for s in sources], 3)
    us_ad = us_batch / n
    rows.append(("analysis_check_ad", us_ad, 1e6 / us_ad))  # ads/sec

    # ---- repo lint throughput on a representative module ----
    us_lint = _time(lambda: lint_source(LINT_SRC, "repro/storage/backoff.py"), 20)
    rows.append(("analysis_lint_module", us_lint, 1e6 / us_lint))

    # ---- broker-side validation overhead on select() ----
    g = _grid()
    us_off = _select_us(g, "off")
    us_warn = _select_us(g, "warn")
    rows.append(("analysis_select_off", us_off, 1e6 / us_off))
    rows.append(("analysis_select_warn", us_warn, 1e6 / us_warn))
    rows.append(("analysis_select_overhead", 0.0, us_warn / us_off))
    return rows
