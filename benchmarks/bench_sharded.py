"""Sharded GIIS-scale matchmaking (DESIGN.md §9): per-shard walk +
hierarchical merge throughput at federation scale, and the delta-refresh
claim — a 1% single-site update must not cost a full snapshot rebuild.

Scenario: S=100k replica rows over G=8 registrant shards. Steady state
(snapshot resident, rank orders warm, plans lowered) answers B=64
requests per call through :func:`sharded_sparse_topk`; the flat
comparison is the sequential columnar steady state at the same S —
exactly the pair the ``sharded_vs_flat_columnar_b64_s100k_g8`` claim
check gates (>=5x throughput). Delta refresh re-pushes ONE dirty shard
(1% of rows updated, all in shard 0) and is gated >=10x faster than the
flat full epoch re-push at equal S.

Rows: (name, µs/call, derived — request·rows/sec for throughput rows,
ratio for the *_vs_* rows).
"""

import time

import numpy as np

from repro.core.classads import parse_classad
from repro.core.compile import build_columns, compile_program
from repro.core.plancache import PlanCache
from repro.core.snapshot import ReplicaSnapshot
from repro.core.snapshot_sharded import ShardedSnapshot
from repro.kernels.matchrank.sharded import sharded_sparse_topk
from repro.kernels.matchrank.sparse import canonicalize_plans

S = 100_000
G = 8
B = 64

REQUEST_SRC = """
reqdSpace = 5G;
rank = other.AvgRDBandwidth;
requirements = other.availableSpace > 5G && other.MaxRDBandwidth >= 50K;
"""

NAMES = ["availablespace", "maxrdbandwidth", "avgrdbandwidth", "loadfactor"]


def make_shard_entries(s=S, g=G, seed=0):
    rng = np.random.default_rng(seed)
    cols = np.stack(
        [
            rng.uniform(0, 20 * 1024**3, s),
            rng.uniform(0, 200 * 1024, s),
            rng.uniform(0, 100e6, s),
            rng.uniform(0, 8, s),
        ],
        axis=1,
    )
    per = s // g
    out = {}
    for gi in range(g):
        rows = []
        for li in range(per):
            i = gi * per + li
            e = {"endpoint": f"gsiftp://site{gi}/ep{li:05d}"}
            e.update({n: float(cols[i, j]) for j, n in enumerate(NAMES)})
            rows.append(e)
        out[f"shard-{gi:03d}"] = rows
    return out


def _time(fn, reps, *, max_warm=3, tol=0.25):
    prev = None
    for _ in range(max_warm):
        t0 = time.perf_counter()
        fn()
        dt = time.perf_counter() - t0
        if prev is not None and abs(dt - prev) <= tol * max(dt, prev):
            break
        prev = dt
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e6  # µs


def run():
    rows = []
    shard_entries = make_shard_entries()
    snap = ShardedSnapshot(shard_entries)
    assert snap.n == S and snap.g == G

    pc = PlanCache()
    batch = [
        parse_classad(REQUEST_SRC.replace("5G", f"{4 + i % 4}G")) for i in range(B)
    ]
    plans = [pc.kernel_plan(r, snap.vocab_key()) for r in batch]
    iv = canonicalize_plans(plans, len(snap.attr_names))
    assert iv is not None
    shards = [snap.shard_logical_columns(gi) for gi in range(G)]

    def sharded():
        return sharded_sparse_topk(
            shards, iv, k=1, offsets=snap.offsets, rank_order=snap.shard_rank_order
        )

    us_sh = _time(sharded, 20)
    rows.append((f"sharded_steady_b{B}_s100k_g{G}", us_sh, B * S / us_sh * 1e6))

    # flat columnar steady state at the same S: program compiled once,
    # columns built once, one request matched+ranked per call
    flat_entries = [e for nm in sorted(shard_entries) for e in shard_entries[nm]]
    present = {n for e in flat_entries[:64] for n in (k.lower() for k in e)}
    prog = compile_program(batch[0], column_names=lambda n: n in present)
    tbl = build_columns(flat_entries, sorted(present))

    def flat_steady():
        mask, rank = prog.run(tbl, np)
        return int(np.argmax(np.where(mask, rank, -np.inf)))

    us_flat = _time(flat_steady, 20)
    rows.append(("flat_columnar_steady_s100k", us_flat, S / us_flat * 1e6))
    rows.append(
        (f"sharded_vs_flat_columnar_b{B}_s100k_g{G}", 0.0, B * us_flat / us_sh)
    )

    # ---- delta refresh: 1% of rows (one site's dynamic attrs) vs the
    # flat full epoch re-push at equal S ----
    # payload generation is the information plane's job, not the
    # snapshot's — precomputed outside the timed region; gc is paused for
    # both sides (the ~200k resident entry dicts make collection pauses
    # dominate otherwise, equally unfairly for either path)
    import gc
    import itertools

    rng = np.random.default_rng(1)
    update_rows = list(range(S // 100))  # 1% of rows, all inside shard 0
    payloads = itertools.cycle(
        [
            {r: {"loadFactor": float(v)} for r, v in zip(update_rows, vs)}
            for vs in rng.uniform(0, 8, (4, len(update_rows)))
        ]
    )

    def delta():
        snap.update_rows(next(payloads))

    flat_snap = ReplicaSnapshot(flat_entries)

    def full_repush():
        return flat_snap.new_epoch(flat_entries)

    gc.collect()
    gc.disable()
    try:
        us_delta = _time(delta, 5)
        us_full = _time(full_repush, 2, max_warm=1)
    finally:
        gc.enable()
    rows.append(
        ("sharded_delta_refresh_1pct_s100k", us_delta, len(update_rows) / us_delta * 1e6)
    )
    rows.append(("flat_full_repush_s100k", us_full, S / us_full * 1e6))
    rows.append(("sharded_delta_vs_full_repush_s100k", 0.0, us_full / us_delta))
    return rows
