"""h2o-danube-3-4b — dense LM, llama+mistral mix with sliding-window attention.

24L, d_model=3840, 32 heads / 8 KV heads, d_ff=10240, vocab=32000.
[arXiv:2401.16818; unverified]
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="h2o-danube3-4b",
    family="dense",
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    d_ff=10240,
    vocab_size=32000,
    activation="silu",
    glu=True,
    norm="rmsnorm",
    rope_theta=10000.0,
    sliding_window=4096,  # SWA ⇒ bounded KV ⇒ runs long_500k
    notes="sliding-window attention; sub-quadratic decode",
))
