"""nemotron-4-340b — dense LM, GQA kv=8, squared-ReLU MLP.

96L, d_model=18432, 96 heads / 8 KV heads, d_ff=73728, vocab=256000.
[arXiv:2402.16819; unverified]
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="nemotron-4-340b",
    family="dense",
    n_layers=96,
    d_model=18432,
    n_heads=96,
    n_kv_heads=8,
    d_ff=73728,
    vocab_size=256000,
    activation="relu2",  # squared ReLU
    glu=False,
    norm="layernorm",
    rope_theta=10000.0,
    remat="nested",  # two-level √L remat: 96 residual saves do not fit v5e
    notes="squared-ReLU non-gated MLP; the 340B memory stress test",
))
