"""llava-next-34b — VLM backbone (anyres tiling frontend stubbed).

60L, d_model=7168, 56 heads / 8 KV heads, d_ff=20480, vocab=64000.
Modality frontend is a STUB: ``input_specs()`` supplies precomputed patch
embeddings spliced ahead of the text embeddings.
[hf:llava-hf/llava-v1.6 (family); unverified]
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab_size=64000,
    activation="silu",
    glu=True,
    norm="rmsnorm",
    rope_theta=5000000.0,
    n_patches=2880,  # anyres budget
    notes="Yi-34B-style backbone; patch embeddings precomputed",
))
