"""moonshot-v1-16b-a3b — MoE LM (kimi/moonlight style), 64 experts top-6.

48L, d_model=2048, 16 heads (kv=16 ⇒ MHA), expert d_ff=1408, vocab=163840.
[hf:moonshotai/Moonlight-16B-A3B; hf]
"""
from .base import ArchConfig, MoEConfig, register

CONFIG = register(ArchConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,           # per-expert FFN width
    vocab_size=163840,
    activation="silu",
    glu=True,
    norm="rmsnorm",
    rope_theta=50000.0,
    moe=MoEConfig(n_experts=64, top_k=6, d_ff_expert=1408,
                  every_k_layers=1, moe_offset=0),
    notes="every layer MoE; large vocab",
))
