"""whisper-base — encoder-decoder audio backbone (conv frontend stubbed).

6 encoder + 6 decoder layers, d_model=512, 8 heads, d_ff=2048,
vocab=51865. The conv frontend is a STUB: ``input_specs()`` supplies
precomputed frame embeddings (1500 frames = 30 s). [arXiv:2212.04356]
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="whisper-base",
    family="audio",
    n_layers=6,           # decoder layers
    n_enc_layers=6,
    enc_dec=True,
    enc_seq=1500,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    activation="gelu",
    glu=False,
    norm="layernorm",
    positional="learned",
    qkv_bias=True,
    notes="enc-dec; decode shapes run (decoder KV + cross-attn cache)",
))
