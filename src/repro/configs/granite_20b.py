"""granite-20b — dense code LM, llama-arch with MQA (kv=1).

52L, d_model=6144, 48 heads (GQA kv=1 ⇒ multi-query), d_ff=24576,
vocab=49152. [arXiv:2405.04324; hf]
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="granite-20b",
    family="dense",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    activation="gelu",
    glu=False,  # GPT-BigCode-style 4x MLP (matches the 20B param count)
    norm="rmsnorm",
    rope_theta=10000.0,
    notes="code LM; multi-query attention (single KV head)",
))
