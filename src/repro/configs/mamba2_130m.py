"""mamba2-130m — pure SSM (state-space duality), attention-free.

24L, d_model=768, ssm_state=128, vocab=50280. [arXiv:2405.21060]
"""
from .base import ArchConfig, SSMConfig, register

CONFIG = register(ArchConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=1,            # attention-free; unused
    n_kv_heads=1,
    d_ff=0,               # no MLP blocks in mamba2
    vocab_size=50280,
    norm="rmsnorm",
    positional="none",
    tie_embeddings=True,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=256),
    notes="SSD chunked scan; O(1) decode state ⇒ runs long_500k",
))
