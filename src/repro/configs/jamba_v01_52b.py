"""jamba-v0.1-52b — hybrid Mamba+attention (1:7) with interleaved MoE.

32L, d_model=4096, 32 heads / 8 KV heads, d_ff=14336, vocab=65536,
MoE 16 experts top-2 on every other layer; attention once per 8 layers
(offset 4). [arXiv:2403.19887; hf]
"""
from .base import ArchConfig, MoEConfig, SSMConfig, register

# period of 8: mamba everywhere except slot 4 (HF: attn_layer_period=8,
# attn_layer_offset=4)
_PATTERN = tuple("a" if i == 4 else "m" for i in range(8))

CONFIG = register(ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    activation="silu",
    glu=True,
    norm="rmsnorm",
    positional="none",  # Jamba uses no positional encoding
    layer_pattern=_PATTERN,
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=14336,
                  every_k_layers=2, moe_offset=1),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=64, chunk=256),
    notes="paper uses Mamba-1 blocks; we lower both hybrid+ssm archs "
          "through the SSD (Mamba-2) formulation (DESIGN.md §5)",
))
