"""mistral-nemo-12b — dense LM, GQA kv=8, explicit head_dim=128, 128k ctx.

40L, d_model=5120, 32 heads / 8 KV heads, d_ff=14336, vocab=131072.
[hf:mistralai/Mistral-Nemo-Base-2407; hf]
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="mistral-nemo-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,  # decoupled from d_model/n_heads (=160) per the HF config
    d_ff=14336,
    vocab_size=131072,
    activation="silu",
    glu=True,
    norm="rmsnorm",
    rope_theta=1000000.0,
    max_seq=131072,
    notes="128k context; tekken tokenizer vocab",
))
