"""Architecture configuration system + registry.

One :class:`ArchConfig` describes everything the model stack, sharding
policy, dry-run and smoke tests need about an architecture. Each assigned
architecture contributes one module in this package registering its exact
published configuration; ``reduced()`` derives the CPU-smoke variant
(same family/topology, tiny dims).
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Sequence, Tuple

__all__ = [
    "MoEConfig",
    "SSMConfig",
    "ArchConfig",
    "register",
    "get_arch",
    "list_archs",
    "INPUT_SHAPES",
    "ShapeSpec",
]


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    every_k_layers: int = 1  # MoE on layers where (layer % every_k) == moe_offset
    moe_offset: int = 1
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    aux_loss_weight: float = 0.01


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256
    dt_min: float = 0.001
    dt_max: float = 0.1

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class ArchConfig:
    # identity
    name: str
    family: str  # dense | moe | hybrid | ssm | vlm | audio
    # transformer backbone
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default d_model // n_heads
    # layer flavour
    activation: str = "silu"  # silu | gelu | relu2
    glu: bool = True  # gated MLP (llama-style)
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    rope_theta: float = 10000.0
    positional: str = "rope"  # rope | learned | sinusoidal | none
    tie_embeddings: bool = False
    qkv_bias: bool = False
    sliding_window: Optional[int] = None  # SWA window (h2o-danube)
    logit_softcap: Optional[float] = None
    # mixture of experts
    moe: Optional[MoEConfig] = None
    # state-space layers
    ssm: Optional[SSMConfig] = None
    # hybrid stacking: one period of layer kinds ('a'=attention, 'm'=mamba),
    # tiled to n_layers. None ⇒ all 'a' (or all 'm' for family=ssm).
    layer_pattern: Optional[Tuple[str, ...]] = None
    # encoder-decoder (whisper)
    enc_dec: bool = False
    n_enc_layers: int = 0
    enc_seq: int = 1500  # encoder frames (whisper 30 s @ 50 Hz)
    # vlm
    n_patches: int = 2880  # anyres patch budget (llava-next)
    # numerics / training
    dtype: str = "bfloat16"
    remat: str = "block"  # none | block | full
    max_seq: int = 131072
    notes: str = ""

    # ---- derived -----------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.resolved_head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.resolved_head_dim

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer kind string of length n_layers."""
        if self.layer_pattern is None:
            kind = "m" if self.family == "ssm" else "a"
            return tuple(kind for _ in range(self.n_layers))
        period = len(self.layer_pattern)
        assert self.n_layers % period == 0, (self.n_layers, period)
        return tuple(self.layer_pattern[i % period] for i in range(self.n_layers))

    def is_moe_layer(self, layer_idx: int) -> bool:
        if self.moe is None:
            return False
        return layer_idx % self.moe.every_k_layers == self.moe.moe_offset % self.moe.every_k_layers

    # ---- parameter counting (roofline MODEL_FLOPS) -----------------------------
    def param_counts(self) -> Dict[str, float]:
        """Total and active parameter counts (embedding included/excluded)."""
        d, hd = self.d_model, self.resolved_head_dim
        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        mlp_mult = 3 if self.glu else 2
        dense_mlp = mlp_mult * d * self.d_ff if self.d_ff else 0
        ssm_p = 0.0
        if self.ssm is not None:
            di = self.ssm.d_inner(d)
            gn = self.ssm.n_groups * self.ssm.d_state
            nh = self.ssm.n_heads(d)
            in_proj = d * (2 * di + 2 * gn + nh)
            ssm_p = in_proj + di * d + self.ssm.d_conv * (di + 2 * gn) + 2 * nh + di
        total = 0.0
        active = 0.0
        for i, kind in enumerate(self.layer_kinds()):
            if kind == "a":
                total += attn
                active += attn
            else:
                total += ssm_p
                active += ssm_p
            if self.is_moe_layer(i):
                m = self.moe
                expert = mlp_mult * d * m.d_ff_expert
                total += m.n_experts * expert + d * m.n_experts
                active += m.top_k * expert + d * m.n_experts
            elif self.d_ff:
                total += dense_mlp
                active += dense_mlp
        # encoder stack (whisper): attn + cross-attn + mlp per enc layer
        if self.enc_dec:
            enc = (attn + dense_mlp) * self.n_enc_layers
            cross = attn * self.n_layers  # decoder cross-attention
            total += enc + cross
            active += enc + cross
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        norms = 2 * d * self.n_layers
        return {
            "total": total + norms,
            "active": active + norms,
            "embedding": emb,
            "total_with_emb": total + norms + emb,
        }

    def model_flops_per_token(self) -> float:
        """6·N_active (dense fwd+bwd rule of thumb), embeddings excluded."""
        return 6.0 * self.param_counts()["active"]

    # ---- reductions --------------------------------------------------------
    def reduced(self) -> "ArchConfig":
        """A tiny same-family config for CPU smoke tests."""
        period = len(self.layer_pattern) if self.layer_pattern else 1
        n_layers = max(2 * period, 2)
        if self.enc_dec:
            n_layers = 2
        kv = min(self.n_kv_heads, 2) if self.n_kv_heads > 1 else 1
        heads = max(4, kv)
        moe = None
        if self.moe:
            moe = replace(
                self.moe,
                n_experts=min(self.moe.n_experts, 4),
                top_k=min(self.moe.top_k, 2),
                d_ff_expert=64,
            )
        ssm = None
        if self.ssm:
            ssm = replace(self.ssm, d_state=16, head_dim=16, chunk=32)
        return replace(
            self,
            name=self.name + "-smoke",
            n_layers=n_layers,
            d_model=64,
            n_heads=heads,
            n_kv_heads=kv,
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab_size=512,
            moe=moe,
            ssm=ssm,
            n_enc_layers=2 if self.enc_dec else 0,
            enc_seq=32,
            n_patches=8,
            sliding_window=16 if self.sliding_window else None,
            max_seq=512,
            dtype="float32",  # CPU smoke: exact decode==forward checks
        )


# ---------------------------------------------------------------------------
# Input shapes (the assigned 4-shape set for LM-family archs)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ArchConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs() -> List[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


_LOADED = False


def _ensure_loaded() -> None:
    global _LOADED
    if _LOADED:
        return
    _LOADED = True
    from importlib import import_module

    for mod in (
        "granite_20b",
        "mistral_nemo_12b",
        "nemotron_4_340b",
        "h2o_danube3_4b",
        "jamba_v01_52b",
        "granite_moe_3b_a800m",
        "moonshot_v1_16b_a3b",
        "llava_next_34b",
        "whisper_base",
        "mamba2_130m",
    ):
        import_module(f"repro.configs.{mod}")
