"""granite-moe-3b-a800m — fine-grained MoE, 40 experts top-8.

32L, d_model=1536, 24 heads / 8 KV heads, expert d_ff=512, vocab=49155.
[hf:ibm-granite/granite-3.0-1b-a400m-base (family); hf]
"""
from .base import ArchConfig, MoEConfig, register

CONFIG = register(ArchConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,            # per-expert FFN width
    vocab_size=49155,
    activation="silu",
    glu=True,
    norm="rmsnorm",
    rope_theta=10000.0,
    tie_embeddings=True,
    moe=MoEConfig(n_experts=40, top_k=8, d_ff_expert=512,
                  every_k_layers=1, moe_offset=0),
    notes="every layer MoE; fine-grained small experts",
))
