"""Coalescing selection scheduler: many concurrent broker selections,
few kernel launches.

Serving replicas, data-pipeline workers, and checkpoint restores all
issue storms of small ``broker.select`` calls that hit the same published
GRIS snapshot. The :class:`BatchScheduler` queues them and flushes the
queue through :meth:`DataBroker.select_many` — one stacked
``matchrank_batched`` launch per flush — under two triggers:

  * **size**: the queue reached ``max_batch`` (a full kernel batch),
  * **latency**: the oldest queued request has waited ``max_delay``
    (checked by :meth:`poll`, driven by the injected deterministic
    clock — nothing here spawns threads),

plus an explicit :meth:`flush`, and an implicit one when a caller forces
a ticket's :meth:`~SelectionTicket.result` (a synchronous caller never
deadlocks waiting on its own unflushed batch).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, List, Optional, Sequence, Tuple

from repro.core.broker import BrokerError, DataBroker, RankedReplica
from repro.core.classads import ClassAd

__all__ = ["SelectionTicket", "BatchScheduler"]


class SelectionTicket:
    """A pending selection: filled by the scheduler at flush time."""

    def __init__(self, scheduler: "BatchScheduler", lfn: str):
        self._scheduler = scheduler
        self.lfn = lfn
        self._outcome: Any = None
        self._done = False

    def _fill(self, outcome: Any) -> None:
        self._outcome = outcome
        self._done = True

    @property
    def done(self) -> bool:
        return self._done

    def result(self) -> List[RankedReplica]:
        """The ranked list; forces a flush if still queued. Raises the
        per-request ``BrokerError`` (NoReplica/NoMatch) like ``select``."""
        if not self._done:
            self._scheduler.flush()
        if isinstance(self._outcome, BrokerError):
            raise self._outcome
        return self._outcome


class BatchScheduler:
    """Aggregates concurrent selections into batched kernel launches."""

    def __init__(
        self,
        broker: DataBroker,
        *,
        max_batch: int = 64,
        max_delay: float = 0.005,
        top_k: Optional[int] = None,
        use_kernel: Optional[bool] = None,
        clock=None,
        metrics=None,
    ):
        self.broker = broker
        self.max_batch = int(max_batch)
        self.max_delay = float(max_delay)
        self.top_k = top_k
        self.use_kernel = use_kernel
        self.clock = clock if clock is not None else broker.clock
        self._pending: List[Tuple[str, Optional[ClassAd], SelectionTicket]] = []
        self._oldest_at: Optional[float] = None
        self.stats = {
            "submitted": 0,
            "batches": 0,
            "latency_flushes": 0,
            "size_flushes": 0,
            "max_batch_seen": 0,
        }
        # obs: share the broker's registry/tracer unless told otherwise;
        # self.stats stays the source of truth for exact-count consumers
        self.metrics = metrics if metrics is not None else broker.metrics
        self.tracer = broker.tracer
        self._c_submitted = self.metrics.counter(
            "scheduler_submitted_total", "selections queued"
        )
        self._c_flush = {
            reason: self.metrics.counter(
                "scheduler_flushes_total", "queue flushes by trigger", reason=reason
            )
            for reason in ("size", "latency", "forced")
        }
        self._g_queue = self.metrics.gauge(
            "scheduler_queue_depth", "selections currently queued"
        )
        self._h_batch = self.metrics.histogram(
            "scheduler_coalesced_batch_size",
            "selections per select_many flush",
            buckets=(1, 2, 4, 8, 16, 32, 64, 128, 256, float("inf")),
        )

    # ----------------------------------------------------------- submission
    def submit(self, lfn: str, request: Optional[ClassAd] = None) -> SelectionTicket:
        """Queue one selection; may trigger a size flush."""
        ticket = SelectionTicket(self, lfn)
        if not self._pending:
            self._oldest_at = self.clock.now()
        self._pending.append((lfn, request, ticket))
        self.stats["submitted"] += 1
        self._c_submitted.inc()
        self._g_queue.set(len(self._pending))
        if len(self._pending) >= self.max_batch:
            self.stats["size_flushes"] += 1
            self.flush(reason="size")
        return ticket

    def submit_many(
        self, queries: Sequence[Tuple[str, Optional[ClassAd]]]
    ) -> List[SelectionTicket]:
        return [self.submit(lfn, req) for lfn, req in queries]

    def select(self, lfn: str, request: Optional[ClassAd] = None) -> List[RankedReplica]:
        """Synchronous convenience: submit + force the result."""
        return self.submit(lfn, request).result()

    # -------------------------------------------------------------- flushing
    def poll(self) -> bool:
        """Max-latency trigger: flush if the oldest queued selection has
        waited ``max_delay``. Returns True if a flush happened."""
        if self._pending and self.clock.now() - self._oldest_at >= self.max_delay:
            self.stats["latency_flushes"] += 1
            self.flush(reason="latency")
            return True
        return False

    def flush(self, *, reason: str = "forced") -> None:
        """Run every queued selection as one ``select_many`` batch.

        ``reason`` labels the flush trigger ("size" | "latency" |
        "forced") in the metrics registry; submit/poll pass theirs."""
        if not self._pending:
            return
        batch, self._pending = self._pending, []
        self._oldest_at = None
        self.stats["batches"] += 1
        self.stats["max_batch_seen"] = max(self.stats["max_batch_seen"], len(batch))
        self._c_flush.get(reason, self._c_flush["forced"]).inc()
        self._h_batch.observe(len(batch))
        self._g_queue.set(0)
        with self.tracer.span("scheduler.flush", batch=len(batch), reason=reason):
            outcomes = self.broker.select_many(
                [(lfn, req) for lfn, req, _ in batch],
                top_k=self.top_k,
                use_kernel=self.use_kernel,
                strict=False,
            )
        for (_, _, ticket), outcome in zip(batch, outcomes):
            ticket._fill(outcome)

    @property
    def pending(self) -> int:
        return len(self._pending)

    def coalescing_ratio(self) -> float:
        """Selections per kernel launch — the amortization factor."""
        b = self.stats["batches"]
        return self.stats["submitted"] / b if b else 0.0
