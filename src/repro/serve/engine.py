"""Batched serving engine: prefill + lockstep decode with slot reuse.

A deliberately small but real engine:

  * fixed batch of decode slots; prompts prefill into per-layer caches,
  * greedy (or temperature-0-equivalent argmax) lockstep decode with a
    jitted ``decode_step``; finished sequences (EOS / max length) are
    masked and their slots padded,
  * model weights arrive through the broker (``ServeEngine.from_grid``):
    serving replicas select the best weight-shard source exactly like the
    data pipeline selects dataset shards — the paper's mechanism applied
    to model distribution at serve time (examples/serve_lm.py). Chunk
    selections are coalesced through a :class:`BatchScheduler` into
    batched matchmaking launches instead of per-chunk broker calls.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import transformer
from repro.obs import MetricsRegistry, Tracer

__all__ = ["ServeEngine", "GenerationResult"]


@dataclass
class GenerationResult:
    tokens: np.ndarray  # [B, ≤max_new]
    n_generated: np.ndarray  # [B]
    prefill_s: float
    decode_s: float

    @property
    def decode_tokens_per_s(self) -> float:
        total = int(self.n_generated.sum())
        return total / self.decode_s if self.decode_s > 0 else 0.0


class ServeEngine:
    def __init__(
        self,
        cfg: ArchConfig,
        params: Any,
        *,
        max_seq: int = 4096,
        eos_id: int = 2,
        metrics: Optional[MetricsRegistry] = None,
        tracer: Optional[Tracer] = None,
    ):
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self.eos_id = eos_id
        self._prefill = jax.jit(
            lambda p, b: transformer.prefill(p, b, cfg, max_seq=max_seq)
        )
        self._decode = jax.jit(
            lambda p, t, c, s: transformer.decode_step(p, t, c, s, cfg)
        )
        self.selection_stats: Dict[str, Any] = {}
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.tracer = tracer if tracer is not None else Tracer()
        self._h_prefill = self.metrics.histogram(
            "serve_prefill_seconds", "prompt prefill wall time per generate()"
        )
        self._h_decode = self.metrics.histogram(
            "serve_decode_seconds", "lockstep decode wall time per generate()"
        )
        self._c_tokens = self.metrics.counter(
            "serve_generated_tokens_total", "tokens emitted across generate() calls"
        )

    @classmethod
    def from_grid(
        cls,
        cfg: ArchConfig,
        manager,  # repro.checkpoint.manager.CheckpointManager
        step: int,
        template: Any,
        *,
        max_seq: int = 4096,
        eos_id: int = 2,
        max_batch: int = 64,
    ) -> "ServeEngine":
        """Build an engine whose weights are pulled through the data grid
        with *coalesced* replica selection: every checkpoint chunk's
        Search+Match runs through one BatchScheduler → ``select_many`` →
        batched matchmaking launch, then the Access Phase streams chunks
        with the usual failover. ``selection_stats`` records the
        coalescing achieved."""
        from .scheduler import BatchScheduler

        scheduler = BatchScheduler(manager.broker, max_batch=max_batch)
        params = manager.restore(step, template, scheduler=scheduler)
        # one registry/tracer across broker, scheduler, and engine: the
        # whole serve path shows up in a single exposition / trace
        engine = cls(
            cfg,
            params,
            max_seq=max_seq,
            eos_id=eos_id,
            metrics=manager.broker.metrics,
            tracer=manager.broker.tracer,
        )
        engine.selection_stats = {
            **scheduler.stats,
            "coalescing_ratio": scheduler.coalescing_ratio(),
            "batch_selects": manager.broker.stats["batch_selects"],
        }
        # when the manager pulls chunks through the resilient access layer,
        # surface how the weights actually arrived (striped? hedged? any
        # endpoint breaker-tripped mid-restore?)
        xfer = manager.transfer
        if hasattr(xfer, "breakers"):
            engine.selection_stats.update(
                stripes=int(xfer._c_stripes.value),
                hedges=int(xfer._c_hedges.value),
                hedge_wins=int(xfer._c_hedge_wins.value),
                retries=int(xfer._c_retries.value),
                stripe_failovers=int(xfer._c_stripe_failovers.value),
                breaker_open=sorted(
                    ep
                    for ep, br in xfer.breakers.breakers.items()
                    if br.state != "closed"
                ),
            )
        return engine

    def generate(
        self,
        prompts: np.ndarray,  # [B, S_prompt] int32 (left-padded with 0s allowed)
        *,
        max_new: int = 32,
        extras: Optional[Dict[str, np.ndarray]] = None,
    ) -> GenerationResult:
        b, s = prompts.shape
        batch = {"tokens": jnp.asarray(prompts, jnp.int32)}
        if extras:
            batch.update({k: jnp.asarray(v) for k, v in extras.items()})

        with self.tracer.span("serve.generate", batch=b, prompt_len=s) as gen_span:
            with self.tracer.span("serve.prefill") as prefill_span:
                logits, caches = self._prefill(self.params, batch)
                jax.block_until_ready(logits)

            tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)  # [B]
            out = [np.asarray(tokens)]
            done = np.asarray(tokens) == self.eos_id
            pos = jnp.full((b,), s, jnp.int32)
            n_gen = np.ones((b,), np.int32)

            with self.tracer.span("serve.decode", max_new=max_new) as decode_span:
                for i in range(max_new - 1):
                    logits, caches = self._decode(
                        self.params, tokens[:, None], caches, pos
                    )
                    tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                    t_np = np.asarray(tokens)
                    out.append(np.where(done, self.eos_id, t_np))
                    n_gen += (~done).astype(np.int32)
                    done |= t_np == self.eos_id
                    pos = pos + 1
                    if done.all():
                        break
            gen_span.set(generated=int(n_gen.sum()))
        self._h_prefill.observe(prefill_span.duration)
        self._h_decode.observe(decode_span.duration)
        self._c_tokens.inc(int(n_gen.sum()))
        return GenerationResult(
            tokens=np.stack(out, axis=1),
            n_generated=n_gen,
            prefill_s=prefill_span.duration,
            decode_s=decode_span.duration,
        )
