"""Selection-decision audit trail: "why was this replica chosen?".

Every :meth:`DataBroker.select`/:meth:`~DataBroker.select_many` records
one :class:`DecisionRecord` — the candidate set the Search Phase found,
how the request lowered (plan-cache hit/miss, snapshot build/reuse, which
execution tier answered it), every candidate's rank score, the chosen
replica, and — once the Access Phase runs — failovers, straggler
switches, and predicted vs. observed bandwidth. Records are retrievable
by ``request_id`` via :meth:`DataBroker.explain` and dump to JSONL for
offline analysis.

The trail is a bounded ring (``capacity``): a broker serving millions of
selections keeps the most recent window; evicted ids raise ``KeyError``
from :meth:`AuditTrail.get`.
"""

from __future__ import annotations

import json
from collections import OrderedDict
from dataclasses import asdict, dataclass, field
from typing import Any, Dict, IO, Iterable, List, Optional, Union

__all__ = ["CandidateScore", "DecisionRecord", "AuditTrail"]

#: execution tiers a selection can take (DecisionRecord.kernel_path)
PATHS = (
    "interpreter",       # per-ad ClassAd interpreter (reference semantics)
    "vectorized",        # columnar engine inside a sequential select()
    "batched_kernel",    # stacked matchrank_batched launch (Pallas / ref)
    "sparse_topk",       # rank-order sparse top-k CPU fast path
    "sharded_topk",      # per-shard walk + hierarchical merge (DESIGN.md §9)
    "batched_columnar",  # per-request columnar program over the snapshot
    "batched_interp",    # interpreter fallback inside select_many
)


@dataclass
class CandidateScore:
    """One candidate replica's fate in the Match Phase."""

    endpoint: str
    rank: Optional[float]  # None when the candidate failed requirements
    matched: bool

    def to_dict(self) -> Dict[str, Any]:
        return {"endpoint": self.endpoint, "rank": self.rank, "matched": self.matched}


@dataclass
class DecisionRecord:
    """The complete story of one selection (and its access, if any)."""

    request_id: str
    lfn: str
    mode: str  # "select" | "select_many"
    at: float  # broker clock at selection time

    # --- Match Phase ---
    kernel_path: str = ""  # one of PATHS
    candidates: List[str] = field(default_factory=list)  # endpoint urls found
    scores: List[CandidateScore] = field(default_factory=list)
    chosen: Optional[str] = None  # best-ranked endpoint url
    top_k: Optional[int] = None
    plan_cache: Optional[str] = None  # "hit" | "miss" | None (tier unused)
    snapshot: Optional[str] = None  # "build" | "reuse" | "delta" | None
    # shard indices that contributed this selection's candidates (sharded
    # snapshots only — which corners of the federation the answer touched)
    shards: List[int] = field(default_factory=list)
    error: Optional[str] = None  # BrokerError name when the selection failed
    # request-ad analyzer findings (repro.analysis Diagnostic dicts),
    # recorded when the broker runs with ad_check enabled
    ad_diagnostics: List[Dict[str, Any]] = field(default_factory=list)

    # --- Access Phase (filled by DataBroker.access) ---
    accessed: bool = False
    fetched_from: Optional[str] = None  # endpoint that served the bytes
    attempts: int = 0
    failovers: int = 0
    straggler_switches: int = 0
    predicted_bandwidth: Optional[float] = None
    observed_bandwidth: Optional[float] = None
    nbytes: Optional[int] = None

    def to_dict(self) -> Dict[str, Any]:
        d = asdict(self)
        d["scores"] = [s.to_dict() for s in self.scores]
        return d


class AuditTrail:
    """Bounded, id-addressed ring of :class:`DecisionRecord`\\ s."""

    def __init__(self, capacity: int = 1024, *, id_prefix: str = "req"):
        self.capacity = int(capacity)
        self.id_prefix = id_prefix
        self._records: "OrderedDict[str, DecisionRecord]" = OrderedDict()
        self._next = 1
        self.evicted = 0

    # ------------------------------------------------------------ creation
    def new_id(self) -> str:
        rid = f"{self.id_prefix}-{self._next:08d}"
        self._next += 1
        return rid

    def begin(self, lfn: str, *, mode: str, at: float) -> DecisionRecord:
        """Open a record (assigns the request id) and retain it."""
        rec = DecisionRecord(self.new_id(), lfn, mode, at)
        self._records[rec.request_id] = rec
        while len(self._records) > self.capacity:
            self._records.popitem(last=False)
            self.evicted += 1
        return rec

    # ------------------------------------------------------------- reading
    def get(self, request_id: str) -> DecisionRecord:
        rec = self._records.get(request_id)
        if rec is None:
            raise KeyError(
                f"no decision record for {request_id!r} "
                f"(trail keeps the last {self.capacity})"
            )
        return rec

    def records(self) -> List[DecisionRecord]:
        return list(self._records.values())

    def __len__(self) -> int:
        return len(self._records)

    def __contains__(self, request_id: str) -> bool:
        return request_id in self._records

    # -------------------------------------------------------------- export
    def dump_jsonl(self, path_or_file: Union[str, IO[str]]) -> int:
        """Write one JSON object per record; returns the record count."""
        records = self.records()
        if isinstance(path_or_file, str):
            with open(path_or_file, "w") as f:
                for rec in records:
                    f.write(json.dumps(rec.to_dict()) + "\n")
        else:
            for rec in records:
                path_or_file.write(json.dumps(rec.to_dict()) + "\n")
        return len(records)
