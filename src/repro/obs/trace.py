"""Tracing spans: nested timing scopes exportable as Chrome trace events.

A :class:`Tracer` hands out :class:`Span` scopes via a context manager or
decorator; spans nest (parent/child through an explicit stack, no
thread-locals — the repo is single-controller per host) and the finished
buffer exports as Chrome ``traceEvents`` JSON, loadable in Perfetto or
``chrome://tracing``.

Around kernel dispatch the tracer can additionally enter a
``jax.profiler.TraceAnnotation`` so spans line up with XLA's own traces
(``jax_annotations=True``); the passthrough is best-effort and degrades
to a no-op when the profiler is unavailable.

The span buffer is bounded (``max_spans``): a serving process tracing
every batch keeps the most recent window instead of growing without
bound.
"""

from __future__ import annotations

import functools
import json
import time
from collections import deque
from contextlib import contextmanager
from typing import Any, Callable, Deque, Dict, Iterator, List, Optional

__all__ = ["Span", "Tracer"]


class Span:
    """One timing scope. ``duration`` is valid after the scope exits."""

    __slots__ = ("name", "span_id", "parent_id", "depth", "t0", "t1", "args")

    def __init__(self, name: str, span_id: int, parent_id: Optional[int],
                 depth: int, t0: float, args: Dict[str, Any]):
        self.name = name
        self.span_id = span_id
        self.parent_id = parent_id
        self.depth = depth
        self.t0 = t0
        self.t1: Optional[float] = None
        self.args = args

    @property
    def duration(self) -> float:
        return (self.t1 - self.t0) if self.t1 is not None else 0.0

    def set(self, **kv: Any) -> None:
        """Attach result attributes mid-scope (batch sizes, cache hits)."""
        self.args.update(kv)

    def to_event(self, epoch: float) -> Dict[str, Any]:
        """Chrome trace-event 'complete' (ph=X) form, µs timestamps."""
        return {
            "name": self.name,
            "cat": "repro",
            "ph": "X",
            "ts": (self.t0 - epoch) * 1e6,
            "dur": self.duration * 1e6,
            "pid": 0,
            "tid": self.depth,
            "args": {"span_id": self.span_id,
                     "parent_id": self.parent_id,
                     **{k: _jsonable(v) for k, v in self.args.items()}},
        }


def _jsonable(v: Any) -> Any:
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    return repr(v)


class Tracer:
    """Span factory + bounded buffer of finished spans.

    Parameters
    ----------
    time_fn:
        Timestamp source; defaults to ``time.perf_counter``. Inject a
        deterministic clock's ``now`` for reproducible traces in tests.
    jax_annotations:
        Also enter ``jax.profiler.TraceAnnotation(name)`` for every span
        — used around kernel dispatch so broker spans appear inside
        ``jax.profiler`` traces.
    max_spans:
        Finished-span ring-buffer capacity.
    """

    def __init__(
        self,
        *,
        time_fn: Optional[Callable[[], float]] = None,
        jax_annotations: bool = False,
        max_spans: int = 8192,
    ):
        self.time_fn = time_fn or time.perf_counter  # lint: allow-wallclock
        self.jax_annotations = bool(jax_annotations)
        self._spans: Deque[Span] = deque(maxlen=int(max_spans))
        self._stack: List[Span] = []
        self._next_id = 1
        self.epoch = self.time_fn()
        self.dropped = 0

    # ------------------------------------------------------------- scoping
    @contextmanager
    def span(self, name: str, **args: Any) -> Iterator[Span]:
        parent = self._stack[-1] if self._stack else None
        s = Span(
            name,
            self._next_id,
            parent.span_id if parent else None,
            len(self._stack),
            self.time_fn(),
            dict(args),
        )
        self._next_id += 1
        self._stack.append(s)
        annotation = None
        if self.jax_annotations:
            try:
                from jax.profiler import TraceAnnotation

                annotation = TraceAnnotation(name)
                annotation.__enter__()
            except Exception:
                annotation = None
        try:
            yield s
        finally:
            if annotation is not None:
                annotation.__exit__(None, None, None)
            s.t1 = self.time_fn()
            self._stack.pop()
            if len(self._spans) == self._spans.maxlen:
                self.dropped += 1
            self._spans.append(s)

    def trace(self, name: Optional[str] = None) -> Callable:
        """Decorator form: ``@tracer.trace("phase")``."""

        def deco(fn: Callable) -> Callable:
            span_name = name or fn.__qualname__

            @functools.wraps(fn)
            def wrapper(*a, **kw):
                with self.span(span_name):
                    return fn(*a, **kw)

            return wrapper

        return deco

    # ------------------------------------------------------------- reading
    def spans(self, name: Optional[str] = None) -> List[Span]:
        if name is None:
            return list(self._spans)
        return [s for s in self._spans if s.name == name]

    def clear(self) -> None:
        self._spans.clear()
        self.dropped = 0

    @property
    def depth(self) -> int:
        return len(self._stack)

    # -------------------------------------------------------------- export
    def export_chrome(self) -> Dict[str, Any]:
        """Chrome/Perfetto ``traceEvents`` JSON object."""
        return {
            "displayTimeUnit": "ms",
            "traceEvents": [s.to_event(self.epoch) for s in self._spans],
        }

    def dump_json(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.export_chrome(), f, indent=2)
            f.write("\n")
