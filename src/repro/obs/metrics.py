"""Labeled metrics registry: Counter / Gauge / Histogram, Prometheus text.

The observability backbone for every subsystem (broker, scheduler, serve
engine, transfer service, GRIS, caches, train loop). Deliberately
dependency-free — the registry mirrors the Prometheus client-library data
model without importing it:

  * metric *families* are (kind, name, help, label names); *children* are
    one sample series per label-value tuple,
  * label sets are **bounded** per family (``max_label_sets``): once the
    cap is reached, new label values collapse into a single ``__other__``
    series instead of growing without bound (a broker fleet labels by
    endpoint/client URL, which is effectively unbounded),
  * :meth:`MetricsRegistry.expose_text` renders the standard Prometheus
    text exposition format; :meth:`to_dict`/:meth:`from_dict` round-trip
    the full registry through plain JSON for archival (bench snapshots,
    CI artifacts, GRIS publication).

Hot-path discipline: ``counter()``/``gauge()``/``histogram()`` resolve a
family + child once; callers on hot paths hold the returned object and
call ``inc()``/``observe()`` directly (an attribute add, no dict walk).
"""

from __future__ import annotations

import json
import math
import re
from bisect import bisect_left
from collections import OrderedDict
from typing import Any, Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

__all__ = [
    "MetricError",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "DEFAULT_BUCKETS",
]


class MetricError(ValueError):
    """Invalid metric name / label / operation."""


_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")

#: Latency-shaped default buckets (seconds), Prometheus-style.
DEFAULT_BUCKETS: Tuple[float, ...] = (
    0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1,
    0.25, 0.5, 1.0, 2.5, 5.0, 10.0, float("inf"),
)

#: The collapsed label value used once a family's label-set cap is hit.
OVERFLOW_LABEL = "__other__"


def _fmt(v: float) -> str:
    """Prometheus sample-value formatting (integers without a fraction)."""
    if v == math.inf:
        return "+Inf"
    if v == -math.inf:
        return "-Inf"
    if math.isnan(v):
        return "NaN"
    f = float(v)
    if f.is_integer() and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


def _escape(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


class Counter:
    """Monotonically non-decreasing sample."""

    kind = "counter"
    __slots__ = ("_value",)

    def __init__(self) -> None:
        self._value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise MetricError("counters only go up")
        self._value += amount

    @property
    def value(self) -> float:
        return self._value

    def _dump(self) -> Dict[str, Any]:
        return {"value": self._value}

    def _load(self, d: Mapping[str, Any]) -> None:
        self._value = float(d["value"])


class Gauge:
    """Sample that can go up and down (queue depth, loss, hit rate)."""

    kind = "gauge"
    __slots__ = ("_value",)

    def __init__(self) -> None:
        self._value = 0.0

    def set(self, value: float) -> None:
        self._value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        self._value += amount

    def dec(self, amount: float = 1.0) -> None:
        self._value -= amount

    def set_max(self, value: float) -> None:
        """Keep the running maximum (high-water marks)."""
        if value > self._value:
            self._value = float(value)

    @property
    def value(self) -> float:
        return self._value

    def _dump(self) -> Dict[str, Any]:
        return {"value": self._value}

    def _load(self, d: Mapping[str, Any]) -> None:
        self._value = float(d["value"])


class Histogram:
    """Cumulative-bucket histogram (Prometheus semantics).

    ``observe`` is O(log buckets); per-bucket counts are stored
    non-cumulative and cumulated at exposition time.
    """

    kind = "histogram"
    __slots__ = ("bounds", "counts", "_sum", "_count")

    def __init__(self, buckets: Sequence[float] = DEFAULT_BUCKETS) -> None:
        bounds = sorted(float(b) for b in buckets)
        if not bounds or bounds[-1] != math.inf:
            bounds.append(math.inf)
        self.bounds: Tuple[float, ...] = tuple(bounds)
        self.counts: List[int] = [0] * len(self.bounds)
        self._sum = 0.0
        self._count = 0

    def observe(self, value: float) -> None:
        v = float(value)
        self.counts[bisect_left(self.bounds, v)] += 1
        self._sum += v
        self._count += 1

    @property
    def sum(self) -> float:
        return self._sum

    @property
    def count(self) -> int:
        return self._count

    @property
    def mean(self) -> float:
        return self._sum / self._count if self._count else 0.0

    def cumulative(self) -> List[Tuple[float, int]]:
        out: List[Tuple[float, int]] = []
        running = 0
        for bound, c in zip(self.bounds, self.counts):
            running += c
            out.append((bound, running))
        return out

    def _dump(self) -> Dict[str, Any]:
        return {
            "buckets": [b if b != math.inf else "+Inf" for b in self.bounds],
            "counts": list(self.counts),
            "sum": self._sum,
            "count": self._count,
        }

    def _load(self, d: Mapping[str, Any]) -> None:
        self.bounds = tuple(
            math.inf if b == "+Inf" else float(b) for b in d["buckets"]
        )
        self.counts = [int(c) for c in d["counts"]]
        self._sum = float(d["sum"])
        self._count = int(d["count"])


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class _Family:
    """One metric family: shared name/help/label-names, many children."""

    __slots__ = ("kind", "name", "help", "label_names", "buckets",
                 "max_label_sets", "children")

    def __init__(
        self,
        kind: str,
        name: str,
        help: str,
        label_names: Tuple[str, ...],
        buckets: Optional[Sequence[float]],
        max_label_sets: int,
    ):
        self.kind = kind
        self.name = name
        self.help = help
        self.label_names = label_names
        self.buckets = tuple(buckets) if buckets is not None else None
        self.max_label_sets = max_label_sets
        self.children: "OrderedDict[Tuple[str, ...], Any]" = OrderedDict()

    def _new_child(self):
        if self.kind == "histogram":
            return Histogram(self.buckets or DEFAULT_BUCKETS)
        return _KINDS[self.kind]()

    def child(self, label_values: Tuple[str, ...]):
        c = self.children.get(label_values)
        if c is not None:
            return c
        if self.label_names and len(self.children) >= self.max_label_sets:
            # bounded label sets: collapse the overflow into one series
            label_values = tuple(OVERFLOW_LABEL for _ in self.label_names)
            c = self.children.get(label_values)
            if c is not None:
                return c
        c = self._new_child()
        self.children[label_values] = c
        return c


class MetricsRegistry:
    """A process- or component-scoped collection of metric families.

    Each :class:`~repro.core.broker.DataBroker` owns one (decentralized,
    like the matchmaker); cooperating components (scheduler, serve
    engine, transfer service) share the broker's so one exposition covers
    the whole selection pipeline. Pass an explicit registry to aggregate
    across components, or keep separate registries and merge snapshots.
    """

    def __init__(self, *, max_label_sets: int = 64) -> None:
        self.max_label_sets = int(max_label_sets)
        self._families: "OrderedDict[str, _Family]" = OrderedDict()

    # ------------------------------------------------------------ creation
    def _family(
        self,
        kind: str,
        name: str,
        help: str,
        label_names: Tuple[str, ...],
        buckets: Optional[Sequence[float]] = None,
    ) -> _Family:
        if not _NAME_RE.match(name):
            raise MetricError(f"invalid metric name {name!r}")
        for ln in label_names:
            if not _LABEL_RE.match(ln) or ln == "le":
                raise MetricError(f"invalid label name {ln!r}")
        fam = self._families.get(name)
        if fam is None:
            fam = _Family(kind, name, help, label_names, buckets,
                          self.max_label_sets)
            self._families[name] = fam
            return fam
        if fam.kind != kind:
            raise MetricError(
                f"{name!r} already registered as a {fam.kind}, not {kind}"
            )
        if fam.label_names != label_names:
            raise MetricError(
                f"{name!r} label names {fam.label_names} != {label_names}"
            )
        return fam

    def _metric(self, kind, name, help, labels, buckets=None):
        names = tuple(sorted(labels))
        fam = self._family(kind, name, help, names, buckets)
        values = tuple(str(labels[k]) for k in names)
        return fam.child(values)

    def counter(self, name: str, help: str = "", **labels: Any) -> Counter:
        return self._metric("counter", name, help, labels)

    def gauge(self, name: str, help: str = "", **labels: Any) -> Gauge:
        return self._metric("gauge", name, help, labels)

    def histogram(
        self,
        name: str,
        help: str = "",
        *,
        buckets: Optional[Sequence[float]] = None,
        **labels: Any,
    ) -> Histogram:
        return self._metric("histogram", name, help, labels, buckets)

    # ------------------------------------------------------------- reading
    def value(self, name: str, **labels: Any) -> float:
        """Point read of one counter/gauge sample (tests, stats views)."""
        fam = self._families[name]
        values = tuple(str(labels[k]) for k in sorted(labels))
        return fam.children[values].value

    def families(self) -> List[str]:
        return list(self._families)

    def samples(self) -> List[Tuple[str, Dict[str, str], Any]]:
        """Flat (name, labels, metric) triples — GRIS publication walks
        this."""
        out = []
        for fam in self._families.values():
            for values, metric in fam.children.items():
                out.append((fam.name, dict(zip(fam.label_names, values)), metric))
        return out

    # --------------------------------------------------------- exposition
    def expose_text(self) -> str:
        """The Prometheus text exposition format (version 0.0.4)."""
        lines: List[str] = []
        for fam in self._families.values():
            if fam.help:
                lines.append(f"# HELP {fam.name} {_escape(fam.help)}")
            lines.append(f"# TYPE {fam.name} {fam.kind}")
            for values, metric in fam.children.items():
                base = [
                    f'{k}="{_escape(v)}"'
                    for k, v in zip(fam.label_names, values)
                ]
                if fam.kind == "histogram":
                    for bound, cum in metric.cumulative():
                        lbl = ",".join(base + [f'le="{_fmt(bound)}"'])
                        lines.append(f"{fam.name}_bucket{{{lbl}}} {cum}")
                    suffix = "{" + ",".join(base) + "}" if base else ""
                    lines.append(f"{fam.name}_sum{suffix} {_fmt(metric.sum)}")
                    lines.append(f"{fam.name}_count{suffix} {_fmt(metric.count)}")
                else:
                    suffix = "{" + ",".join(base) + "}" if base else ""
                    lines.append(f"{fam.name}{suffix} {_fmt(metric.value)}")
        return "\n".join(lines) + ("\n" if lines else "")

    def to_dict(self) -> Dict[str, Any]:
        """JSON-safe snapshot of every family and child."""
        fams = []
        for fam in self._families.values():
            fams.append(
                {
                    "kind": fam.kind,
                    "name": fam.name,
                    "help": fam.help,
                    "label_names": list(fam.label_names),
                    "buckets": (
                        [b if b != math.inf else "+Inf" for b in fam.buckets]
                        if fam.buckets is not None
                        else None
                    ),
                    "children": [
                        {"labels": list(values), **metric._dump()}
                        for values, metric in fam.children.items()
                    ],
                }
            )
        return {"families": fams}

    @classmethod
    def from_dict(cls, d: Mapping[str, Any], *, max_label_sets: int = 64) -> "MetricsRegistry":
        reg = cls(max_label_sets=max_label_sets)
        for f in d["families"]:
            buckets = None
            if f.get("buckets") is not None:
                buckets = [
                    math.inf if b == "+Inf" else float(b) for b in f["buckets"]
                ]
            fam = reg._family(
                f["kind"], f["name"], f.get("help", ""),
                tuple(f["label_names"]), buckets,
            )
            for child in f["children"]:
                metric = fam.child(tuple(child["labels"]))
                metric._load(child)
        return reg

    def dump_json(self, path: str, *, extra: Optional[Mapping[str, Any]] = None) -> None:
        """Archive the registry: JSON families + the text exposition, plus
        caller-supplied context (bench timings, run args)."""
        payload: Dict[str, Any] = dict(extra or {})
        payload.update(self.to_dict())
        payload["exposition"] = self.expose_text()
        with open(path, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
