"""Unified observability: metrics, tracing spans, selection audit trail.

Dependency-free instrumentation for the whole selection pipeline:

  * :mod:`.metrics` — labeled Counter/Gauge/Histogram registry with
    bounded label sets, Prometheus text exposition and JSON snapshots,
  * :mod:`.trace` — nested tracing spans (context manager / decorator)
    exportable as Chrome trace-event JSON (Perfetto), with optional
    ``jax.profiler`` trace-annotation passthrough around kernel dispatch,
  * :mod:`.audit` — per-selection decision records answering "why was
    this replica chosen?" (``DataBroker.explain``),
  * :mod:`.telemetry` — the broker's registry published back through the
    GRIS/LDIF mechanism it consumes (``BrokerTelemetry`` DIT subtree).

See DESIGN.md §7 for the architecture and the decision-record schema.
"""

from .audit import AuditTrail, CandidateScore, DecisionRecord
from .metrics import (
    DEFAULT_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    MetricError,
    MetricsRegistry,
)
from .telemetry import BROKER_METRIC, BROKER_TELEMETRY, BrokerTelemetryGRIS
from .trace import Span, Tracer

__all__ = [
    "AuditTrail",
    "CandidateScore",
    "DecisionRecord",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricError",
    "MetricsRegistry",
    "DEFAULT_BUCKETS",
    "Span",
    "Tracer",
    "BROKER_TELEMETRY",
    "BROKER_METRIC",
    "BrokerTelemetryGRIS",
]
