"""GRIS-published broker telemetry: the obs loop closed through MDS.

The paper's whole premise is that *published* dynamic state (GRIS/GIIS
attributes) drives better selection. This module applies the same
mechanism to the broker itself: a :class:`BrokerTelemetryGRIS` publishes
a broker's metrics registry as an LDAP DIT subtree —

    gbt=<broker>, o=grid                          BrokerTelemetry (summary)
      └─ gbm=<metric>{labels}, gbt=<broker>, ...  BrokerMetric (per series)

— so a GIIS aggregates broker health exactly like it aggregates storage
attributes: ``register()`` the publisher, then ``search`` for
``objectClass=Grid::Broker::Telemetry`` across the fleet. The object
classes follow the §3 schema machinery (MUST/MAY, cisfloat/cis,
validated before publication).
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence

from repro.core.gris import Clock
from repro.core.ldif import Entry, Filter, dumps as ldif_dumps, parse_filter
from repro.core.schema import AttributeSpec, ObjectClass, validate_entry

__all__ = ["BROKER_TELEMETRY", "BROKER_METRIC", "BrokerTelemetryGRIS"]


def _f(name: str) -> AttributeSpec:
    return AttributeSpec(name, "cisfloat", True)


def _s(name: str) -> AttributeSpec:
    return AttributeSpec(name, "cis", True)


#: Broker-health summary — one entry per broker, the thing a GIIS-wide
#: "which brokers are unhealthy?" query reads.
BROKER_TELEMETRY = ObjectClass(
    name="Grid::Broker::Telemetry",
    rdn="gbt",
    subclass_of=None,
    child_of=("Grid::organizationalUnit", "Grid::organization", "Grid::Top"),
    must=(
        _s("brokerUrl"),
        _f("searchesTotal"),
        _f("matchesTotal"),
        _f("fetchesTotal"),
        _f("failoversTotal"),
        _f("stragglerSwitchesTotal"),
    ),
    may=(
        _f("batchSelectsTotal"),
        _f("snapshotBuilds"),
        _f("snapshotReuses"),
        _f("planCacheHits"),
        _f("planCacheMisses"),
        _f("planCacheHitRate"),
        _f("auditRecords"),
    ),
)

#: One metric series (family × label set) — the full registry, drillable
#: the way SourceTransferBandwidth children hang under TransferBandwidth.
BROKER_METRIC = ObjectClass(
    name="Grid::Broker::Metric",
    rdn="gbm",
    subclass_of="Grid::Broker::Telemetry",
    child_of=(
        "Grid::Broker::Telemetry",
        "Grid::organizationalUnit",
        "Grid::organization",
        "Grid::Top",
    ),
    must=(_s("metricName"), _s("metricType"), _f("metricValue")),
    may=(_s("metricLabels"), _f("sampleCount"), _f("sampleSum")),
)


def _project(entry: Entry, attrs: Optional[Sequence[str]]) -> Entry:
    if attrs is None:
        return dict(entry)
    want = {a.lower() for a in attrs} | {"dn", "objectclass"}
    return {k: v for k, v in entry.items() if k.lower() in want}


class BrokerTelemetryGRIS:
    """A GRIS-shaped information server over one broker's telemetry.

    Duck-types the :class:`~repro.core.gris.StorageGRIS` surface a GIIS
    needs (``entries()``/``search()``/``to_ldif()``), so
    ``giis.register(name, publisher)`` makes broker health discoverable
    alongside storage resources. Entries are materialized per query from
    the live registry (shell-backend semantics: always current).
    """

    def __init__(
        self,
        dn: str,
        broker: Any,  # repro.core.broker.DataBroker
        *,
        clock: Optional[Clock] = None,
        validate: bool = True,
        max_metric_entries: int = 256,
    ):
        self.dn = dn
        self.broker = broker
        self.clock = clock or getattr(broker, "clock", None) or Clock()
        self.validate = validate
        self.max_metric_entries = int(max_metric_entries)
        self.query_count = 0

    # ------------------------------------------------------ materialization
    def telemetry_entry(self) -> Entry:
        stats = self.broker.stats
        pc = self.broker.plan_cache.stats
        lookups = pc["hits"] + pc["misses"] + pc["negative_hits"]
        entry: Entry = {
            "dn": self.dn,
            "objectClass": BROKER_TELEMETRY.name,
            "brokerUrl": self.broker.client_url,
            "searchesTotal": float(stats.get("searches", 0)),
            "matchesTotal": float(stats.get("matches", 0)),
            "fetchesTotal": float(stats.get("fetches", 0)),
            "failoversTotal": float(stats.get("failovers", 0)),
            "stragglerSwitchesTotal": float(stats.get("straggler_switches", 0)),
            "batchSelectsTotal": float(stats.get("batch_selects", 0)),
            "snapshotBuilds": float(stats.get("snapshot_builds", 0)),
            "snapshotReuses": float(stats.get("snapshot_reuses", 0)),
            "planCacheHits": float(pc["hits"]),
            "planCacheMisses": float(pc["misses"]),
            "planCacheHitRate": float(pc["hits"] / lookups) if lookups else 0.0,
            "auditRecords": float(len(self.broker.audit)),
        }
        if self.validate:
            validate_entry(entry, BROKER_TELEMETRY)
        return entry

    def metric_entries(self) -> List[Entry]:
        """One child entry per metric series in the broker's registry."""
        out: List[Entry] = []
        for name, labels, metric in self.broker.metrics.samples():
            if len(out) >= self.max_metric_entries:
                break
            label_str = ",".join(f"{k}={v}" for k, v in sorted(labels.items()))
            rdn_label = f"{name}{{{label_str}}}" if label_str else name
            entry: Entry = {
                "dn": f"gbm={rdn_label}, {self.dn}",
                "objectClass": BROKER_METRIC.name,
                "metricName": name,
                "metricType": metric.kind,
            }
            if label_str:
                entry["metricLabels"] = label_str
            if metric.kind == "histogram":
                entry["metricValue"] = float(metric.mean)
                entry["sampleCount"] = float(metric.count)
                entry["sampleSum"] = float(metric.sum)
            else:
                entry["metricValue"] = float(metric.value)
            if self.validate:
                validate_entry(entry, BROKER_METRIC)
            out.append(entry)
        return out

    def entries(self) -> List[Entry]:
        """The full telemetry subtree, parent-first (the GIIS snapshot)."""
        return [self.telemetry_entry()] + self.metric_entries()

    # --------------------------------------------------------------- search
    def search(
        self,
        flt: Optional["Filter | str"] = None,
        attrs: Optional[Sequence[str]] = None,
    ) -> List[Entry]:
        self.query_count += 1
        if isinstance(flt, str):
            flt = parse_filter(flt)
        out: List[Entry] = []
        for entry in self.entries():
            if flt is None or flt.matches(entry):
                out.append(_project(entry, attrs))
        return out

    def to_ldif(self) -> str:
        return ldif_dumps(self.entries())
