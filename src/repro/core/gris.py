"""Grid Resource Information Service (GRIS) for storage resources (§3.1).

"Each storage resource in the Globus Data Grid incorporates a Grid
Resource Information Server, configured to collect and publish system
configuration metadata describing that storage system."

The paper's GRIS is an OpenLDAP daemon whose *dynamic* attributes
(``availableSpace``, ``totalSpace``, ``mountPoint``) are produced by
shell-backend scripts executed per query, while *static* attributes (seek
times, usage policy) come from an administrator configuration file.

We preserve those semantics in-process:

  * static attributes are a plain dict, set at construction / by the admin,
  * dynamic attributes are **provider callbacks** invoked on query, with a
    per-attribute TTL cache (shell-backends were expensive; MDS cached),
  * entries are validated against the §3 object classes before publication,
  * queries take LDAP filters and an optional attribute projection, and
    return LDIF entries — exactly what the broker's Search Phase consumes.

A GRIS owns a small DIT: the ServerVolume entry, one TransferBandwidth
child summarizing all transfers, and one SourceTransferBandwidth child per
remote source site (Figures 2, 4, 5).
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

from .ldif import Entry, Filter, dumps as ldif_dumps, parse_filter
from .schema import (
    OBJECT_CLASSES,
    SERVER_VOLUME,
    SOURCE_TRANSFER_BANDWIDTH,
    TRANSFER_BANDWIDTH,
    ObjectClass,
    SchemaError,
    validate_entry,
)

__all__ = ["DynamicAttribute", "StorageGRIS", "Clock"]


class Clock:
    """Injected, manually-advanced clock so TTL caching and the ``time()``
    ClassAd builtin are deterministic in tests and benchmarks."""

    def __init__(self, start: float = 0.0):
        self._now = float(start)

    def now(self) -> float:
        return self._now

    def advance(self, dt: float) -> None:
        self._now += float(dt)

    def set(self, t: float) -> None:
        self._now = float(t)


@dataclass
class DynamicAttribute:
    """A shell-backend-style dynamic attribute: provider + TTL cache."""

    name: str
    provider: Callable[[], Any]
    ttl: float = 5.0
    _cached: Any = None
    _cached_at: float = float("-inf")
    calls: int = 0  # instrumentation: provider invocations (cache misses)
    hits: int = 0  # instrumentation: TTL-cache hits

    def value(self, now: float) -> Any:
        if now - self._cached_at >= self.ttl:
            self._cached = self.provider()
            self._cached_at = now
            self.calls += 1
        else:
            self.hits += 1
        return self._cached

    def invalidate(self) -> None:
        self._cached_at = float("-inf")


class StorageGRIS:
    """The per-resource information server, holding the storage DIT.

    Parameters
    ----------
    dn:
        Distinguished name of the ServerVolume entry, e.g.
        ``gss=vol0, ou=mcs, o=anl, o=grid``.
    static_attrs:
        Administrator-configured attributes (seek times, ``requirements``
        policy string, hostname, zone, ...).
    clock:
        Shared deterministic clock (drives TTL expiry).
    """

    def __init__(
        self,
        dn: str,
        static_attrs: Optional[Mapping[str, Any]] = None,
        *,
        clock: Optional[Clock] = None,
        validate: bool = True,
    ):
        self.dn = dn
        self.clock = clock or Clock()
        self.validate = validate
        self._static: Dict[str, Any] = dict(static_attrs or {})
        self._dynamic: Dict[str, DynamicAttribute] = {}
        # bandwidth summary + per-source children, maintained by the
        # TransferMonitor (core/bandwidth.py) via publish_* below.
        self._bw_summary: Optional[Dict[str, Any]] = None
        self._bw_sources: Dict[str, Dict[str, Any]] = {}
        # per-source *health* attributes (circuit-breaker feedback from the
        # resilient access layer) — kept apart from the bandwidth children
        # so a TransferMonitor publish never wipes them, merged into the
        # same per-source entry at materialization time
        self._src_health: Dict[str, Dict[str, Any]] = {}
        self.query_count = 0  # instrumentation
        # optional obs registry (settable after construction: a broker can
        # attach its own to the GRISes it polls — see launch/serve.py)
        self.metrics: Any = None
        # static analysis of the admin's usage policy at registration time:
        # a policy with a typo'd attribute or a cis/cisfloat confusion would
        # otherwise only surface as a silent non-match at selection
        self.policy_diagnostics: List[Any] = []
        self._analyze_policy()

    # -- instrumentation ------------------------------------------------------
    def ttl_cache_stats(self) -> Dict[str, int]:
        """Aggregate dynamic-attribute TTL cache hits/misses (provider
        invocations are misses — the expensive shell-backend runs)."""
        hits = sum(d.hits for d in self._dynamic.values())
        misses = sum(d.calls for d in self._dynamic.values())
        return {"hits": hits, "misses": misses}

    def _observe_query(self) -> None:
        self.query_count += 1
        if self.metrics is not None:
            self.metrics.counter(
                "gris_queries_total", "LDAP-style searches served"
            ).inc()
            stats = self.ttl_cache_stats()
            lookups = stats["hits"] + stats["misses"]
            self.metrics.gauge(
                "gris_dynamic_ttl_hit_rate",
                "fraction of dynamic-attribute reads served from TTL cache",
            ).set(stats["hits"] / lookups if lookups else 0.0)

    def _analyze_policy(self) -> None:
        """Run the ClassAd analyzer over the static ``requirements``
        policy, if any. Findings are kept on ``policy_diagnostics``; with
        ``validate=True`` an error-severity finding refuses registration,
        like any other schema violation."""
        policy = None
        for k, v in self._static.items():
            if k.lower() == "requirements" and isinstance(v, str):
                policy = v
                break
        if policy is None:
            self.policy_diagnostics = []
            return
        from repro.analysis.adlint import check_policy_source

        self.policy_diagnostics = check_policy_source(policy, name=self.dn)
        if self.validate:
            errors = [
                d for d in self.policy_diagnostics if d.severity.value == "error"
            ]
            if errors:
                raise SchemaError(
                    "invalid requirements policy: "
                    + "; ".join(f"{d.rule}: {d.message}" for d in errors)
                )

    # -- attribute management ------------------------------------------------
    def set_static(self, name: str, value: Any) -> None:
        self._static[name] = value
        if name.lower() == "requirements":
            self._analyze_policy()

    def register_dynamic(
        self, name: str, provider: Callable[[], Any], ttl: float = 5.0
    ) -> None:
        """Attach a shell-backend-style provider for a dynamic attribute."""
        self._dynamic[name] = DynamicAttribute(name, provider, ttl)

    def invalidate(self, name: Optional[str] = None) -> None:
        if name is None:
            for d in self._dynamic.values():
                d.invalidate()
        elif name in self._dynamic:
            self._dynamic[name].invalidate()

    # -- bandwidth publication (called by TransferMonitor) --------------------
    def publish_bandwidth_summary(self, attrs: Mapping[str, Any]) -> None:
        entry = dict(attrs)
        if self.validate:
            validate_entry(entry, TRANSFER_BANDWIDTH)
        self._bw_summary = entry

    def publish_source_bandwidth(self, source_url: str, attrs: Mapping[str, Any]) -> None:
        entry = dict(attrs)
        entry.setdefault("sourceUrl", source_url)
        if self.validate:
            validate_entry(entry, SOURCE_TRANSFER_BANDWIDTH)
        self._bw_sources[source_url] = entry

    def publish_source_health(self, source_url: str, attrs: Mapping[str, Any]) -> None:
        """Merge client-observed health attributes (e.g. the resilient
        layer's ``breakerOpenToSource``) into ``source_url``'s per-source
        view — the feedback loop that lets that client's own matchmaking
        avoid endpoints it has tripped a breaker on."""
        self._src_health.setdefault(source_url, {}).update(attrs)

    def _source_view(self, source_url: str) -> Optional[Dict[str, Any]]:
        """Bandwidth child + health attrs for one source, merged."""
        bw = self._bw_sources.get(source_url)
        health = self._src_health.get(source_url)
        if bw is None and health is None:
            return None
        merged: Dict[str, Any] = dict(bw or {"sourceUrl": source_url})
        if health:
            merged.update(health)
        return merged

    # -- entry materialization -------------------------------------------------
    def volume_entry(self) -> Entry:
        now = self.clock.now()
        entry: Entry = {"dn": self.dn, "objectClass": SERVER_VOLUME.name}
        entry.update(self._static)
        for name, dyn in self._dynamic.items():
            entry[name] = dyn.value(now)
        if self.validate:
            validate_entry(entry, SERVER_VOLUME)
        return entry

    def bandwidth_entry(self) -> Optional[Entry]:
        if self._bw_summary is None:
            return None
        entry: Entry = {
            "dn": f"gss=bw, {self.dn}",
            "objectClass": TRANSFER_BANDWIDTH.name,
        }
        entry.update(self._bw_summary)
        return entry

    def source_entries(self) -> List[Entry]:
        out: List[Entry] = []
        for src in sorted(set(self._bw_sources) | set(self._src_health)):
            entry: Entry = {
                "dn": f"gss=src-{src}, gss=bw, {self.dn}",
                "objectClass": SOURCE_TRANSFER_BANDWIDTH.name,
            }
            entry.update(self._source_view(src) or {})
            out.append(entry)
        return out

    def entries(self) -> List[Entry]:
        """The full DIT subtree rooted at this GRIS, parent-first."""
        out = [self.volume_entry()]
        bw = self.bandwidth_entry()
        if bw is not None:
            out.append(bw)
        out.extend(self.source_entries())
        return out

    # -- search (the LDAP surface) ----------------------------------------------
    def search(
        self,
        flt: Optional[Filter | str] = None,
        attrs: Optional[Sequence[str]] = None,
        *,
        source: Optional[str] = None,
    ) -> List[Entry]:
        """LDAP-style search over this GRIS's DIT.

        ``flt`` filters entries; ``attrs`` projects returned attributes (the
        broker asks only for "the attributes of interest"); ``source``
        narrows SourceTransferBandwidth children to one remote site and
        *flattens* the matching child into the volume view, which is how
        brokers read end-to-end stats for their own site in one query.
        """
        self._observe_query()
        if isinstance(flt, str):
            flt = parse_filter(flt)

        candidates = [self.volume_entry()]
        bw = self.bandwidth_entry()
        if bw is not None:
            candidates.append(bw)
        if source is not None:
            src = self._source_view(source)
            if src is not None:
                entry: Entry = {
                    "dn": f"gss=src-{source}, gss=bw, {self.dn}",
                    "objectClass": SOURCE_TRANSFER_BANDWIDTH.name,
                }
                entry.update(src)
                candidates.append(entry)
        else:
            candidates.extend(self.source_entries())

        out: List[Entry] = []
        for entry in candidates:
            if flt is None or flt.matches(entry):
                out.append(_project(entry, attrs))
        return out

    def flattened_view(self, source: Optional[str] = None) -> Entry:
        """One merged attribute dict over the whole DIT subtree — what the
        broker converts to a ClassAd. Children override nothing; their
        attribute names are disjoint by schema design."""
        view: Entry = {}
        for entry in self.search(source=source):
            for k, v in entry.items():
                if k == "dn":
                    continue
                if k == "objectClass":
                    view.setdefault("objectClass", [])
                    if isinstance(view["objectClass"], list):
                        view["objectClass"].append(v)
                    continue
                view.setdefault(k, v)
        view["dn"] = self.dn
        return view

    def to_ldif(self) -> str:
        return ldif_dumps(self.entries())


def _project(entry: Entry, attrs: Optional[Sequence[str]]) -> Entry:
    if attrs is None:
        return dict(entry)
    want = {a.lower() for a in attrs} | {"dn", "objectclass"}
    return {k: v for k, v in entry.items() if k.lower() in want}
