"""Device-resident replica snapshots: build the columnar table ONCE per
GRIS/GIIS epoch, keep it on-device, update rows incrementally.

The paper's broker re-reads the information service on every selection;
our fleet scenario has thousands of concurrent selections against the
*same published snapshot* of GRIS state. The per-call costs that
dominated the old path — numpy ``pad_columns`` + a fresh [S_PAD, A_PAD]
host→device transfer per ``matchrank`` call — are paid here exactly once
per epoch:

  * numeric attributes of all entries are columnarized (f64 ``ColumnTable``
    for the columnar/policy programs — bit-identical broker semantics),
  * the f32 [S_PAD, A_PAD] attrs/valid blocks are padded to lane/sublane
    alignment and pushed to the device as ``jax.Array``s,
  * dynamic-attribute refreshes between epochs are applied as *row
    updates* (``update_rows``) — an O(rows_changed) ``.at[].set`` instead
    of an O(S·A) rebuild,
  * every mutation bumps ``version`` so plan/launch caches can invalidate.

``matchrank``/``matchrank_batched`` accept the snapshot's pre-padded
device blocks directly (``n_rows`` marks the live prefix), so the steady
state ships only the tiny per-request plan tensors per launch.
"""

from __future__ import annotations

import math
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from .compile import ColumnTable

__all__ = ["ReplicaSnapshot", "entry_row", "numeric_attr_names"]


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _numeric(v: Any) -> Optional[float]:
    """ClassAd-compatible numeric coercion (bool counts as a number)."""
    if isinstance(v, bool):
        return 1.0 if v else 0.0
    if isinstance(v, (int, float)):
        return float(v)
    return None


def numeric_attr_names(entries: Sequence[Mapping[str, Any]]) -> List[str]:
    """The sorted union of attribute names that are numeric in at least
    one entry — the snapshot's column vocabulary."""
    names = set()
    for e in entries:
        for k, v in e.items():
            if _numeric(v) is not None:
                names.add(k.lower())
    return sorted(names)


def entry_row(
    entry: Mapping[str, Any], index: Mapping[str, int], a_pad: int
) -> Tuple[np.ndarray, np.ndarray]:
    """One entry's (vals, ok) column vectors over a vocabulary index —
    the row-fill semantics shared by the flat and sharded snapshots."""
    vals = np.zeros((a_pad,), dtype=np.float32)
    ok = np.zeros((a_pad,), dtype=np.float32)
    for k, v in entry.items():
        j = index.get(k.lower())
        if j is None:
            continue
        x = _numeric(v)
        if x is None or not math.isfinite(x):
            continue  # NaN/inf publishes as Undefined, not a poisoned cell
        vals[j] = np.float32(x)
        ok[j] = 1.0
    return vals, ok


class ReplicaSnapshot:
    """One GRIS epoch's candidate table, padded and device-resident.

    Parameters
    ----------
    entries:
        One flattened GRIS view (attribute dict) per candidate row. Row
        order is the snapshot's candidate index space.
    attr_names:
        Column vocabulary (lower-cased, ordered). Defaults to the union
        of numeric attributes across ``entries`` — pass an explicit
        vocabulary to keep plans reusable across epochs whose attribute
        sets drift.
    block_s:
        Row padding granularity (the kernel's S-block).
    device:
        Keep the padded f32 blocks resident as ``jax.Array``s. With
        ``device=False`` the snapshot is numpy-only (no jax import cost),
        still amortizing the pad.
    """

    def __init__(
        self,
        entries: Sequence[Mapping[str, Any]],
        attr_names: Optional[Sequence[str]] = None,
        *,
        block_s: int = 512,
        device: bool = True,
        epoch: int = 0,
    ):
        self.entries: List[Dict[str, Any]] = [dict(e) for e in entries]
        if attr_names is None:
            attr_names = numeric_attr_names(self.entries)
        self.attr_names: List[str] = [n.lower() for n in attr_names]
        self._index = {n: j for j, n in enumerate(self.attr_names)}
        self.block_s = int(block_s)
        self.epoch = int(epoch)
        self.version = 0  # bumped on every mutation (epoch or row update)
        self._device = bool(device)

        n = len(self.entries)
        a = len(self.attr_names)
        self.n = n
        self.a_pad = max(_round_up(a, 128), 128)
        self.s_pad = max(_round_up(max(n, 1), self.block_s), self.block_s)

        self._attrs = np.zeros((self.s_pad, self.a_pad), dtype=np.float32)
        self._valid = np.zeros((self.s_pad, self.a_pad), dtype=np.float32)
        for i, e in enumerate(self.entries):
            self._fill_row_host(i, e)
        self._attrs_dev = None
        self._valid_dev = None
        self._rank_orders: Dict[
            Tuple[bytes, float], Tuple[int, np.ndarray, np.ndarray]
        ] = {}
        if self._device:
            self._push_all()

    # ------------------------------------------------------------- building
    def _row_vectors(self, entry: Mapping[str, Any]) -> Tuple[np.ndarray, np.ndarray]:
        return entry_row(entry, self._index, self.a_pad)

    def _fill_row_host(self, i: int, entry: Mapping[str, Any]) -> None:
        vals, ok = self._row_vectors(entry)
        self._attrs[i] = vals
        self._valid[i] = ok

    def _push_all(self) -> None:
        import jax.numpy as jnp

        self._attrs_dev = jnp.asarray(self._attrs)
        self._valid_dev = jnp.asarray(self._valid)

    # ------------------------------------------------------------ accessors
    def device_columns(self):
        """→ (attrs, valid, n_rows): the padded candidate block (device-
        resident when built with ``device=True``)."""
        if self._attrs_dev is not None:
            return self._attrs_dev, self._valid_dev, self.n
        return self._attrs, self._valid, self.n

    def host_columns(self) -> Tuple[np.ndarray, np.ndarray, int]:
        return self._attrs, self._valid, self.n

    def logical_columns(self) -> Tuple[np.ndarray, np.ndarray]:
        """→ contiguous (attrs [n, A] f32, valid [n, A] bool) over the live
        rows at logical (unpadded) width — the operand shape of the sparse
        top-k walk, where striding across the padded block would defeat
        the cache. Materialized once per version."""
        a = len(self.attr_names)
        hit = getattr(self, "_logical", None)
        if hit is not None and hit[0] == self.version:
            return hit[1], hit[2]
        attrs = np.ascontiguousarray(self._attrs[: self.n, :a])
        valid = np.ascontiguousarray(self._valid[: self.n, :a] > 0.5)
        self._logical = (self.version, attrs, valid)
        return attrs, valid

    def table(self) -> ColumnTable:
        """An f64 :class:`ColumnTable` over the live rows — the operand of
        columnar programs and compiled server policies (numpy semantics
        identical to the per-request broker path)."""
        tbl = ColumnTable(self.n)
        for name, j in self._index.items():
            tbl.add(
                name,
                self._attrs[: self.n, j].astype(np.float64),
                self._valid[: self.n, j] > 0.5,
            )
        return tbl

    def vocab_key(self) -> Tuple[str, ...]:
        """Hashable vocabulary identity for plan caching."""
        return tuple(self.attr_names)

    def rank_order(
        self, weights: np.ndarray, bias: float = 0.0
    ) -> Tuple[np.ndarray, np.ndarray]:
        """→ (order, svals) for a linear rank over the live rows, with the
        dense ref's Condor semantics: a row where *any* non-zero-weight
        attribute is invalid scores 0.0 (the whole rank is Undefined, bias
        included); everywhere else ``attrs @ w + bias``. ``order`` is a
        *stable* descending argsort (ties → lowest row index, matching the
        dense top-k).

        Cached per (version, weights, bias) — the sort is paid once per
        epoch per distinct rank expression, then every sparse top-k walk
        (:func:`repro.kernels.matchrank.sparse.topk_in_rank_order`)
        reuses it. Row updates invalidate via the version bump."""
        w = np.asarray(weights, dtype=np.float32).reshape(-1)
        a = len(self.attr_names)
        if w.shape[0] < a:
            w = np.pad(w, (0, a - w.shape[0]))
        key = (w[:a].tobytes(), float(bias))
        hit = self._rank_orders.get(key)
        if hit is not None and hit[0] == self.version:
            return hit[1], hit[2]
        live_a = self._attrs[: self.n, :a]
        live_v = self._valid[: self.n, :a]
        w = w[:a]
        svals = (live_a @ w + np.float32(bias)).astype(np.float32)
        wactive = w != 0
        if wactive.any():
            bad = ~(live_v[:, wactive] > 0.5).all(axis=1)
            svals[bad] = 0.0
        order = np.argsort(-svals, kind="stable")
        self._rank_orders[key] = (self.version, order, svals)
        return order, svals

    # ------------------------------------------------------------ mutation
    def update_rows(self, updates: Mapping[int, Mapping[str, Any]]) -> None:
        """Incremental refresh: merge attribute dicts into existing rows.

        This is the between-epoch path for dynamic attributes (load
        factor, available space, bandwidth EWMAs): O(rows_changed) host
        work and ONE scatter per call on device, no table rebuild.
        """
        if not updates:
            return
        rows = sorted(updates)
        for i in rows:
            if not (0 <= i < self.n):
                raise IndexError(f"row {i} outside snapshot (n={self.n})")
            self.entries[i].update(updates[i])
            self._fill_row_host(i, self.entries[i])
        if self._attrs_dev is not None:
            import jax.numpy as jnp

            idx = np.asarray(rows, dtype=np.int32)
            new_attrs = jnp.asarray(self._attrs[idx])
            new_valid = jnp.asarray(self._valid[idx])
            self._attrs_dev = self._attrs_dev.at[idx].set(new_attrs)
            self._valid_dev = self._valid_dev.at[idx].set(new_valid)
        self.version += 1

    def new_epoch(
        self, entries: Sequence[Mapping[str, Any]], *, reuse_vocab: bool = True
    ) -> "ReplicaSnapshot":
        """A full rebuild for the next published GRIS epoch."""
        return ReplicaSnapshot(
            entries,
            self.attr_names if reuse_vocab else None,
            block_s=self.block_s,
            device=self._device,
            epoch=self.epoch + 1,
        )

    def __len__(self) -> int:
        return self.n

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return (
            f"ReplicaSnapshot(n={self.n}, a={len(self.attr_names)}, "
            f"pad=[{self.s_pad},{self.a_pad}], epoch={self.epoch}, "
            f"version={self.version}, device={self._attrs_dev is not None})"
        )
