"""The storage DIT object classes of the paper's §3 (Figures 2–5).

The paper defines a Directory Information Tree for storage systems:

    Grid::Top
      └─ Grid::organization
           └─ Grid::organizationalUnit
                └─ Grid::Storage::ServerVolume          (Figure 2)
                     └─ Grid::Storage::TransferBandwidth      (Figure 4)
                          └─ Grid::Storage::SourceTransferBandwidth (Figure 5)

Each object class declares MUST CONTAIN / MAY CONTAIN attribute sets with
typed syntaxes (``cisfloat``/``cis``). We reproduce those definitions
verbatim and add validation so a GRIS refuses to publish an entry that
violates its schema — the property the LDAP server would have enforced.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

__all__ = [
    "AttributeSpec",
    "ObjectClass",
    "SERVER_VOLUME",
    "TRANSFER_BANDWIDTH",
    "SOURCE_TRANSFER_BANDWIDTH",
    "OBJECT_CLASSES",
    "SchemaError",
    "validate_entry",
]


class SchemaError(ValueError):
    """An entry violates its object class definition."""


@dataclass(frozen=True)
class AttributeSpec:
    """One attribute in an object class: name, LDAP syntax, multiplicity."""

    name: str
    syntax: str  # 'cisfloat' (numeric) | 'cis' (case-insensitive string)
    singular: bool = True

    def check(self, value: Any) -> None:
        values = value if isinstance(value, (list, tuple)) else [value]
        if self.singular and len(values) != 1:
            raise SchemaError(f"{self.name}: singular attribute given {len(values)} values")
        for v in values:
            if self.syntax == "cisfloat":
                if isinstance(v, bool) or not isinstance(v, (int, float)):
                    raise SchemaError(f"{self.name}: expected numeric (cisfloat), got {v!r}")
            elif self.syntax == "cis":
                if not isinstance(v, str):
                    raise SchemaError(f"{self.name}: expected string (cis), got {v!r}")
            else:  # pragma: no cover - schema definition error
                raise SchemaError(f"{self.name}: unknown syntax {self.syntax!r}")


@dataclass(frozen=True)
class ObjectClass:
    """An LDAP object class: MUST/MAY attribute sets within the DIT."""

    name: str
    rdn: str
    subclass_of: Optional[str]
    child_of: Tuple[str, ...]
    must: Tuple[AttributeSpec, ...]
    may: Tuple[AttributeSpec, ...] = ()

    def attr(self, name: str) -> Optional[AttributeSpec]:
        low = name.lower()
        for spec in self.must + self.may:
            if spec.name.lower() == low:
                return spec
        return None

    @property
    def must_names(self) -> List[str]:
        return [s.name for s in self.must]


def _f(name: str) -> AttributeSpec:
    return AttributeSpec(name, "cisfloat", True)


def _s(name: str, singular: bool = True) -> AttributeSpec:
    return AttributeSpec(name, "cis", singular)


#: Figure 2 — ``Grid::Storage::ServerVolume``: System Configuration Metadata.
#: ``totalSpace``/``availableSpace``/``mountPoint`` are *dynamic* (gathered by
#: shell-backends on each query); the transfer/seek times and the admin
#: ``requirements`` policy are *static* (from a configuration file).
#: The paper's figure types mountPoint as cisfloat and availableSpace as cis —
#: plainly typos (a mount point is a path); we use the sensible syntaxes.
SERVER_VOLUME = ObjectClass(
    name="Grid::Storage::ServerVolume",
    rdn="gss",
    subclass_of="Grid::PhysicalResource",
    child_of=("Grid::organizationalUnit", "Grid::organization", "Grid::Top"),
    must=(
        _f("totalSpace"),
        _f("availableSpace"),
        _s("mountPoint"),
        _f("diskTransferRate"),
        _f("drdTime"),
        _f("dwrTime"),
    ),
    may=(
        _s("requirements"),
        AttributeSpec("filesystem", "cis", singular=False),
        _s("hostname"),
        _s("zone"),
        _f("nStreamsMax"),
        _f("loadFactor"),
    ),
)

#: Figure 4 — ``Grid::Storage::TransferBandwidth``: site-wide summary of
#: observed GridFTP transfer performance.
TRANSFER_BANDWIDTH = ObjectClass(
    name="Grid::Storage::TransferBandwidth",
    rdn="gss",
    subclass_of="Grid::Storage::ServerVolume",
    child_of=(
        "Grid::Storage::ServerVolume",
        "Grid::organizationalUnit",
        "Grid::organization",
        "Grid::Top",
    ),
    must=(
        _f("MaxRDBandwidth"),
        _f("MinRDBandwidth"),
        _f("AvgRDBandwidth"),
        _f("MaxWRBandwidth"),
        _f("MinWRBandwidth"),
        _f("AvgWRBandwidth"),
    ),
    may=(
        _f("StdRDBandwidth"),
        _f("StdWRBandwidth"),
        _f("nRDSamples"),
        _f("nWRSamples"),
    ),
)

#: Figure 5 — ``Grid::Storage::SourceTransferBandwidth``: per-source-site
#: end-to-end performance ("significant reuse of storage servers by clients
#: ... justifying performance information on a per source basis").
SOURCE_TRANSFER_BANDWIDTH = ObjectClass(
    name="Grid::Storage::SourceTransferBandwidth",
    rdn="gss",
    subclass_of="Grid::Storage::TransferBandwidth",
    child_of=(
        "Grid::Storage::TransferBandwidth",
        "Grid::Storage::ServerVolume",
        "Grid::organizationalUnit",
        "Grid::organization",
        "Grid::Top",
    ),
    must=(
        _f("lastWRBandwidth"),
        _s("lastWRurl"),
        _f("lastRDBandwidth"),
        _s("lastRDurl"),
    ),
    may=(
        _f("AvgRDBandwidthToSource"),
        _f("AvgWRBandwidthToSource"),
        _f("EwmaRDBandwidthToSource"),
        _f("MedianRDBandwidthToSource"),
        _f("nSamplesToSource"),
        _s("sourceUrl"),
    ),
)

OBJECT_CLASSES: Dict[str, ObjectClass] = {
    oc.name.lower(): oc
    for oc in (SERVER_VOLUME, TRANSFER_BANDWIDTH, SOURCE_TRANSFER_BANDWIDTH)
}


def validate_entry(
    entry: Mapping[str, Any], object_class: ObjectClass, *, strict_may: bool = False
) -> None:
    """Check ``entry`` against ``object_class``.

    Raises :class:`SchemaError` if a MUST attribute is missing, a value has
    the wrong syntax, or (``strict_may``) an attribute is not declared at
    all. Keys are matched case-insensitively, like LDAP.
    """
    keys = {k.lower(): k for k in entry.keys()}
    for spec in object_class.must:
        k = keys.get(spec.name.lower())
        if k is None:
            raise SchemaError(f"missing MUST attribute {spec.name!r} for {object_class.name}")
        spec.check(entry[k])
    for spec in object_class.may:
        k = keys.get(spec.name.lower())
        if k is not None:
            spec.check(entry[k])
    if strict_may:
        declared = {s.name.lower() for s in object_class.must + object_class.may}
        declared |= {"dn", "objectclass"}
        for k in keys:
            if k not in declared:
                raise SchemaError(f"undeclared attribute {k!r} for {object_class.name}")
