"""The Replica Catalog: logical file → physical replica locations (§2.2, §5).

"A replica manager typically maintains a replica catalog containing
replica site addresses and the file instances." The broker's Search Phase
step 1 "queries the replica catalog, which contains addresses of all
replicas for each logical file".

The catalog maps a *logical file name* (LFN) to a set of *physical file
names* (PFNs) — (endpoint URL, path, size, checksum). Logical collections
group LFNs (the Globus replica catalog had collections; our data pipeline
uses them for shard manifests, and the checkpoint manager for step
manifests). The catalog is deliberately dumb: no selection logic lives
here, only existence — selection is the broker's job.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field, replace
from typing import Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

__all__ = ["PhysicalFile", "LogicalFile", "ReplicaCatalog", "CatalogError"]


class CatalogError(KeyError):
    pass


@dataclass(frozen=True)
class PhysicalFile:
    """One replica instance of a logical file."""

    endpoint: str  # endpoint URL, e.g. "gsiftp://hugo.mcs.anl.gov"
    path: str  # path at the endpoint, e.g. "/dev/sandbox/chunk-000017"
    size: int  # bytes
    checksum: Optional[str] = None  # content digest (integrity on restore)

    @property
    def url(self) -> str:
        return f"{self.endpoint}{self.path}"


@dataclass
class LogicalFile:
    lfn: str
    replicas: List[PhysicalFile] = field(default_factory=list)
    attributes: Dict[str, object] = field(default_factory=dict)  # app metadata


class ReplicaCatalog:
    """An in-memory replica catalog with collections.

    Thread-safe: the async checkpoint writer registers replicas from a
    background thread while the training loop reads.
    """

    def __init__(self):
        self._files: Dict[str, LogicalFile] = {}
        self._collections: Dict[str, List[str]] = {}
        self._lock = threading.RLock()

    # -- logical files -----------------------------------------------------
    def create_logical(self, lfn: str, attributes: Optional[Mapping[str, object]] = None) -> None:
        with self._lock:
            if lfn not in self._files:
                self._files[lfn] = LogicalFile(lfn)
            if attributes:
                self._files[lfn].attributes.update(attributes)

    def register_replica(self, lfn: str, pfn: PhysicalFile) -> None:
        """Add a replica instance; idempotent on (endpoint, path)."""
        with self._lock:
            self.create_logical(lfn)
            lf = self._files[lfn]
            for existing in lf.replicas:
                if existing.endpoint == pfn.endpoint and existing.path == pfn.path:
                    lf.replicas.remove(existing)
                    break
            lf.replicas.append(pfn)

    def unregister_replica(self, lfn: str, endpoint: str, path: Optional[str] = None) -> int:
        """Remove replicas at ``endpoint`` (optionally a specific path).
        Returns the number removed. Used when an endpoint is declared dead."""
        with self._lock:
            lf = self._files.get(lfn)
            if lf is None:
                return 0
            before = len(lf.replicas)
            lf.replicas = [
                r
                for r in lf.replicas
                if not (r.endpoint == endpoint and (path is None or r.path == path))
            ]
            return before - len(lf.replicas)

    def unregister_endpoint(self, endpoint: str) -> int:
        """Drop every replica hosted by ``endpoint`` (node death)."""
        with self._lock:
            n = 0
            for lfn in list(self._files):
                n += self.unregister_replica(lfn, endpoint)
            return n

    def lookup(self, lfn: str) -> List[PhysicalFile]:
        """Search Phase step 1: all replica locations of a logical file."""
        with self._lock:
            lf = self._files.get(lfn)
            if lf is None:
                raise CatalogError(lfn)
            return list(lf.replicas)

    def attributes(self, lfn: str) -> Dict[str, object]:
        with self._lock:
            lf = self._files.get(lfn)
            if lf is None:
                raise CatalogError(lfn)
            return dict(lf.attributes)

    def exists(self, lfn: str) -> bool:
        with self._lock:
            return lfn in self._files

    def logical_files(self) -> List[str]:
        with self._lock:
            return sorted(self._files)

    # -- collections ----------------------------------------------------------
    def create_collection(self, name: str, lfns: Optional[Iterable[str]] = None) -> None:
        with self._lock:
            self._collections.setdefault(name, [])
            if lfns:
                for lfn in lfns:
                    self.add_to_collection(name, lfn)

    def add_to_collection(self, name: str, lfn: str) -> None:
        with self._lock:
            self.create_logical(lfn)
            coll = self._collections.setdefault(name, [])
            if lfn not in coll:
                coll.append(lfn)

    def collection(self, name: str) -> List[str]:
        with self._lock:
            if name not in self._collections:
                raise CatalogError(name)
            return list(self._collections[name])

    def collections(self) -> List[str]:
        with self._lock:
            return sorted(self._collections)

    def drop_collection(self, name: str, *, drop_logical: bool = True) -> None:
        """Remove a collection (and optionally its now-orphaned LFNs)."""
        with self._lock:
            lfns = self._collections.pop(name, [])
            if drop_logical:
                for lfn in lfns:
                    lf = self._files.get(lfn)
                    if lf is not None and not lf.replicas:
                        del self._files[lfn]

    # -- stats -------------------------------------------------------------
    def replica_counts(self) -> Dict[str, int]:
        with self._lock:
            return {lfn: len(lf.replicas) for lfn, lf in self._files.items()}

    def endpoints(self) -> List[str]:
        with self._lock:
            eps = {r.endpoint for lf in self._files.values() for r in lf.replicas}
            return sorted(eps)
