"""Matchmaking: pairing request ClassAds with resource ClassAds.

Implements the Match Phase of the paper's §5.1.2:

  2. "The broker then performs a match of the application's requirement
     ClassAd against the list of replica capability ClassAds, obtaining a
     set of replica locations that satisfy the criterion."
  3. "The ClassAd ranking feature can be used to prioritize successful
     matches based on some attribute, specified by the application."

Matching is *two-sided* (Condor semantics): both the request's and the
resource's ``requirements`` must evaluate to True inside the MatchClassAd.
This is how the paper expresses *site usage policy* — the storage ad of §4
only admits requests with ``other.reqdSpace < 10G``.

Ranking follows Condor: the *request's* ``rank`` expression is evaluated
against each matched resource; non-numeric / Undefined ranks are treated
as 0.0. Ties are broken deterministically by the resource's name attribute
(and finally by input order) so that two decentralized brokers holding the
same published state reach the same decision — a property we test.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from .classads import ClassAd, MatchContext, Undefined, Value

__all__ = ["MatchResult", "Matchmaker", "match", "rank_value"]


@dataclass
class MatchResult:
    """One successful match: the resource ad and the request's rank for it."""

    ad: ClassAd
    rank: float
    index: int  # position in the candidate list (deterministic tiebreak)
    name: str = ""

    def __repr__(self) -> str:
        return f"MatchResult(name={self.name!r}, rank={self.rank}, index={self.index})"


def rank_value(request: ClassAd, resource: ClassAd, env: Optional[Dict[str, Value]] = None) -> float:
    """Evaluate the request's ``rank`` against ``resource``; 0.0 if absent
    or non-numeric (Condor's convention)."""
    v = request.eval_attr("rank", resource, env)
    if isinstance(v, bool):
        return 1.0 if v else 0.0
    if isinstance(v, (int, float)):
        return float(v)
    return 0.0


def _resource_name(ad: ClassAd, idx: int) -> str:
    for attr in ("name", "hostname", "endpoint", "url"):
        v = ad.eval_attr(attr)
        if isinstance(v, str):
            return v
    return f"resource-{idx}"


class Matchmaker:
    """A reusable matchmaker with an injected evaluation environment.

    The environment supplies deterministic globals (e.g. ``now`` for the
    ``time()`` builtin). A fresh Matchmaker per broker keeps the process
    decentralized: there is no shared state between clients.
    """

    def __init__(self, env: Optional[Dict[str, Value]] = None):
        self.env = dict(env or {})

    # -- predicates -----------------------------------------------------
    def requirements_met(self, request: ClassAd, resource: ClassAd) -> bool:
        """Two-sided requirements check (Undefined / Error fail closed)."""
        return MatchContext(request, resource, self.env).symmetric_match()

    def one_sided(self, evaluator: ClassAd, target: ClassAd) -> bool:
        """Check only ``evaluator.requirements`` against ``target``."""
        return evaluator.eval_attr("requirements", target, self.env) is True

    # -- matching ---------------------------------------------------------
    def match(
        self,
        request: ClassAd,
        candidates: Sequence[ClassAd],
        *,
        top_k: Optional[int] = None,
        require_symmetric: bool = True,
    ) -> List[MatchResult]:
        """Match ``request`` against ``candidates``; return rank-sorted results.

        ``require_symmetric=False`` degrades to one-sided matching (only the
        request's requirements), for resources that publish no policy.
        """
        results: List[MatchResult] = []
        for idx, cand in enumerate(candidates):
            if require_symmetric and "requirements" in cand:
                ok = self.requirements_met(request, cand)
            else:
                ok = self.one_sided(request, cand)
            if not ok:
                continue
            r = rank_value(request, cand, self.env)
            results.append(MatchResult(cand, r, idx, _resource_name(cand, idx)))
        # Descending rank; deterministic tiebreak by (name, index).
        results.sort(key=lambda m: (-m.rank, m.name, m.index))
        if top_k is not None:
            results = results[:top_k]
        return results

    def best(self, request: ClassAd, candidates: Sequence[ClassAd]) -> Optional[MatchResult]:
        res = self.match(request, candidates, top_k=1)
        return res[0] if res else None


def match(
    request: ClassAd,
    candidates: Sequence[ClassAd],
    *,
    env: Optional[Dict[str, Value]] = None,
    top_k: Optional[int] = None,
) -> List[MatchResult]:
    """Module-level convenience wrapper around :class:`Matchmaker`."""
    return Matchmaker(env).match(request, candidates, top_k=top_k)
