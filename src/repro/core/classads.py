"""Condor Classified Advertisements (ClassAds) for the storage context.

This module implements the ClassAd expression language used by the paper
("Replica Selection in the Globus Data Grid", Vazhkudai/Tuecke/Foster 2001,
building on Raman/Livny/Solomon's matchmaking, HPDC-7 1998):

  * a value model with the tri-state semantics of Condor ClassAds
    (Undefined / Error propagate through operators with well-defined
    absorption rules, e.g. ``False && Undefined == False``),
  * a lexer + Pratt parser for the expression language, including the
    unit-suffixed numeric literals used by the paper's example ads
    (``50G``, ``75K``),
  * an evaluator with ``MY``/``self`` and ``TARGET``/``other`` scoping inside
    a MatchClassAd, the structure Condor builds when matching two ads,
  * the ``ClassAd`` record type itself, with case-insensitive attribute
    names and LDIF-friendly conversion hooks (see :mod:`repro.core.ldif`).

The language is a principled subset of Condor's: everything exercised by
the paper (two-sided ``requirements``, ``rank``, ``other.`` references,
arithmetic/boolean/comparison operators) plus lists, nested ads, ternary,
``=?=``/``=!=`` identity comparison and ~25 builtin functions. All builtins
are deterministic (``time()`` reads an injected clock) so selection results
are reproducible across hosts — a property the decentralized broker relies
on when we test that independent clients reach identical decisions from
identical published state.
"""

from __future__ import annotations

import math
import re as _re
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterator, List, Optional, Sequence, Tuple, Union

__all__ = [
    "Undefined",
    "Error",
    "ClassAd",
    "MatchContext",
    "Expr",
    "Literal",
    "AttrRef",
    "UnaryOp",
    "BinOp",
    "Ternary",
    "FuncCall",
    "ListExpr",
    "Select",
    "Index",
    "parse",
    "parse_classad",
    "evaluate",
    "ClassAdSyntaxError",
    "BUILTINS",
    "UNIT_SUFFIXES",
]


# ---------------------------------------------------------------------------
# Value model
# ---------------------------------------------------------------------------


class _Singleton:
    """Base for the Undefined / Error sentinel values."""

    _name = "singleton"

    def __repr__(self) -> str:  # pragma: no cover - trivial
        return self._name

    def __bool__(self) -> bool:
        raise TypeError(
            f"ClassAd {self._name} has no Python truth value; "
            "use classads semantics (evaluate) instead"
        )


class _Undefined(_Singleton):
    _name = "undefined"


class _Error(_Singleton):
    _name = "error"


#: The ClassAd ``undefined`` value: an attribute that is not present.
Undefined = _Undefined()

#: The ClassAd ``error`` value: a type error / division by zero / bad call.
Error = _Error()

# A ClassAd runtime value.
Value = Union[bool, int, float, str, list, "_Undefined", "_Error", "ClassAd"]

#: Unit suffixes accepted on numeric literals. The paper's example ads use
#: ``50G`` and ``75K``; we follow storage convention (powers of 1024).
UNIT_SUFFIXES = {"K": 1024, "M": 1024**2, "G": 1024**3, "T": 1024**4, "P": 1024**5}


def is_undef(v: Value) -> bool:
    return v is Undefined


def is_error(v: Value) -> bool:
    return v is Error


def is_exceptional(v: Value) -> bool:
    return v is Undefined or v is Error


def _is_number(v: Value) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool)


# ---------------------------------------------------------------------------
# AST
# ---------------------------------------------------------------------------


class Expr:
    """Base class for ClassAd expression AST nodes."""

    __slots__ = ()

    def eval(self, ctx: "EvalContext") -> Value:  # pragma: no cover - abstract
        raise NotImplementedError

    # Helper so users can write ``expr.evaluate(ad)`` directly.
    def evaluate(
        self,
        ad: Optional["ClassAd"] = None,
        other: Optional["ClassAd"] = None,
        env: Optional[Dict[str, Value]] = None,
    ) -> Value:
        return evaluate(self, ad, other, env)


@dataclass(frozen=True)
class Literal(Expr):
    value: Value

    __slots__ = ("value",)

    def eval(self, ctx: "EvalContext") -> Value:
        return self.value

    def __repr__(self) -> str:
        if isinstance(self.value, str):
            return '"%s"' % self.value
        return repr(self.value)


@dataclass(frozen=True)
class AttrRef(Expr):
    """Attribute reference, possibly scoped: ``name``, ``other.name``, ``my.name``."""

    scope: Optional[str]  # None | 'my' | 'other'  ('self'→'my', 'target'→'other')
    name: str

    __slots__ = ("scope", "name")

    def eval(self, ctx: "EvalContext") -> Value:
        return ctx.lookup(self.scope, self.name)

    def __repr__(self) -> str:
        return f"{self.scope}.{self.name}" if self.scope else self.name


@dataclass(frozen=True)
class UnaryOp(Expr):
    op: str  # '-' | '+' | '!'
    operand: Expr

    __slots__ = ("op", "operand")

    def eval(self, ctx: "EvalContext") -> Value:
        v = self.operand.eval(ctx)
        if self.op == "!":
            if v is Undefined or v is Error:
                return v
            if isinstance(v, bool):
                return not v
            return Error
        # numeric +/-
        if v is Undefined or v is Error:
            return v
        if _is_number(v):
            return -v if self.op == "-" else +v
        return Error

    def __repr__(self) -> str:
        return f"{self.op}({self.operand!r})"


_CMP_OPS = {"==", "!=", "<", "<=", ">", ">="}
_ARITH_OPS = {"+", "-", "*", "/", "%"}


@dataclass(frozen=True)
class BinOp(Expr):
    op: str
    left: Expr
    right: Expr

    __slots__ = ("op", "left", "right")

    def eval(self, ctx: "EvalContext") -> Value:
        op = self.op
        # --- short-circuiting boolean connectives (Condor absorption) ---
        if op == "&&":
            return _eval_and(self.left, self.right, ctx)
        if op == "||":
            return _eval_or(self.left, self.right, ctx)

        l = self.left.eval(ctx)
        r = self.right.eval(ctx)

        # --- identity comparison: total, never Undefined/Error ---
        if op == "=?=":
            return _is_identical(l, r)
        if op == "=!=":
            return not _is_identical(l, r)

        # --- strict propagation for everything else ---
        if l is Error or r is Error:
            return Error
        if l is Undefined or r is Undefined:
            return Undefined

        if op in _CMP_OPS:
            return _compare(op, l, r)
        if op in _ARITH_OPS:
            return _arith(op, l, r)
        return Error  # pragma: no cover - parser emits only known ops

    def __repr__(self) -> str:
        return f"({self.left!r} {self.op} {self.right!r})"


@dataclass(frozen=True)
class Ternary(Expr):
    cond: Expr
    then: Expr
    other: Expr

    __slots__ = ("cond", "then", "other")

    def eval(self, ctx: "EvalContext") -> Value:
        c = self.cond.eval(ctx)
        if c is Undefined or c is Error:
            return c
        if not isinstance(c, bool):
            return Error
        return self.then.eval(ctx) if c else self.other.eval(ctx)

    def __repr__(self) -> str:
        return f"({self.cond!r} ? {self.then!r} : {self.other!r})"


@dataclass(frozen=True)
class FuncCall(Expr):
    name: str
    args: Tuple[Expr, ...]

    __slots__ = ("name", "args")

    def eval(self, ctx: "EvalContext") -> Value:
        fn = ctx.function(self.name)
        if fn is None:
            return Error
        argv = [a.eval(ctx) for a in self.args]
        try:
            return fn(ctx, argv)
        except _ClassAdError:
            return Error
        except Exception:
            return Error

    def __repr__(self) -> str:
        return f"{self.name}({', '.join(map(repr, self.args))})"


@dataclass(frozen=True)
class ListExpr(Expr):
    items: Tuple[Expr, ...]

    __slots__ = ("items",)

    def eval(self, ctx: "EvalContext") -> Value:
        return [item.eval(ctx) for item in self.items]

    def __repr__(self) -> str:
        return "{%s}" % ", ".join(map(repr, self.items))


@dataclass(frozen=True)
class Select(Expr):
    """Attribute selection from a nested ClassAd value: ``expr.name``."""

    base: Expr
    name: str

    __slots__ = ("base", "name")

    def eval(self, ctx: "EvalContext") -> Value:
        base = self.base.eval(ctx)
        if base is Undefined or base is Error:
            return base
        if isinstance(base, ClassAd):
            expr = base.lookup_expr(self.name)
            if expr is None:
                return Undefined
            return expr.eval(ctx.rescope(base))
        return Error

    def __repr__(self) -> str:
        return f"{self.base!r}.{self.name}"


@dataclass(frozen=True)
class Index(Expr):
    base: Expr
    index: Expr

    __slots__ = ("base", "index")

    def eval(self, ctx: "EvalContext") -> Value:
        base = self.base.eval(ctx)
        idx = self.index.eval(ctx)
        if base is Error or idx is Error:
            return Error
        if base is Undefined or idx is Undefined:
            return Undefined
        if isinstance(base, list) and isinstance(idx, int) and not isinstance(idx, bool):
            if 0 <= idx < len(base):
                return base[idx]
            return Error
        return Error

    def __repr__(self) -> str:
        return f"{self.base!r}[{self.index!r}]"


# ---------------------------------------------------------------------------
# Operator semantics
# ---------------------------------------------------------------------------


def _eval_and(left: Expr, right: Expr, ctx: "EvalContext") -> Value:
    l = left.eval(ctx)
    if l is False:
        return False
    r = right.eval(ctx)
    if r is False:
        return False
    if l is Error or r is Error:
        return Error
    if l is Undefined or r is Undefined:
        return Undefined
    if isinstance(l, bool) and isinstance(r, bool):
        return True  # both are True here
    return Error


def _eval_or(left: Expr, right: Expr, ctx: "EvalContext") -> Value:
    l = left.eval(ctx)
    if l is True:
        return True
    r = right.eval(ctx)
    if r is True:
        return True
    if l is Error or r is Error:
        return Error
    if l is Undefined or r is Undefined:
        return Undefined
    if isinstance(l, bool) and isinstance(r, bool):
        return False
    return Error


def _is_identical(l: Value, r: Value) -> bool:
    """``=?=``: identical-comparison, a total predicate (never U/E)."""
    if l is Undefined or r is Undefined:
        return l is r
    if l is Error or r is Error:
        return l is r
    if isinstance(l, bool) != isinstance(r, bool):
        return False
    if _is_number(l) and _is_number(r):
        # =?= requires same type in Condor; we compare value and int-ness.
        return (isinstance(l, int) == isinstance(r, int)) and l == r
    if isinstance(l, str) and isinstance(r, str):
        return l == r  # case-SENSITIVE, unlike ==
    if type(l) is type(r):
        try:
            return bool(l == r)
        except Exception:
            return False
    return False


def _compare(op: str, l: Value, r: Value) -> Value:
    if _is_number(l) and _is_number(r):
        lv, rv = float(l), float(r)
    elif isinstance(l, str) and isinstance(r, str):
        # Condor string comparison is case-insensitive for the ordered ops.
        lv, rv = l.lower(), r.lower()
    elif isinstance(l, bool) and isinstance(r, bool):
        if op == "==":
            return l == r
        if op == "!=":
            return l != r
        return Error
    else:
        return Error  # incompatible types
    if op == "==":
        return lv == rv
    if op == "!=":
        return lv != rv
    if op == "<":
        return lv < rv
    if op == "<=":
        return lv <= rv
    if op == ">":
        return lv > rv
    if op == ">=":
        return lv >= rv
    return Error  # pragma: no cover


def _arith(op: str, l: Value, r: Value) -> Value:
    if op == "+" and isinstance(l, str) and isinstance(r, str):
        return l + r
    if not (_is_number(l) and _is_number(r)):
        return Error
    if op == "+":
        return l + r
    if op == "-":
        return l - r
    if op == "*":
        return l * r
    if op == "/":
        if r == 0:
            return Error
        if isinstance(l, int) and isinstance(r, int):
            # Condor: integer division truncates toward zero.
            q = abs(l) // abs(r)
            return -q if (l < 0) != (r < 0) else q
        return l / r
    if op == "%":
        if r == 0:
            return Error
        if isinstance(l, int) and isinstance(r, int):
            m = abs(l) % abs(r)
            return -m if l < 0 else m
        return math.fmod(l, r)
    return Error  # pragma: no cover


# ---------------------------------------------------------------------------
# ClassAd record
# ---------------------------------------------------------------------------


class ClassAd:
    """A classified advertisement: an attribute → expression mapping.

    Attribute names are case-insensitive (as in Condor); the original
    spelling is preserved for round-tripping. Values assigned as plain
    Python objects are wrapped in :class:`Literal`; strings that should be
    *expressions* must be assigned via :meth:`set_expr` or constructed with
    :func:`parse`.
    """

    __slots__ = ("_attrs", "_spelling")

    def __init__(self, attrs: Optional[Dict[str, Any]] = None):
        self._attrs: Dict[str, Expr] = {}
        self._spelling: Dict[str, str] = {}
        if attrs:
            for k, v in attrs.items():
                self[k] = v

    # -- mapping protocol ---------------------------------------------------
    def __setitem__(self, name: str, value: Any) -> None:
        if isinstance(value, Expr):
            expr = value
        elif isinstance(value, ClassAd):
            expr = Literal(value)
        elif isinstance(value, (bool, int, float, str)) or value is None:
            expr = Literal(Undefined if value is None else value)
        elif isinstance(value, (list, tuple)):
            expr = ListExpr(
                tuple(v if isinstance(v, Expr) else Literal(v) for v in value)
            )
        elif value is Undefined or value is Error:
            expr = Literal(value)
        else:
            raise TypeError(f"cannot store {type(value)!r} in a ClassAd")
        key = name.lower()
        self._attrs[key] = expr
        self._spelling[key] = name

    def set_expr(self, name: str, source: str) -> None:
        """Assign an attribute from ClassAd expression source text."""
        self[name] = parse(source)

    def __getitem__(self, name: str) -> Expr:
        return self._attrs[name.lower()]

    def __contains__(self, name: str) -> bool:
        return name.lower() in self._attrs

    def __delitem__(self, name: str) -> None:
        key = name.lower()
        del self._attrs[key]
        del self._spelling[key]

    def __iter__(self) -> Iterator[str]:
        return iter(self._spelling.values())

    def __len__(self) -> int:
        return len(self._attrs)

    def keys(self) -> List[str]:
        return list(self._spelling.values())

    def items(self) -> List[Tuple[str, Expr]]:
        return [(self._spelling[k], v) for k, v in self._attrs.items()]

    def lookup_expr(self, name: str) -> Optional[Expr]:
        return self._attrs.get(name.lower())

    # -- evaluation ----------------------------------------------------------
    def eval_attr(
        self,
        name: str,
        other: Optional["ClassAd"] = None,
        env: Optional[Dict[str, Value]] = None,
    ) -> Value:
        """Evaluate attribute ``name`` of this ad (optionally in a match)."""
        expr = self.lookup_expr(name)
        if expr is None:
            return Undefined
        return evaluate(expr, self, other, env)

    # -- conversion / io ------------------------------------------------------
    def flatten(
        self, other: Optional["ClassAd"] = None, env: Optional[Dict[str, Value]] = None
    ) -> Dict[str, Value]:
        """Evaluate every attribute; exceptional values are preserved."""
        return {k: self.eval_attr(k, other, env) for k in self.keys()}

    def copy(self) -> "ClassAd":
        ad = ClassAd()
        ad._attrs = dict(self._attrs)
        ad._spelling = dict(self._spelling)
        return ad

    def update(self, other: "ClassAd") -> None:
        for k, v in other.items():
            self[k] = v

    def __repr__(self) -> str:
        inner = "; ".join(f"{k} = {v!r}" for k, v in self.items())
        return f"[ {inner} ]"

    def __eq__(self, other: object) -> bool:
        if not isinstance(other, ClassAd):
            return NotImplemented
        return repr(self) == repr(other)

    def __hash__(self):  # pragma: no cover - ads are mutable; hash by id
        return id(self)


# ---------------------------------------------------------------------------
# Evaluation context
# ---------------------------------------------------------------------------


class _ClassAdError(Exception):
    """Internal: raised by builtins to signal the Error value."""


class EvalContext:
    """Evaluation context: the pair of ads in a match plus an environment.

    ``self_ad`` is the ad whose expression is being evaluated; ``other_ad``
    is the candidate on the far side of the MatchClassAd. Unqualified
    attribute references resolve in ``self_ad`` first, then ``other_ad``,
    then the environment — Condor's lookup order inside a match.
    """

    __slots__ = ("self_ad", "other_ad", "env", "_depth")

    MAX_DEPTH = 64  # cycle guard for self-referential ads

    def __init__(
        self,
        self_ad: Optional[ClassAd],
        other_ad: Optional[ClassAd] = None,
        env: Optional[Dict[str, Value]] = None,
        _depth: int = 0,
    ):
        self.self_ad = self_ad
        self.other_ad = other_ad
        self.env = env or {}
        self._depth = _depth

    def rescope(self, new_self: ClassAd) -> "EvalContext":
        return EvalContext(new_self, self.other_ad, self.env, self._depth + 1)

    def _swap(self) -> "EvalContext":
        return EvalContext(self.other_ad, self.self_ad, self.env, self._depth + 1)

    def lookup(self, scope: Optional[str], name: str) -> Value:
        if self._depth > self.MAX_DEPTH:
            return Error
        key = name.lower()
        if scope == "other":
            if self.other_ad is None:
                return Undefined
            expr = self.other_ad.lookup_expr(key)
            if expr is None:
                return Undefined
            return expr.eval(self._swap())
        if scope == "my":
            if self.self_ad is None:
                return Undefined
            expr = self.self_ad.lookup_expr(key)
            if expr is None:
                return Undefined
            return expr.eval(self._bump())
        # unqualified: self, then other, then environment
        if self.self_ad is not None:
            expr = self.self_ad.lookup_expr(key)
            if expr is not None:
                return expr.eval(self._bump())
        if self.other_ad is not None:
            expr = self.other_ad.lookup_expr(key)
            if expr is not None:
                return expr.eval(self._swap())
        if key in self.env:
            return self.env[key]
        return Undefined

    def _bump(self) -> "EvalContext":
        return EvalContext(self.self_ad, self.other_ad, self.env, self._depth + 1)

    def function(self, name: str) -> Optional[Callable]:
        fn = self.env.get("__functions__", BUILTINS).get(name.lower())
        return fn


def evaluate(
    expr: Expr,
    ad: Optional[ClassAd] = None,
    other: Optional[ClassAd] = None,
    env: Optional[Dict[str, Value]] = None,
) -> Value:
    """Evaluate ``expr`` in the context of ``ad`` (matched against ``other``)."""
    return expr.eval(EvalContext(ad, other, env))


class MatchContext:
    """The MatchClassAd of the paper's §4: a container for two ads.

    "When two ClassAds are being matched, a MatchClassAd is created that
    contains both ClassAds. Each ClassAd can refer to the other ClassAd by
    using the `other` keyword."
    """

    __slots__ = ("left", "right", "env")

    def __init__(self, left: ClassAd, right: ClassAd, env: Optional[Dict[str, Value]] = None):
        self.left = left
        self.right = right
        self.env = env

    def left_value(self, attr: str) -> Value:
        return self.left.eval_attr(attr, self.right, self.env)

    def right_value(self, attr: str) -> Value:
        return self.right.eval_attr(attr, self.left, self.env)

    def symmetric_match(self) -> bool:
        """Both ``requirements`` must evaluate to True (U/E fail the match)."""
        return self.left_value("requirements") is True and (
            self.right_value("requirements") is True
        )


# ---------------------------------------------------------------------------
# Lexer
# ---------------------------------------------------------------------------


class ClassAdSyntaxError(ValueError):
    def __init__(self, msg: str, pos: int, text: str):
        near = text[max(0, pos - 12) : pos + 12]
        super().__init__(f"{msg} at position {pos} (near {near!r})")
        self.pos = pos


_TOKEN_RE = _re.compile(
    r"""
    (?P<ws>\s+|\#[^\n]*|//[^\n]*)
  | (?P<real>\d+\.\d*(?:[eE][-+]?\d+)?|\.\d+(?:[eE][-+]?\d+)?|\d+[eE][-+]?\d+)
    (?P<realunit>[KMGTPkmgtp]\b)?
  | (?P<int>\d+)(?P<intunit>[KMGTPkmgtp]\b)?
  | (?P<string>"(?:[^"\\]|\\.)*")
  | (?P<op>=\?=|=!=|&&|\|\||<=|>=|==|!=|[-+*/%<>!?:(),.\[\]{};=])
  | (?P<ident>[A-Za-z_][A-Za-z0-9_]*)
    """,
    _re.VERBOSE,
)

_KEYWORDS = {"true", "false", "undefined", "error", "is", "isnt"}


@dataclass
class _Token:
    kind: str  # 'num' | 'str' | 'ident' | 'op' | 'eof'
    value: Any
    pos: int


def _lex(text: str) -> List[_Token]:
    tokens: List[_Token] = []
    pos = 0
    n = len(text)
    while pos < n:
        m = _TOKEN_RE.match(text, pos)
        if m is None:
            raise ClassAdSyntaxError("unexpected character", pos, text)
        if m.lastgroup is None or m.group("ws"):
            pos = m.end()
            continue
        if m.group("real") is not None:
            val = float(m.group("real"))
            unit = m.group("realunit")
            if unit:
                val *= UNIT_SUFFIXES[unit.upper()]
            tokens.append(_Token("num", val, pos))
        elif m.group("int") is not None:
            val = int(m.group("int"))
            unit = m.group("intunit")
            if unit:
                val *= UNIT_SUFFIXES[unit.upper()]
            tokens.append(_Token("num", val, pos))
        elif m.group("string") is not None:
            raw = m.group("string")[1:-1]
            val = raw.encode("utf-8").decode("unicode_escape")
            tokens.append(_Token("str", val, pos))
        elif m.group("op") is not None:
            tokens.append(_Token("op", m.group("op"), pos))
        elif m.group("ident") is not None:
            ident = m.group("ident")
            low = ident.lower()
            if low in ("is", "isnt"):
                tokens.append(_Token("op", "=?=" if low == "is" else "=!=", pos))
            else:
                tokens.append(_Token("ident", ident, pos))
        pos = m.end()
    tokens.append(_Token("eof", None, n))
    return tokens


# ---------------------------------------------------------------------------
# Parser (Pratt / precedence climbing)
# ---------------------------------------------------------------------------

# precedence: higher binds tighter
_BIN_PREC = {
    "||": 10,
    "&&": 20,
    "==": 30,
    "!=": 30,
    "=?=": 30,
    "=!=": 30,
    "<": 40,
    "<=": 40,
    ">": 40,
    ">=": 40,
    "+": 50,
    "-": 50,
    "*": 60,
    "/": 60,
    "%": 60,
}

_TERNARY_PREC = 5

_SCOPES = {"my": "my", "self": "my", "other": "other", "target": "other"}


class _Parser:
    def __init__(self, text: str):
        self.text = text
        self.tokens = _lex(text)
        self.i = 0

    # -- token helpers ---------------------------------------------------
    def peek(self) -> _Token:
        return self.tokens[self.i]

    def next(self) -> _Token:
        tok = self.tokens[self.i]
        self.i += 1
        return tok

    def expect_op(self, op: str) -> None:
        tok = self.next()
        if tok.kind != "op" or tok.value != op:
            raise ClassAdSyntaxError(f"expected {op!r}", tok.pos, self.text)

    def at_op(self, *ops: str) -> bool:
        tok = self.peek()
        return tok.kind == "op" and tok.value in ops

    # -- grammar -----------------------------------------------------------
    def parse_expr(self, min_prec: int = 0) -> Expr:
        left = self.parse_unary()
        while True:
            tok = self.peek()
            if tok.kind == "op" and tok.value == "?" and _TERNARY_PREC >= min_prec:
                self.next()
                then = self.parse_expr(0)
                self.expect_op(":")
                other = self.parse_expr(_TERNARY_PREC)
                left = Ternary(left, then, other)
                continue
            if tok.kind != "op" or tok.value not in _BIN_PREC:
                break
            prec = _BIN_PREC[tok.value]
            if prec < min_prec:
                break
            op = self.next().value
            right = self.parse_expr(prec + 1)
            left = BinOp(op, left, right)
        return left

    def parse_unary(self) -> Expr:
        tok = self.peek()
        if tok.kind == "op" and tok.value in ("-", "+", "!"):
            self.next()
            return UnaryOp(tok.value, self.parse_unary())
        return self.parse_postfix()

    def parse_postfix(self) -> Expr:
        expr = self.parse_primary()
        while True:
            if self.at_op("."):
                self.next()
                tok = self.next()
                if tok.kind != "ident":
                    raise ClassAdSyntaxError("expected attribute name", tok.pos, self.text)
                # `other.x` / `my.x` on a bare scope keyword becomes AttrRef
                if isinstance(expr, AttrRef) and expr.scope is None and expr.name.lower() in _SCOPES:
                    expr = AttrRef(_SCOPES[expr.name.lower()], tok.value)
                else:
                    expr = Select(expr, tok.value)
            elif self.at_op("["):
                self.next()
                idx = self.parse_expr(0)
                self.expect_op("]")
                expr = Index(expr, idx)
            else:
                break
        return expr

    def parse_primary(self) -> Expr:
        tok = self.next()
        if tok.kind == "num":
            return Literal(tok.value)
        if tok.kind == "str":
            return Literal(tok.value)
        if tok.kind == "ident":
            low = tok.value.lower()
            if low == "true":
                return Literal(True)
            if low == "false":
                return Literal(False)
            if low == "undefined":
                return Literal(Undefined)
            if low == "error":
                return Literal(Error)
            # function call?
            if self.at_op("("):
                self.next()
                args: List[Expr] = []
                if not self.at_op(")"):
                    args.append(self.parse_expr(0))
                    while self.at_op(","):
                        self.next()
                        args.append(self.parse_expr(0))
                self.expect_op(")")
                return FuncCall(low, tuple(args))
            return AttrRef(None, tok.value)
        if tok.kind == "op":
            if tok.value == "(":
                inner = self.parse_expr(0)
                self.expect_op(")")
                return inner
            if tok.value == "{":
                items: List[Expr] = []
                if not self.at_op("}"):
                    items.append(self.parse_expr(0))
                    while self.at_op(","):
                        self.next()
                        items.append(self.parse_expr(0))
                self.expect_op("}")
                return ListExpr(tuple(items))
            if tok.value == "[":
                return self.parse_record_body()
        raise ClassAdSyntaxError("unexpected token", tok.pos, self.text)

    def parse_record_body(self) -> Literal:
        """`[ a = expr ; b = expr ]` — nested ClassAd literal."""
        ad = ClassAd()
        while not self.at_op("]"):
            tok = self.next()
            if tok.kind != "ident":
                raise ClassAdSyntaxError("expected attribute name", tok.pos, self.text)
            self.expect_op("=")
            ad[tok.value] = self.parse_expr(0)
            if self.at_op(";"):
                self.next()
        self.expect_op("]")
        return Literal(ad)


def parse(text: str) -> Expr:
    """Parse ClassAd expression source text into an AST."""
    p = _Parser(text)
    expr = p.parse_expr(0)
    tok = p.peek()
    if tok.kind != "eof":
        raise ClassAdSyntaxError("trailing input", tok.pos, text)
    return expr


def parse_classad(text: str) -> ClassAd:
    """Parse a full ClassAd in either record syntax or newline/;-separated
    ``name = expr`` form (the paper's Figure-style ads)."""
    stripped = text.strip()
    if stripped.startswith("["):
        lit = parse(stripped)
        if isinstance(lit, Literal) and isinstance(lit.value, ClassAd):
            return lit.value
        raise ClassAdSyntaxError("not a ClassAd record", 0, text)
    # name = expr; name = expr ... (semicolons and/or newlines)
    ad = ClassAd()
    p = _Parser(stripped)
    while p.peek().kind != "eof":
        tok = p.next()
        if tok.kind != "ident":
            raise ClassAdSyntaxError("expected attribute name", tok.pos, stripped)
        p.expect_op("=")
        ad[tok.value] = p.parse_expr(0)
        if p.at_op(";"):
            p.next()
    return ad


# ---------------------------------------------------------------------------
# Builtin function library (all deterministic)
# ---------------------------------------------------------------------------


def _need_number(v: Value) -> float:
    if _is_number(v):
        return float(v)
    raise _ClassAdError()


def _fn_wrap_exceptional(argv: Sequence[Value]) -> Optional[Value]:
    for a in argv:
        if a is Error:
            return Error
    for a in argv:
        if a is Undefined:
            return Undefined
    return None


def _builtin(name: str, *, strict: bool = True):
    def deco(fn):
        def wrapper(ctx: EvalContext, argv: List[Value]) -> Value:
            if strict:
                exc = _fn_wrap_exceptional(argv)
                if exc is not None:
                    return exc
            return fn(ctx, argv)

        BUILTINS[name] = wrapper
        return fn

    return deco


BUILTINS: Dict[str, Callable[[EvalContext, List[Value]], Value]] = {}


@_builtin("abs")
def _fn_abs(ctx, argv):
    (v,) = argv
    if _is_number(v):
        return abs(v)
    return Error


@_builtin("floor")
def _fn_floor(ctx, argv):
    return int(math.floor(_need_number(argv[0])))


@_builtin("ceiling")
def _fn_ceiling(ctx, argv):
    return int(math.ceil(_need_number(argv[0])))


BUILTINS["ceil"] = BUILTINS["ceiling"]


@_builtin("round")
def _fn_round(ctx, argv):
    # round-half-away-from-zero, like C round(); Python's round is banker's
    x = _need_number(argv[0])
    return int(math.floor(x + 0.5)) if x >= 0 else int(math.ceil(x - 0.5))


@_builtin("pow")
def _fn_pow(ctx, argv):
    base, exp = _need_number(argv[0]), _need_number(argv[1])
    try:
        r = math.pow(base, exp)
    except (ValueError, OverflowError):
        return Error
    return r


@_builtin("sqrt")
def _fn_sqrt(ctx, argv):
    x = _need_number(argv[0])
    if x < 0:
        return Error
    return math.sqrt(x)


@_builtin("log")
def _fn_log(ctx, argv):
    x = _need_number(argv[0])
    if x <= 0:
        return Error
    return math.log(x)


@_builtin("exp")
def _fn_exp(ctx, argv):
    try:
        return math.exp(_need_number(argv[0]))
    except OverflowError:
        return Error


@_builtin("int")
def _fn_int(ctx, argv):
    (v,) = argv
    if isinstance(v, bool):
        return int(v)
    if _is_number(v):
        return int(v)
    if isinstance(v, str):
        try:
            return int(float(v))
        except ValueError:
            return Error
    return Error


@_builtin("real")
def _fn_real(ctx, argv):
    (v,) = argv
    if isinstance(v, bool):
        return float(v)
    if _is_number(v):
        return float(v)
    if isinstance(v, str):
        try:
            return float(v)
        except ValueError:
            return Error
    return Error


@_builtin("string")
def _fn_string(ctx, argv):
    (v,) = argv
    if isinstance(v, str):
        return v
    if isinstance(v, bool):
        return "true" if v else "false"
    if _is_number(v):
        return repr(v)
    return Error


@_builtin("strcat")
def _fn_strcat(ctx, argv):
    parts = []
    for v in argv:
        s = _fn_string(ctx, [v])
        if s is Error:
            return Error
        parts.append(s)
    return "".join(parts)


@_builtin("strlen")
def _fn_strlen(ctx, argv):
    (v,) = argv
    return len(v) if isinstance(v, str) else Error


@_builtin("substr")
def _fn_substr(ctx, argv):
    s = argv[0]
    if not isinstance(s, str):
        return Error
    start = argv[1]
    if not isinstance(start, int) or isinstance(start, bool):
        return Error
    if len(argv) >= 3:
        length = argv[2]
        if not isinstance(length, int) or isinstance(length, bool):
            return Error
        return s[start : start + length]
    return s[start:]


@_builtin("tolower")
def _fn_tolower(ctx, argv):
    (v,) = argv
    return v.lower() if isinstance(v, str) else Error


@_builtin("toupper")
def _fn_toupper(ctx, argv):
    (v,) = argv
    return v.upper() if isinstance(v, str) else Error


@_builtin("size")
def _fn_size(ctx, argv):
    (v,) = argv
    if isinstance(v, (list, str)):
        return len(v)
    if isinstance(v, ClassAd):
        return len(v)
    return Error


@_builtin("member", strict=False)
def _fn_member(ctx, argv):
    if len(argv) != 2:
        return Error
    item, lst = argv
    if lst is Error or item is Error:
        return Error
    if lst is Undefined:
        return Undefined
    if not isinstance(lst, list):
        return Error
    for x in lst:
        if _is_identical(item, x):
            return True
        if (
            _is_number(item)
            and _is_number(x)
            and float(item) == float(x)
        ):
            return True
        if isinstance(item, str) and isinstance(x, str) and item.lower() == x.lower():
            return True
    return False


def _numeric_list(argv: List[Value]) -> Optional[List[float]]:
    if len(argv) == 1 and isinstance(argv[0], list):
        vals = argv[0]
    else:
        vals = argv
    out = []
    for v in vals:
        if not _is_number(v):
            return None
        out.append(float(v))
    return out


@_builtin("min")
def _fn_min(ctx, argv):
    vals = _numeric_list(argv)
    if not vals:
        return Error
    return min(vals)


@_builtin("max")
def _fn_max(ctx, argv):
    vals = _numeric_list(argv)
    if not vals:
        return Error
    return max(vals)


@_builtin("sum")
def _fn_sum(ctx, argv):
    vals = _numeric_list(argv)
    if vals is None:
        return Error
    return sum(vals)


@_builtin("avg")
def _fn_avg(ctx, argv):
    vals = _numeric_list(argv)
    if not vals:
        return Error
    return sum(vals) / len(vals)


@_builtin("regexp")
def _fn_regexp(ctx, argv):
    if len(argv) < 2:
        return Error
    pat, s = argv[0], argv[1]
    if not (isinstance(pat, str) and isinstance(s, str)):
        return Error
    flags = 0
    if len(argv) >= 3 and isinstance(argv[2], str) and "i" in argv[2].lower():
        flags |= _re.IGNORECASE
    try:
        return _re.search(pat, s, flags) is not None
    except _re.error:
        return Error


@_builtin("ifthenelse", strict=False)
def _fn_ifthenelse(ctx, argv):
    if len(argv) != 3:
        return Error
    c = argv[0]
    if c is Undefined or c is Error:
        return c
    if not isinstance(c, bool):
        return Error
    return argv[1] if c else argv[2]


@_builtin("isundefined", strict=False)
def _fn_isundefined(ctx, argv):
    return argv[0] is Undefined


@_builtin("iserror", strict=False)
def _fn_iserror(ctx, argv):
    return argv[0] is Error


@_builtin("isboolean", strict=False)
def _fn_isboolean(ctx, argv):
    return isinstance(argv[0], bool)


@_builtin("isinteger", strict=False)
def _fn_isinteger(ctx, argv):
    return isinstance(argv[0], int) and not isinstance(argv[0], bool)


@_builtin("isreal", strict=False)
def _fn_isreal(ctx, argv):
    return isinstance(argv[0], float)


@_builtin("isstring", strict=False)
def _fn_isstring(ctx, argv):
    return isinstance(argv[0], str)


@_builtin("islist", strict=False)
def _fn_islist(ctx, argv):
    return isinstance(argv[0], list)


@_builtin("time", strict=False)
def _fn_time(ctx, argv):
    # Deterministic: reads the injected clock from the environment.
    clk = ctx.env.get("now")
    if clk is None:
        return Error
    return int(clk)
