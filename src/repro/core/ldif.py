"""LDIF serialization and LDAP-style search filters.

The paper's GRIS publishes storage metadata "in a suitable format (for
example, LDIF)" and the broker "uses the application ClassAd to build
specialized LDAP search queries", later converting "data, represented in
LDAP format, into ClassAds" (§6: "we have, in fact, developed primitive
libraries to achieve the conversion of this attribute set").

This module is those primitive libraries:

  * :func:`dumps` / :func:`loads` — LDIF text ↔ entry dicts,
  * :class:`Filter` / :func:`parse_filter` — an RFC 4515-style search
    filter language ``(&(availableSpace>=5368709120)(objectClass=...))``
    with ``&``, ``|``, ``!``, ``=``, ``>=``, ``<=``, presence ``=*`` and
    substring ``=ab*cd`` matching,
  * :func:`entry_to_classad` / :func:`classad_to_entry` — the LDIF↔ClassAd
    conversion the paper calls "not cumbersome and worth the effort".
"""

from __future__ import annotations

import re
from dataclasses import dataclass
from functools import lru_cache
from typing import Any, Dict, Iterable, List, Mapping, Optional, Sequence, Tuple, Union

from .classads import ClassAd, Expr, ListExpr, Literal, parse as parse_expr

__all__ = [
    "Entry",
    "dumps",
    "loads",
    "Filter",
    "parse_filter",
    "FilterSyntaxError",
    "entry_to_classad",
    "classad_to_entry",
]

#: An LDAP entry: attribute → value or list of values. ``dn`` is an attribute.
Entry = Dict[str, Any]


# ---------------------------------------------------------------------------
# LDIF text format
# ---------------------------------------------------------------------------


def _format_value(v: Any) -> str:
    if isinstance(v, bool):
        return "TRUE" if v else "FALSE"
    if isinstance(v, float):
        return repr(v)
    return str(v)


def dumps(entries: Iterable[Entry]) -> str:
    """Serialize entries to LDIF text. ``dn`` is emitted first; multi-valued
    attributes repeat the attribute line, per LDIF."""
    blocks: List[str] = []
    for entry in entries:
        lines: List[str] = []
        if "dn" in entry:
            lines.append(f"dn: {entry['dn']}")
        for k, v in entry.items():
            if k == "dn":
                continue
            values = v if isinstance(v, (list, tuple)) else [v]
            for item in values:
                lines.append(f"{k}: {_format_value(item)}")
        blocks.append("\n".join(lines))
    return "\n\n".join(blocks) + ("\n" if blocks else "")


_NUM_RE = re.compile(r"^-?\d+$")
_FLOAT_RE = re.compile(r"^-?(\d+\.\d*|\.\d+|\d+)([eE][-+]?\d+)?$")


def _parse_value(s: str) -> Any:
    if s == "TRUE":
        return True
    if s == "FALSE":
        return False
    if _NUM_RE.match(s):
        return int(s)
    if _FLOAT_RE.match(s):
        try:
            return float(s)
        except ValueError:  # pragma: no cover
            return s
    return s


def loads(text: str) -> List[Entry]:
    """Parse LDIF text into entry dicts (typed: ints/floats/bools restored).

    Repeated attributes accumulate into lists; line continuations (leading
    space) are honoured.
    """
    entries: List[Entry] = []
    current: Optional[Entry] = None
    # unfold continuations
    unfolded: List[str] = []
    for line in text.splitlines():
        if line.startswith(" ") and unfolded:
            unfolded[-1] += line[1:]
        else:
            unfolded.append(line)
    for line in unfolded:
        if not line.strip():
            if current:
                entries.append(current)
                current = None
            continue
        if line.lstrip().startswith("#"):
            continue
        if ":" not in line:
            raise ValueError(f"malformed LDIF line: {line!r}")
        k, _, v = line.partition(":")
        k = k.strip()
        v = _parse_value(v.strip())
        if current is None:
            current = {}
        if k in current:
            prev = current[k]
            if isinstance(prev, list):
                prev.append(v)
            else:
                current[k] = [prev, v]
        else:
            current[k] = v
    if current:
        entries.append(current)
    return entries


# ---------------------------------------------------------------------------
# LDAP search filters (RFC 4515 subset)
# ---------------------------------------------------------------------------


class FilterSyntaxError(ValueError):
    pass


@dataclass(frozen=True)
class Filter:
    """A parsed LDAP search filter node."""

    op: str  # '&' | '|' | '!' | '=' | '>=' | '<=' | 'present' | 'substr'
    children: Tuple["Filter", ...] = ()
    attr: str = ""
    value: Any = None

    def matches(self, entry: Mapping[str, Any]) -> bool:
        op = self.op
        if op == "&":
            return all(c.matches(entry) for c in self.children)
        if op == "|":
            return any(c.matches(entry) for c in self.children)
        if op == "!":
            return not self.children[0].matches(entry)

        # attribute comparisons: case-insensitive key lookup; multi-valued
        # attributes match if ANY value matches (LDAP semantics).
        low = self.attr.lower()
        found = None
        for k, v in entry.items():
            if k.lower() == low:
                found = v
                break
        if found is None:
            return False
        values = found if isinstance(found, (list, tuple)) else [found]

        if op == "present":
            return True
        for v in values:
            if op == "=" and _eq(v, self.value):
                return True
            if op == ">=" and _cmp_ge(v, self.value):
                return True
            if op == "<=" and _cmp_le(v, self.value):
                return True
            if op == "substr" and _substr(v, self.value):
                return True
        return False

    def attributes(self) -> List[str]:
        """All attribute names referenced by this filter (for GRIS
        projection — the broker requests only 'the attributes of
        interest')."""
        out: List[str] = []
        if self.attr:
            out.append(self.attr)
        for c in self.children:
            out.extend(c.attributes())
        return out

    def __str__(self) -> str:
        if self.op in ("&", "|"):
            return "(%s%s)" % (self.op, "".join(map(str, self.children)))
        if self.op == "!":
            return "(!%s)" % self.children[0]
        if self.op == "present":
            return f"({self.attr}=*)"
        if self.op == "substr":
            return f"({self.attr}={'*'.join(self.value)})"
        return f"({self.attr}{self.op}{_format_value(self.value)})"


def _coerce_pair(a: Any, b: Any) -> Optional[Tuple[Any, Any]]:
    an = isinstance(a, (int, float)) and not isinstance(a, bool)
    bn = isinstance(b, (int, float)) and not isinstance(b, bool)
    if an and bn:
        return float(a), float(b)
    if an or bn:
        # one side numeric, other string: try to coerce the string
        try:
            return float(a), float(b)
        except (TypeError, ValueError):
            return None
    return str(a).lower(), str(b).lower()


def _eq(a: Any, b: Any) -> bool:
    pair = _coerce_pair(a, b)
    return pair is not None and pair[0] == pair[1]


def _cmp_ge(a: Any, b: Any) -> bool:
    pair = _coerce_pair(a, b)
    return pair is not None and pair[0] >= pair[1]


def _cmp_le(a: Any, b: Any) -> bool:
    pair = _coerce_pair(a, b)
    return pair is not None and pair[0] <= pair[1]


def _substr(value: Any, parts: Sequence[str]) -> bool:
    s = str(value).lower()
    pos = 0
    for i, part in enumerate(parts):
        p = part.lower()
        if not p:
            continue
        j = s.find(p, pos)
        if j < 0:
            return False
        if i == 0 and parts[0] and j != 0:
            return False
        pos = j + len(p)
    if parts and parts[-1] and not s.endswith(parts[-1].lower()):
        return False
    return True


class _FParser:
    def __init__(self, text: str):
        self.text = text
        self.i = 0

    def error(self, msg: str) -> FilterSyntaxError:
        return FilterSyntaxError(f"{msg} at {self.i} in {self.text!r}")

    def parse(self) -> Filter:
        f = self.parse_filter()
        if self.i != len(self.text.strip()):
            # allow trailing whitespace only
            if self.text[self.i :].strip():
                raise self.error("trailing input")
        return f

    def parse_filter(self) -> Filter:
        self._skip_ws()
        if self.i >= len(self.text) or self.text[self.i] != "(":
            raise self.error("expected '('")
        self.i += 1
        self._skip_ws()
        ch = self.text[self.i] if self.i < len(self.text) else ""
        if ch in "&|":
            self.i += 1
            children = []
            self._skip_ws()
            while self.i < len(self.text) and self.text[self.i] == "(":
                children.append(self.parse_filter())
                self._skip_ws()
            self._expect(")")
            if not children:
                raise self.error("empty composite filter")
            return Filter(ch, tuple(children))
        if ch == "!":
            self.i += 1
            child = self.parse_filter()
            self._skip_ws()
            self._expect(")")
            return Filter("!", (child,))
        # simple: attr OP value
        m = re.match(r"([A-Za-z_][A-Za-z0-9_.;-]*)\s*(>=|<=|=)", self.text[self.i :])
        if not m:
            raise self.error("expected attribute comparison")
        attr, op = m.group(1), m.group(2)
        self.i += m.end()
        # value: up to the matching close paren
        depth = 0
        j = self.i
        while j < len(self.text):
            c = self.text[j]
            if c == "(":
                depth += 1
            elif c == ")":
                if depth == 0:
                    break
                depth -= 1
            j += 1
        if j >= len(self.text):
            raise self.error("unterminated filter")
        raw = self.text[self.i : j].strip()
        self.i = j + 1  # consume ')'
        if op == "=":
            if raw == "*":
                return Filter("present", attr=attr)
            if "*" in raw:
                return Filter("substr", attr=attr, value=tuple(raw.split("*")))
            return Filter("=", attr=attr, value=_parse_value(raw))
        return Filter(op, attr=attr, value=_parse_value(raw))

    def _skip_ws(self) -> None:
        while self.i < len(self.text) and self.text[self.i].isspace():
            self.i += 1

    def _expect(self, ch: str) -> None:
        if self.i >= len(self.text) or self.text[self.i] != ch:
            raise self.error(f"expected {ch!r}")
        self.i += 1


def parse_filter(text: str) -> Filter:
    """Parse an RFC 4515-style LDAP search filter."""
    return _FParser(text).parse()


# ---------------------------------------------------------------------------
# LDIF ↔ ClassAd conversion (the paper's "primitive libraries")
# ---------------------------------------------------------------------------

#: Attributes whose LDIF string values are ClassAd *expressions*, not data.
#: The paper's ``requirements`` policy attribute is the canonical case.
_EXPR_ATTRS = {"requirements", "rank"}


@lru_cache(maxsize=512)
def _parse_expr_cached(src: str) -> Expr:
    """Parsed policy expressions, memoized: a grid's GRIS entries repeat a
    handful of distinct ``requirements``/``rank`` sources thousands of
    times (expression trees are immutable, so sharing is safe)."""
    return parse_expr(src)


def entry_to_classad(entry: Mapping[str, Any], *, expr_attrs: Optional[set] = None) -> ClassAd:
    """Convert an LDIF entry into a ClassAd (Match Phase step 1).

    Scalar values become literals; the ``requirements`` / ``rank`` strings
    are parsed as ClassAd expressions so site policy survives conversion.
    ``dn`` and ``objectClass`` ride along as plain string attributes.

    This sits on the GRIS hot path (every flattened-view row of every
    snapshot build), so the common scalar cases skip ``__setitem__``'s
    isinstance ladder and populate the ad's slots directly; anything
    exotic falls back to the full assignment path.
    """
    exprs = _EXPR_ATTRS if expr_attrs is None else expr_attrs
    ad = ClassAd()
    attrs = ad._attrs
    spelling = ad._spelling
    for k, v in entry.items():
        kl = k.lower()
        tv = v.__class__
        if tv is str:
            e = _parse_expr_cached(v) if kl in exprs else Literal(v)
        elif tv is int or tv is float or tv is bool:
            e = Literal(v)
        elif tv is list or tv is tuple:
            e = ListExpr(tuple(x if isinstance(x, Expr) else Literal(x) for x in v))
        else:
            ad[k] = v  # Expr / ClassAd / None: full __setitem__ dispatch
            continue
        attrs[kl] = e
        spelling[kl] = k
    return ad


def classad_to_entry(ad: ClassAd, *, dn: Optional[str] = None) -> Entry:
    """Convert a ClassAd back to an LDIF entry. Expression-valued attributes
    are serialized as their source form; evaluated literals as values."""
    entry: Entry = {}
    if dn is not None:
        entry["dn"] = dn
    for k, expr in ad.items():
        if isinstance(expr, Literal) and not isinstance(expr.value, ClassAd):
            v = expr.value
            entry[k] = list(v) if isinstance(v, list) else v
        else:
            entry[k] = repr(expr)
    return entry
