"""LRU plan cache: skip ClassAd tree traversal for repeated request shapes.

Fleet traffic is template-heavy: thousands of clients submit requests
minted from the same few helpers (``default_read_request`` et al.), so the
broker keeps re-lowering structurally identical (requirements, rank)
pairs. This cache fronts the two compilation tiers:

  * :func:`repro.kernels.matchrank.ops.lower_request` → ``KernelPlan``
    (the Pallas / batched-kernel tier),
  * :func:`repro.core.compile.compile_program` → ``CompiledProgram`` and
    ``compile_policy`` → policy closures (the columnar tier).

Keys canonicalize the *content* of the request — the source of every
attribute expression (constants like ``reqdSpace = 5G`` are folded into
thresholds at lowering time, so they must key the entry) — plus the
column vocabulary and the evaluation environment. ``CompileError``s are
cached too (negative caching): a request that falls outside a tier's
subset skips the failed traversal on every retry and falls through to
the next tier immediately.
"""

from __future__ import annotations

from collections import OrderedDict
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

from .classads import ClassAd
from .compile import CompileError, CompiledProgram, compile_policy, compile_program

__all__ = ["PlanCache", "request_cache_key"]


def request_cache_key(
    request: ClassAd,
    vocab_key: Tuple[str, ...],
    env: Optional[Dict[str, Any]] = None,
) -> Tuple:
    """Canonical structural identity of (request, vocabulary, env).

    Two requests with identical attribute sources get identical keys even
    if parsed from different ad objects; any constant that lowering would
    fold (e.g. ``my.reqdSpace``) is part of the key by construction.
    """
    attrs = tuple(sorted((name.lower(), repr(expr)) for name, expr in request.items()))
    env_key = tuple(sorted((k.lower(), repr(v)) for k, v in (env or {}).items()))
    return (attrs, tuple(vocab_key), env_key)


class PlanCache:
    """A bounded LRU over compiled request artifacts.

    One instance per broker (decentralized, like the matchmaker) or one
    shared instance per serving process — entries are immutable once
    built, so sharing is safe for concurrent readers.
    """

    def __init__(self, maxsize: int = 256, *, metrics: Any = None):
        self.maxsize = int(maxsize)
        self._entries: "OrderedDict[Tuple, Any]" = OrderedDict()
        # sharded top-k results: key → (touched {shard: epoch}, value)
        self._topk: "OrderedDict[Tuple, Tuple[Dict[int, int], Any]]" = OrderedDict()
        self.stats = {
            "hits": 0,
            "misses": 0,
            "evictions": 0,
            "negative_hits": 0,
            "topk_hits": 0,
            "topk_misses": 0,
            "topk_stale": 0,
        }
        # optional mirror into an obs MetricsRegistry (labels: event=...);
        # self.stats stays the source of truth for exact-count consumers
        self._mctr = (
            {
                ev: metrics.counter(
                    "plan_cache_events_total", "plan-cache lookups by outcome", event=ev
                )
                for ev in self.stats
            }
            if metrics is not None
            else None
        )

    def _bump(self, event: str) -> None:
        self.stats[event] += 1
        if self._mctr is not None:
            self._mctr[event].inc()

    # ------------------------------------------------------------- plumbing
    def _get(self, key: Tuple) -> Tuple[bool, Any]:
        if key in self._entries:
            self._entries.move_to_end(key)
            val = self._entries[key]
            self._bump("negative_hits" if isinstance(val, CompileError) else "hits")
            return True, val
        self._bump("misses")
        return False, None

    def _put(self, key: Tuple, val: Any) -> None:
        self._entries[key] = val
        self._entries.move_to_end(key)
        while len(self._entries) > self.maxsize:
            self._entries.popitem(last=False)
            self._bump("evictions")

    def _cached_compile(self, key: Tuple, build: Callable[[], Any]) -> Any:
        hit, val = self._get(key)
        if hit:
            if isinstance(val, CompileError):
                raise CompileError(str(val))
            return val
        try:
            val = build()
        except CompileError as e:
            self._put(key, e)
            raise
        self._put(key, val)
        return val

    # ------------------------------------------------------------ interfaces
    def kernel_plan(
        self,
        request: ClassAd,
        attr_names: Sequence[str],
        *,
        env: Optional[Dict[str, Any]] = None,
    ):
        """Cached :func:`lower_request` → ``KernelPlan`` (raises
        ``CompileError`` — negatively cached — outside the kernel subset)."""
        # deferred: kernels pull in jax/pallas
        from repro.kernels.matchrank.ops import lower_request

        vocab = tuple(n.lower() for n in attr_names)
        key = ("kernel",) + request_cache_key(request, vocab, env)
        return self._cached_compile(
            key, lambda: lower_request(request, vocab, env=env)
        )

    def columnar_program(
        self,
        request: ClassAd,
        vocab_key: Tuple[str, ...],
        *,
        env: Optional[Dict[str, Any]] = None,
    ) -> CompiledProgram:
        """Cached :func:`compile_program` against a named column set."""
        vocab = tuple(n.lower() for n in vocab_key)
        present = frozenset(vocab)
        key = ("columnar",) + request_cache_key(request, vocab, env)
        return self._cached_compile(
            key,
            lambda: compile_program(
                request, column_names=lambda n: n.lower() in present, env=env
            ),
        )

    def policy_fn(
        self,
        policy_src: str,
        request: ClassAd,
        vocab_key: Tuple[str, ...],
        *,
        env: Optional[Dict[str, Any]] = None,
    ) -> Callable:
        """Cached server-policy compile (policy text × request constants)."""
        from .classads import parse as parse_expr

        vocab = tuple(n.lower() for n in vocab_key)
        present = frozenset(vocab)
        key = ("policy", policy_src) + request_cache_key(request, vocab, env)
        return self._cached_compile(
            key,
            lambda: compile_policy(
                parse_expr(policy_src),
                request,
                column_names=lambda n: n.lower() in present,
                env=env,
            ),
        )

    # ------------------------------------------- sharded top-k results
    def topk_get(self, key: Tuple, shard_epochs: Sequence[int]) -> Tuple[bool, Any]:
        """Look up a cached selection result under per-shard epoch keys.

        A hit requires every shard the result's candidate set *touched*
        to still be at the epoch it was computed against — so one site's
        ``update_rows`` invalidates only results that drew candidates
        from that shard, never the rest of the federation's (DESIGN.md
        §9 cache keying). Stale entries are dropped eagerly."""
        entry = self._topk.get(key)
        if entry is None:
            self._bump("topk_misses")
            return False, None
        touched, val = entry
        for g, ep in touched.items():
            if g >= len(shard_epochs) or int(shard_epochs[g]) != ep:
                del self._topk[key]
                self._bump("topk_stale")
                self._bump("topk_misses")
                return False, None
        self._topk.move_to_end(key)
        self._bump("topk_hits")
        return True, val

    def topk_put(self, key: Tuple, touched: Dict[int, int], val: Any) -> None:
        """Store a selection result with the {shard: epoch} set its
        candidates came from."""
        self._topk[key] = (dict(touched), val)
        self._topk.move_to_end(key)
        while len(self._topk) > self.maxsize:
            self._topk.popitem(last=False)
            self._bump("evictions")

    def clear(self) -> None:
        self._entries.clear()
        self._topk.clear()

    def __len__(self) -> int:
        return len(self._entries) + len(self._topk)
