"""Grid Index Information Service (GIIS) — the discovery index of §3.

"Users will typically direct broad queries to GIIS to discover resources
and then drill down with direct queries to GRIS to get up-to-date,
detailed information about individual resources."

A GIIS holds *registrations* from GRIS servers (or child GIISs — the MDS
hierarchy), answers broad searches from a cached snapshot with a
registration-level TTL, and hands back GRIS references for drill-down.
The cache models MDS behaviour: index answers may be slightly stale; the
authoritative fresh answer always comes from the resource's own GRIS.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Tuple, Union

from .gris import Clock, StorageGRIS
from .ldif import Entry, Filter, parse_filter

__all__ = ["Registration", "GIIS"]


@dataclass
class Registration:
    """One GRIS (or child GIIS) registered with an index."""

    name: str
    service: Union[StorageGRIS, "GIIS"]
    registered_at: float
    snapshot: List[Entry] = field(default_factory=list)
    snapshot_at: float = float("-inf")
    #: bumped only when a refresh actually changed the entries — the key
    #: sharded snapshots use to skip re-ingesting unchanged registrants
    epoch: int = 0


class GIIS:
    """An index over GRIS servers, optionally hierarchical.

    Parameters
    ----------
    name:
        Index name (e.g. ``o=grid`` or a zone like ``o=pod-3``).
    cache_ttl:
        How long an index-level snapshot of a registrant's entries is
        served before being refreshed from the registrant.
    """

    def __init__(self, name: str, *, clock: Optional[Clock] = None, cache_ttl: float = 30.0):
        self.name = name
        self.clock = clock or Clock()
        self.cache_ttl = cache_ttl
        self._registry: Dict[str, Registration] = {}
        self.query_count = 0
        self.refresh_count = 0

    # -- registration ------------------------------------------------------
    def register(self, name: str, service: Union[StorageGRIS, "GIIS"]) -> None:
        self._registry[name] = Registration(name, service, self.clock.now())

    def deregister(self, name: str) -> None:
        self._registry.pop(name, None)

    def registrants(self) -> List[str]:
        return sorted(self._registry)

    def lookup(self, name: str) -> Optional[Union[StorageGRIS, "GIIS"]]:
        reg = self._registry.get(name)
        return reg.service if reg else None

    # -- search --------------------------------------------------------------
    def _snapshot(self, reg: Registration) -> List[Entry]:
        now = self.clock.now()
        if now - reg.snapshot_at >= self.cache_ttl:
            svc = reg.service
            if isinstance(svc, GIIS):
                new = svc.search(None)
            else:
                new = svc.entries()
            if new != reg.snapshot:
                reg.epoch += 1
            reg.snapshot = new
            reg.snapshot_at = now
            self.refresh_count += 1
        return reg.snapshot

    def registrant_epochs(self, *, refresh: bool = False) -> Dict[str, int]:
        """Per-registrant change counters — lets a
        :class:`~repro.core.snapshot_sharded.ShardedSnapshot` tell which
        shards' source data moved since it was built. With
        ``refresh=True`` each registrant is TTL-polled first (an epoch
        can only move when someone polls), without copying any entries."""
        if refresh:
            for reg in self._registry.values():
                self._snapshot(reg)
        return {name: reg.epoch for name, reg in self._registry.items()}

    def registrant_entries(self, name: str) -> List[Entry]:
        """One registrant's entries (TTL-fresh), as independent copies —
        the per-shard drill-down of the paper's two-phase query pattern."""
        reg = self._registry[name]
        return [dict(e) for e in self._snapshot(reg)]

    def search(
        self,
        flt: Optional[Filter | str] = None,
        attrs: Optional[Sequence[str]] = None,
    ) -> List[Entry]:
        """Broad search across every registrant (cached snapshots)."""
        self.query_count += 1
        if isinstance(flt, str):
            flt = parse_filter(flt)
        out: List[Entry] = []
        for name in sorted(self._registry):
            for entry in self._snapshot(self._registry[name]):
                if flt is None or flt.matches(entry):
                    if attrs is None:
                        out.append(dict(entry))
                    else:
                        want = {a.lower() for a in attrs} | {"dn", "objectclass"}
                        out.append({k: v for k, v in entry.items() if k.lower() in want})
        return out

    def discover(self, flt: Optional[Filter | str] = None) -> List[Tuple[str, StorageGRIS]]:
        """Discovery: which GRIS servers have entries matching ``flt``?

        Returns (registrant name, GRIS) pairs for drill-down — the paper's
        two-phase "broad query to GIIS, direct query to GRIS" pattern.
        Hierarchy is flattened (child GIISs are recursed into).
        """
        if isinstance(flt, str):
            flt = parse_filter(flt)
        out: List[Tuple[str, StorageGRIS]] = []
        for name in sorted(self._registry):
            reg = self._registry[name]
            svc = reg.service
            if isinstance(svc, GIIS):
                out.extend(svc.discover(flt))
                continue
            for entry in self._snapshot(reg):
                if flt is None or flt.matches(entry):
                    out.append((name, svc))
                    break
        return out
