"""Sharded device-resident snapshots: one shard per GRIS/GIIS registrant.

The flat :class:`~repro.core.snapshot.ReplicaSnapshot` re-pushes every
column when an epoch rolls — fine at S=10k, hopeless at the GIIS
federation scale where one site's dynamic-attribute refresh would force
re-uploading a million untouched rows. A :class:`ShardedSnapshot`
partitions the replica rows along the information-service topology:

  * rows are grouped into named shards (per-GRIS / per-GIIS-registrant),
    stacked into ``[G, S_shard, A_PAD]`` blocks over ONE shared attribute
    vocabulary — the operand shape of the vmapped per-shard matchrank
    (:mod:`repro.kernels.matchrank.sharded`),
  * **delta refresh**: ``update_rows``/``refresh`` track dirty shards and
    re-upload only those — ``shard_epochs[g]`` bumps per dirty shard and
    ``pushed_rows`` accounts exactly what went to the device, so a 1%%
    update ships ~1%% of the rows,
  * per-shard rank-order caches: one site's update re-sorts only its own
    shard's rows, not the federation,
  * **double-buffered epoch swap** for free: device blocks are immutable
    per-shard ``jax.Array``s (replaced, never mutated) and the stacked
    ``[G, S_shard, A_PAD]`` kernel operand is rebuilt lazily per version,
    so any in-flight selection holding references to the previous arrays
    keeps computing against a consistent epoch while the snapshot swaps.

The global row space is the shard-major concatenation of live rows (shard
order = sorted shard names), so brokers keep using plain integer rows;
``shard_of_row``/``offsets`` translate between the two.
"""

from __future__ import annotations

import itertools
import zlib
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from .compile import ColumnTable
from .snapshot import _round_up, entry_row, numeric_attr_names

__all__ = ["ShardedSnapshot", "shard_by_hash"]

_UID = itertools.count(1)


def shard_by_hash(key: str, n_shards: int) -> int:
    """Deterministic endpoint→shard bucket (crc32 — platform-stable,
    unlike ``hash()`` under PYTHONHASHSEED)."""
    return zlib.crc32(key.encode("utf-8")) % max(1, int(n_shards))


class ShardedSnapshot:
    """Per-registrant sharded candidate table, padded and device-resident.

    Parameters
    ----------
    shard_entries:
        shard name → list of flattened GRIS views (one per candidate
        row). Shard order is ``sorted(shard_entries)``; the global row
        space concatenates the shards in that order.
    attr_names:
        Shared column vocabulary (lower-cased). Defaults to the union of
        numeric attributes across *all* shards.
    block_s:
        Row padding granularity per shard (the kernel's S-block).
    device:
        Keep the stacked ``[G, S_shard, A_PAD]`` f32 blocks resident as
        ``jax.Array``s.
    """

    def __init__(
        self,
        shard_entries: Mapping[str, Sequence[Mapping[str, Any]]],
        attr_names: Optional[Sequence[str]] = None,
        *,
        block_s: int = 512,
        device: bool = True,
        epoch: int = 0,
    ):
        if not shard_entries:
            raise ValueError("ShardedSnapshot needs at least one shard")
        self.shard_names: List[str] = sorted(shard_entries)
        self.entries_by_shard: Dict[str, List[Dict[str, Any]]] = {
            name: [dict(e) for e in shard_entries[name]] for name in self.shard_names
        }
        all_entries = [
            e for name in self.shard_names for e in self.entries_by_shard[name]
        ]
        if attr_names is None:
            attr_names = numeric_attr_names(all_entries)
        self.attr_names: List[str] = [n.lower() for n in attr_names]
        self._index = {n: j for j, n in enumerate(self.attr_names)}
        self.block_s = int(block_s)
        self.epoch = int(epoch)
        self.version = 0  # bumped on every mutation
        self._device = bool(device)
        #: identity for result caches (two snapshots must never share keys)
        self.uid = next(_UID)

        self.g = len(self.shard_names)
        self.counts = np.array(
            [len(self.entries_by_shard[n]) for n in self.shard_names], dtype=np.int64
        )
        self.offsets = np.zeros((self.g,), dtype=np.int64)
        np.cumsum(self.counts[:-1], out=self.offsets[1:])
        self.n = int(self.counts.sum())
        a = len(self.attr_names)
        self.a_pad = max(_round_up(a, 128), 128)
        max_count = int(self.counts.max()) if self.g else 1
        self.s_shard_pad = max(_round_up(max(max_count, 1), self.block_s), self.block_s)

        self._attrs = np.zeros((self.g, self.s_shard_pad, self.a_pad), np.float32)
        self._valid = np.zeros((self.g, self.s_shard_pad, self.a_pad), np.float32)
        for gi in range(self.g):
            self._fill_shard_host(gi)

        #: per-shard delta-refresh counters — the PlanCache's sharded
        #: result-cache validity key: a cached top-k stays valid iff every
        #: shard that contributed (or could have contributed) candidates
        #: still carries the epoch recorded at store time.
        self.shard_epochs = np.zeros((self.g,), dtype=np.int64)
        #: device-upload accounting: live rows shipped so far. Proves the
        #: delta behaviour in tests/benchmarks (``.at[g].set`` replaces the
        #: whole stacked array object, so identity can't).
        self.pushed_rows = 0
        self.push_counts = np.zeros((self.g,), dtype=np.int64)
        # (w bytes, bias) → per-shard [(shard_epoch, order, svals) | None]
        self._rank_orders: Dict[
            Tuple[bytes, float], List[Optional[Tuple[int, np.ndarray, np.ndarray]]]
        ] = {}
        self._shard_logical: List[Optional[Tuple[int, np.ndarray, np.ndarray]]] = [
            None
        ] * self.g
        self._attrs_dev = None
        self._valid_dev = None
        self._stacked_dev = None  # lazy (version, attrs, valid) kernel stack
        self._flat_dev = None  # lazy flat-compatible padded block
        if self._device:
            self._push_all()

    # ------------------------------------------------------------- building
    def _fill_shard_host(self, gi: int) -> None:
        name = self.shard_names[gi]
        self._attrs[gi] = 0.0
        self._valid[gi] = 0.0
        for li, e in enumerate(self.entries_by_shard[name]):
            vals, ok = entry_row(e, self._index, self.a_pad)
            self._attrs[gi, li] = vals
            self._valid[gi, li] = ok

    def _push_all(self) -> None:
        import jax.numpy as jnp

        self._attrs_dev = [jnp.asarray(self._attrs[gi]) for gi in range(self.g)]
        self._valid_dev = [jnp.asarray(self._valid[gi]) for gi in range(self.g)]
        self.pushed_rows += self.n
        self.push_counts += 1

    def _push_shards(self, dirty: Sequence[int]) -> None:
        """Re-upload only the dirty shards. Device blocks are held
        per-shard (one ``[S_shard, A_PAD]`` array each), so a 1-shard
        delta ships 1/G of the bytes — the stacked view the vmapped
        kernel wants is materialized lazily in
        :meth:`shard_device_columns`, cached per version."""
        if self._attrs_dev is None or not dirty:
            return
        import jax.numpy as jnp

        gidx = sorted(int(g) for g in dirty)
        for gi in gidx:
            self._attrs_dev[gi] = jnp.asarray(self._attrs[gi])
            self._valid_dev[gi] = jnp.asarray(self._valid[gi])
        self.pushed_rows += int(self.counts[gidx].sum())
        self.push_counts[gidx] += 1

    # ------------------------------------------------------------ accessors
    def shard_of_row(self, row: int) -> int:
        """Global row index → owning shard index."""
        if not (0 <= row < self.n):
            raise IndexError(f"row {row} outside snapshot (n={self.n})")
        return int(np.searchsorted(self.offsets, row, side="right") - 1)

    def shard_device_columns(self):
        """→ (attrs [G, S_shard, A_PAD], valid, counts [G]) — the stacked
        per-shard blocks the vmapped kernel consumes. The stack is built
        lazily and cached per version: the sparse CPU walk never pays for
        it, and a delta refresh only re-stacks when the kernel tier next
        asks (in-flight consumers keep their previous epoch's stack —
        the double-buffered swap)."""
        if self._attrs_dev is None:
            return self._attrs, self._valid, self.counts
        hit = self._stacked_dev
        if hit is not None and hit[0] == self.version:
            return hit[1], hit[2], self.counts
        import jax.numpy as jnp

        attrs = jnp.stack(self._attrs_dev)
        valid = jnp.stack(self._valid_dev)
        self._stacked_dev = (self.version, attrs, valid)
        return attrs, valid, self.counts

    def device_columns(self):
        """Flat-compatible view → (attrs [S_PAD, A_PAD], valid, n): the
        live rows of every shard concatenated and re-padded, for callers
        that speak the flat :class:`ReplicaSnapshot` protocol (the dense
        batched fallback). Materialized lazily, cached per version — the
        sharded hot paths never touch it."""
        hit = self._flat_dev
        if hit is not None and hit[0] == self.version:
            return hit[1], hit[2], self.n
        attrs_l, valid_l = self.logical_columns()
        s_pad = max(_round_up(max(self.n, 1), self.block_s), self.block_s)
        attrs = np.zeros((s_pad, self.a_pad), np.float32)
        valid = np.zeros((s_pad, self.a_pad), np.float32)
        a = len(self.attr_names)
        attrs[: self.n, :a] = attrs_l
        valid[: self.n, :a] = valid_l
        if self._device:
            import jax.numpy as jnp

            attrs, valid = jnp.asarray(attrs), jnp.asarray(valid)
        self._flat_dev = (self.version, attrs, valid)
        return attrs, valid, self.n

    def shard_logical_columns(self, gi: int) -> Tuple[np.ndarray, np.ndarray]:
        """→ contiguous (attrs [c_g, A] f32, valid [c_g, A] bool) over one
        shard's live rows at logical width — the sparse walk's operand.
        Cached per (shard, shard_epoch)."""
        hit = self._shard_logical[gi]
        if hit is not None and hit[0] == self.shard_epochs[gi]:
            return hit[1], hit[2]
        a = len(self.attr_names)
        c = int(self.counts[gi])
        attrs = np.ascontiguousarray(self._attrs[gi, :c, :a])
        valid = np.ascontiguousarray(self._valid[gi, :c, :a] > 0.5)
        self._shard_logical[gi] = (int(self.shard_epochs[gi]), attrs, valid)
        return attrs, valid

    def logical_columns(self) -> Tuple[np.ndarray, np.ndarray]:
        """Global contiguous (attrs [n, A] f32, valid [n, A] bool) in
        shard-major row order — the flat-protocol view."""
        hit = getattr(self, "_logical", None)
        if hit is not None and hit[0] == self.version:
            return hit[1], hit[2]
        parts = [self.shard_logical_columns(gi) for gi in range(self.g)]
        attrs = (
            np.concatenate([p[0] for p in parts])
            if self.n
            else np.zeros((0, len(self.attr_names)), np.float32)
        )
        valid = (
            np.concatenate([p[1] for p in parts])
            if self.n
            else np.zeros((0, len(self.attr_names)), bool)
        )
        self._logical = (self.version, attrs, valid)
        return attrs, valid

    def table(self) -> ColumnTable:
        """f64 :class:`ColumnTable` over the global live rows — same
        semantics as the flat snapshot's."""
        attrs, valid = self.logical_columns()
        tbl = ColumnTable(self.n)
        for name, j in self._index.items():
            tbl.add(name, attrs[:, j].astype(np.float64), valid[:, j].copy())
        return tbl

    def vocab_key(self) -> Tuple[str, ...]:
        return tuple(self.attr_names)

    def shard_rank_order(
        self, gi: int, weights: np.ndarray, bias: float = 0.0
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Per-shard (order, svals) with the flat snapshot's Condor rank
        semantics, over *local* row indices. Cached per (weights, bias,
        shard_epoch): one shard's delta refresh re-sorts only its own
        ``S/G`` rows."""
        a = len(self.attr_names)
        w = np.asarray(weights, dtype=np.float32).reshape(-1)
        if w.shape[0] < a:
            w = np.pad(w, (0, a - w.shape[0]))
        w = w[:a]
        key = (w.tobytes(), float(bias))
        per = self._rank_orders.get(key)
        if per is None:
            per = [None] * self.g
            self._rank_orders[key] = per
        hit = per[gi]
        if hit is not None and hit[0] == self.shard_epochs[gi]:
            return hit[1], hit[2]
        attrs, valid = self.shard_logical_columns(gi)
        svals = (attrs @ w + np.float32(bias)).astype(np.float32)
        wactive = w != 0
        if wactive.any():
            bad = ~valid[:, wactive].all(axis=1)
            svals[bad] = 0.0
        order = np.argsort(-svals, kind="stable")
        per[gi] = (int(self.shard_epochs[gi]), order, svals)
        return order, svals

    # ------------------------------------------------------------ mutation
    def update_rows(self, updates: Mapping[int, Mapping[str, Any]]) -> List[int]:
        """Incremental refresh keyed by *global* row: merge attribute
        dicts into existing rows, re-upload only the shards touched.
        Returns the dirty shard indices."""
        if not updates:
            return []
        from .snapshot import _numeric
        import math

        rows_sorted = np.fromiter(sorted(updates), dtype=np.int64, count=len(updates))
        if int(rows_sorted[0]) < 0 or int(rows_sorted[-1]) >= self.n:
            bad = rows_sorted[0] if rows_sorted[0] < 0 else rows_sorted[-1]
            raise IndexError(f"row {int(bad)} outside snapshot (n={self.n})")
        gis = np.searchsorted(self.offsets, rows_sorted, side="right") - 1
        dirty: Dict[int, bool] = {}
        for row, gi in zip(rows_sorted.tolist(), gis.tolist()):
            name = self.shard_names[gi]
            li = row - int(self.offsets[gi])
            entry = self.entries_by_shard[name][li]
            upd = updates[row]
            # spelling-aware merge: attribute names are case-insensitive
            # (ClassAd semantics), so an update must overwrite the
            # resident spelling, not add a second key for the same column
            lower_of = {kk.lower(): kk for kk in entry}
            for k, v in upd.items():
                kk = lower_of.setdefault(k.lower(), k)
                entry[kk] = v
            # scalar fast path: a purely numeric in-vocabulary update can
            # write its cells directly — exact vs an entry_row recompute
            # as long as no two entry spellings collide on a column
            fast = len(entry) == len(lower_of)
            if fast:
                for k, v in upd.items():
                    j = self._index.get(k.lower())
                    x = _numeric(v)
                    if j is None or x is None or not math.isfinite(x):
                        fast = False
                        break
                    self._attrs[gi, li, j] = np.float32(x)
                    self._valid[gi, li, j] = 1.0
            if not fast:
                vals, ok = entry_row(entry, self._index, self.a_pad)
                self._attrs[gi, li] = vals
                self._valid[gi, li] = ok
            dirty[gi] = True
        changed = sorted(dirty)
        self._push_shards(changed)
        self.shard_epochs[changed] += 1
        self.version += 1
        return changed

    def refresh(
        self, shard_entries: Mapping[str, Sequence[Mapping[str, Any]]]
    ) -> List[str]:
        """Epoch roll with delta detection: compare each shard's new entry
        list against the resident one; only *changed* shards are refilled
        and re-uploaded. Returns the changed shard names.

        Raises ``ValueError`` when the shard set or a shard's row count
        changed, or when a new numeric attribute falls outside the shared
        vocabulary — those alter the row space / column space, and the
        caller must fall back to a full rebuild (:meth:`new_epoch`).
        """
        if sorted(shard_entries) != self.shard_names:
            raise ValueError("shard set changed — rebuild with new_epoch()")
        changed: List[str] = []
        new_lists: Dict[str, List[Dict[str, Any]]] = {}
        for name in self.shard_names:
            new = shard_entries[name]
            old = self.entries_by_shard[name]
            if new is old:
                continue
            new_list = [dict(e) for e in new]
            if new_list == old:
                continue
            if len(new_list) != len(old):
                raise ValueError(
                    f"shard {name!r} row count changed "
                    f"({len(old)} → {len(new_list)}) — rebuild with new_epoch()"
                )
            for e in new_list:
                for k, v in e.items():
                    if (
                        k.lower() not in self._index
                        and isinstance(v, (bool, int, float))
                    ):
                        raise ValueError(
                            f"attribute {k!r} outside the shared vocabulary "
                            "— rebuild with new_epoch()"
                        )
            new_lists[name] = new_list
            changed.append(name)
        self.epoch += 1
        if not changed:
            return []
        dirty = []
        for name in changed:
            gi = self.shard_names.index(name)
            self.entries_by_shard[name] = new_lists[name]
            self._fill_shard_host(gi)
            dirty.append(gi)
        self._push_shards(dirty)
        self.shard_epochs[dirty] += 1
        self.version += 1
        return changed

    def new_epoch(
        self,
        shard_entries: Mapping[str, Sequence[Mapping[str, Any]]],
        *,
        reuse_vocab: bool = True,
    ) -> "ShardedSnapshot":
        """Full rebuild for a structurally changed epoch (new shard set,
        grown shards, vocabulary drift)."""
        return ShardedSnapshot(
            shard_entries,
            self.attr_names if reuse_vocab else None,
            block_s=self.block_s,
            device=self._device,
            epoch=self.epoch + 1,
        )

    # -------------------------------------------------------- GIIS bridge
    @classmethod
    def from_giis(cls, giis, **kwargs) -> "ShardedSnapshot":
        """Build one shard per GIIS registrant (the paper's topology: one
        GRIS per storage site, aggregated by the index)."""
        shard_entries = {
            name: giis.registrant_entries(name) for name in giis.registrants()
        }
        snap = cls(shard_entries, **kwargs)
        snap._giis_epochs = dict(giis.registrant_epochs())
        return snap

    def refresh_from_giis(self, giis) -> List[str]:
        """Delta refresh driven by the GIIS's per-registrant epoch
        counters: only registrants whose epoch moved are re-read, the rest
        never leave the device. Raises ``ValueError`` (like
        :meth:`refresh`) when the topology changed structurally."""
        prev = getattr(self, "_giis_epochs", {})
        now_epochs = giis.registrant_epochs(refresh=True)
        if sorted(now_epochs) != self.shard_names:
            raise ValueError("GIIS registrant set changed — rebuild with new_epoch()")
        payload: Dict[str, Sequence[Mapping[str, Any]]] = {}
        for name in self.shard_names:
            if now_epochs[name] != prev.get(name):
                payload[name] = giis.registrant_entries(name)
            else:
                payload[name] = self.entries_by_shard[name]  # identity ⇒ skipped
        changed = self.refresh(payload)
        self._giis_epochs = dict(now_epochs)
        return changed

    def __len__(self) -> int:
        return self.n

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return (
            f"ShardedSnapshot(g={self.g}, n={self.n}, a={len(self.attr_names)}, "
            f"pad=[{self.s_shard_pad},{self.a_pad}], epoch={self.epoch}, "
            f"version={self.version}, pushed_rows={self.pushed_rows})"
        )
