"""Transfer instrumentation and bandwidth statistics (§3.2).

"Storage systems are configured to provide information on their own
behavior and performance... We gather this performance data by using
instrumentation incorporated in the GridFTP server."

The :class:`TransferMonitor` is that instrumentation: every transfer in or
out of a storage endpoint is observed, accumulated into

  * an aggregate summary (Figure 4: Max/Min/Avg RD/WR bandwidth, plus the
    std-dev extension the paper suggests), and
  * per-source end-to-end series (Figure 5: last RD/WR bandwidth + URL,
    plus the predictor extensions of §7),

and *published* into the endpoint's Storage GRIS, from which any broker
can read it. History rings are bounded (``window``); the vectorized
fleet-scale path (``kernels/bwstats``) consumes the same rings as arrays.
"""

from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from .gris import StorageGRIS
from .predictors import AdaptivePredictor, Ewma, Predictor, RunningMean, SlidingMedian

__all__ = ["TransferRecord", "SeriesStats", "TransferMonitor"]


@dataclass(frozen=True)
class TransferRecord:
    """One observed transfer, as the GridFTP hook reports it."""

    direction: str  # 'read' (replica -> client) | 'write' (client -> replica)
    peer_url: str  # the far end (the paper's per-"source" key)
    nbytes: int
    seconds: float
    started_at: float

    @property
    def bandwidth(self) -> float:
        return self.nbytes / self.seconds if self.seconds > 0 else 0.0


class SeriesStats:
    """Streaming stats + bounded history for one (direction, peer) series."""

    def __init__(self, window: int = 64):
        self.window = window
        self.history: Deque[float] = deque(maxlen=window)
        self.n = 0
        self.min = math.inf
        self.max = -math.inf
        self._mean = 0.0
        self._m2 = 0.0
        self.last = 0.0
        self.last_url = ""
        self.ewma = Ewma(0.25)
        self.median = SlidingMedian(16)
        self.adaptive = AdaptivePredictor()

    def update(self, bw: float, url: str) -> None:
        self.n += 1
        self.history.append(bw)
        self.min = min(self.min, bw)
        self.max = max(self.max, bw)
        d = bw - self._mean
        self._mean += d / self.n
        self._m2 += d * (bw - self._mean)
        self.last = bw
        self.last_url = url
        self.ewma.update(bw)
        self.median.update(bw)
        self.adaptive.update(bw)

    @property
    def mean(self) -> float:
        return self._mean if self.n else 0.0

    @property
    def std(self) -> float:
        return math.sqrt(self._m2 / self.n) if self.n > 1 else 0.0

    def as_array(self) -> np.ndarray:
        return np.asarray(self.history, dtype=np.float32)


class TransferMonitor:
    """Per-endpoint transfer instrumentation, publishing into a GRIS.

    Parameters
    ----------
    gris:
        The endpoint's Storage GRIS; summary and per-source entries are
        (re)published after every observation — mirroring how the paper's
        FTP-server hooks feed the information service.
    window:
        History ring length per series.
    """

    def __init__(self, gris: Optional[StorageGRIS] = None, *, window: int = 64):
        self.gris = gris
        self.window = window
        # aggregate over ALL transfers, by direction
        self.aggregate: Dict[str, SeriesStats] = {
            "read": SeriesStats(window),
            "write": SeriesStats(window),
        }
        # per-peer end-to-end series, by direction
        self.per_source: Dict[str, Dict[str, SeriesStats]] = {}
        self.records: List[TransferRecord] = []
        self.max_records = 4096

    # -- observation ---------------------------------------------------------
    def observe(self, rec: TransferRecord) -> None:
        if rec.direction not in ("read", "write"):
            raise ValueError(f"direction must be read|write, got {rec.direction!r}")
        bw = rec.bandwidth
        self.aggregate[rec.direction].update(bw, rec.peer_url)
        per = self.per_source.setdefault(rec.peer_url, {})
        if rec.direction not in per:
            per[rec.direction] = SeriesStats(self.window)
        per[rec.direction].update(bw, rec.peer_url)
        self.records.append(rec)
        if len(self.records) > self.max_records:
            self.records = self.records[-self.max_records :]
        if self.gris is not None:
            self._publish(rec.peer_url)

    def observe_transfer(
        self, direction: str, peer_url: str, nbytes: int, seconds: float, now: float = 0.0
    ) -> None:
        self.observe(TransferRecord(direction, peer_url, nbytes, seconds, now))

    # -- publication (Figures 4 & 5) ----------------------------------------
    def summary_attrs(self) -> Dict[str, float]:
        rd, wr = self.aggregate["read"], self.aggregate["write"]
        return {
            "MaxRDBandwidth": _finite(rd.max),
            "MinRDBandwidth": _finite(rd.min),
            "AvgRDBandwidth": rd.mean,
            "MaxWRBandwidth": _finite(wr.max),
            "MinWRBandwidth": _finite(wr.min),
            "AvgWRBandwidth": wr.mean,
            "StdRDBandwidth": rd.std,
            "StdWRBandwidth": wr.std,
            "nRDSamples": float(rd.n),
            "nWRSamples": float(wr.n),
        }

    def source_attrs(self, peer_url: str) -> Dict[str, object]:
        per = self.per_source.get(peer_url, {})
        rd = per.get("read")
        wr = per.get("write")
        attrs: Dict[str, object] = {
            "lastRDBandwidth": rd.last if rd else 0.0,
            "lastRDurl": rd.last_url if rd else "",
            "lastWRBandwidth": wr.last if wr else 0.0,
            "lastWRurl": wr.last_url if wr else "",
            "nSamplesToSource": float((rd.n if rd else 0) + (wr.n if wr else 0)),
        }
        if rd:
            attrs["AvgRDBandwidthToSource"] = rd.mean
            ew = rd.ewma.predict()
            attrs["EwmaRDBandwidthToSource"] = ew if ew is not None else 0.0
            md = rd.median.predict()
            attrs["MedianRDBandwidthToSource"] = md if md is not None else 0.0
        if wr:
            attrs["AvgWRBandwidthToSource"] = wr.mean
        return attrs

    def _publish(self, peer_url: str) -> None:
        assert self.gris is not None
        self.gris.publish_bandwidth_summary(self.summary_attrs())
        self.gris.publish_source_bandwidth(peer_url, self.source_attrs(peer_url))

    def republish_all(self) -> None:
        if self.gris is None:
            return
        self.gris.publish_bandwidth_summary(self.summary_attrs())
        for peer in self.per_source:
            self.gris.publish_source_bandwidth(peer, self.source_attrs(peer))

    # -- prediction -------------------------------------------------------------
    def predict_bandwidth(
        self, direction: str, peer_url: str, *, kind: str = "adaptive"
    ) -> Optional[float]:
        """Predict end-to-end bandwidth to ``peer_url``; falls back to the
        aggregate when the per-source series is empty (a new client pairs
        with the site-wide summary, per §3.2's 'simple heuristic')."""
        per = self.per_source.get(peer_url, {}).get(direction)
        series = per if per and per.n else self.aggregate[direction]
        if not series.n:
            return None
        if kind == "last":
            return series.last
        if kind == "mean":
            return series.mean
        if kind == "ewma":
            return series.ewma.predict()
        if kind == "median":
            return series.median.predict()
        return series.adaptive.predict()

    # -- fleet-scale export (for kernels/bwstats) --------------------------------
    def history_matrix(self, direction: str = "read") -> Tuple[np.ndarray, np.ndarray, List[str]]:
        """Stack per-source histories into ``[N, W]`` (right-aligned, zero-
        padded) + valid-count vector — the bwstats kernel input layout."""
        peers = sorted(p for p, d in self.per_source.items() if direction in d)
        n, w = len(peers), self.window
        mat = np.zeros((n, w), dtype=np.float32)
        counts = np.zeros((n,), dtype=np.int32)
        for i, p in enumerate(peers):
            h = self.per_source[p][direction].as_array()
            mat[i, : len(h)] = h
            counts[i] = len(h)
        return mat, counts, peers


def _finite(x: float) -> float:
    return x if math.isfinite(x) else 0.0
