"""The decentralized storage broker (§5.1) — the paper's main artifact.

"The entity that identifies the suitable instance of a replicated file
based on application requirements is referred to as a broker."

Every client that needs a replica runs its *own* broker instance (§5.1.1:
"we have designed a decentralized storage brokering strategy wherein every
client that requires access to a replica performs the selection process").
There is no shared mutable state between brokers: each works from the
replica catalog and the *published* GRIS/GIIS state, so two clients with
the same view reach the same (deterministic) decision.

The broker executes the three phases of §5.1.2:

  Search — catalog lookup for all replicas of the logical file, then a
      per-replica GRIS LDAP query projected to the attributes the request
      references (the broker "uses the application ClassAd to build
      specialized LDAP search queries"), narrowed to this client's own
      per-source bandwidth child.
  Match — LDIF → ClassAds (``ldif.entry_to_classad``), symmetric
      Condor matchmaking against the request ad, rank-ordering. Either the
      faithful interpreted matchmaker or the vectorized columnar engine
      (``core.compile``) can run this phase; both produce identical
      selections (tested).
  Access — fetch through an injected transfer service, with two
      fault-tolerance behaviours layered on the paper's design:
      *failover* (endpoint refused/died → next-ranked replica) and
      *straggler mitigation* (observed mid-transfer bandwidth below
      ``straggler_factor ×`` predicted → abandon and re-select).
"""

from __future__ import annotations

import math
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Mapping, Optional, Protocol, Sequence, Tuple

from repro.obs import AuditTrail, CandidateScore, MetricsRegistry, Tracer

from .bandwidth import TransferMonitor
from .catalog import PhysicalFile, ReplicaCatalog
from .classads import (
    AttrRef,
    BinOp,
    ClassAd,
    Expr,
    FuncCall,
    Ternary,
    UnaryOp,
    parse as parse_expr,
)
from .gris import Clock, StorageGRIS
from .ldif import Entry, entry_to_classad
from .matchmaker import Matchmaker, MatchResult
from .transferplan import (
    TransferFailure,
    TransferPlan,
    TransferRequest,
    TransferResult,
)

__all__ = [
    "ReplicaView",
    "RankedReplica",
    "SelectionResult",
    "FetchOutcome",
    "TransferService",
    "BrokerError",
    "NoReplicaError",
    "NoMatchError",
    "DataBroker",
    "default_read_request",
    "default_write_request",
]


def _referenced_attrs(expr: Optional[Expr]) -> set:
    """Lower-cased attribute names referenced anywhere in an expression."""
    out: set = set()

    def walk(e):
        if e is None:
            return
        if isinstance(e, AttrRef):
            out.add(e.name.lower())
        elif isinstance(e, UnaryOp):
            walk(e.operand)
        elif isinstance(e, BinOp):
            walk(e.left)
            walk(e.right)
        elif isinstance(e, Ternary):
            walk(e.cond)
            walk(e.then)
            walk(e.other)
        elif isinstance(e, FuncCall):
            for a in e.args:
                walk(a)

    walk(expr)
    return out


#: attributes the Search Phase attaches per (lfn, replica) — present in a
#: sequential select's view but NOT in the shared endpoint snapshot, so a
#: request referencing them must take the per-request interpreter path.
_PER_REPLICA_ATTRS = frozenset({"replicapath", "replicasize"})


@dataclass
class _SnapshotState:
    """The broker's cached view of one published GRIS epoch: tensor
    snapshot + per-row ads, shared by every selection until it expires."""

    snapshot: Any  # core.snapshot.ReplicaSnapshot
    endpoints: Tuple[str, ...]  # row order
    row_of: Dict[str, int]  # endpoint url → row
    entries: List[Entry]
    ads: List[ClassAd]
    table: Any  # core.compile.ColumnTable (f64, live rows)
    built_at: float


def _rows_of(
    replicas: Sequence[PhysicalFile], st: "_SnapshotState"
) -> Dict[int, PhysicalFile]:
    """Snapshot row → replica, for the replicas resident in the snapshot."""
    by_row: Dict[int, PhysicalFile] = {}
    for pfn in replicas:
        r = st.row_of.get(pfn.endpoint)
        if r is not None:
            by_row.setdefault(r, pfn)
    return by_row


def _row_name(st: "_SnapshotState", r: int) -> str:
    """The resource name used as the deterministic rank tiebreak."""
    e = st.entries[r]
    for attr in ("name", "hostname", "endpoint", "url"):
        for k, v in e.items():
            if k.lower() == attr and isinstance(v, str):
                return v
    return f"resource-{r}"


class BrokerError(RuntimeError):
    pass


class NoReplicaError(BrokerError):
    """The catalog has no replicas for the logical file."""


class NoMatchError(BrokerError):
    """Replicas exist but none satisfied the two-sided requirements."""


class AdValidationError(BrokerError):
    """``ad_check="strict"``: the request ad has error-severity findings
    from the static analyzer (undefined attributes, type confusions,
    unsatisfiable requirements) that would silently distort selection."""


@dataclass
class ReplicaView:
    """Search-phase product: a replica plus its GRIS-published state."""

    pfn: PhysicalFile
    entry: Entry  # flattened GRIS view (volume + bw summary + per-source)
    ad: ClassAd  # the converted ClassAd (Match Phase step 1)


@dataclass
class RankedReplica:
    """Match-phase product: a matched replica with its rank value."""

    view: ReplicaView
    rank: float

    @property
    def pfn(self) -> PhysicalFile:
        return self.view.pfn


class SelectionResult(Sequence):
    """The one result shape every selection path produces.

    ``select``, ``select_many`` and ``select_placements`` used to return
    bare ``List[RankedReplica]`` — the caller had to hold the request id,
    re-derive bandwidth predictions, and invent its own striping. A
    SelectionResult *is* the ranked list (it iterates, indexes and
    lengths like one, so ``sel[0].pfn`` keeps working) and additionally
    carries:

      * ``plan`` — the broker's :class:`TransferPlan` (primary + ranked
        backups + predicted bandwidths + stripe bound), executable by
        ``ResilientTransferService.execute``,
      * ``request_id`` — the decision record to ``explain()`` /
        annotate after access,
      * ``scores`` — per-candidate (endpoint, rank, matched) fates.
    """

    __slots__ = ("ranked", "lfn", "request_id", "plan", "scores")

    def __init__(
        self,
        ranked: Sequence[RankedReplica],
        *,
        lfn: Optional[str] = None,
        request_id: Optional[str] = None,
        plan: Optional[TransferPlan] = None,
        scores: Optional[List[CandidateScore]] = None,
    ):
        self.ranked = list(ranked)
        self.lfn = lfn
        self.request_id = request_id
        self.plan = plan
        self.scores = scores or []

    def __len__(self) -> int:
        return len(self.ranked)

    def __iter__(self):
        return iter(self.ranked)

    def __getitem__(self, i):
        return self.ranked[i]

    def __bool__(self) -> bool:
        return bool(self.ranked)

    def __eq__(self, other) -> bool:
        if isinstance(other, SelectionResult):
            return self.ranked == other.ranked
        if isinstance(other, list):
            return self.ranked == other
        return NotImplemented

    def __repr__(self) -> str:
        eps = [rr.pfn.endpoint for rr in self.ranked[:3]]
        more = f", +{len(self.ranked) - 3}" if len(self.ranked) > 3 else ""
        return (
            f"SelectionResult({self.lfn!r}, ranked={eps}{more}, "
            f"request_id={self.request_id!r})"
        )

    @property
    def best(self) -> RankedReplica:
        return self.ranked[0]


@dataclass
class FetchOutcome:
    """Access-phase product."""

    lfn: str
    replica: PhysicalFile
    nbytes: int
    seconds: float
    attempts: int
    switched: int  # straggler-mitigation replica switches
    ranked: List[RankedReplica]
    payload: Any = None

    @property
    def bandwidth(self) -> float:
        return self.nbytes / self.seconds if self.seconds > 0 else 0.0


class TransferService(Protocol):
    """What the Access Phase needs from the storage layer (GridFTP stand-in).

    ``transfer`` executes one :class:`TransferRequest` and returns a
    :class:`TransferResult`; it may raise ``TransferFailure`` (endpoint
    dead / refused). ``transfer_chunks`` yields
    :class:`~repro.core.transferplan.ChunkEvent` increments for
    straggler monitoring and restart markers.
    """

    def transfer(self, request: TransferRequest) -> TransferResult: ...

    def transfer_chunks(self, request: TransferRequest): ...


def default_read_request(
    client_url: str,
    *,
    min_bandwidth: float = 0.0,
    rank: str = "predicted",
) -> ClassAd:
    """The request ad a data-pipeline client submits for a shard read.

    Rank prefers this client's own end-to-end history (Figure 5's
    per-source attributes), falling back to the site-wide average
    (Figure 4), falling back to the static ``diskTransferRate`` for a
    cold-start endpoint — the paper's "simple heuristic of combining past
    observed performance with current load".
    """
    ad = ClassAd()
    ad["clientUrl"] = client_url
    ad["reqdRDBandwidth"] = float(min_bandwidth)
    # Reads consume no space; declared so that space-gating site policies
    # (e.g. the paper's ``other.reqdSpace < 10G``) evaluate defined-True.
    ad["reqdSpace"] = 0
    if rank == "predicted":
        ad.set_expr(
            "rank",
            "ifThenElse(!isUndefined(other.EwmaRDBandwidthToSource) && other.EwmaRDBandwidthToSource > 0,"
            " other.EwmaRDBandwidthToSource,"
            " ifThenElse(!isUndefined(other.AvgRDBandwidth) && other.AvgRDBandwidth > 0,"
            "  other.AvgRDBandwidth,"
            "  other.diskTransferRate / (1 + other.loadFactor)))",
        )
    elif rank == "last":
        ad.set_expr("rank", "other.lastRDBandwidth")
    elif rank == "static":
        ad.set_expr("rank", "other.diskTransferRate / (1 + other.loadFactor)")
    else:
        ad.set_expr("rank", rank)  # caller-supplied expression
    # two clauses: the bandwidth gate, and the resilient layer's circuit-
    # breaker feedback — an endpoint whose breaker THIS client tripped
    # publishes breakerOpenToSource=1 into our per-source GRIS view and is
    # excluded from matchmaking until its half-open probe window (0.5,
    # which passes the < 1 gate so the probe stays selectable).
    ad.set_expr(
        "requirements",
        "(isUndefined(other.MaxRDBandwidth) || my.reqdRDBandwidth <= 0"
        " || other.MaxRDBandwidth >= my.reqdRDBandwidth)"
        " && (isUndefined(other.breakerOpenToSource)"
        " || other.breakerOpenToSource < 1)",
    )
    return ad


def default_write_request(client_url: str, nbytes: int) -> ClassAd:
    """The request ad a checkpoint writer submits for replica placement:
    needs space, ranks by predicted write bandwidth then free space."""
    ad = ClassAd()
    ad["clientUrl"] = client_url
    ad["reqdSpace"] = int(nbytes)
    ad.set_expr(
        "rank",
        "ifThenElse(!isUndefined(other.AvgWRBandwidthToSource) && other.AvgWRBandwidthToSource > 0,"
        " other.AvgWRBandwidthToSource * 1000000000,"
        " ifThenElse(!isUndefined(other.AvgWRBandwidth) && other.AvgWRBandwidth > 0,"
        "  other.AvgWRBandwidth * 1000000000,"
        "  other.diskTransferRate))"
        " + other.availableSpace / 1G",
    )
    ad.set_expr("requirements", "other.availableSpace >= my.reqdSpace")
    return ad


class DataBroker:
    """One client's replica-selection broker.

    Parameters
    ----------
    client_url:
        This client's URL — the per-source key under which endpoints have
        recorded end-to-end history about us.
    catalog:
        The replica catalog (read-only here).
    gris_resolver:
        endpoint URL → StorageGRIS. Usually ``grid.gris_for`` from the
        storage simulation, or a GIIS lookup.
    env:
        ClassAd evaluation environment (deterministic ``now`` etc.).
    use_vectorized:
        Route the Match Phase through the columnar engine
        (:mod:`repro.core.compile`) when the request compiles; falls back
        to the interpreter otherwise. Selections are identical.
    """

    def __init__(
        self,
        client_url: str,
        catalog: ReplicaCatalog,
        gris_resolver: Callable[[str], Optional[StorageGRIS]],
        *,
        env: Optional[Dict[str, Any]] = None,
        clock: Optional[Clock] = None,
        use_vectorized: bool = False,
        straggler_factor: float = 0.35,
        straggler_patience: int = 3,
        max_attempts: int = 4,
        stripe_k: int = 3,
        snapshot_ttl: float = 5.0,
        batch_use_kernel: bool = False,
        batch_use_sparse: bool = False,
        snapshot_shards: int = 0,
        shard_key: Optional[Callable[[str], int]] = None,
        plan_cache_size: int = 256,
        metrics: Optional[MetricsRegistry] = None,
        tracer: Optional[Tracer] = None,
        audit: Optional[AuditTrail] = None,
        audit_capacity: int = 1024,
        ad_check: str = "warn",
    ):
        self.client_url = client_url
        self.catalog = catalog
        self.gris_resolver = gris_resolver
        self.clock = clock or Clock()
        self.env = dict(env or {})
        self.env.setdefault("now", self.clock.now())
        self.matchmaker = Matchmaker(self.env)
        self.use_vectorized = use_vectorized
        self.straggler_factor = straggler_factor
        self.straggler_patience = straggler_patience
        self.max_attempts = max_attempts
        self.stripe_k = stripe_k  # TransferPlan stripe bound
        # batched-selection state: snapshot TTL mirrors the GRIS dynamic-
        # attribute TTL (stale columns would diverge from fresh LDAP reads)
        self.snapshot_ttl = snapshot_ttl
        self.batch_use_kernel = batch_use_kernel
        self.batch_use_sparse = batch_use_sparse
        # sharded matchmaking (DESIGN.md §9): partition the snapshot into
        # this many per-registrant shards (0 = flat snapshot). shard_key
        # maps endpoint → bucket; default is the crc32 hash bucketing.
        self.snapshot_shards = int(snapshot_shards)
        self.shard_key = shard_key
        self._plan_cache = None  # lazily built (pulls in core.plancache)
        self._plan_cache_size = plan_cache_size
        self._snap_state: Optional[_SnapshotState] = None
        # local (client-side) observation history: end-to-end from OUR side
        self.local_monitor = TransferMonitor(None)
        # observability: per-broker registry (decentralized, like the
        # matchmaker); cooperating components (scheduler, engine) share it
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.tracer = tracer if tracer is not None else Tracer()
        self.audit = audit if audit is not None else AuditTrail(audit_capacity)
        if ad_check not in ("off", "warn", "strict"):
            raise ValueError(f"ad_check must be off|warn|strict, got {ad_check!r}")
        # request-ad static analysis at select time: "warn" records analyzer
        # findings into the decision record; "strict" additionally refuses
        # error-severity ads. Results are memoized per distinct ad source.
        self.ad_check = ad_check
        self._ad_diag_cache: "OrderedDict[str, List[Dict[str, Any]]]" = OrderedDict()
        self._ad_diag_cache_size = 128
        self.last_request_id: Optional[str] = None
        self.last_request_ids: List[str] = []
        # pre-bound counters: the hot path touches these per call, so the
        # family/child resolution happens once here
        self._ctr = {
            name: self.metrics.counter(f"broker_{name}_total", help)
            for name, help in (
                ("searches", "Search Phase sweeps (catalog + GRIS)"),
                ("matches", "Match Phase runs"),
                ("fetches", "Access Phase fetches"),
                ("failovers", "dead/refused endpoints skipped to next rank"),
                ("straggler_switches", "mid-transfer abandons (slow replica)"),
                ("vectorized_matches", "sequential matches on the columnar engine"),
                ("batch_selects", "select_many batches"),
                ("batched_kernel_requests", "requests answered by the stacked kernel"),
                ("batched_sparse_requests", "requests answered by sparse top-k"),
                ("batched_sharded_requests", "requests answered by the sharded walk+merge"),
                ("batched_columnar_requests", "requests answered columnar per-request"),
                ("batched_interp_requests", "requests answered by the interpreter"),
                ("snapshot_builds", "GRIS snapshot (re)builds"),
                ("snapshot_reuses", "GRIS snapshot TTL reuses"),
                ("snapshot_delta_refreshes", "sharded snapshots refreshed in place (dirty shards only)"),
                ("ad_findings", "request-ad analyzer findings recorded"),
            )
        }
        self._ctr_shard_rows = self.metrics.counter(
            "shard_refresh_rows_total",
            "rows re-pushed to the device by sharded delta refreshes",
        )
        self._shard_hists: Dict[int, Any] = {}
        self._h_gris_query = self.metrics.histogram(
            "broker_gris_query_seconds", "per-endpoint GRIS query latency"
        )
        self._h_fetch_bw = self.metrics.histogram(
            "broker_fetch_bandwidth_mb_per_s",
            "achieved Access Phase bandwidth",
            buckets=(0.1, 0.5, 1, 2, 5, 10, 25, 50, 100, 250, 1000, float("inf")),
        )
        self._h_batch = self.metrics.histogram(
            "broker_select_many_batch_size", "queries per select_many call",
            buckets=(1, 2, 4, 8, 16, 32, 64, 128, 256, float("inf")),
        )

    @property
    def stats(self) -> Dict[str, Any]:
        """Legacy counter view, now backed by the metrics registry. Keys
        and integer values are unchanged from the pre-obs dict."""
        out: Dict[str, Any] = {}
        for k, c in self._ctr.items():
            v = c.value
            out[k] = int(v) if float(v).is_integer() else v
        return out

    @property
    def plan_cache(self):
        if self._plan_cache is None:
            from .plancache import PlanCache

            self._plan_cache = PlanCache(self._plan_cache_size, metrics=self.metrics)
        return self._plan_cache

    def explain(self, request_id: str):
        """The :class:`~repro.obs.DecisionRecord` for a past selection —
        candidates, plan-cache status, kernel path, per-candidate scores,
        chosen replica, and (after access) failovers and bandwidths."""
        return self.audit.get(request_id)

    # ------------------------------------------------------------------ Search
    def search(self, lfn: str, attrs: Optional[Sequence[str]] = None) -> List[ReplicaView]:
        """Search Phase: catalog → per-replica GRIS query → ClassAd views."""
        self._ctr["searches"].inc()
        replicas = self.catalog.lookup(lfn)
        if not replicas:
            raise NoReplicaError(lfn)
        views: List[ReplicaView] = []
        for pfn in replicas:
            gris = self.gris_resolver(pfn.endpoint)
            if gris is None:
                continue  # endpoint unreachable: skip (failover will cover)
            with self.tracer.span("broker.gris_query", endpoint=pfn.endpoint) as sp:
                entry = gris.flattened_view(source=self.client_url)
            self._h_gris_query.observe(sp.duration)
            entry.setdefault("endpoint", pfn.endpoint)
            entry.setdefault("replicaPath", pfn.path)
            entry.setdefault("replicaSize", pfn.size)
            ad = entry_to_classad(entry)
            views.append(ReplicaView(pfn, entry, ad))
        if not views:
            raise NoReplicaError(f"{lfn}: no reachable replicas")
        return views

    # ------------------------------------------------------------------- Match
    def match(self, request: ClassAd, views: Sequence[ReplicaView]) -> List[RankedReplica]:
        """Match Phase: two-sided matchmaking + rank ordering."""
        self._ctr["matches"].inc()
        if self.use_vectorized:
            ranked = self._match_vectorized(request, views)
            if ranked is not None:
                self._ctr["vectorized_matches"].inc()
                return ranked
        results = self.matchmaker.match(request, [v.ad for v in views])
        return [RankedReplica(views[m.index], m.rank) for m in results]

    def _match_vectorized(
        self, request: ClassAd, views: Sequence[ReplicaView]
    ) -> Optional[List[RankedReplica]]:
        # deferred import: core.compile pulls in jax
        try:
            from .compile import vectorized_match
        except Exception:  # pragma: no cover - jax always present here
            return None
        return vectorized_match(request, views, env=self.env)

    def _predicted_bandwidth(self, rr: RankedReplica) -> Optional[float]:
        """The bandwidth we expect from a ranked replica. Only trust
        ``rank`` as a prediction when it comes from observed history; a
        cold static rank (disk rate) can exceed the achievable path
        bandwidth several-fold. Cold endpoints fall back to this client's
        own typical achieved bandwidth (local aggregate), if any."""
        has_history = isinstance(
            rr.view.entry.get("EwmaRDBandwidthToSource"), (int, float)
        ) and rr.view.entry.get("EwmaRDBandwidthToSource", 0) > 0
        if rr.rank > 0 and has_history:
            return rr.rank
        agg = self.local_monitor.aggregate["read"]
        return agg.mean if agg.n >= 3 else None

    def _check_request_ad(self, req: ClassAd, rec) -> None:
        """Static analysis of the request ad (``ad_check``), recorded into
        the decision record. Memoized per distinct ad source — the common
        case (the default read request, a scheduler's fixed template) pays
        the analyzer exactly once per broker."""
        if self.ad_check == "off":
            return
        key = ";".join(f"{k}={e!r}" for k, e in req.items())
        diags = self._ad_diag_cache.get(key)
        if diags is None:
            from repro.analysis.adlint import check_request_ad

            diags = [d.to_dict() for d in check_request_ad(req)]
            self._ad_diag_cache[key] = diags
            if len(self._ad_diag_cache) > self._ad_diag_cache_size:
                self._ad_diag_cache.popitem(last=False)
        else:
            self._ad_diag_cache.move_to_end(key)
        if diags:
            rec.ad_diagnostics = list(diags)
            self._ctr["ad_findings"].inc(len(diags))
            if self.ad_check == "strict" and any(
                d["severity"] == "error" for d in diags
            ):
                msgs = "; ".join(
                    f"{d['rule']}: {d['message']}"
                    for d in diags if d["severity"] == "error"
                )
                rec.error = f"AdValidationError: {msgs}"
                raise AdValidationError(msgs)

    def _result(
        self,
        lfn: str,
        ranked: List[RankedReplica],
        request_id: Optional[str],
        scores: Optional[List[CandidateScore]] = None,
    ) -> SelectionResult:
        """Ranked list → SelectionResult, with the executable plan."""
        plan = TransferPlan(
            lfn=lfn,
            replicas=[rr.pfn for rr in ranked],
            ranks=[rr.rank for rr in ranked],
            predicted=[self._predicted_bandwidth(rr) for rr in ranked],
            stripe_k=self.stripe_k,
            request_id=request_id,
        )
        return SelectionResult(
            ranked, lfn=lfn, request_id=request_id, plan=plan, scores=scores
        )

    def select(
        self,
        lfn: str,
        request: Optional[ClassAd] = None,
        *,
        top_k: Optional[int] = None,
    ) -> SelectionResult:
        """Search + Match in one call, best replica first.

        Returns a :class:`SelectionResult` — iterable like the ranked
        list, plus the executable ``plan`` and the ``request_id`` of the
        decision record :meth:`explain` retrieves."""
        req = request if request is not None else default_read_request(self.client_url)
        if self.snapshot_shards > 0 and top_k:
            # sharded brokers answer sequential selections through the
            # batched sharded tier so they hit the same snapshot + result
            # cache (requests needing per-(lfn,replica) attributes can't:
            # those attrs aren't in the shared snapshot)
            refs = _referenced_attrs(req.lookup_expr("requirements")) | _referenced_attrs(
                req.lookup_expr("rank")
            )
            if not (refs & _PER_REPLICA_ATTRS):
                return self.select_many([(lfn, req)], top_k=top_k)[0]
        rec = self.audit.begin(lfn, mode="select", at=self.clock.now())
        rec.top_k = top_k
        self.last_request_id = rec.request_id
        self._check_request_ad(req, rec)
        try:
            views, ranked, path = self._select_impl(lfn, req)
        except BrokerError as e:
            rec.error = f"{type(e).__name__}: {e}"
            raise
        rec.kernel_path = path
        self._fill_match_audit(rec, [v.pfn.endpoint for v in views], ranked)
        if not ranked:
            rec.error = "NoMatchError"
            raise NoMatchError(lfn)
        if top_k:
            ranked = ranked[:top_k]
        return self._result(lfn, ranked, rec.request_id, scores=rec.scores)

    def _select_impl(
        self, lfn: str, req: ClassAd
    ) -> Tuple[List[ReplicaView], List[RankedReplica], str]:
        """Search + Match without audit bookkeeping (select_many's
        interpreter tier reuses this under its own records)."""
        views = self.search(lfn, None)
        vec_before = self._ctr["vectorized_matches"].value
        ranked = self.match(req, views)
        path = (
            "vectorized"
            if self._ctr["vectorized_matches"].value > vec_before
            else "interpreter"
        )
        return views, ranked, path

    def _fill_match_audit(
        self, rec, candidates: List[str], ranked: Sequence[RankedReplica]
    ) -> None:
        """Candidate set + per-candidate scores + chosen replica."""
        rec.candidates = candidates
        matched = {rr.pfn.endpoint: rr.rank for rr in ranked}
        rec.scores = [
            CandidateScore(ep, matched.get(ep), ep in matched) for ep in candidates
        ]
        rec.chosen = ranked[0].pfn.endpoint if ranked else None

    # --------------------------------------------------------- Batched Match
    def _snapshot_state(self, endpoints: Sequence[str]) -> _SnapshotState:
        """The cached snapshot of the published GRIS epoch, rebuilt when
        the TTL lapses or a new endpoint appears (the 'epoch' boundary)."""
        want = [ep for ep in endpoints if self.gris_resolver(ep) is not None]
        now = self.clock.now()
        st = self._snap_state
        if (
            st is not None
            and now - st.built_at < self.snapshot_ttl
            and all(ep in st.row_of for ep in want)
        ):
            self._ctr["snapshot_reuses"].inc()
            return st
        if self.snapshot_shards > 0:
            return self._snapshot_state_sharded(want, now, st)

        from .snapshot import ReplicaSnapshot

        # keep previously known endpoints resident so the snapshot grows
        # monotonically within a broker's lifetime (stable row space)
        known: List[str] = list(st.endpoints) if st is not None else []
        for ep in want:
            if st is None or ep not in st.row_of:
                known.append(ep)
        rows: List[str] = []
        entries: List[Entry] = []
        ads: List[ClassAd] = []
        for ep in known:
            gris = self.gris_resolver(ep)
            if gris is None:
                continue  # endpoint died: drop its row this epoch
            entry = gris.flattened_view(source=self.client_url)
            entry.setdefault("endpoint", ep)
            rows.append(ep)
            entries.append(entry)
            ads.append(entry_to_classad(entry))
        prev = st.snapshot if st is not None else None
        snapshot = (
            prev.new_epoch(entries, reuse_vocab=False)
            if prev is not None
            else ReplicaSnapshot(entries)
        )
        st = _SnapshotState(
            snapshot=snapshot,
            endpoints=tuple(rows),
            row_of={ep: i for i, ep in enumerate(rows)},
            entries=entries,
            ads=ads,
            table=snapshot.table(),
            built_at=now,
        )
        self._snap_state = st
        self._ctr["snapshot_builds"].inc()
        return st

    def _shard_name(self, ep: str) -> str:
        """Endpoint → shard name. Zero-padded so sorted(shard names) is
        numeric bucket order (the global row space is shard-major)."""
        from .snapshot_sharded import shard_by_hash

        bucket = (
            self.shard_key(ep)
            if self.shard_key is not None
            else shard_by_hash(ep, self.snapshot_shards)
        )
        return f"shard-{int(bucket) % self.snapshot_shards:03d}"

    def _shard_hist(self, g: int):
        """Per-shard rank-walk latency histogram (bounded label set: one
        child per shard index)."""
        h = self._shard_hists.get(g)
        if h is None:
            h = self.metrics.histogram(
                "broker_shard_rank_seconds",
                "per-shard sparse rank-walk latency",
                shard=str(g),
            )
            self._shard_hists[g] = h
        return h

    def _snapshot_state_sharded(
        self, want: Sequence[str], now: float, st: Optional[_SnapshotState]
    ) -> _SnapshotState:
        """Sharded twin of :meth:`_snapshot_state`: endpoints are bucketed
        into per-registrant shards, and a TTL lapse with unchanged
        membership becomes a **delta refresh** — unchanged shards never
        leave the device, changed shards re-push in one scatter — instead
        of a full rebuild (DESIGN.md §9)."""
        from .snapshot_sharded import ShardedSnapshot

        known: List[str] = list(st.endpoints) if st is not None else []
        for ep in want:
            if st is None or ep not in st.row_of:
                known.append(ep)
        by_shard: Dict[str, List[str]] = {}
        shard_entries: Dict[str, List[Entry]] = {}
        for ep in known:
            gris = self.gris_resolver(ep)
            if gris is None:
                continue  # endpoint died: drop its row this epoch
            entry = gris.flattened_view(source=self.client_url)
            entry.setdefault("endpoint", ep)
            name = self._shard_name(ep)
            by_shard.setdefault(name, []).append(ep)
            shard_entries.setdefault(name, []).append(entry)
        if not shard_entries:
            # every endpoint unreachable: an empty flat snapshot keeps the
            # n == 0 handling in select_many uniform
            from .snapshot import ReplicaSnapshot

            empty = ReplicaSnapshot([])
            st = _SnapshotState(
                snapshot=empty,
                endpoints=(),
                row_of={},
                entries=[],
                ads=[],
                table=empty.table(),
                built_at=now,
            )
            self._snap_state = st
            self._ctr["snapshot_builds"].inc()
            return st

        shard_names = sorted(shard_entries)
        prev = st.snapshot if st is not None else None
        snapshot = None
        changed: Optional[List[str]] = None
        if (
            isinstance(prev, ShardedSnapshot)
            and prev.shard_names == shard_names
            and all(
                len(shard_entries[nm]) == len(prev.entries_by_shard[nm])
                for nm in shard_names
            )
        ):
            rows_before = prev.pushed_rows
            try:
                changed = prev.refresh(shard_entries)
                snapshot = prev
            except ValueError:
                snapshot = None  # vocab/shape drift: fall through to rebuild
            if snapshot is not None:
                self._ctr["snapshot_delta_refreshes"].inc()
                self._ctr_shard_rows.inc(int(snapshot.pushed_rows - rows_before))
        if snapshot is None:
            snapshot = ShardedSnapshot(
                shard_entries, epoch=prev.epoch + 1 if prev is not None else 0
            )

        rows = [ep for nm in shard_names for ep in by_shard[nm]]
        entries = [e for nm in shard_names for e in shard_entries[nm]]
        if changed is not None and st is not None:
            # delta: re-convert ads only for shards whose entries moved
            changed_set = set(changed)
            ads: List[ClassAd] = []
            pos = 0
            for nm in shard_names:
                cnt = len(shard_entries[nm])
                if nm in changed_set:
                    ads.extend(entry_to_classad(e) for e in shard_entries[nm])
                else:
                    ads.extend(st.ads[pos : pos + cnt])
                pos += cnt
        else:
            ads = [entry_to_classad(e) for e in entries]
        st = _SnapshotState(
            snapshot=snapshot,
            endpoints=tuple(rows),
            row_of={ep: i for i, ep in enumerate(rows)},
            entries=entries,
            ads=ads,
            table=snapshot.table(),
            built_at=now,
        )
        self._snap_state = st
        if changed is None:
            self._ctr["snapshot_builds"].inc()
        return st

    def invalidate_snapshot(self) -> None:
        self._snap_state = None

    def select_many(
        self,
        queries: Sequence[Tuple[str, Optional[ClassAd]]],
        *,
        top_k: Optional[int] = None,
        use_kernel: Optional[bool] = None,
        use_sparse: Optional[bool] = None,
        strict: bool = True,
    ) -> List[Any]:
        """Batched Search+Match: many ``(lfn, request)`` selections against
        ONE device-resident snapshot in (at most) one kernel launch.

        Requests whose plans lower to the kernel subset are stacked into a
        single ``matchrank_batched`` call (or, with ``use_sparse`` and a
        ``top_k``, answered by the rank-order sparse top-k walk when every
        plan canonicalizes); requests that only compile to the columnar
        subset run per-request against the same snapshot table; everything
        else takes the paper-faithful interpreter — all tiers produce
        identical selections (tested; the sparse tier may order exact
        rank-ties at the k-boundary differently, which is why it is
        opt-in).

        Every query gets a decision record (``self.last_request_ids``,
        :meth:`explain`) noting its kernel path, plan-cache and snapshot
        status, and per-candidate scores.

        Returns one :class:`SelectionResult` per query, in query order.
        With ``strict=False``, a query that fails (no replicas / no
        match) yields its exception object in place of a result instead
        of raising — the coalescing scheduler path, where one bad
        request must not poison the batch.
        """
        use_kernel = self.batch_use_kernel if use_kernel is None else use_kernel
        if use_sparse is None:
            # sharded snapshots answer through the per-shard walk + merge
            # tier, which rides the sparse gate
            use_sparse = self.batch_use_sparse or self.snapshot_shards > 0
        self._ctr["batch_selects"].inc()
        n = len(queries)
        self._h_batch.observe(n)
        results: List[Any] = [None] * n
        recs = [
            self.audit.begin(lfn, mode="select_many", at=self.clock.now())
            for lfn, _ in queries
        ]
        for rec in recs:
            rec.top_k = top_k
        self.last_request_ids = [rec.request_id for rec in recs]
        if recs:
            self.last_request_id = recs[-1].request_id

        # ---- Search: one catalog+GRIS sweep for the whole batch ----
        reqs: List[Optional[ClassAd]] = [None] * n
        replica_lists: List[Optional[List[PhysicalFile]]] = [None] * n
        all_endpoints: List[str] = []
        seen = set()
        from .catalog import CatalogError

        with self.tracer.span("broker.batch_search", batch=n):
            for i, (lfn, req) in enumerate(queries):
                reqs[i] = req if req is not None else default_read_request(self.client_url)
                try:
                    self._check_request_ad(reqs[i], recs[i])
                except AdValidationError as e:
                    if strict:
                        raise
                    results[i] = e
                    continue
                try:
                    replicas = self.catalog.lookup(lfn)
                except CatalogError:
                    replicas = None
                if not replicas:
                    results[i] = NoReplicaError(lfn)
                    recs[i].error = f"NoReplicaError: {lfn}"
                    continue
                replica_lists[i] = replicas
                recs[i].candidates = [p.endpoint for p in replicas]
                for pfn in replicas:
                    if pfn.endpoint not in seen:
                        seen.add(pfn.endpoint)
                        all_endpoints.append(pfn.endpoint)
            self._ctr["searches"].inc()
        if not all_endpoints:
            if strict:
                raise NoReplicaError(queries[0][0] if queries else "<empty batch>")
            return results
        builds_before = self._ctr["snapshot_builds"].value
        deltas_before = self._ctr["snapshot_delta_refreshes"].value
        with self.tracer.span("broker.snapshot", endpoints=len(all_endpoints)):
            st = self._snapshot_state(all_endpoints)
        if self._ctr["snapshot_builds"].value > builds_before:
            snap_status = "build"
        elif self._ctr["snapshot_delta_refreshes"].value > deltas_before:
            snap_status = "delta"
        else:
            snap_status = "reuse"
        for i in range(n):
            if results[i] is None:
                recs[i].snapshot = snap_status
        if st.snapshot.n == 0:  # every endpoint unreachable
            for i in range(n):
                if results[i] is None:
                    msg = f"{queries[i][0]}: no reachable replicas"
                    results[i] = NoReplicaError(msg)
                    recs[i].error = f"NoReplicaError: {msg}"
            if strict:
                raise next(r for r in results if isinstance(r, BrokerError))
            return results
        vocab = st.snapshot.vocab_key()

        # ---- per-request lowering through the plan cache (tiered) ----
        from .compile import CompileError

        kernel_batch: List[int] = []  # query indices in the stacked launch
        kernel_plans: List[Any] = []
        columnar: List[int] = []
        interp: List[int] = []
        policy_cache: Dict[Tuple[str, int], Any] = {}

        def policy_pass(i: int) -> Optional[List[float]]:
            """Fold every row's server policy into a [rows] admit vector
            for request i; None ⇒ some policy is outside the columnar
            subset and request i must go to the interpreter."""
            import numpy as np

            admit = np.ones((st.snapshot.n,), dtype=np.float32)
            groups: Dict[str, List[int]] = {}
            for r, ad in enumerate(st.ads):
                pexpr = ad.lookup_expr("requirements")
                if pexpr is None:
                    continue
                groups.setdefault(repr(pexpr), []).append(r)
            for src, rows in groups.items():
                try:
                    fn = self.plan_cache.policy_fn(src, reqs[i], vocab, env=self.env)
                except CompileError:
                    return None
                t = fn(st.table, np)
                ok = t.ok if t.ok is not True else np.ones((st.snapshot.n,), bool)
                pol = np.broadcast_to(np.asarray(t.val), (st.snapshot.n,)) & np.broadcast_to(
                    np.asarray(ok), (st.snapshot.n,)
                )
                for r in rows:
                    if not pol[r]:
                        admit[r] = 0.0
            return admit

        import numpy as np

        admits: Dict[int, np.ndarray] = {}
        with self.tracer.span("broker.lowering"):
            for i in range(n):
                if results[i] is not None:
                    continue
                req = reqs[i]
                refs = _referenced_attrs(
                    req.lookup_expr("requirements")
                ) | _referenced_attrs(req.lookup_expr("rank"))
                if refs & _PER_REPLICA_ATTRS:
                    interp.append(i)  # needs per-(lfn,replica) attrs, not in snapshot
                    continue
                pcs = self.plan_cache.stats
                pc_before = (pcs["hits"], pcs["misses"], pcs["negative_hits"])
                admit = policy_pass(i)
                if admit is None:
                    interp.append(i)
                else:
                    admits[i] = admit
                    try:
                        plan = self.plan_cache.kernel_plan(req, vocab, env=self.env)
                        kernel_batch.append(i)
                        kernel_plans.append(plan)
                    except CompileError:
                        try:
                            self.plan_cache.columnar_program(req, vocab, env=self.env)
                            columnar.append(i)
                        except CompileError:
                            interp.append(i)
                pcs = self.plan_cache.stats
                if pcs["misses"] > pc_before[1]:
                    recs[i].plan_cache = "miss"
                elif pcs["hits"] > pc_before[0] or pcs["negative_hits"] > pc_before[2]:
                    recs[i].plan_cache = "hit"

        # ---- tier 1: one stacked kernel launch for the whole sub-batch ----
        if kernel_batch:
            from repro.kernels.matchrank.ops import (
                matchrank_batched,
                matchrank_batched_topk,
                stack_plans,
            )

            attrs, valid, n_rows = st.snapshot.device_columns()
            admit_mat = np.zeros((len(kernel_batch), n_rows), dtype=np.float32)
            for bi, i in enumerate(kernel_batch):
                row_ok = admits[i]
                for pfn in replica_lists[i]:
                    r = st.row_of.get(pfn.endpoint)
                    if r is not None and row_ok[r] > 0:
                        admit_mat[bi, r] = 1.0
            sparse_done = False
            if use_sparse and top_k:
                from repro.kernels.matchrank.sparse import canonicalize_plans

                from .snapshot_sharded import ShardedSnapshot

                na = len(kernel_plans[0].attr_names)
                iv = canonicalize_plans(kernel_plans, na)
                if iv is not None and isinstance(st.snapshot, ShardedSnapshot):
                    # tier 1a: per-shard walk + hierarchical merge, fronted
                    # by the per-shard-epoch result cache (DESIGN.md §9)
                    self._sharded_topk_tier(
                        st,
                        iv,
                        kernel_batch,
                        replica_lists,
                        reqs,
                        recs,
                        results,
                        admit_mat,
                        top_k,
                        vocab,
                    )
                    sparse_done = True
                elif iv is not None:
                    l_attrs, l_valid = st.snapshot.logical_columns()
                    with self.tracer.span(
                        "broker.sparse_topk",
                        batch=len(kernel_batch),
                        rows=st.snapshot.n,
                        k=top_k,
                    ):
                        ti, ts = matchrank_batched_topk(
                            l_attrs,
                            l_valid,
                            kernel_plans,
                            k=top_k,
                            admit=admit_mat[:, : st.snapshot.n],
                            rank_order=st.snapshot.rank_order,
                        )
                    for bi, i in enumerate(kernel_batch):
                        results[i] = self._ranked_from_topk(
                            replica_lists[i], st, ti[bi], ts[bi]
                        )
                        recs[i].kernel_path = "sparse_topk"
                        self._fill_batched_audit(recs[i], st, results[i])
                        self._ctr["batched_sparse_requests"].inc()
                    sparse_done = True
            if not sparse_done:
                with self.tracer.span(
                    "broker.kernel_launch",
                    batch=len(kernel_batch),
                    rows=n_rows,
                    use_kernel=use_kernel,
                ):
                    mask, score, _, _ = matchrank_batched(
                        attrs,
                        valid,
                        stack_plans(kernel_plans),
                        admit=admit_mat,
                        n_rows=n_rows,
                        use_kernel=use_kernel,
                    )
                for bi, i in enumerate(kernel_batch):
                    results[i] = self._ranked_from_scores(
                        queries[i][0], replica_lists[i], st, mask[bi], score[bi]
                    )
                    recs[i].kernel_path = "batched_kernel"
                    self._fill_batched_audit(
                        recs[i], st, results[i], mask=mask[bi], score=score[bi]
                    )
                    self._ctr["batched_kernel_requests"].inc()

        # ---- tier 2: columnar programs over the shared snapshot table ----
        for i in columnar:
            with self.tracer.span("broker.columnar", lfn=queries[i][0]):
                prog = self.plan_cache.columnar_program(reqs[i], vocab, env=self.env)
                mask, rank = prog.run(st.table, np)
                mask = np.asarray(mask, bool) & (admits[i] > 0)
                row_admit = np.zeros((st.snapshot.n,), bool)
                for pfn in replica_lists[i]:
                    r = st.row_of.get(pfn.endpoint)
                    if r is not None:
                        row_admit[r] = True
                mask &= row_admit
                score = np.asarray(rank, np.float64)
                results[i] = self._ranked_from_scores(
                    queries[i][0], replica_lists[i], st, mask, score
                )
            recs[i].kernel_path = "batched_columnar"
            self._fill_batched_audit(recs[i], st, results[i], mask=mask, score=score)
            self._ctr["batched_columnar_requests"].inc()

        # ---- tier 3: the paper-faithful interpreter, per request ----
        for i in interp:
            with self.tracer.span("broker.interp", lfn=queries[i][0]):
                try:
                    views, ranked, _ = self._select_impl(queries[i][0], reqs[i])
                    self._fill_match_audit(
                        recs[i], [v.pfn.endpoint for v in views], ranked
                    )
                    results[i] = ranked
                except BrokerError as e:
                    recs[i].error = f"{type(e).__name__}: {e}"
                    results[i] = e
            recs[i].kernel_path = "batched_interp"
            self._ctr["batched_interp_requests"].inc()

        # ---- finalize: every successful query becomes a SelectionResult ----
        for i in range(n):
            r = results[i]
            if isinstance(r, list):
                if not r:
                    results[i] = NoMatchError(queries[i][0])
                    recs[i].error = "NoMatchError"
                    continue
                if top_k:
                    r = r[:top_k]
                results[i] = self._result(
                    queries[i][0], r, recs[i].request_id, scores=recs[i].scores
                )
        if strict:
            for r in results:
                if isinstance(r, BrokerError):
                    raise r
        return results

    def _ranked_from_scores(
        self, lfn: str, replicas: Sequence[PhysicalFile], st: _SnapshotState, mask, score
    ) -> List[RankedReplica]:
        """Snapshot rows + per-request scores → the same rank-ordered
        RankedReplica list the interpreter produces (same tiebreak)."""
        by_row = _rows_of(replicas, st)
        rows = [r for r in by_row if bool(mask[r])]
        rows.sort(key=lambda r: (-float(score[r]), _row_name(st, r), r))
        out = []
        for r in rows:
            view = ReplicaView(by_row[r], st.entries[r], st.ads[r])
            out.append(RankedReplica(view, float(score[r])))
        return out

    def _ranked_from_topk(
        self, replicas: Sequence[PhysicalFile], st: _SnapshotState, idx, scores
    ) -> List[RankedReplica]:
        """Sparse top-k winners (row indices + scores) → RankedReplica
        list, re-sorted with the dense tiebreak key."""
        by_row = _rows_of(replicas, st)
        picked: List[Tuple[int, float]] = []
        for r, s in zip(idx, scores):
            r, s = int(r), float(s)
            if r < 0 or (math.isinf(s) and s < 0):
                continue  # empty slot past the request's match count
            if r in by_row:
                picked.append((r, s))
        picked.sort(key=lambda rs: (-rs[1], _row_name(st, rs[0]), rs[0]))
        return [
            RankedReplica(ReplicaView(by_row[r], st.entries[r], st.ads[r]), s)
            for r, s in picked
        ]

    def _sharded_topk_tier(
        self,
        st: _SnapshotState,
        iv: Any,
        kernel_batch: List[int],
        replica_lists: Sequence[Optional[List[PhysicalFile]]],
        reqs: Sequence[Optional[ClassAd]],
        recs: Sequence[Any],
        results: List[Any],
        admit_mat: Any,
        top_k: int,
        vocab: Tuple[str, ...],
    ) -> None:
        """Tier 1a for sharded snapshots: each query is first looked up in
        the per-shard-epoch result cache — valid while every shard its
        candidates live in is unchanged — and only the misses walk the
        per-shard sparse top-k + hierarchical merge (DESIGN.md §9)."""
        import numpy as np
        from contextlib import contextmanager

        from repro.kernels.matchrank.sharded import sharded_sparse_topk
        from repro.kernels.matchrank.sparse import IntervalBatch

        from .plancache import request_cache_key

        snap = st.snapshot
        answers: Dict[int, Tuple[Any, Any]] = {}  # batch slot → (ti, ts)
        shard_sets: List[List[int]] = []
        keys: List[Tuple] = []
        miss_bis: List[int] = []
        for bi, i in enumerate(kernel_batch):
            rows = [
                r
                for pfn in replica_lists[i]
                if (r := st.row_of.get(pfn.endpoint)) is not None
            ]
            shard_sets.append(sorted({snap.shard_of_row(r) for r in rows}))
            key = (
                "sharded_topk",
                recs[i].lfn,
                int(top_k),
                tuple(sorted(p.endpoint for p in replica_lists[i])),
                request_cache_key(reqs[i], vocab, self.env),
                snap.uid,
            )
            keys.append(key)
            hit, val = self.plan_cache.topk_get(key, snap.shard_epochs)
            if hit:
                answers[bi] = val
            else:
                miss_bis.append(bi)
        if miss_bis:
            m = np.asarray(miss_bis, dtype=np.int64)
            batch_m = IntervalBatch(
                lo=iv.lo[m],
                hi=iv.hi[m],
                used=iv.used[m],
                weights=iv.weights[m],
                bias=iv.bias[m],
                undef_rank=iv.undef_rank[m],
            )
            tracer = self.tracer

            @contextmanager
            def observe(g):
                with tracer.span("broker.shard_rank", shard=int(g)) as sp:
                    yield
                self._shard_hist(int(g)).observe(sp.duration)

            shards = [snap.shard_logical_columns(g) for g in range(snap.g)]
            with self.tracer.span(
                "broker.sharded_topk",
                batch=len(miss_bis),
                rows=snap.n,
                shards=snap.g,
                k=top_k,
            ):
                ti, ts = sharded_sparse_topk(
                    shards,
                    batch_m,
                    k=top_k,
                    offsets=snap.offsets,
                    admit=admit_mat[m][:, : snap.n],
                    rank_order=snap.shard_rank_order,
                    observe=observe,
                )
            for j, bi in enumerate(miss_bis):
                val = (ti[j].copy(), ts[j].copy())
                touched = {g: int(snap.shard_epochs[g]) for g in shard_sets[bi]}
                self.plan_cache.topk_put(keys[bi], touched, val)
                answers[bi] = val
        for bi, i in enumerate(kernel_batch):
            ti_row, ts_row = answers[bi]
            results[i] = self._ranked_from_topk(replica_lists[i], st, ti_row, ts_row)
            recs[i].kernel_path = "sharded_topk"
            recs[i].shards = sorted(
                {snap.shard_of_row(int(r)) for r in ti_row if int(r) >= 0}
            )
            self._fill_batched_audit(recs[i], st, results[i])
            self._ctr["batched_sharded_requests"].inc()

    def _fill_batched_audit(
        self, rec, st: _SnapshotState, result: List[RankedReplica], mask=None, score=None
    ) -> None:
        """Per-candidate fates for a snapshot-tier request. Dense tiers
        pass row-level (mask, score); the sparse tier only probed until k
        candidates passed, so non-winners are recorded unmatched/unscored."""
        if mask is not None:
            scores = []
            for ep in rec.candidates:
                r = st.row_of.get(ep)
                ok = r is not None and bool(mask[r])
                scores.append(CandidateScore(ep, float(score[r]) if ok else None, ok))
            rec.scores = scores
        else:
            won = {rr.pfn.endpoint: rr.rank for rr in result}
            rec.scores = [
                CandidateScore(ep, won.get(ep), ep in won) for ep in rec.candidates
            ]
        rec.chosen = result[0].pfn.endpoint if result else None

    # ------------------------------------------------------------------ Access
    def fetch(
        self,
        lfn: str,
        transfer: TransferService,
        request: Optional[ClassAd] = None,
        *,
        monitor_stragglers: bool = True,
    ) -> FetchOutcome:
        """Search+Match+Access in one call (the paper's full loop)."""
        ranked = self.select(lfn, request)
        return self.access(lfn, ranked, transfer, monitor_stragglers=monitor_stragglers)

    def access(
        self,
        lfn: str,
        ranked: "SelectionResult | List[RankedReplica]",
        transfer: TransferService,
        *,
        monitor_stragglers: bool = True,
        request_id: Optional[str] = None,
    ) -> FetchOutcome:
        """Access Phase with failover and straggler mitigation, over a
        pre-computed selection (e.g. from a batched ``select_many``).

        Walks the ranked list; a failed endpoint advances to the next
        (failover); a transfer whose observed chunk bandwidth stays below
        ``straggler_factor × predicted`` for ``straggler_patience`` chunks
        is abandoned mid-flight and the next replica is tried.

        The outcome annotates the selection's decision record — a
        :class:`SelectionResult` carries its own ``request_id``; a bare
        list attaches to ``last_request_id`` when its lfn matches.
        """
        if request_id is None and isinstance(ranked, SelectionResult):
            request_id = ranked.request_id
        with self.tracer.span("broker.access", lfn=lfn):
            return self._access_impl(
                lfn,
                ranked,
                transfer,
                monitor_stragglers=monitor_stragglers,
                request_id=request_id,
            )

    def note_access(self, request_id: Optional[str], result: TransferResult) -> None:
        """Annotate a selection's decision record with an access outcome
        produced *outside* :meth:`access` — the resilient transfer
        service executes the plan itself and reports back here. Also
        feeds the client-side history monitor, keyed by the endpoint
        that contributed the most bytes."""
        self._ctr["fetches"].inc()
        top = None
        if result.per_replica:
            top = max(result.per_replica.items(), key=lambda kv: (kv[1], kv[0]))[0]
            self.local_monitor.observe_transfer(
                "read", top, result.nbytes, result.seconds, self.clock.now()
            )
        self._h_fetch_bw.observe(result.bandwidth / 1e6)
        if result.failovers:
            self._ctr["failovers"].inc(result.failovers)
        if request_id is not None and request_id in self.audit:
            rec = self.audit.get(request_id)
            rec.accessed = True
            rec.fetched_from = top
            rec.attempts = result.stripes + result.failovers
            rec.failovers += result.failovers
            rec.observed_bandwidth = result.bandwidth
            rec.nbytes = int(result.nbytes)

    def _access_impl(
        self,
        lfn: str,
        ranked: "SelectionResult | List[RankedReplica]",
        transfer: TransferService,
        *,
        monitor_stragglers: bool,
        request_id: Optional[str],
    ) -> FetchOutcome:
        if not ranked:
            raise NoMatchError(lfn)
        rid = request_id or self.last_request_id
        rec = None
        if rid is not None and rid in self.audit:
            cand = self.audit.get(rid)
            # implicit attachment only when the record is for this file
            if request_id is not None or cand.lfn == lfn:
                rec = cand
        self._ctr["fetches"].inc()
        attempts = 0
        switched = 0
        errors: List[str] = []
        abandoned: List[RankedReplica] = []  # straggler-abandoned, still alive

        def _finish(
            rr: RankedReplica, payload, nbytes, seconds, predicted
        ) -> FetchOutcome:
            self.local_monitor.observe_transfer(
                "read", rr.pfn.endpoint, nbytes, seconds, self.clock.now()
            )
            bw = nbytes / seconds if seconds > 0 else 0.0
            self._h_fetch_bw.observe(bw / 1e6)
            if rec is not None:
                rec.accessed = True
                rec.fetched_from = rr.pfn.endpoint
                rec.attempts = attempts
                rec.predicted_bandwidth = predicted
                rec.observed_bandwidth = bw
                rec.nbytes = int(nbytes)
            return FetchOutcome(
                lfn, rr.pfn, nbytes, seconds, attempts, switched, ranked, payload
            )

        for rr in ranked:
            if attempts >= self.max_attempts:
                break
            attempts += 1
            predicted = self._predicted_bandwidth(rr)
            try:
                if monitor_stragglers and predicted:
                    result = self._monitored_read(transfer, rr, predicted)
                    if result is None:  # straggler: try next replica
                        switched += 1
                        self._ctr["straggler_switches"].inc()
                        if rec is not None:
                            rec.straggler_switches += 1
                        abandoned.append(rr)
                        continue
                    payload, nbytes, seconds = result
                else:
                    res = transfer.transfer(TransferRequest(rr.pfn, self.client_url))
                    payload, nbytes, seconds = res.payload, res.nbytes, res.seconds
            except TransferFailure as e:
                errors.append(str(e))
                self._ctr["failovers"].inc()
                if rec is not None:
                    rec.failovers += 1
                continue
            return _finish(rr, payload, nbytes, seconds, predicted)
        # Mitigation must never turn a working fetch into a failure: if the
        # list was exhausted by straggler switches, take the best abandoned
        # replica to completion without monitoring.
        for rr in abandoned:
            attempts += 1
            try:
                res = transfer.transfer(TransferRequest(rr.pfn, self.client_url))
                payload, nbytes, seconds = res.payload, res.nbytes, res.seconds
            except TransferFailure as e:
                errors.append(str(e))
                continue
            return _finish(rr, payload, nbytes, seconds, None)
        if rec is not None:
            rec.attempts = attempts
            rec.error = f"AccessFailed: all {attempts} attempt(s) failed"
        raise BrokerError(
            f"all {attempts} attempt(s) to fetch {lfn!r} failed"
            + (f": {errors}" if errors else "")
        )

    def _monitored_read(
        self, transfer: TransferService, rr: RankedReplica, predicted: float
    ) -> Optional[Tuple[Any, int, float]]:
        """Chunked read with mid-transfer bandwidth watch. Returns None if
        abandoned as a straggler."""
        chunks: List[Any] = []
        nbytes = 0
        seconds = 0.0
        slow = 0
        for ev in transfer.transfer_chunks(TransferRequest(rr.pfn, self.client_url)):
            payload, cbytes, csecs = ev.payload, ev.nbytes, ev.seconds
            chunks.append(payload)
            nbytes += cbytes
            seconds += csecs
            bw = cbytes / csecs if csecs > 0 else math.inf
            if bw < self.straggler_factor * predicted:
                slow += 1
                if slow >= self.straggler_patience:
                    return None
            else:
                slow = 0
        merged = b"".join(c for c in chunks if isinstance(c, (bytes, bytearray))) if chunks and isinstance(chunks[0], (bytes, bytearray)) else chunks
        return merged, nbytes, seconds

    # -------------------------------------------------------------- placement
    def select_placements(
        self,
        nbytes: int,
        endpoints: Sequence[str],
        *,
        k: int = 2,
        request: Optional[ClassAd] = None,
    ) -> SelectionResult:
        """Write-side matchmaking: choose ``k`` placement targets for a new
        replica of size ``nbytes`` (checkpoint placement uses this).
        Returns the same :class:`SelectionResult` shape as the read path
        (no transfer plan — writes create replicas, they don't stripe
        reads over them)."""
        req = request if request is not None else default_write_request(self.client_url, nbytes)
        views: List[ReplicaView] = []
        for ep in endpoints:
            gris = self.gris_resolver(ep)
            if gris is None:
                continue
            entry = gris.flattened_view(source=self.client_url)
            entry.setdefault("endpoint", ep)
            pfn = PhysicalFile(ep, "", nbytes)
            views.append(ReplicaView(pfn, entry, entry_to_classad(entry)))
        ranked = self.match(req, views)
        if len(ranked) < 1:
            raise NoMatchError(f"no endpoint admits a {nbytes}-byte replica")
        ranked = ranked[:k]
        matched = {rr.pfn.endpoint: rr.rank for rr in ranked}
        scores = [
            CandidateScore(ep, matched.get(ep), ep in matched) for ep in endpoints
        ]
        return SelectionResult(ranked, lfn=f"<placement:{nbytes}B>", scores=scores)
