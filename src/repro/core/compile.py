"""ClassAd → columnar tensor compiler (beyond-paper, TPU adaptation).

The paper matches one request against tens of ads with a tree-walking
interpreter. At fleet scale (10⁴ clients × 10⁴ replicas, selection on
every shard fetch), the Match Phase becomes a hot loop. The TPU-native
observation is that matchmaking over *numeric* attributes is a columnar
predicate + scoring problem:

    attrs[S, A] (server attribute matrix)  ×  one compiled (requirements,
    rank) program  →  mask[S], score[S]  →  top-k.

This module compiles the request's ``requirements``/``rank`` ASTs — and
each *distinct* server-policy expression (servers publish policies drawn
from a small set of admin templates, so we group by expression source) —
into closures over an array namespace ``xp``. The same compiled program
executes under numpy (float64 — bit-identical selection semantics for the
broker) or ``jax.numpy`` under ``jit`` (float32 — throughput path, and the
front half of the Pallas ``matchrank`` kernel).

Undefined/Error semantics survive vectorization: every column carries a
validity mask and boolean results are Kleene (value, defined) pairs with
Condor's absorption rules (``False && Undefined == False``). Error is
conservatively folded into "not defined" — for match gating and ranking
the two are indistinguishable (neither is ``True``; a non-numeric rank is
0.0), so selections are identical to the interpreter's (property-tested).

Expressions that fall outside the columnar subset (string ops, list ops,
nested-ad selects) raise :class:`CompileError`; callers fall back to the
interpreter — the paper-faithful path is always available.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .classads import (
    AttrRef,
    BinOp,
    ClassAd,
    Error,
    Expr,
    FuncCall,
    Literal,
    Ternary,
    UnaryOp,
    Undefined,
    evaluate,
)
from .matchmaker import rank_value

__all__ = [
    "CompileError",
    "Tri",
    "Num",
    "CompiledProgram",
    "compile_program",
    "ColumnTable",
    "build_columns",
    "vectorized_match",
    "extract_conjunctive_terms",
    "extract_linear_rank",
    "ConjTerm",
]


class CompileError(ValueError):
    """Expression falls outside the columnar subset."""


# ---------------------------------------------------------------------------
# Runtime representations
# ---------------------------------------------------------------------------


@dataclass
class Num:
    """A numeric array (or scalar) with a validity mask."""

    val: Any  # xp array [S] or python float
    ok: Any  # xp bool array [S] or python bool


@dataclass
class Tri:
    """Kleene boolean: (value, defined). Undefined/Error ⇒ defined=False."""

    val: Any
    ok: Any


class ColumnTable:
    """Named numeric columns with validity masks over S candidates."""

    def __init__(self, n: int):
        self.n = n
        self.cols: Dict[str, np.ndarray] = {}
        self.valid: Dict[str, np.ndarray] = {}

    def add(self, name: str, values: np.ndarray, valid: np.ndarray) -> None:
        self.cols[name.lower()] = values
        self.valid[name.lower()] = valid

    def has(self, name: str) -> bool:
        return name.lower() in self.cols

    def names(self) -> List[str]:
        return sorted(self.cols)


# ---------------------------------------------------------------------------
# Compilation
# ---------------------------------------------------------------------------

_SUPPORTED_FUNCS = {"ifthenelse", "isundefined", "abs", "min", "max", "floor", "ceiling"}


@dataclass
class _Ctx:
    """Compile-time context: which side is columns, which is constants."""

    column_ad: Optional[ClassAd]  # the ad whose attrs become columns (may be None)
    const_ad: Optional[ClassAd]  # the ad whose attrs are evaluated to scalars
    column_names: Callable[[str], bool]  # does a name exist as a column?
    env: Dict[str, Any]
    refs: List[str] = field(default_factory=list)  # columns referenced


def _const_value(ctx: _Ctx, name: str) -> Any:
    """Evaluate a constant-side attribute to a scalar at compile time."""
    if ctx.const_ad is None:
        return Undefined
    return ctx.const_ad.eval_attr(name, None, ctx.env)


def _lit_num(x: float) -> Callable:
    def run(tbl, xp):
        return Num(x, True)

    return run


def _lit_tri(b: Optional[bool]) -> Callable:
    def run(tbl, xp):
        if b is None:
            return Tri(False, False)
        return Tri(bool(b), True)

    return run


def _col_ref(name: str) -> Callable:
    low = name.lower()

    def run(tbl, xp):
        return Num(tbl.cols[low], tbl.valid[low])

    return run


def _broadcast_ok(a, b, xp):
    return xp.logical_and(a, b) if not (a is True and b is True) else True


def _and_ok(a, b, xp):
    if a is True:
        return b
    if b is True:
        return a
    return xp.logical_and(a, b)


def compile_expr(expr: Expr, ctx: _Ctx) -> Tuple[str, Callable]:
    """Compile to a closure ``f(table, xp) -> Num | Tri``.

    Returns ('num'|'tri', fn). Raises CompileError outside the subset.
    """
    if isinstance(expr, Literal):
        v = expr.value
        if isinstance(v, bool):
            return "tri", _lit_tri(v)
        if isinstance(v, (int, float)):
            return "num", _lit_num(float(v))
        if v is Undefined or v is Error:
            return "tri", _lit_tri(None)
        raise CompileError(f"literal {v!r} not columnar")

    if isinstance(expr, AttrRef):
        return _compile_attr(expr, ctx)

    if isinstance(expr, UnaryOp):
        kind, f = compile_expr(expr.operand, ctx)
        if expr.op == "!":
            if kind != "tri":
                raise CompileError("! on non-boolean")

            def run_not(tbl, xp, f=f):
                t = f(tbl, xp)
                return Tri(xp.logical_not(t.val), t.ok)

            return "tri", run_not
        if kind != "num":
            raise CompileError("unary +/- on non-numeric")
        sign = -1.0 if expr.op == "-" else 1.0

        def run_neg(tbl, xp, f=f, sign=sign):
            v = f(tbl, xp)
            return Num(v.val * sign, v.ok)

        return "num", run_neg

    if isinstance(expr, BinOp):
        return _compile_binop(expr, ctx)

    if isinstance(expr, Ternary):
        ck, cf = compile_expr(expr.cond, ctx)
        if ck != "tri":
            raise CompileError("ternary condition must be boolean")
        tk, tf = compile_expr(expr.then, ctx)
        ek, ef = compile_expr(expr.other, ctx)
        if tk != ek:
            raise CompileError("ternary arms must have the same kind")
        if tk == "num":

            def run_tern_n(tbl, xp, cf=cf, tf=tf, ef=ef):
                c, t, e = cf(tbl, xp), tf(tbl, xp), ef(tbl, xp)
                val = xp.where(c.val, t.val, e.val)
                ok = _and_ok(c.ok, xp.where(c.val, _ok_arr(t.ok, xp), _ok_arr(e.ok, xp)), xp)
                return Num(val, ok)

            return "num", run_tern_n

        def run_tern_b(tbl, xp, cf=cf, tf=tf, ef=ef):
            c, t, e = cf(tbl, xp), tf(tbl, xp), ef(tbl, xp)
            val = xp.where(c.val, t.val, e.val)
            ok = _and_ok(c.ok, xp.where(c.val, _ok_arr(t.ok, xp), _ok_arr(e.ok, xp)), xp)
            return Tri(val, ok)

        return "tri", run_tern_b

    if isinstance(expr, FuncCall):
        return _compile_func(expr, ctx)

    raise CompileError(f"{type(expr).__name__} not columnar")


def _ok_arr(ok, xp):
    return ok if ok is not True else xp.asarray(True)


def _compile_attr(expr: AttrRef, ctx: _Ctx) -> Tuple[str, Callable]:
    name = expr.name
    scope = expr.scope
    # Decide column vs constant, mirroring the interpreter's lookup order:
    # unqualified → self (const side here is 'my'), then other.
    if scope == "other":
        side = "column"
    elif scope == "my":
        side = "const"
    else:
        if ctx.const_ad is not None and name.lower() in ctx.const_ad:
            side = "const"
        elif ctx.column_names(name):
            side = "column"
        elif name.lower() in ctx.env:
            v = ctx.env[name.lower()]
            if isinstance(v, bool):
                return "tri", _lit_tri(v)
            if isinstance(v, (int, float)):
                return "num", _lit_num(float(v))
            raise CompileError(f"env value {name} not numeric")
        else:
            # unknown everywhere: Undefined
            return "tri", _lit_tri(None)

    if side == "const":
        v = _const_value(ctx, name)
        if isinstance(v, bool):
            return "tri", _lit_tri(v)
        if isinstance(v, (int, float)):
            return "num", _lit_num(float(v))
        if v is Undefined or v is Error:
            return "tri", _lit_tri(None)
        raise CompileError(f"constant attr {name} is non-numeric: {v!r}")

    # column side — even when compiling the *request* ("other" = server),
    # or a server policy (unqualified = server's own columns).
    ctx.refs.append(name.lower())
    low = name.lower()

    def run(tbl, xp, low=low):
        if low not in tbl.cols:
            # column absent for every candidate ⇒ Undefined
            return Num(xp.zeros((tbl.n,)), xp.zeros((tbl.n,), dtype=bool))
        return Num(tbl.cols[low], tbl.valid[low])

    return "num", run


_NUM_BIN = {"+", "-", "*", "/", "%"}
_CMP_BIN = {"==", "!=", "<", "<=", ">", ">="}


def _compile_binop(expr: BinOp, ctx: _Ctx) -> Tuple[str, Callable]:
    op = expr.op
    if op in ("&&", "||"):
        lk, lf = compile_expr(expr.left, ctx)
        rk, rf = compile_expr(expr.right, ctx)
        if lk != "tri" or rk != "tri":
            raise CompileError(f"{op} on non-boolean")
        if op == "&&":

            def run_and(tbl, xp, lf=lf, rf=rf):
                l, r = lf(tbl, xp), rf(tbl, xp)
                val = xp.logical_and(l.val, r.val)
                l_ok, r_ok = _ok_arr(l.ok, xp), _ok_arr(r.ok, xp)
                # defined if both defined, or either side is a defined False
                ok = xp.logical_or(
                    xp.logical_and(l_ok, r_ok),
                    xp.logical_or(
                        xp.logical_and(l_ok, xp.logical_not(l.val)),
                        xp.logical_and(r_ok, xp.logical_not(r.val)),
                    ),
                )
                return Tri(val, ok)

            return "tri", run_and

        def run_or(tbl, xp, lf=lf, rf=rf):
            l, r = lf(tbl, xp), rf(tbl, xp)
            val = xp.logical_or(l.val, r.val)
            l_ok, r_ok = _ok_arr(l.ok, xp), _ok_arr(r.ok, xp)
            ok = xp.logical_or(
                xp.logical_and(l_ok, r_ok),
                xp.logical_or(
                    xp.logical_and(l_ok, l.val), xp.logical_and(r_ok, r.val)
                ),
            )
            return Tri(val, ok)

        return "tri", run_or

    if op in ("=?=", "=!="):
        raise CompileError("identity comparison not columnar")  # rarely numeric

    lk, lf = compile_expr(expr.left, ctx)
    rk, rf = compile_expr(expr.right, ctx)
    if lk != "num" or rk != "num":
        raise CompileError(f"{op} requires numeric operands")

    if op in _CMP_BIN:
        import operator

        fns = {
            "==": operator.eq,
            "!=": operator.ne,
            "<": operator.lt,
            "<=": operator.le,
            ">": operator.gt,
            ">=": operator.ge,
        }
        cmp = fns[op]

        def run_cmp(tbl, xp, lf=lf, rf=rf, cmp=cmp):
            l, r = lf(tbl, xp), rf(tbl, xp)
            return Tri(cmp(l.val, r.val), _and_ok(l.ok, r.ok, xp))

        return "tri", run_cmp

    if op in _NUM_BIN:

        def run_arith(tbl, xp, lf=lf, rf=rf, op=op):
            l, r = lf(tbl, xp), rf(tbl, xp)
            ok = _and_ok(l.ok, r.ok, xp)
            if op == "+":
                v = l.val + r.val
            elif op == "-":
                v = l.val - r.val
            elif op == "*":
                v = l.val * r.val
            elif op == "/":
                denom_ok = r.val != 0
                v = l.val / xp.where(denom_ok, r.val, 1.0)
                ok = _and_ok(ok, denom_ok, xp)
            else:  # %
                denom_ok = r.val != 0
                v = xp.where(denom_ok, l.val - xp.trunc(l.val / xp.where(denom_ok, r.val, 1.0)) * r.val, 0.0)
                ok = _and_ok(ok, denom_ok, xp)
            return Num(v, ok)

        return "num", run_arith

    raise CompileError(f"operator {op} not columnar")  # pragma: no cover


def _compile_func(expr: FuncCall, ctx: _Ctx) -> Tuple[str, Callable]:
    name = expr.name
    if name not in _SUPPORTED_FUNCS:
        raise CompileError(f"builtin {name}() not columnar")
    if name == "isundefined":
        (arg,) = expr.args
        kind, f = compile_expr(arg, ctx)

        def run_isundef(tbl, xp, f=f):
            v = f(tbl, xp)
            ok = _ok_arr(v.ok, xp)
            return Tri(xp.logical_not(ok), True)

        return "tri", run_isundef
    if name == "ifthenelse":
        c, t, e = expr.args
        return compile_expr(Ternary(c, t, e), ctx)
    if name == "abs":
        (arg,) = expr.args
        kind, f = compile_expr(arg, ctx)
        if kind != "num":
            raise CompileError("abs on non-numeric")

        def run_abs(tbl, xp, f=f):
            v = f(tbl, xp)
            return Num(xp.abs(v.val), v.ok)

        return "num", run_abs
    if name in ("floor", "ceiling"):
        (arg,) = expr.args
        kind, f = compile_expr(arg, ctx)
        if kind != "num":
            raise CompileError(f"{name} on non-numeric")
        g = np.floor if name == "floor" else np.ceil

        def run_fc(tbl, xp, f=f, name=name):
            v = f(tbl, xp)
            fn = xp.floor if name == "floor" else xp.ceil
            return Num(fn(v.val), v.ok)

        return "num", run_fc
    # min/max over 2+ numeric args
    fs = []
    for a in expr.args:
        kind, f = compile_expr(a, ctx)
        if kind != "num":
            raise CompileError(f"{name} on non-numeric")
        fs.append(f)
    take_min = name == "min"

    def run_mm(tbl, xp, fs=tuple(fs), take_min=take_min):
        vals = [f(tbl, xp) for f in fs]
        acc = vals[0].val
        ok = vals[0].ok
        for v in vals[1:]:
            acc = xp.minimum(acc, v.val) if take_min else xp.maximum(acc, v.val)
            ok = _and_ok(ok, v.ok, xp)
        return Num(acc, ok)

    return "num", run_mm


# ---------------------------------------------------------------------------
# Whole-program compilation
# ---------------------------------------------------------------------------


@dataclass
class CompiledProgram:
    """A compiled (requirements, rank) pair for one request (plus the
    distinct server-policy programs it must be symmetric against)."""

    req_fn: Optional[Callable]  # f(tbl, xp) -> Tri, None means no requirements
    rank_fn: Optional[Callable]  # f(tbl, xp) -> Num, None means rank 0
    referenced: List[str]

    def run(self, tbl: ColumnTable, xp=np) -> Tuple[np.ndarray, np.ndarray]:
        """→ (mask[S] bool, rank[S] float). Undefined rank → 0."""
        if self.req_fn is None:
            mask = xp.ones((tbl.n,), dtype=bool)
        else:
            t = self.req_fn(tbl, xp)
            ok = _ok_arr(t.ok, xp)
            mask = xp.logical_and(xp.asarray(t.val), ok)
            mask = xp.broadcast_to(mask, (tbl.n,))
        if self.rank_fn is None:
            rank = xp.zeros((tbl.n,))
        else:
            r = self.rank_fn(tbl, xp)
            ok = _ok_arr(r.ok, xp)
            rank = xp.where(ok, r.val, 0.0)
            rank = xp.broadcast_to(xp.asarray(rank, dtype=xp.asarray(0.0).dtype), (tbl.n,))
        return mask, rank


def compile_program(
    request: ClassAd,
    *,
    column_names: Callable[[str], bool],
    env: Optional[Dict[str, Any]] = None,
) -> CompiledProgram:
    """Compile a request ad's requirements+rank against server columns."""
    env = {k.lower(): v for k, v in (env or {}).items()}
    ctx = _Ctx(column_ad=None, const_ad=request, column_names=column_names, env=env)
    req_fn = None
    if "requirements" in request:
        kind, fn = compile_expr(request["requirements"], ctx)
        if kind != "tri":
            raise CompileError("requirements must be boolean")
        req_fn = fn
    rank_fn = None
    if "rank" in request:
        kind, fn = compile_expr(request["rank"], ctx)
        if kind == "tri":
            # boolean rank: true→1.0 (Condor)
            bfn = fn

            def rank_from_bool(tbl, xp, bfn=bfn):
                t = bfn(tbl, xp)
                return Num(xp.where(t.val, 1.0, 0.0), t.ok)

            rank_fn = rank_from_bool
        else:
            rank_fn = fn
    return CompiledProgram(req_fn, rank_fn, sorted(set(ctx.refs)))


def compile_policy(
    policy_expr: Expr,
    request: ClassAd,
    *,
    column_names: Callable[[str], bool],
    env: Optional[Dict[str, Any]] = None,
) -> Callable:
    """Compile a *server-side* policy: unqualified/my = server columns,
    other = the (constant) request. Returns f(tbl, xp) -> Tri."""
    env = {k.lower(): v for k, v in (env or {}).items()}

    # Swap roles: other.→const(request); unqualified/my.→columns.
    def swap(expr: Expr) -> Expr:
        if isinstance(expr, AttrRef):
            if expr.scope == "other":
                return AttrRef("my", expr.name)  # resolves in const_ad
            if expr.scope == "my" or expr.scope is None:
                return AttrRef("other", expr.name)  # resolves to columns
            return expr
        if isinstance(expr, UnaryOp):
            return UnaryOp(expr.op, swap(expr.operand))
        if isinstance(expr, BinOp):
            return BinOp(expr.op, swap(expr.left), swap(expr.right))
        if isinstance(expr, Ternary):
            return Ternary(swap(expr.cond), swap(expr.then), swap(expr.other))
        if isinstance(expr, FuncCall):
            return FuncCall(expr.name, tuple(swap(a) for a in expr.args))
        return expr

    ctx = _Ctx(column_ad=None, const_ad=request, column_names=column_names, env=env)
    kind, fn = compile_expr(swap(policy_expr), ctx)
    if kind != "tri":
        raise CompileError("policy must be boolean")
    return fn


# ---------------------------------------------------------------------------
# Column building + end-to-end vectorized match
# ---------------------------------------------------------------------------


def build_columns(entries: Sequence[Dict[str, Any]], names: Sequence[str]) -> ColumnTable:
    """Assemble named numeric columns (with validity) from entry dicts."""
    n = len(entries)
    tbl = ColumnTable(n)
    for name in names:
        low = name.lower()
        vals = np.zeros((n,), dtype=np.float64)
        ok = np.zeros((n,), dtype=bool)
        for i, e in enumerate(entries):
            v = None
            for k, x in e.items():
                if k.lower() == low:
                    v = x
                    break
            if isinstance(v, bool):
                vals[i] = 1.0 if v else 0.0
                ok[i] = True
            elif isinstance(v, (int, float)):
                vals[i] = float(v)
                ok[i] = True
        tbl.add(low, vals, ok)
    return tbl


def vectorized_match(request: ClassAd, views: Sequence, *, env=None, xp=np):
    """Drop-in replacement for the interpreted Match Phase.

    Returns rank-sorted ``RankedReplica`` list identical to the
    interpreter's, or None if the request (or any server policy) falls
    outside the columnar subset.
    """
    from .broker import RankedReplica  # local import to avoid cycle
    from .classads import parse as parse_expr

    if not views:
        return []
    entries = [v.entry for v in views]
    present: set = set()
    for e in entries:
        present.update(k.lower() for k in e.keys())

    try:
        prog = compile_program(request, column_names=lambda n: n.lower() in present, env=env)
        # group server policies by source text; compile each once
        policy_groups: Dict[str, List[int]] = {}
        for i, v in enumerate(views):
            pexpr = v.ad.lookup_expr("requirements")
            key = repr(pexpr) if pexpr is not None else ""
            policy_groups.setdefault(key, []).append(i)
        policy_fns: Dict[str, Optional[Callable]] = {}
        for key in policy_groups:
            if key == "":
                policy_fns[key] = None
                continue
            policy_fns[key] = compile_policy(
                parse_expr(key), request, column_names=lambda n: n.lower() in present, env=env
            )
    except CompileError:
        return None

    names = set(prog.referenced)
    # policies may reference more columns; recompile-collect via a dry ref scan
    tbl = build_columns(entries, sorted(present))  # build all numeric columns
    mask, rank = prog.run(tbl, xp)
    mask = np.asarray(mask, dtype=bool).copy()
    rank = np.asarray(rank, dtype=np.float64)

    for key, idxs in policy_groups.items():
        fn = policy_fns[key]
        if fn is None:
            continue
        t = fn(tbl, xp)
        ok = t.ok if t.ok is not True else np.ones((tbl.n,), dtype=bool)
        pol = np.logical_and(np.broadcast_to(np.asarray(t.val), (tbl.n,)),
                             np.broadcast_to(np.asarray(ok), (tbl.n,)))
        sel = np.zeros((tbl.n,), dtype=bool)
        sel[idxs] = True
        mask &= np.where(sel, pol, True)

    order = _rank_order(mask, rank, views)
    return [RankedReplica(views[i], float(rank[i])) for i in order]


def _rank_order(mask: np.ndarray, rank: np.ndarray, views) -> List[int]:
    """Descending rank with the interpreter's deterministic tiebreak."""

    def name_of(i):
        e = views[i].entry
        for attr in ("name", "hostname", "endpoint", "url"):
            for k, v in e.items():
                if k.lower() == attr and isinstance(v, str):
                    return v
        return f"resource-{i}"

    idx = [i for i in range(len(views)) if mask[i]]
    idx.sort(key=lambda i: (-rank[i], name_of(i), i))
    return idx


# ---------------------------------------------------------------------------
# Kernel lowering: conjunctive-threshold extraction
# ---------------------------------------------------------------------------

#: opcode encoding shared with kernels/matchrank
OPCODES = {"<": 0, "<=": 1, ">": 2, ">=": 3, "==": 4, "!=": 5}


@dataclass(frozen=True)
class ConjTerm:
    attr: str
    op: str  # one of OPCODES
    threshold: float


def _scalar_of(expr: Expr, request: ClassAd, env) -> Optional[float]:
    """Evaluate an expression that involves only the request/env to a float."""
    try:
        v = evaluate(expr, request, None, env)
    except Exception:
        return None
    if isinstance(v, bool) or not isinstance(v, (int, float)):
        return None
    return float(v)


def extract_conjunctive_terms(
    expr: Expr, request: ClassAd, *, env=None
) -> Optional[List[ConjTerm]]:
    """If ``expr`` is a conjunction of ``other.attr OP const`` comparisons,
    return the terms for the Pallas kernel path; else None.

    ``const`` may be any request-side scalar expression (e.g.
    ``my.reqdSpace * 2``) — it is folded at extraction time.
    """
    terms: List[ConjTerm] = []

    def walk(e: Expr) -> bool:
        if isinstance(e, BinOp) and e.op == "&&":
            return walk(e.left) and walk(e.right)
        if isinstance(e, BinOp) and e.op in OPCODES:
            # other.attr OP scalar   |   scalar OP other.attr
            for attr_side, const_side, flip in ((e.left, e.right, False), (e.right, e.left, True)):
                if isinstance(attr_side, AttrRef) and attr_side.scope in ("other", None):
                    c = _scalar_of(const_side, request, env)
                    if c is None:
                        continue
                    op = e.op
                    if flip:
                        op = {"<": ">", "<=": ">=", ">": "<", ">=": "<=", "==": "==", "!=": "!="}[op]
                    terms.append(ConjTerm(attr_side.name.lower(), op, c))
                    return True
            return False
        if isinstance(e, Literal) and e.value is True:
            return True
        return False

    return terms if walk(expr) else None


def extract_linear_rank(
    expr: Expr, request: ClassAd, *, env=None
) -> Optional[Dict[str, float]]:
    """If ``rank`` is (a constant multiple / sum of) ``other.attr`` terms,
    return {attr: weight, '': bias} for the kernel's dot-product scorer."""
    weights: Dict[str, float] = {}

    def add(attr: str, w: float) -> None:
        weights[attr] = weights.get(attr, 0.0) + w

    def walk(e: Expr, scale: float) -> bool:
        if isinstance(e, AttrRef) and e.scope in ("other", None):
            add(e.name.lower(), scale)
            return True
        if isinstance(e, BinOp) and e.op == "+":
            return walk(e.left, scale) and walk(e.right, scale)
        if isinstance(e, BinOp) and e.op == "-":
            return walk(e.left, scale) and walk(e.right, -scale)
        if isinstance(e, BinOp) and e.op == "*":
            c = _scalar_of(e.left, request, env)
            if c is not None:
                return walk(e.right, scale * c)
            c = _scalar_of(e.right, request, env)
            if c is not None:
                return walk(e.left, scale * c)
            return False
        if isinstance(e, BinOp) and e.op == "/":
            c = _scalar_of(e.right, request, env)
            if c is not None and c != 0:
                return walk(e.left, scale / c)
            return False
        if isinstance(e, UnaryOp) and e.op == "-":
            return walk(e.operand, -scale)
        c = _scalar_of(e, request, env)
        if c is not None:
            add("", scale * c)
            return True
        return False

    return weights if walk(expr, 1.0) else None
