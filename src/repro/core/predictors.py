"""Bandwidth predictors: history as a predictor of future transfer times.

§3.2: "We favor an alternative approach in which historical information
concerning data transfer rates is used as a predictor of future transfer
times... statistical information based on the performance data, such as
average transfer bandwidths and their standard deviations, that can help
predict the behavior of a particular replica."

§7 points at the Network Weather Service for predictive analysis; NWS
(Wolski '98) runs a *family* of forecasters and picks whichever has the
lowest recent error. We implement the paper's simple statistics (last
value, running mean/min/max/std) plus the NWS-style family:

  * ``LastValue``       — the paper's ``lastRDBandwidth`` heuristic,
  * ``RunningMean``     — the paper's ``AvgRDBandwidth``,
  * ``SlidingMean(w)``, ``SlidingMedian(w)`` — windowed robust variants,
  * ``Ewma(alpha)``     — exponential smoothing,
  * ``AdaptivePredictor`` — NWS-style: tracks per-forecaster MAE online and
    predicts with the current best.

All are O(1)-update streaming estimators over scalar series, used by the
broker to turn per-source history into a rank attribute, and mirrored in
vectorized form by ``kernels/bwstats`` for fleet-scale batches.
"""

from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Sequence, Tuple

__all__ = [
    "Predictor",
    "LastValue",
    "RunningMean",
    "SlidingMean",
    "SlidingMedian",
    "Ewma",
    "AdaptivePredictor",
    "make_predictor",
    "PREDICTOR_FAMILIES",
]


class Predictor:
    """Streaming scalar predictor interface."""

    name = "base"

    def update(self, value: float) -> None:  # pragma: no cover - abstract
        raise NotImplementedError

    def predict(self) -> Optional[float]:  # pragma: no cover - abstract
        raise NotImplementedError

    def update_many(self, values: Sequence[float]) -> None:
        for v in values:
            self.update(v)


class LastValue(Predictor):
    """Predict the most recent observation (paper's ``lastRDBandwidth``)."""

    name = "last"

    def __init__(self):
        self._last: Optional[float] = None

    def update(self, value: float) -> None:
        self._last = float(value)

    def predict(self) -> Optional[float]:
        return self._last


class RunningMean(Predictor):
    """Predict the all-history mean (paper's ``AvgRDBandwidth``), with
    Welford-stable mean/std tracking."""

    name = "mean"

    def __init__(self):
        self.n = 0
        self._mean = 0.0
        self._m2 = 0.0

    def update(self, value: float) -> None:
        self.n += 1
        d = value - self._mean
        self._mean += d / self.n
        self._m2 += d * (value - self._mean)

    def predict(self) -> Optional[float]:
        return self._mean if self.n else None

    @property
    def std(self) -> float:
        return math.sqrt(self._m2 / self.n) if self.n > 1 else 0.0


class SlidingMean(Predictor):
    name = "sliding_mean"

    def __init__(self, window: int = 16):
        if window < 1:
            raise ValueError("window must be >= 1")
        self.window = window
        self._buf: Deque[float] = deque(maxlen=window)
        self._sum = 0.0

    def update(self, value: float) -> None:
        if len(self._buf) == self.window:
            self._sum -= self._buf[0]
        self._buf.append(float(value))
        self._sum += float(value)

    def predict(self) -> Optional[float]:
        return self._sum / len(self._buf) if self._buf else None


class SlidingMedian(Predictor):
    """Windowed median — robust to the bandwidth outliers WANs produce."""

    name = "sliding_median"

    def __init__(self, window: int = 16):
        if window < 1:
            raise ValueError("window must be >= 1")
        self.window = window
        self._buf: Deque[float] = deque(maxlen=window)

    def update(self, value: float) -> None:
        self._buf.append(float(value))

    def predict(self) -> Optional[float]:
        if not self._buf:
            return None
        s = sorted(self._buf)
        n = len(s)
        mid = n // 2
        return s[mid] if n % 2 else 0.5 * (s[mid - 1] + s[mid])


class Ewma(Predictor):
    name = "ewma"

    def __init__(self, alpha: float = 0.25):
        if not 0.0 < alpha <= 1.0:
            raise ValueError("alpha must be in (0, 1]")
        self.alpha = alpha
        self._value: Optional[float] = None

    def update(self, value: float) -> None:
        if self._value is None:
            self._value = float(value)
        else:
            self._value = self.alpha * float(value) + (1.0 - self.alpha) * self._value

    def predict(self) -> Optional[float]:
        return self._value


class AdaptivePredictor(Predictor):
    """NWS-style forecaster selection: run a family, track each member's
    mean absolute error against realized observations, predict with the
    member whose recent error is lowest."""

    name = "adaptive"

    def __init__(self, members: Optional[Sequence[Predictor]] = None, error_window: int = 32):
        self.members: List[Predictor] = list(
            members
            if members is not None
            else [LastValue(), RunningMean(), SlidingMean(8), SlidingMedian(8), Ewma(0.25)]
        )
        self._errors: List[Deque[float]] = [deque(maxlen=error_window) for _ in self.members]

    def update(self, value: float) -> None:
        # Score each member's *prior* prediction against the new truth...
        for pred, errs in zip(self.members, self._errors):
            p = pred.predict()
            if p is not None:
                errs.append(abs(p - value))
        # ...then let everyone absorb the observation.
        for pred in self.members:
            pred.update(value)

    def _mae(self, i: int) -> float:
        errs = self._errors[i]
        return sum(errs) / len(errs) if errs else float("inf")

    def best_member(self) -> Predictor:
        scored = [(self._mae(i), i) for i in range(len(self.members))]
        scored.sort()
        return self.members[scored[0][1]]

    def predict(self) -> Optional[float]:
        # Before any errors accumulate, fall back to the first member
        # that has data (deterministic order).
        best = self.best_member()
        p = best.predict()
        if p is not None:
            return p
        for m in self.members:
            q = m.predict()
            if q is not None:
                return q
        return None


PREDICTOR_FAMILIES = {
    "last": LastValue,
    "mean": RunningMean,
    "sliding_mean": SlidingMean,
    "sliding_median": SlidingMedian,
    "ewma": Ewma,
    "adaptive": AdaptivePredictor,
}


def make_predictor(kind: str, **kwargs) -> Predictor:
    if kind not in PREDICTOR_FAMILIES:
        raise ValueError(f"unknown predictor {kind!r}; options: {sorted(PREDICTOR_FAMILIES)}")
    return PREDICTOR_FAMILIES[kind](**kwargs)
