"""The shared transfer vocabulary: TransferRequest → TransferResult.

The Access Phase used to speak in positional tuples — ``read(replica,
client_url) -> (payload, nbytes, seconds)`` — which could not carry the
things a resilient transfer produces: per-replica byte contributions,
retries, hedges, stripe counts. This module is the one vocabulary the
broker (core), the transfer services (storage) and every consumer
(serve/checkpoint/data) now share:

  * :class:`TransferRequest` — what to move (one replica's byte range,
    stream parallelism), replacing the positional argument pair,
  * :class:`ChunkEvent` — one chunk's worth of progress (straggler
    monitoring, restart markers),
  * :class:`TransferResult` — what happened (bytes, simulated wall time,
    per-replica contribution, retries/hedges/stripes),
  * :class:`TransferPlan` — the broker's Access Phase prescription:
    primary + ranked backups + predicted bandwidths + the per-chunk
    stripe map a striped executor follows.

It lives in ``core`` (below both ``core.broker`` and ``storage``) so
neither layer needs a deferred import of the other.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence

from .catalog import PhysicalFile

__all__ = [
    "TransferFailure",
    "TransferRequest",
    "ChunkEvent",
    "TransferResult",
    "TransferPlan",
]


class TransferFailure(IOError):
    """Endpoint dead / refused / mid-transfer fault."""


@dataclass(frozen=True)
class TransferRequest:
    """One replica read: the unit the base transfer service executes.

    ``offset``/``length`` select a byte range (striped executors read
    ranges; ``length=None`` means to end-of-file). ``n_streams`` is the
    GridFTP stream parallelism for this transfer; ``None`` defers to the
    service's configured default.
    """

    replica: PhysicalFile
    client_url: str
    offset: int = 0
    length: Optional[int] = None
    n_streams: Optional[int] = None


@dataclass(frozen=True)
class ChunkEvent:
    """One completed chunk of an in-flight transfer (restart marker)."""

    payload: bytes
    nbytes: int
    seconds: float
    offset: int  # absolute byte offset within the logical file
    endpoint: str

    @property
    def bandwidth(self) -> float:
        return self.nbytes / self.seconds if self.seconds > 0 else 0.0


@dataclass
class TransferResult:
    """What a transfer actually did, in simulated time."""

    payload: Any
    nbytes: int
    seconds: float
    # endpoint url → bytes it contributed (one entry for single-source)
    per_replica: Dict[str, int] = field(default_factory=dict)
    retries: int = 0  # transient failures retried with backoff
    hedges: int = 0  # backup stripes launched against slow sources
    hedge_wins: int = 0  # chunks the hedge stripe claimed first
    stripes: int = 1  # concurrent stripe count at launch
    failovers: int = 0  # replicas abandoned for dead/exhausted endpoints
    lfn: Optional[str] = None

    @property
    def bandwidth(self) -> float:
        return self.nbytes / self.seconds if self.seconds > 0 else 0.0


@dataclass
class TransferPlan:
    """The broker's prescription for the Access Phase.

    ``replicas`` is rank order — ``replicas[0]`` is the primary, the rest
    are backups. ``predicted[i]`` is the broker's bandwidth prediction
    for ``replicas[i]`` (None when the endpoint is cold and no history
    exists); hedging compares observed chunk bandwidth against it.
    ``stripe_k`` bounds how many replicas a striped executor fans out
    over; :meth:`stripe_map` assigns chunks to stripes proportionally to
    predicted bandwidth.
    """

    lfn: str
    replicas: List[PhysicalFile]
    ranks: List[float]
    predicted: List[Optional[float]]
    stripe_k: int = 3
    request_id: Optional[str] = None

    @property
    def primary(self) -> PhysicalFile:
        return self.replicas[0]

    @property
    def backups(self) -> List[PhysicalFile]:
        return self.replicas[1:]

    def predicted_for(self, endpoint: str) -> Optional[float]:
        for pfn, p in zip(self.replicas, self.predicted):
            if pfn.endpoint == endpoint:
                return p
        return None

    def stripe_map(self, n_chunks: int, k: Optional[int] = None) -> List[int]:
        """chunk index → stripe index (into ``replicas[:k]``), weighted by
        predicted bandwidth so a 2x-faster source owns 2x the chunks.
        Deterministic: largest-remainder apportionment, then contiguous
        runs (each stripe reads a consecutive byte range per run)."""
        k = min(k if k is not None else self.stripe_k, len(self.replicas))
        k = max(k, 1)
        if n_chunks <= 0:
            return []
        weights = []
        for i in range(k):
            p = self.predicted[i]
            weights.append(float(p) if p and p > 0 else 0.0)
        if not any(w > 0 for w in weights):
            weights = [1.0] * k
        else:  # cold stripes still get a floor share so they warm up
            floor = min(w for w in weights if w > 0)
            weights = [w if w > 0 else floor for w in weights]
        total = sum(weights)
        shares = [w / total * n_chunks for w in weights]
        counts = [int(s) for s in shares]
        rem = n_chunks - sum(counts)
        order = sorted(range(k), key=lambda i: (-(shares[i] - counts[i]), i))
        for i in order[:rem]:
            counts[i] += 1
        out: List[int] = []
        for i, c in enumerate(counts):
            out.extend([i] * c)
        return out
