"""Two-stage hierarchical top-k over a sharded snapshot (DESIGN.md §9).

Stage 1 — per-shard fused matchrank+top-k: the existing batched kernel
(:func:`~repro.kernels.matchrank.kernel.matchrank_batched_pallas`) is
``vmap``-ed over the shard axis of a stacked ``[G, S_shard, A_PAD]``
candidate block, producing each request's k best candidates *per shard*
(``[G, B, k]``). On a multi-device mesh the stacked block can be laid out
with :func:`repro.parallel.sharding.shard_axis_mesh` /
``distribute_shards`` so the vmapped kernel partitions along the shard
axis; on one device it runs as a batched loop — same results either way.

Stage 2 — merge: per-shard candidate lists are globalized (local index +
shard row offset), flattened **shard-major** into ``[B, G·k]`` and merged
into the global top-k by a small Pallas kernel (k knockout-argmax rounds
per request, grid ``(B,)``).

Tie-break contract (property-tested): every per-shard list is
rank-descending with ties at the lowest local index, and the shard-major
flattening makes candidate *position* order agree with *global row*
order within any equal-score run — so first-maximum knockout in the
merge reproduces exactly the ``lax.top_k`` tie-break (lowest global row
index) of an equivalent flat snapshot.

:func:`sharded_sparse_topk` is the CPU steady-state twin: the rank-order
sparse walk (:mod:`.sparse`) runs per shard against per-shard cached
rank orders, then the same merge (NumPy reference) combines candidates.
"""

from __future__ import annotations

import functools
import math
from contextlib import nullcontext
from typing import Any, Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .kernel import NEG_INF, matchrank_batched_pallas
from .ops import BatchedPlan, KernelPlan, stack_plans
from .ref import matchrank_batched_ref, merge_topk_ref
from .sparse import IntervalBatch, topk_in_rank_order

__all__ = [
    "MERGE_K_PAD",
    "merge_topk_pallas",
    "sharded_matchrank_topk",
    "sharded_sparse_topk",
]

#: lane-aligned output width of the merge kernel (bounds k)
MERGE_K_PAD = 128


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _merge_topk_kernel(
    scores_ref,  # [1, C_PAD] f32 — request b's flattened per-shard candidates
    idx_ref,  # [1, C_PAD] i32 — matching global row indices
    out_s_ref,  # [1, MERGE_K_PAD] f32
    out_i_ref,  # [1, MERGE_K_PAD] i32
    *,
    k: int,
):
    s = scores_ref[0, :]
    idx = idx_ref[0, :]
    pos = jnp.arange(s.shape[0])
    out_s = jnp.full((MERGE_K_PAD,), NEG_INF, dtype=jnp.float32)
    out_i = jnp.zeros((MERGE_K_PAD,), dtype=jnp.int32)
    # k knockout-argmax rounds; first max ⇒ lowest position on ties, and
    # position order == global-row order within ties (shard-major layout)
    for j in range(k):
        m = jnp.argmax(s)
        out_s = out_s.at[j].set(s[m])
        out_i = out_i.at[j].set(idx[m])
        s = jnp.where(pos == m, NEG_INF, s)
    out_s_ref[0, :] = out_s
    out_i_ref[0, :] = out_i


def merge_topk_pallas(
    cand_scores: jnp.ndarray,  # [B, C] f32
    cand_idx: jnp.ndarray,  # [B, C] i32
    k: int,
    *,
    interpret: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Merge per-shard candidate lists into the global top-k.

    Pads the candidate axis to the lane width with (-inf, 0) and returns
    (scores [B, k] f32, idx [B, k] i32); slots past a request's match
    count hold -inf (index meaningless there, as in the fused kernel).
    """
    assert 1 <= k <= MERGE_K_PAD, (k, MERGE_K_PAD)
    b, c = cand_scores.shape
    c_pad = max(_round_up(c, 128), 128)
    scores = jnp.full((b, c_pad), NEG_INF, dtype=jnp.float32)
    scores = scores.at[:, :c].set(cand_scores.astype(jnp.float32))
    idx = jnp.zeros((b, c_pad), dtype=jnp.int32)
    idx = idx.at[:, :c].set(cand_idx.astype(jnp.int32))

    kernel = functools.partial(_merge_topk_kernel, k=k)
    grid = (b,)
    out_shapes = (
        jax.ShapeDtypeStruct((b, MERGE_K_PAD), jnp.float32),
        jax.ShapeDtypeStruct((b, MERGE_K_PAD), jnp.int32),
    )
    in_specs = [
        pl.BlockSpec((1, c_pad), lambda bi: (bi, 0)),  # scores
        pl.BlockSpec((1, c_pad), lambda bi: (bi, 0)),  # idx
    ]
    out_specs = (
        pl.BlockSpec((1, MERGE_K_PAD), lambda bi: (bi, 0)),
        pl.BlockSpec((1, MERGE_K_PAD), lambda bi: (bi, 0)),
    )
    out_s, out_i = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shapes,
        interpret=interpret,
    )(scores, idx)
    return out_s[:, :k], out_i[:, :k]


@functools.partial(
    jax.jit, static_argnames=("k", "block_s", "use_kernel", "interpret")
)
def _stage1_sharded(
    attrs, valid, admit, sel, op_codes, thresholds, term_active, weights, bias,
    offsets,
    *, k: int, block_s: int, use_kernel: bool, interpret: bool,
):
    """Per-shard fused matchrank+top-k, vmapped over the shard axis.
    → (cand_scores [B, G·k] f32, cand_idx [B, G·k] i32) in shard-major
    candidate order, indices globalized by the shard row offsets."""

    def one(a, v, ad):
        if use_kernel:
            _, _, tks, tki = matchrank_batched_pallas(
                a, v, ad, sel, op_codes, thresholds, term_active, weights,
                bias, block_s=block_s, k=k, interpret=interpret,
            )
        else:
            _, _, tks, tki = matchrank_batched_ref(
                a, v, ad, sel, op_codes, thresholds, term_active, weights,
                bias, k=k,
            )
        return tks, tki

    tks, tki = jax.vmap(one)(attrs, valid, admit)  # [G, B, k]
    gidx = tki.astype(jnp.int32) + offsets[:, None, None].astype(jnp.int32)
    b = tks.shape[1]
    cand_s = jnp.transpose(tks, (1, 0, 2)).reshape(b, -1)  # [B, G·k]
    cand_i = jnp.transpose(gidx, (1, 0, 2)).reshape(b, -1)
    return cand_s, cand_i


def _split_admit(
    admit: Optional[np.ndarray],
    b: int,
    counts: np.ndarray,
    offsets: np.ndarray,
    s_shard_pad: int,
) -> np.ndarray:
    """Global [B, n] pre-mask → stacked [G, B, S_shard] per-shard masks.
    Padded rows are always masked out (they carry no valid attributes but
    a requirement-free request would otherwise admit them)."""
    g = len(counts)
    out = np.zeros((g, b, s_shard_pad), dtype=np.float32)
    for gi in range(g):
        c = int(counts[gi])
        if c == 0:
            continue
        off = int(offsets[gi])
        if admit is None:
            out[gi, :, :c] = 1.0
        else:
            out[gi, :, :c] = np.asarray(admit, dtype=np.float32)[:, off : off + c]
    return out


def sharded_matchrank_topk(
    attrs: Any,  # [G, S_shard, A_PAD] f32 — stacked per-shard blocks
    valid: Any,  # [G, S_shard, A_PAD] f32
    plans: "BatchedPlan | Sequence[KernelPlan]",
    *,
    counts: np.ndarray,  # [G] live rows per shard
    offsets: np.ndarray,  # [G] global row offset per shard
    k: int = 1,
    admit: Optional[np.ndarray] = None,  # [B, n] global pre-mask
    block_s: int = 512,
    use_kernel: bool = True,
    interpret: bool = True,
    merge_kernel: bool = True,
) -> Tuple[np.ndarray, np.ndarray]:
    """Device-parallel hierarchical top-k: per-shard fused kernel (vmap
    over shards) + merge kernel. → (topk_idx [B, k] i64 **global** rows,
    topk_scores [B, k] f32); empty slots hold (-1, -inf).

    Equal to flat ``lax.top_k`` over the dense scores, tie-break included
    (see module docstring). ``merge_kernel=False`` swaps stage 2 for the
    NumPy reference (parity tests).
    """
    batched = plans if isinstance(plans, BatchedPlan) else stack_plans(list(plans))
    s_shard_pad = int(attrs.shape[1])
    if s_shard_pad % block_s:
        # shard padding smaller/misaligned vs the requested S-block (e.g.
        # a snapshot built with a finer block_s): the largest common block
        # keeps the kernel's grid exact
        block_s = math.gcd(s_shard_pad, block_s) or s_shard_pad
    admit_g = _split_admit(admit, batched.b, counts, offsets, s_shard_pad)
    cand_s, cand_i = _stage1_sharded(
        attrs, valid, jnp.asarray(admit_g),
        jnp.asarray(batched.sel), jnp.asarray(batched.op_codes),
        jnp.asarray(batched.thresholds), jnp.asarray(batched.term_active),
        jnp.asarray(batched.weights), jnp.asarray(batched.bias),
        jnp.asarray(np.asarray(offsets, dtype=np.int32)),
        k=k, block_s=block_s, use_kernel=use_kernel, interpret=interpret,
    )
    if merge_kernel:
        ts, ti = merge_topk_pallas(cand_s, cand_i, k, interpret=interpret)
        ts, ti = np.asarray(ts), np.asarray(ti)
    else:
        ts, ti = merge_topk_ref(np.asarray(cand_s), np.asarray(cand_i), k)
    ti = np.where(np.isneginf(ts), -1, ti.astype(np.int64))
    return ti, ts.astype(np.float32)


def sharded_sparse_topk(
    shards: Sequence[Tuple[np.ndarray, np.ndarray]],  # [(attrs, valid)] per shard
    batch: IntervalBatch,
    *,
    k: int = 1,
    offsets: Optional[np.ndarray] = None,
    admit: Optional[np.ndarray] = None,  # [B, n] global pre-mask
    rank_order: Optional[Callable[[int, np.ndarray, float], Tuple]] = None,
    observe: Optional[Callable[[int], Any]] = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """CPU steady-state twin of :func:`sharded_matchrank_topk`: rank-order
    sparse walk per shard, then the reference merge.

    ``rank_order(g, weights, bias) → (order, svals)`` supplies each
    shard's cached rank order (``ShardedSnapshot.shard_rank_order``);
    ``observe(g)`` may return a context manager wrapping shard g's walk
    (the broker passes tracer spans feeding its per-shard latency
    histogram). → (topk_idx [B, k] i64 global rows, topk_scores [B, k]);
    empty slots hold (-1, -inf).
    """
    parts_i: List[np.ndarray] = []
    parts_s: List[np.ndarray] = []
    pos = 0
    for g, (attrs, valid) in enumerate(shards):
        c = attrs.shape[0]
        off = int(offsets[g]) if offsets is not None else pos
        pos += c
        adm = None
        if admit is not None:
            adm = np.asarray(admit)[:, off : off + c]
        ro = None
        if rank_order is not None:
            ro = functools.partial(rank_order, g)
        cm = observe(g) if observe is not None else nullcontext()
        with cm:
            ti, ts = topk_in_rank_order(
                attrs, valid, batch, k=k, admit=adm, rank_order=ro
            )
        parts_i.append(np.where(ti >= 0, ti + off, ti))
        parts_s.append(ts)
    cand_i = np.concatenate(parts_i, axis=1)  # [B, G·k] shard-major
    cand_s = np.concatenate(parts_s, axis=1)
    ts, ti = merge_topk_ref(cand_s, cand_i, k)
    ti = np.where(np.isneginf(ts), -1, ti.astype(np.int64))
    return ti, ts
