"""Jit'd wrapper + request lowering for the matchrank kernel.

``matchrank`` pads/validates inputs and dispatches to the Pallas kernel
(or the pure-jnp ref as a fallback). ``lower_request`` turns a ClassAd
request into kernel operands via the conjunctive-threshold / linear-rank
extractors of :mod:`repro.core.compile` — the bridge from the paper's
language to the TPU hot loop. ``matchrank_topk`` composes the fused scores
with ``lax.top_k`` for k > 1.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.classads import ClassAd
from repro.core.compile import (
    OPCODES,
    CompileError,
    extract_conjunctive_terms,
    extract_linear_rank,
)

from .kernel import matchrank_batched_pallas, matchrank_pallas
from .ref import NEG_INF, matchrank_batched_ref, matchrank_ref

__all__ = [
    "KernelPlan",
    "BatchedPlan",
    "lower_request",
    "stack_plans",
    "matchrank",
    "matchrank_topk",
    "matchrank_batched",
    "matchrank_batched_topk",
    "pad_columns",
]


def _pad_to(x: np.ndarray, n: int, axis: int = 0, fill=0.0) -> np.ndarray:
    pad = n - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return np.pad(x, widths, constant_values=fill)


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass
class KernelPlan:
    """Kernel operands lowered from a ClassAd request over a fixed
    attribute vocabulary (column order)."""

    attr_names: List[str]  # column order, len = A (pre-pad)
    sel: np.ndarray  # [T_PAD, A_PAD]
    op_codes: np.ndarray  # [T_PAD] i32
    thresholds: np.ndarray  # [T_PAD] f32
    term_active: np.ndarray  # [T_PAD] f32
    weights: np.ndarray  # [A_PAD] f32
    bias: np.ndarray  # [1] f32
    a_pad: int
    t_pad: int


def lower_request(
    request: ClassAd,
    attr_names: Sequence[str],
    *,
    env: Optional[Dict] = None,
    t_pad: int = 16,
) -> KernelPlan:
    """Lower (requirements, rank) to kernel operands, or raise CompileError.

    This is the 'predicate pushdown' contract: the request must be a
    conjunction of threshold comparisons and a linear rank — the common
    case for storage selection (space/bandwidth gates, bandwidth rank).
    Anything richer takes the columnar-JAX or interpreter path instead.
    """
    names = [n.lower() for n in attr_names]
    index = {n: i for i, n in enumerate(names)}
    a = len(names)
    a_pad = max(_round_up(a, 128), 128)

    req = request.lookup_expr("requirements")
    terms = []
    if req is not None:
        extracted = extract_conjunctive_terms(req, request, env=env)
        if extracted is None:
            raise CompileError("requirements not conjunctive-threshold")
        terms = extracted
    if len(terms) > t_pad:
        t_pad = _round_up(len(terms), 8)

    sel = np.zeros((t_pad, a_pad), dtype=np.float32)
    op_codes = np.zeros((t_pad,), dtype=np.int32)
    thresholds = np.zeros((t_pad,), dtype=np.float32)
    term_active = np.zeros((t_pad,), dtype=np.float32)
    for t, term in enumerate(terms):
        if term.attr not in index:
            # attribute absent from the vocabulary: every candidate is
            # Undefined on it ⇒ nothing can match. Encode as an
            # always-false active term on column 0.
            sel[t, 0] = 1.0
            op_codes[t] = OPCODES["<"]
            thresholds[t] = float("-inf")
            term_active[t] = 1.0
            continue
        sel[t, index[term.attr]] = 1.0
        op_codes[t] = OPCODES[term.op]
        thresholds[t] = np.float32(term.threshold)
        term_active[t] = 1.0

    rank_expr = request.lookup_expr("rank")
    weights = np.zeros((a_pad,), dtype=np.float32)
    bias = np.zeros((1,), dtype=np.float32)
    if rank_expr is not None:
        lin = extract_linear_rank(rank_expr, request, env=env)
        if lin is None:
            raise CompileError("rank not linear")
        for attr, w in lin.items():
            if attr == "":
                bias[0] += np.float32(w)
            elif attr in index:
                weights[index[attr]] += np.float32(w)
            # weight on an unknown attribute ⇒ rank Undefined ⇒ 0 for all;
            # encode by an impossible validity demand: weight on padding col
            else:
                weights[a_pad - 1] += np.float32(w) if w != 0 else 0.0

    return KernelPlan(
        list(names), sel, op_codes, thresholds, term_active, weights, bias, a_pad, t_pad
    )


@dataclass
class BatchedPlan:
    """B stacked :class:`KernelPlan`\\ s over one shared attribute
    vocabulary, padded to a common T_PAD — the operand set of the
    multi-request kernel."""

    attr_names: List[str]
    sel: np.ndarray  # [B, T_PAD, A_PAD]
    op_codes: np.ndarray  # [B, T_PAD] i32
    thresholds: np.ndarray  # [B, T_PAD] f32
    term_active: np.ndarray  # [B, T_PAD] f32
    weights: np.ndarray  # [B, A_PAD] f32
    bias: np.ndarray  # [B] f32
    a_pad: int
    t_pad: int

    @property
    def b(self) -> int:
        return self.sel.shape[0]


def stack_plans(plans: Sequence[KernelPlan]) -> BatchedPlan:
    """Stack per-request plans into one batched operand set.

    All plans must share the attribute vocabulary (they were lowered
    against the same snapshot); T_PAD is re-padded to the batch maximum
    (padded terms are inactive, so semantics are unchanged).
    """
    if not plans:
        raise ValueError("stack_plans needs at least one plan")
    first = plans[0]
    for p in plans[1:]:
        if p.attr_names != first.attr_names or p.a_pad != first.a_pad:
            raise ValueError("stacked plans must share an attribute vocabulary")
    t_pad = max(p.t_pad for p in plans)

    def pt(x, fill=0.0):
        return _pad_to(x, t_pad, axis=0, fill=fill)

    return BatchedPlan(
        attr_names=list(first.attr_names),
        sel=np.stack([pt(p.sel) for p in plans]),
        op_codes=np.stack([pt(p.op_codes) for p in plans]),
        thresholds=np.stack([pt(p.thresholds) for p in plans]),
        term_active=np.stack([pt(p.term_active) for p in plans]),
        weights=np.stack([p.weights for p in plans]),
        bias=np.concatenate([p.bias for p in plans]),
        a_pad=first.a_pad,
        t_pad=t_pad,
    )


def pad_columns(
    attrs: np.ndarray, valid: np.ndarray, a_pad: int, block_s: int = 512
) -> Tuple[np.ndarray, np.ndarray, int]:
    """Pad [S, A] column blocks to [S_PAD, A_PAD]; padded rows invalid.

    Non-finite attribute cells (NaN/±inf from a misbehaving publisher)
    are zeroed and marked invalid — Condor's Undefined semantics —
    instead of poisoning the f32 cast and every comparison downstream.
    """
    s, a = attrs.shape
    s_pad = max(_round_up(s, block_s), block_s)
    attrs_f = np.asarray(attrs, dtype=np.float32)
    finite = np.isfinite(attrs_f)
    if not finite.all():
        attrs_f = np.where(finite, attrs_f, np.float32(0.0))
        valid = np.asarray(valid, dtype=bool) & finite
    attrs_p = _pad_to(_pad_to(attrs_f, a_pad, axis=1), s_pad, axis=0)
    valid_p = _pad_to(_pad_to(valid.astype(np.float32), a_pad, axis=1), s_pad, axis=0)
    return attrs_p, valid_p, s_pad


@functools.partial(
    jax.jit, static_argnames=("block_s", "use_kernel", "interpret")
)
def _dispatch(
    attrs, valid, admit, sel, op_codes, thresholds, term_active, weights, bias,
    *, block_s: int, use_kernel: bool, interpret: bool,
):
    if use_kernel:
        return matchrank_pallas(
            attrs, valid, admit, sel, op_codes, thresholds, term_active,
            weights, bias, block_s=block_s, interpret=interpret,
        )
    return matchrank_ref(
        attrs, valid, sel, op_codes, thresholds, term_active, weights, bias, admit
    )


@functools.partial(
    jax.jit, static_argnames=("k", "block_s", "use_kernel", "interpret")
)
def _dispatch_topk(
    attrs, valid, admit, sel, op_codes, thresholds, term_active, weights, bias,
    *, k: int, block_s: int, use_kernel: bool, interpret: bool,
):
    """Fused scores + top-k in one jitted program — no host round-trip."""
    mask, score, _, _ = _dispatch(
        attrs, valid, admit, sel, op_codes, thresholds, term_active, weights,
        bias, block_s=block_s, use_kernel=use_kernel, interpret=interpret,
    )
    vals, idx = jax.lax.top_k(score, k)
    return vals, idx


@functools.partial(
    jax.jit, static_argnames=("k", "block_s", "use_kernel", "interpret")
)
def _dispatch_batched(
    attrs, valid, admit, sel, op_codes, thresholds, term_active, weights, bias,
    *, k: int, block_s: int, use_kernel: bool, interpret: bool,
):
    if use_kernel:
        return matchrank_batched_pallas(
            attrs, valid, admit, sel, op_codes, thresholds, term_active,
            weights, bias, block_s=block_s, k=k, interpret=interpret,
        )
    return matchrank_batched_ref(
        attrs, valid, admit, sel, op_codes, thresholds, term_active, weights,
        bias, k=k,
    )


#: numpy comparator per opcode (shared encoding with core.compile.OPCODES)
_CMP_OPS = {
    0: np.less,
    1: np.less_equal,
    2: np.greater,
    3: np.greater_equal,
    4: np.equal,
    5: np.not_equal,
}


def _topk_desc_stable(score: np.ndarray, k: int) -> np.ndarray:
    """One row's top-k indices with the ``lax.top_k`` contract — score
    descending, ties → lowest index — via O(S + k·log k) argpartition
    instead of a full sort."""
    s = score.shape[0]
    if k >= s:
        return np.argsort(-score, kind="stable")[:k]
    part = np.argpartition(-score, k - 1)[:k]
    v = score[part].min()  # k-th value; ties at v need index-stable picking
    gt = np.nonzero(score > v)[0]
    eq = np.nonzero(score == v)[0][: k - gt.size]
    idx = np.concatenate([gt, eq])
    return idx[np.argsort(-score[idx], kind="stable")]


def _matchrank_batched_dense_host(
    attrs, valid, batched: BatchedPlan, admit, s: int, k: int
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Host evaluation of the dense batched fallback, tiled by *shared
    work* instead of materializing the [B, S, T] einsum of the jnp ref
    (which made the fallback ~370× slower than the sparse walk).

    Terms are grouped by (column, opcode) — one vectorized compare per
    group serves every request that asked it (broker batches are
    near-duplicate plans differing only in thresholds) — and rank forms
    by (weights, bias) — one [S, A] matvec per distinct rank expression.
    Semantics are element-identical to :func:`.ref.matchrank_batched_ref`
    (fail-closed Undefined terms, Condor rank-Undefined → 0.0, top-k
    ties → lowest row index).
    """
    a_host = np.asarray(attrs, dtype=np.float32)[:s]
    v_raw = np.asarray(valid)[:s]
    b = batched.b
    aw = a_host.shape[1]  # logical or pre-padded width, both fine
    na = len(batched.attr_names)

    def vcol(c: int) -> np.ndarray:  # one validity column, bool, on demand
        col = np.ascontiguousarray(v_raw[:, c])
        return col if col.dtype == bool else col > 0.5

    mask = np.empty((b, s), dtype=bool)
    if admit is None:
        mask[:] = True
    else:
        mask[:] = np.asarray(admit)[:, :s] > 0.5

    act = batched.term_active > 0.5  # [B, T]
    cols = batched.sel.argmax(axis=2)  # [B, T] — one-hot column per term
    groups: Dict[Tuple[int, int], List[Tuple[int, np.float32]]] = {}
    for bi in range(b):
        for t in np.nonzero(act[bi])[0]:
            key = (int(cols[bi, t]), int(batched.op_codes[bi, t]))
            groups.setdefault(key, []).append(
                (bi, np.float32(batched.thresholds[bi, t]))
            )
    for (c, op), members in groups.items():
        thr = np.array([m[1] for m in members], dtype=np.float32)
        colv = np.ascontiguousarray(a_host[:, c])  # strided col read once
        # [M, S] — member rows contiguous for the fold below
        passed = _CMP_OPS[op](colv[None, :], thr[:, None]) & vcol(c)[None, :]
        for j, (bi, _) in enumerate(members):
            mask[bi] &= passed[j]

    rgroups: Dict[Tuple[bytes, float], List[int]] = {}
    for bi in range(b):
        rkey = (batched.weights[bi].tobytes(), float(batched.bias[bi]))
        rgroups.setdefault(rkey, []).append(bi)
    score = np.empty((b, s), dtype=np.float32)
    for (wb, bias), members in rgroups.items():
        wv = np.frombuffer(wb, dtype=np.float32)
        if (np.abs(wv[na:]) > 0).any():
            # weight on a padding column = rank references an attribute
            # outside the vocabulary ⇒ Undefined ⇒ 0.0 for every row
            sv = np.zeros((s,), dtype=np.float32)
        else:
            w = wv[:aw]
            sv = (a_host @ w + np.float32(bias)).astype(np.float32)
            wcols = np.nonzero(w)[0]
            if wcols.size:
                okw = vcol(wcols[0]).copy()
                for c in wcols[1:]:
                    okw &= vcol(c)
                sv[~okw] = 0.0
        for bi in members:
            score[bi] = sv

    out_score = np.where(mask, score, np.float32(NEG_INF))
    keff = min(k, s)
    if keff == 1:
        # the broker's common case: one vectorized argmax (ties → lowest)
        m = out_score.argmax(axis=1)
        ti = m[:, None].astype(np.int32)
        ts = out_score[np.arange(b), m][:, None].astype(np.float32)
    else:
        ti = np.empty((b, keff), dtype=np.int32)
        ts = np.empty((b, keff), dtype=np.float32)
        for bi in range(b):
            idx = _topk_desc_stable(out_score[bi], keff)
            ti[bi] = idx
            ts[bi] = out_score[bi, idx]
    return mask, out_score, ti, ts


def _is_prepadded(attrs, a_pad: int, block_s: int) -> bool:
    """True when the candidate block is already device-padded (snapshot
    path): lane-aligned columns, block-aligned rows."""
    s, a = attrs.shape
    return a == a_pad and s > 0 and s % block_s == 0


def _prepare_columns(
    attrs, valid, a_pad: int, block_s: int, n_rows: Optional[int]
) -> Tuple[Any, Any, int, int]:
    """→ (attrs_p, valid_p, s, s_pad). Skips the host pad entirely when the
    inputs are already padded (e.g. held resident by a ReplicaSnapshot)."""
    if _is_prepadded(attrs, a_pad, block_s):
        s_pad = attrs.shape[0]
        s = int(n_rows) if n_rows is not None else s_pad
        return attrs, valid, s, s_pad
    s = attrs.shape[0] if n_rows is None else int(n_rows)
    attrs_p, valid_p, s_pad = pad_columns(
        np.asarray(attrs), np.asarray(valid), a_pad, block_s
    )
    return jnp.asarray(attrs_p), jnp.asarray(valid_p), s, s_pad


def matchrank(
    attrs: np.ndarray,  # [S, A] f32 (unpadded, or pre-padded [S_PAD, A_PAD])
    valid: np.ndarray,  # [S, A] bool/f32
    plan: KernelPlan,
    *,
    admit: Optional[np.ndarray] = None,  # [S] pre-mask (folded policies)
    n_rows: Optional[int] = None,  # real row count when pre-padded
    block_s: int = 512,
    use_kernel: bool = True,
    interpret: bool = True,
) -> Tuple[np.ndarray, np.ndarray, float, int]:
    """Run the fused match+rank+top-1. Returns (mask[S], score[S],
    best_score, best_idx) trimmed back to the unpadded S.

    Pre-padded device-resident inputs (``attrs.shape == [S_PAD, A_PAD]``
    with ``S_PAD % block_s == 0``) skip the host-side ``pad_columns`` +
    transfer — pass ``n_rows`` for the live row count.
    """
    attrs_p, valid_p, s, s_pad = _prepare_columns(
        attrs, valid, plan.a_pad, block_s, n_rows
    )
    admit_p = np.zeros((s_pad,), dtype=np.float32)
    if admit is None:
        admit_p[:s] = 1.0
    else:
        admit_p[:s] = np.asarray(admit, dtype=np.float32)[:s]

    mask, score, best_s, best_i = _dispatch(
        attrs_p, valid_p, jnp.asarray(admit_p),
        jnp.asarray(plan.sel), jnp.asarray(plan.op_codes),
        jnp.asarray(plan.thresholds), jnp.asarray(plan.term_active),
        jnp.asarray(plan.weights), jnp.asarray(plan.bias),
        block_s=block_s, use_kernel=use_kernel, interpret=interpret,
    )
    return (
        np.asarray(mask)[:s],
        np.asarray(score)[:s],
        float(best_s[0]),
        int(best_i[0]),
    )


def matchrank_topk(
    attrs: np.ndarray,
    valid: np.ndarray,
    plan: KernelPlan,
    k: int,
    *,
    admit: Optional[np.ndarray] = None,
    n_rows: Optional[int] = None,
    block_s: int = 512,
    use_kernel: bool = True,
    interpret: bool = True,
) -> Tuple[np.ndarray, np.ndarray]:
    """Top-k selection: fused scores + ``lax.top_k`` inside ONE jitted
    program (scores never leave the device before the top-k). Returns
    (indices[k], scores[k]); unmatched slots have score -inf."""
    attrs_p, valid_p, s, s_pad = _prepare_columns(
        attrs, valid, plan.a_pad, block_s, n_rows
    )
    admit_p = np.zeros((s_pad,), dtype=np.float32)
    if admit is None:
        admit_p[:s] = 1.0
    else:
        admit_p[:s] = np.asarray(admit, dtype=np.float32)[:s]

    vals, idx = _dispatch_topk(
        attrs_p, valid_p, jnp.asarray(admit_p),
        jnp.asarray(plan.sel), jnp.asarray(plan.op_codes),
        jnp.asarray(plan.thresholds), jnp.asarray(plan.term_active),
        jnp.asarray(plan.weights), jnp.asarray(plan.bias),
        k=min(k, s), block_s=block_s, use_kernel=use_kernel,
        interpret=interpret,
    )
    return np.asarray(idx), np.asarray(vals)


def matchrank_batched(
    attrs: np.ndarray,  # [S, A] (unpadded) or pre-padded [S_PAD, A_PAD]
    valid: np.ndarray,
    plans: "BatchedPlan | Sequence[KernelPlan]",
    *,
    admit: Optional[np.ndarray] = None,  # [B, S] per-request pre-mask
    n_rows: Optional[int] = None,
    k: int = 1,
    block_s: int = 512,
    use_kernel: bool = True,
    interpret: bool = True,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Batched fused match+rank+top-k: B requests against ONE candidate
    block in a single kernel launch.

    Returns (mask [B,S] bool, score [B,S] f32, topk_idx [B,k] i32,
    topk_scores [B,k] f32), trimmed to the live row count. Top-k slots
    beyond a request's match count hold score -inf (index is meaningless
    there, as in :func:`matchrank_topk`).
    """
    batched = plans if isinstance(plans, BatchedPlan) else stack_plans(list(plans))
    b = batched.b
    if not use_kernel:
        # grouped host evaluation — the jnp ref's [B,S,T] einsums are kept
        # as a parity oracle only (see _matchrank_batched_dense_host)
        s = attrs.shape[0] if n_rows is None else int(n_rows)
        return _matchrank_batched_dense_host(attrs, valid, batched, admit, s, k)
    attrs_p, valid_p, s, s_pad = _prepare_columns(
        attrs, valid, batched.a_pad, block_s, n_rows
    )
    admit_p = np.zeros((b, s_pad), dtype=np.float32)
    if admit is None:
        admit_p[:, :s] = 1.0
    else:
        admit_p[:, :s] = np.asarray(admit, dtype=np.float32)[:, :s]

    mask, score, topk_s, topk_i = _dispatch_batched(
        attrs_p, valid_p, jnp.asarray(admit_p),
        jnp.asarray(batched.sel), jnp.asarray(batched.op_codes),
        jnp.asarray(batched.thresholds), jnp.asarray(batched.term_active),
        jnp.asarray(batched.weights), jnp.asarray(batched.bias),
        k=min(k, s), block_s=block_s, use_kernel=use_kernel,
        interpret=interpret,
    )
    return (
        np.asarray(mask)[:, :s],
        np.asarray(score)[:, :s],
        np.asarray(topk_i),
        np.asarray(topk_s),
    )


def matchrank_batched_topk(
    attrs: np.ndarray,  # [S, A] (unpadded) or pre-padded [S_PAD, A_PAD]
    valid: np.ndarray,
    plans: Sequence[KernelPlan],
    *,
    k: int = 1,
    admit: Optional[np.ndarray] = None,  # [B, S] per-request pre-mask
    n_rows: Optional[int] = None,
    rank_order=None,  # Callable[[weights], (order, svals)] — snapshot cache
    use_sparse: Optional[bool] = None,
    block_s: int = 512,
    use_kernel: bool = False,
    interpret: bool = True,
) -> Tuple[np.ndarray, np.ndarray]:
    """Batched top-k *selection*: B requests → (topk_idx [B,k],
    topk_scores [B,k]); slots past a request's match count hold -inf
    (and index -1 on the sparse path).

    The steady-state CPU fast path answers each request by scanning
    candidates in precomputed rank-descending order until k pass its
    interval-canonicalized requirements (expected probes ≈ k/selectivity
    — see :mod:`.sparse`); plans outside the interval subset, or
    ``use_sparse=False``, fall back to the dense batched launch. Pass a
    :meth:`ReplicaSnapshot.rank_order <repro.core.snapshot.ReplicaSnapshot.rank_order>`
    so the per-(epoch, rank-weights) sort is amortized across calls.
    """
    from .sparse import canonicalize_plans, topk_in_rank_order

    plans = list(plans)
    na = len(plans[0].attr_names)
    if use_sparse is not False:
        batch = canonicalize_plans(plans, na)
        if batch is not None:
            a_host = np.asarray(attrs, dtype=np.float32)
            v_host = np.asarray(valid)
            s = a_host.shape[0] if n_rows is None else int(n_rows)
            return topk_in_rank_order(
                a_host[:s, :na],
                v_host[:s, :na] > 0.5 if v_host.dtype != bool else v_host[:s, :na],
                batch,
                k=k,
                admit=admit,
                rank_order=rank_order,
            )
        if use_sparse:
            raise CompileError("plan batch not interval-canonicalizable")
    _, _, ti, ts = matchrank_batched(
        attrs, valid, plans, admit=admit, n_rows=n_rows, k=k,
        block_s=block_s, use_kernel=use_kernel, interpret=interpret,
    )
    return ti.astype(np.int64), ts
