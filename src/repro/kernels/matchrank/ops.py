"""Jit'd wrapper + request lowering for the matchrank kernel.

``matchrank`` pads/validates inputs and dispatches to the Pallas kernel
(or the pure-jnp ref as a fallback). ``lower_request`` turns a ClassAd
request into kernel operands via the conjunctive-threshold / linear-rank
extractors of :mod:`repro.core.compile` — the bridge from the paper's
language to the TPU hot loop. ``matchrank_topk`` composes the fused scores
with ``lax.top_k`` for k > 1.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.classads import ClassAd
from repro.core.compile import (
    OPCODES,
    CompileError,
    extract_conjunctive_terms,
    extract_linear_rank,
)

from .kernel import matchrank_pallas
from .ref import matchrank_ref

__all__ = ["KernelPlan", "lower_request", "matchrank", "matchrank_topk", "pad_columns"]


def _pad_to(x: np.ndarray, n: int, axis: int = 0, fill=0.0) -> np.ndarray:
    pad = n - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return np.pad(x, widths, constant_values=fill)


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass
class KernelPlan:
    """Kernel operands lowered from a ClassAd request over a fixed
    attribute vocabulary (column order)."""

    attr_names: List[str]  # column order, len = A (pre-pad)
    sel: np.ndarray  # [T_PAD, A_PAD]
    op_codes: np.ndarray  # [T_PAD] i32
    thresholds: np.ndarray  # [T_PAD] f32
    term_active: np.ndarray  # [T_PAD] f32
    weights: np.ndarray  # [A_PAD] f32
    bias: np.ndarray  # [1] f32
    a_pad: int
    t_pad: int


def lower_request(
    request: ClassAd,
    attr_names: Sequence[str],
    *,
    env: Optional[Dict] = None,
    t_pad: int = 16,
) -> KernelPlan:
    """Lower (requirements, rank) to kernel operands, or raise CompileError.

    This is the 'predicate pushdown' contract: the request must be a
    conjunction of threshold comparisons and a linear rank — the common
    case for storage selection (space/bandwidth gates, bandwidth rank).
    Anything richer takes the columnar-JAX or interpreter path instead.
    """
    names = [n.lower() for n in attr_names]
    index = {n: i for i, n in enumerate(names)}
    a = len(names)
    a_pad = max(_round_up(a, 128), 128)

    req = request.lookup_expr("requirements")
    terms = []
    if req is not None:
        extracted = extract_conjunctive_terms(req, request, env=env)
        if extracted is None:
            raise CompileError("requirements not conjunctive-threshold")
        terms = extracted
    if len(terms) > t_pad:
        t_pad = _round_up(len(terms), 8)

    sel = np.zeros((t_pad, a_pad), dtype=np.float32)
    op_codes = np.zeros((t_pad,), dtype=np.int32)
    thresholds = np.zeros((t_pad,), dtype=np.float32)
    term_active = np.zeros((t_pad,), dtype=np.float32)
    for t, term in enumerate(terms):
        if term.attr not in index:
            # attribute absent from the vocabulary: every candidate is
            # Undefined on it ⇒ nothing can match. Encode as an
            # always-false active term on column 0.
            sel[t, 0] = 1.0
            op_codes[t] = OPCODES["<"]
            thresholds[t] = float("-inf")
            term_active[t] = 1.0
            continue
        sel[t, index[term.attr]] = 1.0
        op_codes[t] = OPCODES[term.op]
        thresholds[t] = np.float32(term.threshold)
        term_active[t] = 1.0

    rank_expr = request.lookup_expr("rank")
    weights = np.zeros((a_pad,), dtype=np.float32)
    bias = np.zeros((1,), dtype=np.float32)
    if rank_expr is not None:
        lin = extract_linear_rank(rank_expr, request, env=env)
        if lin is None:
            raise CompileError("rank not linear")
        for attr, w in lin.items():
            if attr == "":
                bias[0] += np.float32(w)
            elif attr in index:
                weights[index[attr]] += np.float32(w)
            # weight on an unknown attribute ⇒ rank Undefined ⇒ 0 for all;
            # encode by an impossible validity demand: weight on padding col
            else:
                weights[a_pad - 1] += np.float32(w) if w != 0 else 0.0

    return KernelPlan(
        list(names), sel, op_codes, thresholds, term_active, weights, bias, a_pad, t_pad
    )


def pad_columns(
    attrs: np.ndarray, valid: np.ndarray, a_pad: int, block_s: int = 512
) -> Tuple[np.ndarray, np.ndarray, int]:
    """Pad [S, A] column blocks to [S_PAD, A_PAD]; padded rows invalid."""
    s, a = attrs.shape
    s_pad = max(_round_up(s, block_s), block_s)
    attrs_p = _pad_to(_pad_to(attrs.astype(np.float32), a_pad, axis=1), s_pad, axis=0)
    valid_p = _pad_to(_pad_to(valid.astype(np.float32), a_pad, axis=1), s_pad, axis=0)
    return attrs_p, valid_p, s_pad


@functools.partial(
    jax.jit, static_argnames=("block_s", "use_kernel", "interpret")
)
def _dispatch(
    attrs, valid, admit, sel, op_codes, thresholds, term_active, weights, bias,
    *, block_s: int, use_kernel: bool, interpret: bool,
):
    if use_kernel:
        return matchrank_pallas(
            attrs, valid, admit, sel, op_codes, thresholds, term_active,
            weights, bias, block_s=block_s, interpret=interpret,
        )
    return matchrank_ref(
        attrs, valid, sel, op_codes, thresholds, term_active, weights, bias, admit
    )


def matchrank(
    attrs: np.ndarray,  # [S, A] f32 (unpadded)
    valid: np.ndarray,  # [S, A] bool/f32
    plan: KernelPlan,
    *,
    admit: Optional[np.ndarray] = None,  # [S] pre-mask (folded policies)
    block_s: int = 512,
    use_kernel: bool = True,
    interpret: bool = True,
) -> Tuple[np.ndarray, np.ndarray, float, int]:
    """Run the fused match+rank+top-1. Returns (mask[S], score[S],
    best_score, best_idx) trimmed back to the unpadded S."""
    s = attrs.shape[0]
    attrs_p, valid_p, s_pad = pad_columns(attrs, valid, plan.a_pad, block_s)
    if admit is None:
        admit_p = np.zeros((s_pad,), dtype=np.float32)
        admit_p[:s] = 1.0
    else:
        admit_p = np.zeros((s_pad,), dtype=np.float32)
        admit_p[:s] = np.asarray(admit, dtype=np.float32)

    mask, score, best_s, best_i = _dispatch(
        jnp.asarray(attrs_p), jnp.asarray(valid_p), jnp.asarray(admit_p),
        jnp.asarray(plan.sel), jnp.asarray(plan.op_codes),
        jnp.asarray(plan.thresholds), jnp.asarray(plan.term_active),
        jnp.asarray(plan.weights), jnp.asarray(plan.bias),
        block_s=block_s, use_kernel=use_kernel, interpret=interpret,
    )
    return (
        np.asarray(mask)[:s],
        np.asarray(score)[:s],
        float(best_s[0]),
        int(best_i[0]),
    )


def matchrank_topk(
    attrs: np.ndarray,
    valid: np.ndarray,
    plan: KernelPlan,
    k: int,
    **kw,
) -> Tuple[np.ndarray, np.ndarray]:
    """Top-k selection: fused kernel scores + lax.top_k. Returns
    (indices[k], scores[k]); unmatched slots have score -inf."""
    mask, score, _, _ = matchrank(attrs, valid, plan, **kw)
    s = jnp.asarray(score)
    vals, idx = jax.lax.top_k(s, min(k, s.shape[0]))
    return np.asarray(idx), np.asarray(vals)
