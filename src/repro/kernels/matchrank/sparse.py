"""Rank-order sparse top-k: the CPU steady-state fast path.

The dense batched dispatch touches every (request, candidate) pair, so a
B=64 × S=10k launch is bound by elementwise throughput no matter how the
arithmetic is arranged. In the steady state the broker answers *top-k*
selections against a snapshot that changes once per GRIS epoch — so the
candidate rows can be pre-sorted by rank score once per (snapshot,
rank-weights) pair and each request answered by scanning candidates in
rank-descending order until k rows pass its requirements. Expected probes
per request ≈ k / selectivity, independent of S.

Two host-side pieces:

* :func:`canonicalize_plans` folds a conjunctive-threshold
  :class:`~repro.kernels.matchrank.ops.KernelPlan` batch into per-column
  ``[lo, hi]`` intervals (strict ops via f32 ``nextafter``, ``==`` as a
  point interval). ``!=`` terms are not interval-shaped → returns None
  and the caller falls back to the dense path.
* :func:`topk_in_rank_order` walks candidates in cached rank order in
  chunks, testing the whole request batch against each chunk at once.

Ties (equal f32 scores) resolve to the lowest candidate index — the same
order ``lax.top_k`` and the kernel's carry merge produce — because the
order is a *stable* argsort of the negated scores.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.compile import OPCODES

__all__ = ["IntervalBatch", "canonicalize_plans", "rank_scores", "topk_in_rank_order"]

_OP_LT = OPCODES["<"]
_OP_LE = OPCODES["<="]
_OP_GT = OPCODES[">"]
_OP_GE = OPCODES[">="]
_OP_EQ = OPCODES["=="]
_OP_NE = OPCODES["!="]

_F32_INF = np.float32(np.inf)


@dataclass(frozen=True)
class IntervalBatch:
    """B conjunctive plans canonicalized to per-column intervals: request
    b admits row s iff for every used column c,
    ``valid[s,c] and lo[b,c] <= attrs[s,c] <= hi[b,c]``.

    ``undef_rank[b]`` marks plans whose rank references an attribute
    outside the vocabulary (lowered as weight on the padding column):
    Condor's convention makes that rank 0.0 for *every* candidate."""

    lo: np.ndarray  # [B, A] f32
    hi: np.ndarray  # [B, A] f32
    used: np.ndarray  # [B, A] bool
    weights: np.ndarray  # [B, A] f32 (logical width, padding trimmed)
    bias: np.ndarray  # [B] f32
    undef_rank: np.ndarray  # [B] bool

    @property
    def b(self) -> int:
        return self.lo.shape[0]

    @property
    def n_attrs(self) -> int:
        return self.lo.shape[1]


def _above(v: np.float32) -> np.float32:
    """Smallest f32 strictly greater than v (x > v  ⟺  x >= _above(v))."""
    return np.nextafter(np.float32(v), _F32_INF)


def _below(v: np.float32) -> np.float32:
    return np.nextafter(np.float32(v), -_F32_INF)


def _plan_interval(plan, n_attrs: int):
    """Per-plan interval fold, memoized on the plan object (plans are
    shared across calls via the PlanCache, so the Python term walk is
    paid once per distinct request shape). Returns None for ``!=``."""
    cached = getattr(plan, "_interval_cache", None)
    if cached is not None and cached[0] == n_attrs:
        return cached[1]
    lo = np.full((n_attrs,), -np.inf, dtype=np.float32)
    hi = np.full((n_attrs,), np.inf, dtype=np.float32)
    used = np.zeros((n_attrs,), dtype=bool)
    result = None
    active = np.asarray(plan.term_active) > 0.5
    sel = np.asarray(plan.sel)
    ops = np.asarray(plan.op_codes)
    thr = np.asarray(plan.thresholds, dtype=np.float32)
    ok = True
    for t in range(sel.shape[0]):
        if not active[t]:
            continue
        c = int(sel[t].argmax())
        if sel[t, c] <= 0.0:
            continue
        op, v = int(ops[t]), np.float32(thr[t])
        if c >= n_attrs or (op == _OP_LT and v == -_F32_INF):
            # always-false term (absent requirement attribute):
            # empty interval on column 0 ⇒ the request never matches
            lo[0], hi[0] = np.inf, -np.inf
            used[0] = True
            continue
        if op == _OP_GT:
            lo[c] = max(lo[c], _above(v))
        elif op == _OP_GE:
            lo[c] = max(lo[c], v)
        elif op == _OP_LT:
            hi[c] = min(hi[c], _below(v))
        elif op == _OP_LE:
            hi[c] = min(hi[c], v)
        elif op == _OP_EQ:
            lo[c] = max(lo[c], v)
            hi[c] = min(hi[c], v)
        else:  # != is not an interval
            ok = False
            break
        used[c] = True
    if ok:
        w_full = np.asarray(plan.weights, dtype=np.float32)
        # weight on a padding column = rank references an out-of-vocabulary
        # attribute ⇒ rank Undefined ⇒ 0.0 for every candidate
        undef = bool((w_full[n_attrs:] != 0).any())
        bias = np.float32(np.asarray(plan.bias).reshape(-1)[0])
        result = (lo, hi, used, w_full[:n_attrs], bias, undef)
    try:
        plan._interval_cache = (n_attrs, result)
    except AttributeError:  # pragma: no cover - exotic plan types
        pass
    return result


def canonicalize_plans(plans: Sequence, n_attrs: int) -> Optional[IntervalBatch]:
    """Fold each plan's active threshold terms into [lo, hi] intervals.

    Returns None when any plan falls outside the interval subset (a ``!=``
    term) — semantics the caller must then get from the dense path.
    """
    parts = [_plan_interval(p, n_attrs) for p in plans]
    if any(p is None for p in parts):
        return None
    return IntervalBatch(
        lo=np.stack([p[0] for p in parts]),
        hi=np.stack([p[1] for p in parts]),
        used=np.stack([p[2] for p in parts]),
        weights=np.stack([p[3] for p in parts]),
        bias=np.array([p[4] for p in parts], dtype=np.float32),
        undef_rank=np.array([p[5] for p in parts], dtype=bool),
    )


def rank_scores(
    attrs: np.ndarray, valid: np.ndarray, weights: np.ndarray, bias: float
) -> np.ndarray:
    """Condor rank semantics, matching the dense ref exactly: rows where
    any non-zero-weight attribute is invalid rank 0.0 (the whole rank is
    Undefined, bias included); everywhere else Σ w_a·attr_a + bias."""
    w = np.asarray(weights, dtype=np.float32)
    svals = (attrs @ w + np.float32(bias)).astype(np.float32)
    wactive = w != 0
    if wactive.any():
        bad = ~valid[:, wactive].all(axis=1)
        svals[bad] = 0.0
    return svals


def _default_rank_order(
    attrs: np.ndarray, valid: np.ndarray
) -> Callable[[np.ndarray, float], Tuple[np.ndarray, np.ndarray]]:
    def rank_order(weights: np.ndarray, bias: float) -> Tuple[np.ndarray, np.ndarray]:
        svals = rank_scores(attrs, valid, weights, bias)
        return np.argsort(-svals, kind="stable"), svals

    return rank_order


def topk_in_rank_order(
    attrs: np.ndarray,  # [S, A] f32 — live rows only, logical width
    valid: np.ndarray,  # [S, A] bool
    batch: IntervalBatch,
    *,
    k: int = 1,
    admit: Optional[np.ndarray] = None,  # [B, S] bool/float pre-mask
    rank_order: Optional[
        Callable[[np.ndarray, float], Tuple[np.ndarray, np.ndarray]]
    ] = None,
    chunk: int = 256,
) -> Tuple[np.ndarray, np.ndarray]:
    """→ (topk_idx [B,k] i64, topk_scores [B,k] f32); slots past a
    request's match count hold (-1, -inf).

    ``rank_order(weights, bias) -> (order, svals)`` supplies the
    rank-descending candidate order and final per-row scores — pass a
    snapshot's cached one so the sort is paid once per (epoch,
    rank-expression), not per call. Requests are grouped by (weights,
    bias); each group walks its own order.
    """
    s = attrs.shape[0]
    b = batch.b
    valid = np.asarray(valid, dtype=bool)
    if admit is not None:
        admit = np.asarray(admit) > 0
    if rank_order is None:
        rank_order = _default_rank_order(attrs, valid)

    ti = np.full((b, k), -1, dtype=np.int64)
    ts = np.full((b, k), -np.inf, dtype=np.float32)
    if s == 0:
        return ti, ts

    groups: dict = {}
    for bi in range(b):
        key = (
            batch.weights[bi].tobytes(),
            float(batch.bias[bi]),
            bool(batch.undef_rank[bi]),
        )
        groups.setdefault(key, []).append(bi)

    for (_, gbias, gundef), members in groups.items():
        if gundef:
            # rank Undefined for every candidate ⇒ all scores 0.0; the
            # candidate order is plain row order (stable-tie semantics)
            order = np.arange(s, dtype=np.int64)
            svals = np.zeros((s,), dtype=np.float32)
        else:
            order, svals = rank_order(batch.weights[members[0]], gbias)
        # requests whose folded interval is empty can never match
        live = np.array(
            [bi for bi in members if not (batch.lo[bi] > batch.hi[bi]).any()],
            dtype=np.int64,
        )
        found = np.zeros(b, dtype=np.int64)
        pos = 0
        while live.size and pos < s:
            rows = order[pos : pos + chunk]
            a_ch, v_ch = attrs[rows], valid[rows]
            ok = np.ones((rows.size, live.size), dtype=bool)
            for c in range(batch.n_attrs):
                u = batch.used[live, c]
                if not u.any():
                    continue
                x = a_ch[:, c : c + 1]
                p = (
                    (x >= batch.lo[live, c][None, :])
                    & (x <= batch.hi[live, c][None, :])
                    & v_ch[:, c : c + 1]
                )
                ok &= np.where(u[None, :], p, True)
            if admit is not None:
                ok &= admit[live][:, rows].T
            if k == 1:
                hit = ok.any(axis=0)
                if hit.any():
                    win = live[hit]
                    r = rows[ok.argmax(axis=0)[hit]]
                    ti[win, 0] = r
                    ts[win, 0] = svals[r]
                    found[win] = 1
                    live = live[~hit]
                pos += chunk
                continue
            done: List[int] = []
            for j, bi in enumerate(live):
                hits = np.nonzero(ok[:, j])[0]
                if hits.size:
                    take = hits[: k - found[bi]]
                    r = rows[take]
                    ti[bi, found[bi] : found[bi] + take.size] = r
                    ts[bi, found[bi] : found[bi] + take.size] = svals[r]
                    found[bi] += take.size
                if found[bi] >= k:
                    done.append(j)
            if done:
                live = np.delete(live, done)
            pos += chunk
    return ti, ts
