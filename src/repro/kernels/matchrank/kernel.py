"""Pallas TPU kernel: fused matchmaking (mask + rank + running top-1).

TPU adaptation of the Match Phase hot loop. Design notes:

  * The candidate axis S is tiled by the grid; each step processes a
    ``(BLOCK_S, A_PAD)`` attribute tile resident in VMEM. A_PAD is lane-
    aligned (128); BLOCK_S is sublane-aligned (multiple of 8).
  * Per-term attribute *gathers* are re-expressed as a one-hot matmul
    ``attrs @ sel.T`` — the MXU eats a [BLOCK_S,128]×[128,T_PAD] matmul;
    a lane gather would serialize on the VPU.
  * All six comparison ops are evaluated vectorized and the per-term op
    is chosen with ``jnp.where`` chains — branch-free VPU code.
  * The running top-1 (score, index) is carried across grid steps in SMEM
    scratch; the final step publishes it. This makes the kernel a single
    pass over HBM: matchmaking is memory-bound (≈4·S·A bytes in, S out),
    so one fused pass is the roofline-optimal schedule.

Weights/thresholds/opcodes ride in VMEM as small aligned arrays; the
kernel is correctness-validated in ``interpret=True`` mode on CPU and
shape/dtype-swept against :mod:`.ref` (see tests/test_kernel_matchrank.py).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = float("-inf")

__all__ = ["matchrank_pallas", "matchrank_batched_pallas"]


def _matchrank_kernel(
    # inputs (VMEM tiles)
    attrs_ref,  # [BLOCK_S, A_PAD] f32
    valid_ref,  # [BLOCK_S, A_PAD] f32
    admit_ref,  # [BLOCK_S] f32
    sel_ref,  # [T_PAD, A_PAD] f32
    ops_ref,  # [T_PAD] i32
    th_ref,  # [T_PAD] f32
    act_ref,  # [T_PAD] f32
    w_ref,  # [A_PAD] f32
    bias_ref,  # [1] f32
    # outputs
    mask_ref,  # [BLOCK_S] f32
    score_ref,  # [BLOCK_S] f32
    best_score_ref,  # [1] f32
    best_idx_ref,  # [1] i32
    # scratch (SMEM carries across grid steps)
    carry_score_ref,  # [1] f32
    carry_idx_ref,  # [1] i32
    *,
    block_s: int,
):
    pi = pl.program_id(0)
    nblocks = pl.num_programs(0)

    attrs = attrs_ref[...]
    validf = valid_ref[...]

    # ---- per-term values: one-hot matmul instead of a lane gather ----
    sel_t = sel_ref[...].T  # [A_PAD, T_PAD]
    vals = jnp.dot(attrs, sel_t, preferred_element_type=jnp.float32)  # [S, T]
    vok = jnp.dot(validf, sel_t, preferred_element_type=jnp.float32) > 0.5

    th = th_ref[...][None, :]
    opc = ops_ref[...][None, :]
    # branch-free op select
    r = jnp.where(opc == 0, vals < th, False)
    r = jnp.where(opc == 1, vals <= th, r)
    r = jnp.where(opc == 2, vals > th, r)
    r = jnp.where(opc == 3, vals >= th, r)
    r = jnp.where(opc == 4, vals == th, r)
    r = jnp.where(opc == 5, vals != th, r)

    act = act_ref[...][None, :] > 0.5
    term_pass = jnp.where(act, jnp.logical_and(r, vok), True)
    mask = jnp.all(term_pass, axis=-1)  # [S]
    mask = jnp.logical_and(mask, admit_ref[...] > 0.5)

    # ---- linear rank with validity gating ----
    w = w_ref[...]
    score_raw = jnp.dot(attrs, w, preferred_element_type=jnp.float32) + bias_ref[0]
    wactive = (jnp.abs(w) > 0).astype(jnp.float32)
    bad = jnp.dot(1.0 - validf, wactive, preferred_element_type=jnp.float32)
    rank = jnp.where(bad > 0, 0.0, score_raw)

    score = jnp.where(mask, rank, NEG_INF)
    mask_ref[...] = mask.astype(jnp.float32)
    score_ref[...] = score

    # ---- running top-1 across grid steps (SMEM carry) ----
    local_idx = jnp.argmax(score)
    local_best = score[local_idx]
    global_idx = (pi * block_s + local_idx).astype(jnp.int32)

    @pl.when(pi == 0)
    def _init():
        carry_score_ref[0] = NEG_INF
        carry_idx_ref[0] = jnp.int32(0)

    prev_score = carry_score_ref[0]
    prev_idx = carry_idx_ref[0]
    take_new = local_best > prev_score  # strict: ties keep earliest index
    carry_score_ref[0] = jnp.where(take_new, local_best, prev_score)
    carry_idx_ref[0] = jnp.where(take_new, global_idx, prev_idx)

    @pl.when(pi == nblocks - 1)
    def _publish():
        best_score_ref[0] = carry_score_ref[0]
        best_idx_ref[0] = carry_idx_ref[0]


def matchrank_pallas(
    attrs: jnp.ndarray,  # [S, A_PAD] f32 (S % block_s == 0, A_PAD % 128 == 0)
    valid: jnp.ndarray,  # [S, A_PAD] f32
    admit: jnp.ndarray,  # [S] f32
    sel: jnp.ndarray,  # [T_PAD, A_PAD] f32
    op_codes: jnp.ndarray,  # [T_PAD] i32
    thresholds: jnp.ndarray,  # [T_PAD] f32
    term_active: jnp.ndarray,  # [T_PAD] f32
    weights: jnp.ndarray,  # [A_PAD] f32
    bias: jnp.ndarray,  # [1] f32
    *,
    block_s: int = 512,
    interpret: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Invoke the fused kernel. Inputs must be pre-padded (ops.py does it)."""
    s, a_pad = attrs.shape
    t_pad = sel.shape[0]
    assert s % block_s == 0, (s, block_s)
    nblocks = s // block_s

    kernel = functools.partial(_matchrank_kernel, block_s=block_s)
    grid = (nblocks,)

    out_shapes = (
        jax.ShapeDtypeStruct((s,), jnp.float32),  # mask
        jax.ShapeDtypeStruct((s,), jnp.float32),  # score
        jax.ShapeDtypeStruct((1,), jnp.float32),  # best score
        jax.ShapeDtypeStruct((1,), jnp.int32),  # best idx
    )
    in_specs = [
        pl.BlockSpec((block_s, a_pad), lambda i: (i, 0)),  # attrs
        pl.BlockSpec((block_s, a_pad), lambda i: (i, 0)),  # valid
        pl.BlockSpec((block_s,), lambda i: (i,)),  # admit
        pl.BlockSpec((t_pad, a_pad), lambda i: (0, 0)),  # sel (replicated)
        pl.BlockSpec((t_pad,), lambda i: (0,)),  # ops
        pl.BlockSpec((t_pad,), lambda i: (0,)),  # thresholds
        pl.BlockSpec((t_pad,), lambda i: (0,)),  # active
        pl.BlockSpec((a_pad,), lambda i: (0,)),  # weights
        pl.BlockSpec((1,), lambda i: (0,)),  # bias
    ]
    out_specs = (
        pl.BlockSpec((block_s,), lambda i: (i,)),
        pl.BlockSpec((block_s,), lambda i: (i,)),
        pl.BlockSpec((1,), lambda i: (0,)),
        pl.BlockSpec((1,), lambda i: (0,)),
    )
    # SMEM scratch for the cross-block top-1 carry
    from jax.experimental.pallas import tpu as pltpu

    scratch_shapes = [
        pltpu.SMEM((1,), jnp.float32),
        pltpu.SMEM((1,), jnp.int32),
    ]

    mask, score, best_s, best_i = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shapes,
        scratch_shapes=scratch_shapes,
        interpret=interpret,
    )(attrs, valid, admit, sel, op_codes, thresholds, term_active, weights, bias)
    return mask > 0.5, score, best_s, best_i


def _matchrank_batched_kernel(
    # inputs (VMEM tiles)
    attrs_ref,  # [BLOCK_S, A_PAD] f32 (shared across the batch)
    valid_ref,  # [BLOCK_S, A_PAD] f32
    admit_ref,  # [1, BLOCK_S] f32 (request b's pre-mask slice)
    sel_ref,  # [1, T_PAD, A_PAD] f32
    ops_ref,  # [1, T_PAD] i32
    th_ref,  # [1, T_PAD] f32
    act_ref,  # [1, T_PAD] f32
    w_ref,  # [1, A_PAD] f32
    bias_ref,  # [1] f32
    # outputs
    mask_ref,  # [1, BLOCK_S] f32
    score_ref,  # [1, BLOCK_S] f32
    topk_score_ref,  # [1, K] f32
    topk_idx_ref,  # [1, K] i32
    # scratch (SMEM carries across the S-block grid steps of request b)
    carry_score_ref,  # [K] f32
    carry_idx_ref,  # [K] i32
    *,
    block_s: int,
    k: int,
):
    si = pl.program_id(1)  # S-block index (innermost: sequential per request)
    nblocks = pl.num_programs(1)

    attrs = attrs_ref[...]
    validf = valid_ref[...]

    # ---- per-term values for THIS request: one-hot matmul on the MXU ----
    sel_t = sel_ref[0].T  # [A_PAD, T_PAD]
    vals = jnp.dot(attrs, sel_t, preferred_element_type=jnp.float32)  # [S, T]
    vok = jnp.dot(validf, sel_t, preferred_element_type=jnp.float32) > 0.5

    th = th_ref[0][None, :]
    opc = ops_ref[0][None, :]
    r = jnp.where(opc == 0, vals < th, False)
    r = jnp.where(opc == 1, vals <= th, r)
    r = jnp.where(opc == 2, vals > th, r)
    r = jnp.where(opc == 3, vals >= th, r)
    r = jnp.where(opc == 4, vals == th, r)
    r = jnp.where(opc == 5, vals != th, r)

    act = act_ref[0][None, :] > 0.5
    term_pass = jnp.where(act, jnp.logical_and(r, vok), True)
    mask = jnp.all(term_pass, axis=-1)  # [S]
    mask = jnp.logical_and(mask, admit_ref[0] > 0.5)

    # ---- linear rank with validity gating ----
    w = w_ref[0]
    score_raw = jnp.dot(attrs, w, preferred_element_type=jnp.float32) + bias_ref[0]
    wactive = (jnp.abs(w) > 0).astype(jnp.float32)
    bad = jnp.dot(1.0 - validf, wactive, preferred_element_type=jnp.float32)
    rank = jnp.where(bad > 0, 0.0, score_raw)

    score = jnp.where(mask, rank, NEG_INF)
    mask_ref[0, :] = mask.astype(jnp.float32)
    score_ref[0, :] = score

    # ---- fused per-request top-k carry across S-blocks ----
    # The carry holds the best k (score, global index) seen so far for
    # request b, sorted descending. Merge = k knockout-argmax rounds over
    # [carry ++ this block]; carry entries come first, so on score ties the
    # earlier block (lower global index) wins — interpreter tiebreak.
    @pl.when(si == 0)
    def _init():
        for j in range(k):
            carry_score_ref[j] = NEG_INF
            carry_idx_ref[j] = jnp.int32(0)

    global_idx = (si * block_s + jnp.arange(block_s)).astype(jnp.int32)
    ext_scores = jnp.concatenate([carry_score_ref[...], score])
    ext_idx = jnp.concatenate([carry_idx_ref[...], global_idx])
    positions = jnp.arange(k + block_s)
    new_scores = []
    new_idx = []
    for _ in range(k):
        j = jnp.argmax(ext_scores)  # first max ⇒ lowest index on ties
        new_scores.append(ext_scores[j])
        new_idx.append(ext_idx[j])
        ext_scores = jnp.where(positions == j, NEG_INF, ext_scores)
    for j in range(k):
        carry_score_ref[j] = new_scores[j]
        carry_idx_ref[j] = new_idx[j]

    @pl.when(si == nblocks - 1)
    def _publish():
        for j in range(k):
            topk_score_ref[0, j] = carry_score_ref[j]
            topk_idx_ref[0, j] = carry_idx_ref[j]


def matchrank_batched_pallas(
    attrs: jnp.ndarray,  # [S, A_PAD] f32 (S % block_s == 0, A_PAD % 128 == 0)
    valid: jnp.ndarray,  # [S, A_PAD] f32
    admit: jnp.ndarray,  # [B, S] f32 — per-request pre-mask
    sel: jnp.ndarray,  # [B, T_PAD, A_PAD] f32
    op_codes: jnp.ndarray,  # [B, T_PAD] i32
    thresholds: jnp.ndarray,  # [B, T_PAD] f32
    term_active: jnp.ndarray,  # [B, T_PAD] f32
    weights: jnp.ndarray,  # [B, A_PAD] f32
    bias: jnp.ndarray,  # [B] f32
    *,
    block_s: int = 512,
    k: int = 1,
    interpret: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Multi-request fused match+rank+top-k over ONE candidate block.

    Grid is ``(B, S//block_s)`` with the candidate axis innermost, so the
    shared ``attrs``/``valid`` tiles stream once per request while each
    request's small plan tensors stay resident. The per-request top-k is
    carried across S-blocks in SMEM and published on the last block —
    still a single pass over HBM per request.

    Returns (mask [B,S] bool, score [B,S] f32, topk_scores [B,k] f32,
    topk_idx [B,k] i32).
    """
    s, a_pad = attrs.shape
    b, t_pad, a_pad2 = sel.shape
    assert a_pad == a_pad2, (a_pad, a_pad2)
    assert s % block_s == 0, (s, block_s)
    assert admit.shape == (b, s), (admit.shape, b, s)
    nblocks = s // block_s

    kernel = functools.partial(_matchrank_batched_kernel, block_s=block_s, k=k)
    grid = (b, nblocks)

    out_shapes = (
        jax.ShapeDtypeStruct((b, s), jnp.float32),  # mask
        jax.ShapeDtypeStruct((b, s), jnp.float32),  # score
        jax.ShapeDtypeStruct((b, k), jnp.float32),  # top-k scores
        jax.ShapeDtypeStruct((b, k), jnp.int32),  # top-k indices
    )
    in_specs = [
        pl.BlockSpec((block_s, a_pad), lambda bi, si: (si, 0)),  # attrs (shared)
        pl.BlockSpec((block_s, a_pad), lambda bi, si: (si, 0)),  # valid (shared)
        pl.BlockSpec((1, block_s), lambda bi, si: (bi, si)),  # admit
        pl.BlockSpec((1, t_pad, a_pad), lambda bi, si: (bi, 0, 0)),  # sel
        pl.BlockSpec((1, t_pad), lambda bi, si: (bi, 0)),  # ops
        pl.BlockSpec((1, t_pad), lambda bi, si: (bi, 0)),  # thresholds
        pl.BlockSpec((1, t_pad), lambda bi, si: (bi, 0)),  # active
        pl.BlockSpec((1, a_pad), lambda bi, si: (bi, 0)),  # weights
        pl.BlockSpec((1,), lambda bi, si: (bi,)),  # bias
    ]
    out_specs = (
        pl.BlockSpec((1, block_s), lambda bi, si: (bi, si)),
        pl.BlockSpec((1, block_s), lambda bi, si: (bi, si)),
        pl.BlockSpec((1, k), lambda bi, si: (bi, 0)),
        pl.BlockSpec((1, k), lambda bi, si: (bi, 0)),
    )
    from jax.experimental.pallas import tpu as pltpu

    scratch_shapes = [
        pltpu.SMEM((k,), jnp.float32),
        pltpu.SMEM((k,), jnp.int32),
    ]

    mask, score, topk_s, topk_i = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shapes,
        scratch_shapes=scratch_shapes,
        interpret=interpret,
    )(attrs, valid, admit, sel, op_codes, thresholds, term_active, weights, bias)
    return mask > 0.5, score, topk_s, topk_i
