"""Pure-jnp oracle for the fused match+rank+top-1 kernel.

Semantics contract (shared with kernel.py and property-tested against the
ClassAd interpreter through ops.py):

  * ``terms``: conjunctive threshold comparisons over attribute columns.
    A term on an *invalid* attribute is Undefined ⇒ the candidate fails
    (fail-closed, like the interpreter's symmetric match).
  * ``rank``: linear form  Σ_a w_a·attr_a + bias. If any attribute with a
    non-zero weight is invalid for a candidate, its rank is 0.0 (Condor's
    non-numeric-rank convention).
  * ``admit``: a caller-supplied pre-mask (folded server policies).
  * score output: rank where matched, ``-inf`` where not (top-k ready).
  * best output: arg-top-1 (score, index), ties → lowest index.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

#: opcode encoding shared with core.compile.OPCODES
OP_LT, OP_LE, OP_GT, OP_GE, OP_EQ, OP_NE = 0, 1, 2, 3, 4, 5

NEG_INF = float("-inf")


def matchrank_ref(
    attrs: jnp.ndarray,  # [S, A] f32
    valid: jnp.ndarray,  # [S, A] bool/f32
    sel: jnp.ndarray,  # [T, A] f32 one-hot rows (padding rows all-zero)
    op_codes: jnp.ndarray,  # [T] i32
    thresholds: jnp.ndarray,  # [T] f32
    term_active: jnp.ndarray,  # [T] bool/f32 (padding terms inactive)
    weights: jnp.ndarray,  # [A] f32
    bias: jnp.ndarray,  # scalar f32
    admit: jnp.ndarray,  # [S] bool/f32
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (mask [S] bool, score [S] f32, best_score [1] f32,
    best_idx [1] i32)."""
    attrs = attrs.astype(jnp.float32)
    validf = valid.astype(jnp.float32)
    self_dtype = jnp.float32

    # per-term values via one-hot matmul (gather-free, MXU-friendly)
    vals = attrs @ sel.T.astype(self_dtype)  # [S, T]
    vok = (validf @ sel.T.astype(self_dtype)) > 0.5  # [S, T]

    th = thresholds[None, :]
    cmps = jnp.stack(
        [
            vals < th,
            vals <= th,
            vals > th,
            vals >= th,
            vals == th,
            vals != th,
        ],
        axis=-1,
    )  # [S, T, 6]
    opc = jnp.clip(op_codes, 0, 5)
    picked = jnp.take_along_axis(cmps, opc[None, :, None], axis=-1)[..., 0]  # [S, T]

    act = term_active.astype(bool)[None, :]
    term_pass = jnp.where(act, picked & vok, True)  # inactive terms pass
    mask = jnp.all(term_pass, axis=-1) & (admit.astype(bool))

    # linear rank with validity gating
    score_raw = attrs @ weights.astype(self_dtype) + bias
    wactive = (jnp.abs(weights) > 0).astype(self_dtype)  # [A]
    bad = (1.0 - validf) @ wactive  # [S] — # of invalid weighted attrs
    rank = jnp.where(bad > 0, 0.0, score_raw)

    score = jnp.where(mask, rank, NEG_INF)
    best_idx = jnp.argmax(score)  # ties → lowest index
    best_score = score[best_idx]
    return mask, score, best_score[None], best_idx[None].astype(jnp.int32)


def matchrank_batched_ref(
    attrs: jnp.ndarray,  # [S, A] f32 — ONE shared candidate block
    valid: jnp.ndarray,  # [S, A] bool/f32
    admit: jnp.ndarray,  # [B, S] bool/f32 — per-request pre-mask
    sel: jnp.ndarray,  # [B, T, A] f32 one-hot rows
    op_codes: jnp.ndarray,  # [B, T] i32
    thresholds: jnp.ndarray,  # [B, T] f32
    term_active: jnp.ndarray,  # [B, T] bool/f32
    weights: jnp.ndarray,  # [B, A] f32
    bias: jnp.ndarray,  # [B] f32
    *,
    k: int = 1,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Multi-request oracle: B stacked plans against one candidate block.

    Same per-request semantics as :func:`matchrank_ref`; the candidate
    table is shared across the batch (the fleet scenario: one published
    GRIS snapshot, many concurrent selections). Returns
    (mask [B,S] bool, score [B,S] f32, topk_scores [B,k], topk_idx [B,k]);
    top-k slot j beyond the number of matches holds -inf. Ties → lowest
    candidate index (lax.top_k is index-stable).
    """
    attrs = attrs.astype(jnp.float32)
    validf = valid.astype(jnp.float32)

    # per-(request, term) values: [S,A] x [B,T,A] -> [B,S,T]
    vals = jnp.einsum("sa,bta->bst", attrs, sel.astype(jnp.float32))
    vok = jnp.einsum("sa,bta->bst", validf, sel.astype(jnp.float32)) > 0.5

    th = thresholds[:, None, :]  # [B,1,T]
    opc = op_codes[:, None, :]  # [B,1,T]
    r = jnp.where(opc == 0, vals < th, False)
    r = jnp.where(opc == 1, vals <= th, r)
    r = jnp.where(opc == 2, vals > th, r)
    r = jnp.where(opc == 3, vals >= th, r)
    r = jnp.where(opc == 4, vals == th, r)
    r = jnp.where(opc == 5, vals != th, r)

    act = term_active.astype(bool)[:, None, :]  # [B,1,T]
    term_pass = jnp.where(act, r & vok, True)
    mask = jnp.all(term_pass, axis=-1) & admit.astype(bool)  # [B,S]

    # linear rank with validity gating, per request
    score_raw = jnp.einsum("sa,ba->bs", attrs, weights.astype(jnp.float32))
    score_raw = score_raw + bias[:, None]
    wactive = (jnp.abs(weights) > 0).astype(jnp.float32)  # [B,A]
    bad = jnp.einsum("sa,ba->bs", 1.0 - validf, wactive)
    rank = jnp.where(bad > 0, 0.0, score_raw)

    score = jnp.where(mask, rank, NEG_INF)  # [B,S]
    k_eff = min(k, score.shape[-1])
    topk_scores, topk_idx = jax.lax.top_k(score, k_eff)
    return mask, score, topk_scores, topk_idx.astype(jnp.int32)


def merge_topk_ref(cand_scores, cand_idx, k: int):
    """NumPy oracle for the hierarchical merge stage: global top-k over
    per-shard candidate lists, by k knockout-argmax rounds.

    ``cand_scores``/``cand_idx`` are [B, C] — each request's per-shard
    top-k lists flattened **shard-major** (shard 0's k candidates, then
    shard 1's, ...). Because every per-shard list is rank-descending with
    ties at the lowest local index, the flattened position order equals
    the global-row order within each score value, so first-maximum
    knockout reproduces ``lax.top_k``'s tie-break (lowest global row)
    exactly. Empty slots hold score -inf; their index rides along
    untouched (callers treat -inf slots as meaningless, like the fused
    kernel's). Returns (scores [B, k] f32, idx [B, k])."""
    import numpy as np

    s = np.array(cand_scores, dtype=np.float32, copy=True)
    idx = np.asarray(cand_idx)
    b = s.shape[0]
    rows = np.arange(b)
    out_s = np.full((b, k), NEG_INF, dtype=np.float32)
    out_i = np.zeros((b, k), dtype=idx.dtype)
    for j in range(k):
        m = np.argmax(s, axis=1)
        out_s[:, j] = s[rows, m]
        out_i[:, j] = idx[rows, m]
        s[rows, m] = NEG_INF
    return out_s, out_i
