"""Jit'd wrapper for the bwstats kernel: padding + dispatch + dict output.

``bwstats`` takes the raw ``TransferMonitor.history_matrix`` output
(arbitrary N, W) and returns the six statistics trimmed to N, as either
the Pallas kernel (default) or the jnp reference. ``publish_fleet_stats``
maps the result back onto GRIS attribute names — the fleet-scale version
of ``TransferMonitor.summary_attrs``.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernel import bwstats_pallas
from .ref import bwstats_ref

__all__ = ["bwstats", "publish_fleet_stats"]

STAT_NAMES = ("min", "max", "mean", "std", "last", "ewma")


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@functools.partial(jax.jit, static_argnames=("alpha", "block_n", "use_kernel", "interpret"))
def _dispatch(hist, counts, *, alpha, block_n, use_kernel, interpret):
    if use_kernel:
        return bwstats_pallas(
            hist, counts, alpha=alpha, block_n=block_n, interpret=interpret
        )
    return bwstats_ref(hist, counts, alpha=alpha)


def bwstats(
    hist: np.ndarray,  # [N, W] f32, left-aligned histories
    counts: np.ndarray,  # [N] i32
    *,
    alpha: float = 0.25,
    block_n: int = 256,
    use_kernel: bool = True,
    interpret: bool = True,
) -> Dict[str, np.ndarray]:
    """→ {'min','max','mean','std','last','ewma'} each [N] f32."""
    n, w = hist.shape
    if n == 0:
        return {k: np.zeros((0,), np.float32) for k in STAT_NAMES}
    n_pad = max(_round_up(n, block_n), block_n)
    w_pad = max(_round_up(w, 128), 128)
    hist_p = np.zeros((n_pad, w_pad), dtype=np.float32)
    hist_p[:n, :w] = hist
    counts_p = np.zeros((n_pad,), dtype=np.int32)
    counts_p[:n] = counts
    outs = _dispatch(
        jnp.asarray(hist_p), jnp.asarray(counts_p),
        alpha=alpha, block_n=block_n, use_kernel=use_kernel, interpret=interpret,
    )
    return {k: np.asarray(v)[:n] for k, v in zip(STAT_NAMES, outs)}


def publish_fleet_stats(
    hist: np.ndarray, counts: np.ndarray, peers: list, direction: str = "RD", **kw
) -> Dict[str, Dict[str, float]]:
    """Fleet-scale GRIS publication: per-peer attribute dicts mirroring
    ``TransferMonitor.source_attrs`` (the Figure-5 object class)."""
    stats = bwstats(hist, counts, **kw)
    out: Dict[str, Dict[str, float]] = {}
    for i, peer in enumerate(peers):
        out[peer] = {
            f"last{direction}Bandwidth": float(stats["last"][i]),
            f"Avg{direction}BandwidthToSource": float(stats["mean"][i]),
            f"Ewma{direction}BandwidthToSource": float(stats["ewma"][i]),
            f"Max{direction}Bandwidth": float(stats["max"][i]),
            f"Min{direction}Bandwidth": float(stats["min"][i]),
            f"Std{direction}Bandwidth": float(stats["std"][i]),
            "nSamplesToSource": float(counts[i]),
        }
    return out
