"""Pure-jnp oracle for the windowed bandwidth-statistics kernel.

Contract: histories are **left-aligned** rows of a ``[N, W]`` matrix
(``hist[i, :counts[i]]`` are valid, oldest→newest), as produced by
``TransferMonitor.history_matrix``. Outputs per series:

  min, max, mean, std (population), last, ewma

EWMA follows the recursive definition seeded with the first observation:
``v_0 = x_0``, ``v_i = α·x_i + (1-α)·v_{i-1}`` — expressed *non-recursively*
as a dot with the decay-weight vector
``w_i = α(1-α)^{n-1-i}`` (i>0), ``w_0 = (1-α)^{n-1}``,
which is the form the TPU kernel evaluates on the VPU (no sequential scan).
Series with count 0 produce zeros across the board.
"""

from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

BIG = jnp.float32(3.4e38)


def bwstats_ref(
    hist: jnp.ndarray,  # [N, W] f32, left-aligned
    counts: jnp.ndarray,  # [N] i32
    alpha: float = 0.25,
) -> Tuple[jnp.ndarray, ...]:
    """→ (min, max, mean, std, last, ewma), each [N] f32."""
    hist = hist.astype(jnp.float32)
    n, w = hist.shape
    lane = jnp.arange(w, dtype=jnp.int32)[None, :]  # [1, W]
    cnt = counts.astype(jnp.int32)[:, None]  # [N, 1]
    m = lane < cnt  # [N, W] valid mask
    cntf = jnp.maximum(cnt.astype(jnp.float32), 1.0)

    mn = jnp.min(jnp.where(m, hist, BIG), axis=1)
    mx = jnp.max(jnp.where(m, hist, -BIG), axis=1)
    s1 = jnp.sum(jnp.where(m, hist, 0.0), axis=1)
    mean = s1 / cntf[:, 0]
    # two-pass variance (f32-stable at bandwidth scales; see kernel.py)
    d = jnp.where(m, hist - mean[:, None], 0.0)
    var = jnp.sum(d * d, axis=1) / cntf[:, 0]
    std = jnp.sqrt(var)

    last = jnp.sum(jnp.where(lane == cnt - 1, hist, 0.0), axis=1)

    # EWMA decay weights: exponent = n-1-i, clamped for masked lanes
    expo = jnp.maximum((cnt - 1 - lane).astype(jnp.float32), 0.0)
    decay = jnp.power(jnp.float32(1.0 - alpha), expo)
    wgt = jnp.where(lane == 0, decay, jnp.float32(alpha) * decay)
    ewma = jnp.sum(jnp.where(m, hist * wgt, 0.0), axis=1)

    empty = counts <= 0
    z = jnp.float32(0.0)
    return (
        jnp.where(empty, z, mn),
        jnp.where(empty, z, mx),
        jnp.where(empty, z, mean),
        jnp.where(empty, z, std),
        jnp.where(empty, z, last),
        jnp.where(empty, z, ewma),
    )
