"""Pallas TPU kernel: one-pass windowed bandwidth statistics.

The information plane of a 1000-node job tracks a per-(endpoint, client)
bandwidth history — N series of W observations. Publishing predictor
attributes (§3.2 / Figure 4-5 extensions) means reducing every series to
min/max/mean/std/last/EWMA after each batch of observations. That is a
single HBM pass: ``4·N·W`` bytes in, ``6·N·4`` bytes out — memory-bound,
so the kernel fuses all six statistics into one read of the history tile.

Layout: the series axis N is tiled by the grid (BLOCK_N sublane-aligned);
the window W is the lane axis (padded to 128). The EWMA is evaluated as a
dot with decay weights computed in-register from the lane index — a VPU
expression, not a sequential scan (state-space-style recurrences lowered
to exponent arithmetic, the same trick our SSD layer uses at model scale).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BIG = 3.4e38


def _bwstats_kernel(
    hist_ref,  # [BLOCK_N, W_PAD] f32
    counts_ref,  # [BLOCK_N] i32
    mn_ref, mx_ref, mean_ref, std_ref, last_ref, ewma_ref,  # [BLOCK_N] f32 each
    *,
    w_pad: int,
    alpha: float,
):
    hist = hist_ref[...]
    cnt = counts_ref[...][:, None]  # [B, 1] i32
    lane = jax.lax.broadcasted_iota(jnp.int32, hist.shape, 1)  # [B, W]
    m = lane < cnt
    cntf = jnp.maximum(cnt.astype(jnp.float32), 1.0)[:, 0]

    mn = jnp.min(jnp.where(m, hist, BIG), axis=1)
    mx = jnp.max(jnp.where(m, hist, -BIG), axis=1)
    s1 = jnp.sum(jnp.where(m, hist, 0.0), axis=1)
    mean = s1 / cntf
    # two-pass variance: E[x²]−E[x]² cancels catastrophically in f32 for
    # bandwidth-scale values (~1e9); the tile is already in VMEM so the
    # second pass is free
    d = jnp.where(m, hist - mean[:, None], 0.0)
    var = jnp.sum(d * d, axis=1) / cntf
    std = jnp.sqrt(var)
    last = jnp.sum(jnp.where(lane == cnt - 1, hist, 0.0), axis=1)

    expo = jnp.maximum((cnt - 1 - lane).astype(jnp.float32), 0.0)
    decay = jnp.power(jnp.float32(1.0 - alpha), expo)  # exact at alpha=1
    wgt = jnp.where(lane == 0, decay, jnp.float32(alpha) * decay)
    ewma = jnp.sum(jnp.where(m, hist * wgt, 0.0), axis=1)

    empty = counts_ref[...] <= 0
    z = jnp.float32(0.0)
    mn_ref[...] = jnp.where(empty, z, mn)
    mx_ref[...] = jnp.where(empty, z, mx)
    mean_ref[...] = jnp.where(empty, z, mean)
    std_ref[...] = jnp.where(empty, z, std)
    last_ref[...] = jnp.where(empty, z, last)
    ewma_ref[...] = jnp.where(empty, z, ewma)


def bwstats_pallas(
    hist: jnp.ndarray,  # [N, W_PAD] f32 (N % block_n == 0, W_PAD % 128 == 0)
    counts: jnp.ndarray,  # [N] i32
    *,
    alpha: float = 0.25,
    block_n: int = 256,
    interpret: bool = True,
) -> Tuple[jnp.ndarray, ...]:
    n, w_pad = hist.shape
    assert n % block_n == 0, (n, block_n)
    grid = (n // block_n,)
    kernel = functools.partial(_bwstats_kernel, w_pad=w_pad, alpha=alpha)
    out_shape = tuple(jax.ShapeDtypeStruct((n,), jnp.float32) for _ in range(6))
    in_specs = [
        pl.BlockSpec((block_n, w_pad), lambda i: (i, 0)),
        pl.BlockSpec((block_n,), lambda i: (i,)),
    ]
    out_specs = tuple(pl.BlockSpec((block_n,), lambda i: (i,)) for _ in range(6))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(hist, counts)
