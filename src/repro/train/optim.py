"""AdamW from scratch, with optional blockwise-int8 moment states.

No optax in the container; the optimizer is ~150 lines anyway and owning
it lets the sharding policy dictate the state layout exactly:

  * parameters live in float32 (the master copy); layers cast weights to
    the compute dtype at use (see models/layers.py),
  * first/second moments are float32 by default, or **blockwise int8**
    (``moments_dtype='int8'``) — 4× smaller optimizer state, the trick
    that brings nemotron-340b training under the v5e HBM budget at 256
    chips (memory analysis in EXPERIMENTS.md §Dry-run). Quantized moments
    follow the 8-bit-Adam recipe: per-256-block absmax scales, dequantize
    → update → requantize each step,
  * global-norm clipping and decoupled weight decay,
  * warmup + cosine schedule helper.

State is a pytree mirroring the parameters, so ``ShardingPolicy.opt_spec``
(ZeRO-1 data sharding) applies leaf-by-leaf.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "AdamWConfig",
    "AdamWState",
    "init_adamw",
    "adamw_update",
    "warmup_cosine",
    "QTensor",
]


class QTensor(NamedTuple):
    """Blockwise int8 tensor.

    Stacked-layer leaves (ndim ≥ 2) keep their leading dim:
    ``q [L, nblk, B] int8, scale [L, nblk, 1] f32`` — so the sharding on
    the layer/block dims survives (a flat block dim would need a reshape
    the SPMD partitioner can only satisfy by full rematerialization — the
    measured 121 GiB all-gathers on nemotron-340b, EXPERIMENTS §Dry-run).
    1-D leaves quantize flat: ``q [nblk, B]``.
    """

    q: jnp.ndarray
    scale: jnp.ndarray


QBLOCK = 256


def _quantize(x: jnp.ndarray, *, preserve_lead: bool = True) -> QTensor:
    xf = x.astype(jnp.float32)
    if preserve_lead and xf.ndim >= 2:
        lead = xf.shape[0]
        flat = xf.reshape(lead, -1)
        pad = (-flat.shape[1]) % QBLOCK
        if pad:
            flat = jnp.pad(flat, ((0, 0), (0, pad)))
        blocks = flat.reshape(lead, -1, QBLOCK)
        axis = 2
    else:
        flat = xf.reshape(-1)
        pad = (-flat.shape[0]) % QBLOCK
        if pad:
            flat = jnp.pad(flat, (0, pad))
        blocks = flat.reshape(-1, QBLOCK)
        axis = 1
    scale = jnp.maximum(
        jnp.max(jnp.abs(blocks), axis=axis, keepdims=True) / 127.0, 1e-12
    )
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return QTensor(q, scale.astype(jnp.float32))


def _dequantize(qt: QTensor, shape) -> jnp.ndarray:
    n = 1
    for s in shape:
        n *= s
    if qt.q.ndim == 3:
        lead = qt.q.shape[0]
        flat = (qt.q.astype(jnp.float32) * qt.scale).reshape(lead, -1)
        return flat[:, : n // lead].reshape(shape)
    flat = (qt.q.astype(jnp.float32) * qt.scale).reshape(-1)
    return flat[:n].reshape(shape)


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moments_dtype: str = "float32"  # float32 | int8
    # 'none': params ARE the f32 master. 'float32': params live in bf16
    # (halving FSDP weight-gathers and gradient reductions — the grads of
    # bf16 params are bf16) and the f32 master rides in the optimizer
    # state, sharded like the moments.
    master_dtype: str = "none"  # none | float32


class AdamWState(NamedTuple):
    step: jnp.ndarray  # scalar int32
    mu: Any  # pytree of f32 arrays or QTensors
    nu: Any
    master: Any = None  # f32 master params (when cfg.master_dtype='float32')


def init_adamw(params: Any, cfg: AdamWConfig) -> AdamWState:
    if cfg.moments_dtype == "int8":
        zeros = jax.tree.map(lambda p: _quantize(jnp.zeros(p.shape, jnp.float32)), params)
        zeros2 = jax.tree.map(lambda p: _quantize(jnp.zeros(p.shape, jnp.float32)), params)
    else:
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        zeros2 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    master = None
    if cfg.master_dtype == "float32":
        master = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return AdamWState(jnp.zeros((), jnp.int32), zeros, zeros2, master)


def _tree_global_norm(tree: Any) -> jnp.ndarray:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )


def adamw_update(
    grads: Any,
    state: AdamWState,
    params: Any,
    cfg: AdamWConfig,
    lr: jnp.ndarray,
) -> Tuple[Any, AdamWState, Dict[str, jnp.ndarray]]:
    """One AdamW step. ``lr`` is the scheduled learning rate (traced)."""
    gnorm = _tree_global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1**t
    bc2 = 1.0 - cfg.b2**t

    quantized = cfg.moments_dtype == "int8"

    def upd_math(p, g, m, v, wd):
        m = cfg.b1 * m + (1.0 - cfg.b1) * g
        v = cfg.b2 * v + (1.0 - cfg.b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        u = mhat / (jnp.sqrt(vhat) + cfg.eps)
        new_p = p - lr * (u + wd * p.astype(jnp.float32))
        return new_p.astype(p.dtype), m, v

    # v is stored in sqrt domain when quantized: linear absmax int8 on raw v
    # collapses small entries to 0 (sqrt(vhat)+eps → giant steps, measured
    # divergence); sqrt compresses the dynamic range quadratically, the
    # same reason 8-bit Adam uses nonlinear quantization maps.
    def _enc_v(v):
        return jnp.sqrt(v)

    def _dec_v(vs):
        return vs * vs

    def upd(p, g, mu, nu):
        # decoupled weight decay (skip 1-D leaves: norms/biases)
        wd = cfg.weight_decay if p.ndim >= 2 else 0.0
        if not quantized:
            return upd_math(p, g.astype(jnp.float32) * scale, mu, nu, wd)
        # Quantized path, chunked over the stacked-layer dim: dequantizing a
        # [96, 18432, 73728] moment to f32 in one shot is a multi-GB
        # transient; lax.map over the (preserved) leading dim bounds the
        # transient to one layer's worth. No reshape of sharded dims.
        if mu.q.ndim == 3 and p.ndim >= 2 and p.shape[0] == mu.q.shape[0] and p.shape[0] > 1:
            slice_shape = p.shape[1:]

            def one(args):
                ps, gs, mq, ms, vq, vs = args
                m = _dequantize(QTensor(mq, ms), slice_shape)
                v = _dec_v(_dequantize(QTensor(vq, vs), slice_shape))
                np_, m, v = upd_math(ps, gs.astype(jnp.float32) * scale, m, v, wd)
                # flat layout: must match init's per-layer block partition
                qm = _quantize(m, preserve_lead=False)
                qv = _quantize(_enc_v(v), preserve_lead=False)
                return np_, qm.q, qm.scale, qv.q, qv.scale

            np_, mq, msc, vq, vsc = jax.lax.map(
                one, (p, g, mu.q, mu.scale, nu.q, nu.scale)
            )
            return np_, QTensor(mq, msc), QTensor(vq, vsc)
        m = _dequantize(mu, p.shape)
        v = _dec_v(_dequantize(nu, p.shape))
        np_, m, v = upd_math(p, g.astype(jnp.float32) * scale, m, v, wd)
        return np_, _quantize(m), _quantize(_enc_v(v))

    work_params = state.master if state.master is not None else params
    flat_p, tdef = jax.tree.flatten(work_params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = tdef.flatten_up_to(state.mu)
    flat_nu = tdef.flatten_up_to(state.nu)
    outs = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_work = tdef.unflatten([o[0] for o in outs])
    new_mu = tdef.unflatten([o[1] for o in outs])
    new_nu = tdef.unflatten([o[2] for o in outs])
    if state.master is not None:
        new_master = new_work
        new_params = jax.tree.map(
            lambda m, p: m.astype(p.dtype), new_master, params
        )
    else:
        new_master = None
        new_params = new_work
    return (
        new_params,
        AdamWState(step, new_mu, new_nu, new_master),
        {"grad_norm": gnorm, "lr": lr, "clip_scale": scale},
    )


def warmup_cosine(
    step: jnp.ndarray, *, peak_lr: float, warmup: int, total: int, floor: float = 0.1
) -> jnp.ndarray:
    """Linear warmup → cosine decay to ``floor × peak``."""
    s = step.astype(jnp.float32)
    warm = peak_lr * jnp.minimum(s / max(warmup, 1), 1.0)
    prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = floor + (1.0 - floor) * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return jnp.where(s < warmup, warm, peak_lr * cos)
