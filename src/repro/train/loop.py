"""The fault-tolerant training loop.

Wires everything: broker-backed data pipeline, jitted train step,
checkpoint/restart, straggler monitoring, fault injection survival.
This is the loop ``examples/train_lm.py`` and ``launch/train.py`` drive;
tests run it over a reduced config with scheduled endpoint kills and
assert the loss curve and checkpoint/restart invariants.

The loop is deliberately *single-controller per host*: in a real
multi-host deployment every host runs this loop over its own pipeline
slice (pjit keeps them in lockstep); here host-0's view is simulated and
the other hosts' step times are modelled for the straggler monitor.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterator, List, Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.checkpoint.restore import resume_or_init
from repro.configs.base import ArchConfig
from repro.data.pipeline import DataPipeline
from repro.obs import MetricsRegistry, Tracer
from repro.storage.faults import FaultInjector

from .straggler import StragglerMonitor
from .train_step import TrainConfig, TrainState, init_train_state, make_train_step

__all__ = ["LoopConfig", "TrainLoop"]


@dataclass
class LoopConfig:
    total_steps: int = 100
    checkpoint_every: int = 25
    log_every: int = 10
    async_checkpoint: bool = False
    repair_every: int = 0  # 0 = off


@dataclass
class StepRecord:
    step: int
    loss: float
    seconds: float
    metrics: Dict[str, float] = field(default_factory=dict)


class TrainLoop:
    def __init__(
        self,
        cfg: ArchConfig,
        tc: TrainConfig,
        lc: LoopConfig,
        pipeline: DataPipeline,
        ckpt: Optional[CheckpointManager] = None,
        *,
        faults: Optional[FaultInjector] = None,
        rng_seed: int = 0,
    ):
        self.cfg = cfg
        self.tc = tc
        self.lc = lc
        self.pipeline = pipeline
        self.ckpt = ckpt
        self.faults = faults
        self.rng = jax.random.PRNGKey(rng_seed)
        self.monitor = StragglerMonitor()
        self.records: List[StepRecord] = []
        self.events: List[str] = []
        self._step_fn = jax.jit(make_train_step(cfg, tc))
        # obs: share the pipeline broker's registry/tracer so data-grid and
        # training metrics land in one exposition
        self.metrics: MetricsRegistry = pipeline.broker.metrics
        self.tracer: Tracer = pipeline.broker.tracer
        self._c_steps = self.metrics.counter("train_steps_total", "optimizer steps")
        self._c_ckpts = self.metrics.counter(
            "train_checkpoints_total", "checkpoints written by the loop"
        )
        self._h_step = self.metrics.histogram(
            "train_step_seconds", "wall time per optimizer step"
        )
        self._g_loss = self.metrics.gauge("train_loss", "most recent step loss")

    # ------------------------------------------------------------------ state
    def init_or_resume(self) -> tuple[TrainState, int]:
        if self.ckpt is None:
            return init_train_state(self.cfg, self.tc, self.rng), 0
        state, start, resumed = resume_or_init(
            self.ckpt, lambda: init_train_state(self.cfg, self.tc, self.rng)
        )
        if resumed:
            self.events.append(f"resumed from step {start}")
        return state, start

    # -------------------------------------------------------------------- run
    def run(self) -> TrainState:
        state, start = self.init_or_resume()
        step = start
        epoch = 0
        batches = self.pipeline.batches(epoch)
        while step < self.lc.total_steps:
            if self.faults is not None:
                for ev in self.faults.tick():
                    self.events.append(f"fault@{ev.at:.1f}: {ev.kind} {ev.endpoint}")
            try:
                batch = next(batches)
            except StopIteration:
                epoch += 1
                batches = self.pipeline.batches(epoch)
                batch = next(batches)

            with self.tracer.span("train.step", step=step + 1) as step_span:
                state, metrics = self._step_fn(
                    state, {k: jax.numpy.asarray(v) for k, v in batch.items()}
                )
                loss = float(metrics["loss"])
            dt = step_span.duration
            step += 1
            self._c_steps.inc()
            self._h_step.observe(dt)
            self._g_loss.set(loss)

            self.records.append(
                StepRecord(step, loss, dt, {k: float(v) for k, v in metrics.items()})
            )
            # feed the straggler monitor (this host + simulated fleet noise)
            host_times = {"host-0": dt}
            self.monitor.observe_step(step, host_times)

            if self.lc.log_every and step % self.lc.log_every == 0:
                self.events.append(f"step {step}: loss={loss:.4f} ({dt*1e3:.0f} ms)")

            if self.ckpt is not None and step % self.lc.checkpoint_every == 0:
                with self.tracer.span("train.checkpoint", step=step):
                    self.ckpt.save(step, state, blocking=not self.lc.async_checkpoint)
                self._c_ckpts.inc()
                self.events.append(f"checkpoint@{step}")
            if (
                self.ckpt is not None
                and self.lc.repair_every
                and step % self.lc.repair_every == 0
            ):
                latest = self.ckpt.latest_step()
                if latest is not None:
                    n = self.ckpt.repair(latest)
                    if n:
                        self.events.append(f"repaired {n} replicas @ step {step}")
        if self.ckpt is not None:
            self.ckpt.wait()
        return state

    # ---------------------------------------------------------------- metrics
    def losses(self) -> List[float]:
        return [r.loss for r in self.records]
