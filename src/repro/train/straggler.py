"""Straggler detection: per-host step-time statistics → mitigation actions.

Two straggler classes exist at scale and GridSelect handles both:

  * **data stragglers** — a host's shard fetches slow down because its
    chosen replica degraded. Handled *inside* the broker (mid-transfer
    re-selection, core/broker.py); nothing to do here.
  * **compute stragglers** — a host's step time drifts (thermal, ECC,
    noisy neighbour). Detected here from the step-time stream each host
    reports: robust z-score against the fleet median/MAD, EWMA-smoothed
    per host. Persistent offenders produce actions: first
    ``rebalance`` (shed input work — shrink that host's prefetch), then
    ``exclude`` (trigger an elastic re-mesh without it, parallel/elastic).

Deterministic and side-effect free: feed observations, read actions.
"""

from __future__ import annotations

from collections import defaultdict, deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Tuple

__all__ = ["StragglerAction", "StragglerMonitor"]


@dataclass(frozen=True)
class StragglerAction:
    host: str
    kind: str  # 'rebalance' | 'exclude'
    z_score: float
    step: int


class StragglerMonitor:
    def __init__(
        self,
        *,
        ewma_alpha: float = 0.3,
        z_rebalance: float = 3.0,
        z_exclude: float = 6.0,
        patience: int = 3,
        window: int = 64,
        min_excess: float = 0.15,  # ignore hosts < 15% over the median
    ):
        self.alpha = ewma_alpha
        self.z_rebalance = z_rebalance
        self.z_exclude = z_exclude
        self.patience = patience
        self.window = window
        self.min_excess = min_excess
        self._ewma: Dict[str, float] = {}
        self._strikes: Dict[str, int] = defaultdict(int)
        self._history: Deque[Tuple[int, Dict[str, float]]] = deque(maxlen=window)
        self.excluded: List[str] = []

    def observe_step(self, step: int, host_times: Dict[str, float]) -> List[StragglerAction]:
        """Feed one step's per-host times; returns triggered actions."""
        for h, t in host_times.items():
            prev = self._ewma.get(h)
            self._ewma[h] = t if prev is None else self.alpha * t + (1 - self.alpha) * prev
        self._history.append((step, dict(host_times)))

        smoothed = {h: v for h, v in self._ewma.items() if h not in self.excluded}
        if len(smoothed) < 3:
            return []
        med = _median(list(smoothed.values()))
        mad = _median([abs(v - med) for v in smoothed.values()]) or 1e-9

        actions: List[StragglerAction] = []
        for h, v in sorted(smoothed.items()):
            z = 0.6745 * (v - med) / mad  # normal-consistent robust z
            if z >= self.z_rebalance and (v - med) / max(med, 1e-9) >= self.min_excess:
                self._strikes[h] += 1
            else:
                self._strikes[h] = 0
                continue
            if self._strikes[h] >= self.patience:
                if z >= self.z_exclude:
                    actions.append(StragglerAction(h, "exclude", z, step))
                    self.excluded.append(h)
                    self._strikes[h] = 0
                else:
                    actions.append(StragglerAction(h, "rebalance", z, step))
        return actions

    def fleet_summary(self) -> Dict[str, float]:
        vals = [v for h, v in self._ewma.items() if h not in self.excluded]
        if not vals:
            return {}
        med = _median(vals)
        return {
            "median_step_s": med,
            "max_step_s": max(vals),
            "straggler_overhead": max(vals) / med - 1.0 if med > 0 else 0.0,
            "excluded_hosts": float(len(self.excluded)),
        }


def _median(xs: List[float]) -> float:
    s = sorted(xs)
    n = len(s)
    if n == 0:
        return 0.0
    return s[n // 2] if n % 2 else 0.5 * (s[n // 2 - 1] + s[n // 2])
