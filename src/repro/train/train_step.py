"""The jitted training step: microbatched grad accumulation → AdamW.

``make_train_step`` closes over (arch config, optimizer config, sharding
policy) and returns a pure ``(state, batch) → (state, metrics)`` suitable
for ``jax.jit`` with explicit in/out shardings (launch/dryrun.py and
launch/train.py provide them).

Structure:

  * the global batch ``[B, S]`` arriving at the step is already the
    *per-data-shard* slice under pjit (B = global_batch, sharded on the
    data axes); gradient accumulation splits it into ``n_micro``
    microbatches with a ``lax.scan`` — activation memory scales with the
    microbatch, gradients accumulate in f32,
  * optional int8 gradient compression with error feedback sits between
    the gradient and the optimizer (parallel/collectives.py) — under pjit
    the data-parallel reduction of the compressed gradient is what moves
    across pods,
  * remat policy is the model's (cfg.remat, applied inside the stack).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer
from repro.parallel.ctx import constrain_batch
from repro.parallel.collectives import (
    ErrorFeedbackState,
    compress_with_feedback,
    init_error_feedback,
)

from .optim import AdamWConfig, AdamWState, adamw_update, init_adamw, warmup_cosine

__all__ = ["TrainConfig", "TrainState", "init_train_state", "make_train_step"]


class TrainConfig(NamedTuple):
    optimizer: AdamWConfig = AdamWConfig()
    n_microbatches: int = 1
    warmup_steps: int = 100
    total_steps: int = 10000
    grad_compression: bool = False  # int8 + error feedback


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState
    ef: Optional[ErrorFeedbackState]
    step: jnp.ndarray


def init_train_state(cfg: ArchConfig, tc: TrainConfig, rng) -> TrainState:
    params = transformer.init_params(cfg, rng)
    opt = init_adamw(params, tc.optimizer)
    if tc.optimizer.master_dtype == "float32":
        # live params in bf16; the f32 master rides in the optimizer state
        params = jax.tree.map(lambda p: p.astype(jnp.bfloat16), params)
    ef = init_error_feedback(params) if tc.grad_compression else None
    return TrainState(params, opt, ef, jnp.zeros((), jnp.int32))


def _split_micro(batch: Dict[str, jnp.ndarray], n: int) -> Dict[str, jnp.ndarray]:
    def split(x):
        b = x.shape[0]
        assert b % n == 0, (b, n)
        return constrain_batch(x.reshape(n, b // n, *x.shape[1:]), batch_dim=1)

    return {k: split(v) for k, v in batch.items()}


def make_train_step(cfg: ArchConfig, tc: TrainConfig, param_shardings=None):
    """→ pure train_step(state, batch) -> (state, metrics).

    ``param_shardings`` (optional pytree of NamedSharding matching params)
    pins the gradient-accumulation carry and the reduced gradients to the
    parameter sharding — without it the partitioner materializes the f32
    accumulator replicated over the zero3 axis (30 GiB/leaf on
    nemotron-340b, EXPERIMENTS §Dry-run)."""

    def _pin(tree):
        if param_shardings is None:
            return tree
        return jax.tree.map(
            lambda x, s: jax.lax.with_sharding_constraint(x, s), tree, param_shardings
        )

    def loss_for(params, micro):
        loss, metrics = transformer.loss_fn(params, micro, cfg)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_for, has_aux=True)

    def train_step(state: TrainState, batch: Dict[str, jnp.ndarray]):
        n = tc.n_microbatches
        if n > 1:
            micros = _split_micro(batch, n)

            def accum(carry, micro):
                gsum, lsum = carry
                (loss, _m), g = grad_fn(state.params, micro)
                gsum = _pin(jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g
                ))
                return (gsum, lsum + loss), None

            gzero = _pin(jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params
            ))
            (gsum, lsum), _ = jax.lax.scan(accum, (gzero, jnp.float32(0.0)), micros)
            grads = _pin(jax.tree.map(lambda g: g / n, gsum))
            loss = lsum / n
        else:
            (loss, _m), grads = grad_fn(state.params, batch)
            grads = _pin(grads)

        ef = state.ef
        metrics: Dict[str, jnp.ndarray] = {}
        if tc.grad_compression and ef is not None:
            grads, ef, cm = compress_with_feedback(grads, ef)
            metrics.update(cm)

        lr = warmup_cosine(
            state.step,
            peak_lr=tc.optimizer.lr,
            warmup=tc.warmup_steps,
            total=tc.total_steps,
        )
        params, opt, om = adamw_update(grads, state.opt, state.params, tc.optimizer, lr)
        metrics.update(om)
        metrics["loss"] = loss
        new_state = TrainState(params, opt, ef, state.step + 1)
        return new_state, metrics

    return train_step
