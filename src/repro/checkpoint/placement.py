"""Checkpoint replica placement: write-side matchmaking.

Placement is the write-direction instance of the paper's selection
problem: for each checkpoint chunk, choose K endpoints that (a) admit the
write under their published policy (``other.reqdSpace``), (b) have the
space, and (c) rank best by predicted write bandwidth / free space — via
``DataBroker.select_placements`` (the same two-sided ClassAd match).

Zone anti-affinity is layered on top: replicas of one chunk prefer
distinct zones, so a zone (pod) outage cannot take out every copy.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.broker import DataBroker, RankedReplica
from repro.storage.endpoint import DataGrid

__all__ = ["PlacementPlan", "plan_placement"]


@dataclass
class PlacementPlan:
    targets: List[str]  # endpoint URLs, best first
    ranked: List[RankedReplica]
    zones: List[str]


def plan_placement(
    broker: DataBroker,
    grid: DataGrid,
    nbytes: int,
    *,
    k: int = 2,
    anti_affinity: bool = True,
) -> PlacementPlan:
    endpoints = grid.alive_endpoints()
    ranked = broker.select_placements(nbytes, endpoints, k=len(endpoints))
    targets: List[str] = []
    zones: List[str] = []
    for rr in ranked:
        ep = rr.pfn.endpoint
        zone = grid.topology.zone_of(ep)
        if anti_affinity and zone in zones and len(zones) < len(set(
            grid.topology.zone_of(e) for e in endpoints
        )):
            continue
        targets.append(ep)
        zones.append(zone)
        if len(targets) == k:
            break
    # relax anti-affinity if we ran short
    if len(targets) < k:
        for rr in ranked:
            if rr.pfn.endpoint not in targets:
                targets.append(rr.pfn.endpoint)
                zones.append(grid.topology.zone_of(rr.pfn.endpoint))
                if len(targets) == k:
                    break
    return PlacementPlan(targets, ranked[:k], zones)
