"""Restore-time helpers: resuming a run on whatever mesh survives.

``resume_or_init`` is the launcher's single entry point: restore the
latest checkpoint if one exists (into the *current* mesh via the policy's
specs), else initialize fresh. It also re-derives the TrainState step so
schedules continue exactly.
"""

from __future__ import annotations

from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from .manager import CheckpointManager

__all__ = ["resume_or_init"]


def resume_or_init(
    manager: CheckpointManager,
    init_fn: Callable[[], Any],
    *,
    mesh=None,
    spec_fn: Optional[Callable] = None,
    scheduler=None,
) -> Tuple[Any, int, bool]:
    """→ (state, start_step, resumed).

    ``scheduler`` (a BatchScheduler over the manager's broker) coalesces
    every chunk's replica selection into batched kernel launches; the
    resulting plans are then executed striped by the manager's resilient
    transfer service."""
    step = manager.latest_step()
    if step is None:
        state = init_fn()
        if mesh is not None and spec_fn is not None:
            from jax.sharding import NamedSharding

            from repro.parallel.sharding import _path_str

            state = jax.tree_util.tree_map_with_path(
                lambda path, leaf: jax.device_put(
                    leaf, NamedSharding(mesh, spec_fn(_path_str(path), tuple(leaf.shape)))
                ),
                state,
            )
        return state, 0, False
    template = jax.eval_shape(init_fn)
    state = manager.restore(
        step, template, mesh=mesh, spec_fn=spec_fn, scheduler=scheduler
    )
    return state, step, True
