"""Fault-tolerant distributed checkpointing over the data grid.

Checkpoints are first-class data-grid citizens:

  * the state pytree is flattened; every leaf serializes to bytes
    (``.npy``-style header + raw) and is **chunked** (default 64 MiB),
  * each chunk is placed on K endpoints chosen by write-side matchmaking
    (checkpoint/placement.py) with zone anti-affinity, registered in the
    replica catalog under the ``ckpt/<run>/<step>`` collection,
  * a manifest (JSON) carries the tree structure, shapes/dtypes, chunk
    LFNs and SHA-256 checksums; the manifest itself is replicated on
    *every* endpoint (it is tiny and everything depends on it),
  * restore brokers each chunk read (failover over surviving replicas),
    verifies checksums, reassembles leaves, and — given a mesh + sharding
    policy — ``device_put``s with the *target* sharding, which is what
    makes elastic re-mesh restores (tests/test_elastic.py) free,
  * ``repair`` re-replicates chunks whose live replica count fell below K
    (the anti-entropy daemon of a real deployment),
  * async save: a background thread runs placement + writes on a snapshot
    (``jax.device_get`` first — the training loop keeps stepping).

QTensor optimizer leaves (int8 moments) checkpoint transparently — they
are pytrees of (q, scale) arrays like everything else.
"""

from __future__ import annotations

import io
import json
import threading
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.broker import DataBroker, default_read_request
from repro.core.catalog import PhysicalFile
from repro.storage.endpoint import DataGrid, checksum as data_checksum

from .placement import plan_placement

__all__ = ["CheckpointManager", "CheckpointError"]

CHUNK_BYTES_DEFAULT = 64 << 20


class CheckpointError(RuntimeError):
    pass


def _leaf_to_bytes(x) -> bytes:
    arr = np.asarray(x)
    buf = io.BytesIO()
    np.save(buf, arr, allow_pickle=False)
    return buf.getvalue()


def _leaf_from_bytes(data: bytes) -> np.ndarray:
    return np.load(io.BytesIO(data), allow_pickle=False)


class CheckpointManager:
    def __init__(
        self,
        run_name: str,
        grid: DataGrid,
        broker: DataBroker,
        *,
        replication: int = 2,
        chunk_bytes: int = CHUNK_BYTES_DEFAULT,
        keep: int = 3,
        resilient: bool = True,
    ):
        self.run_name = run_name
        self.grid = grid
        self.broker = broker
        # chunk reads go through the resilient access layer by default:
        # a restore races the repair daemon against real failures, which
        # is exactly the striped/hedged/breaker-gated path's home turf
        self.resilient = resilient
        if resilient:
            self.transfer = grid.resilient_transfer_service(broker)
        else:
            self.transfer = grid.transfer_service(metrics=broker.metrics)
        self.replication = replication
        self.chunk_bytes = chunk_bytes
        self.keep = keep
        self._async_thread: Optional[threading.Thread] = None
        self._async_error: Optional[BaseException] = None
        self.stats = {"saves": 0, "restores": 0, "repaired_chunks": 0, "gc_steps": 0}

    # ------------------------------------------------------------------ paths
    def _collection(self, step: int) -> str:
        return f"ckpt/{self.run_name}/{step:08d}"

    def _manifest_lfn(self, step: int) -> str:
        return f"{self._collection(step)}/MANIFEST"

    def _chunk_lfn(self, step: int, leaf: int, chunk: int) -> str:
        return f"{self._collection(step)}/leaf-{leaf:04d}/chunk-{chunk:04d}"

    # ------------------------------------------------------------------- save
    def save(self, step: int, state: Any, *, blocking: bool = True) -> Dict[str, Any]:
        """Checkpoint ``state`` (a pytree of arrays) at ``step``."""
        import jax

        host_state = jax.device_get(state)
        if blocking:
            return self._save_snapshot(step, host_state)
        self.wait()  # one async save in flight at a time
        self._async_thread = threading.Thread(
            target=self._save_guarded, args=(step, host_state), daemon=True
        )
        self._async_thread.start()
        return {"step": step, "async": True}

    def _save_guarded(self, step: int, host_state: Any) -> None:
        try:
            self._save_snapshot(step, host_state)
        except BaseException as e:  # surfaced by wait()
            self._async_error = e

    def wait(self) -> None:
        if self._async_thread is not None:
            self._async_thread.join()
            self._async_thread = None
        if self._async_error is not None:
            err, self._async_error = self._async_error, None
            raise CheckpointError(f"async save failed: {err}") from err

    def _save_snapshot(self, step: int, host_state: Any) -> Dict[str, Any]:
        import jax

        leaves, treedef = jax.tree.flatten(host_state)
        manifest: Dict[str, Any] = {
            "run": self.run_name,
            "step": step,
            "treedef": str(treedef),
            "n_leaves": len(leaves),
            "leaves": [],
        }
        collection = self._collection(step)
        self.grid.catalog.create_collection(collection)

        for li, leaf in enumerate(leaves):
            data = _leaf_to_bytes(leaf)
            chunks = [
                data[o : o + self.chunk_bytes] for o in range(0, len(data), self.chunk_bytes)
            ] or [b""]
            leaf_rec = {
                "index": li,
                "shape": list(np.asarray(leaf).shape),
                "dtype": str(np.asarray(leaf).dtype),
                "nbytes": len(data),
                "chunks": [],
            }
            for ci, chunk in enumerate(chunks):
                lfn = self._chunk_lfn(step, li, ci)
                plan = plan_placement(
                    self.broker, self.grid, len(chunk), k=self.replication
                )
                for ep in plan.targets:
                    path = f"/ckpt/{lfn}"
                    self.transfer.write(ep, path, chunk, self.broker.client_url)
                    self.grid.catalog.register_replica(
                        lfn, PhysicalFile(ep, path, len(chunk), data_checksum(chunk))
                    )
                self.grid.catalog.add_to_collection(collection, lfn)
                leaf_rec["chunks"].append(
                    {"lfn": lfn, "nbytes": len(chunk), "sha": data_checksum(chunk)}
                )
            manifest["leaves"].append(leaf_rec)

        mbytes = json.dumps(manifest).encode()
        mlfn = self._manifest_lfn(step)
        for ep in self.grid.alive_endpoints():  # manifest goes everywhere
            path = f"/ckpt/{mlfn}"
            self.grid.endpoints[ep].put(path, mbytes)
            self.grid.catalog.register_replica(
                mlfn, PhysicalFile(ep, path, len(mbytes), data_checksum(mbytes))
            )
        self.grid.catalog.add_to_collection(collection, mlfn)
        self.stats["saves"] += 1
        self._gc()
        return manifest

    # ---------------------------------------------------------------- restore
    def latest_step(self) -> Optional[int]:
        """Newest *complete* checkpoint step.

        A checkpoint is complete iff its MANIFEST is registered — the
        manifest is written last, so an in-flight async save or a crash
        mid-save leaves a collection without one and must stay invisible
        to restore/repair (found by the 300-step driver run: repair raced
        an async save and chased a manifest that wasn't there yet)."""
        steps = []
        prefix = f"ckpt/{self.run_name}/"
        for coll in self.grid.catalog.collections():
            if coll.startswith(prefix):
                try:
                    step = int(coll[len(prefix) :])
                except ValueError:
                    continue
                if self.grid.catalog.exists(self._manifest_lfn(step)):
                    steps.append(step)
        return max(steps) if steps else None

    def _fetch(self, lfn: str, ranked=None) -> bytes:
        if self.resilient:
            # a SelectionResult (e.g. a coalescing-scheduler ticket)
            # carries an executable plan; execute it striped rather than
            # walking the ranked list single-source
            plan = getattr(ranked, "plan", None)
            if plan is not None:
                res = self.transfer.execute(plan)
                self.broker.note_access(getattr(ranked, "request_id", None), res)
                return res.payload
            if ranked is None:
                req = default_read_request(self.broker.client_url)
                return self.transfer.fetch(lfn, req).payload
        if ranked is not None:
            out = self.broker.access(lfn, ranked, self.transfer)
        else:
            out = self.broker.fetch(lfn, self.transfer, default_read_request(self.broker.client_url))
        return out.payload

    def load_manifest(self, step: int) -> Dict[str, Any]:
        return json.loads(self._fetch(self._manifest_lfn(step)).decode())

    def restore(
        self,
        step: int,
        template: Any,
        *,
        mesh=None,
        spec_fn: Optional[Callable] = None,
        scheduler=None,
    ) -> Any:
        """Restore into the structure of ``template`` (any pytree with the
        same leaf count/order). With (mesh, spec_fn), leaves are placed
        sharded — restoring into a *different* mesh than the save is the
        elastic-scaling path.

        With ``scheduler`` (a :class:`repro.serve.scheduler.BatchScheduler`
        over this manager's broker), every chunk's replica selection is
        coalesced into batched kernel launches up front; only the Access
        Phase then runs per chunk."""
        import jax

        manifest = self.load_manifest(step)
        leaves_t, treedef = jax.tree.flatten(template)
        if len(leaves_t) != manifest["n_leaves"]:
            raise CheckpointError(
                f"template has {len(leaves_t)} leaves, checkpoint {manifest['n_leaves']}"
            )
        tickets = {}
        if scheduler is not None:
            for rec in manifest["leaves"]:
                for ch in rec["chunks"]:
                    tickets[ch["lfn"]] = scheduler.submit(ch["lfn"])
            scheduler.flush()
        out_leaves: List[Any] = []
        for li, rec in enumerate(manifest["leaves"]):
            parts: List[bytes] = []
            for ch in rec["chunks"]:
                t = tickets.get(ch["lfn"])
                data = self._fetch(ch["lfn"], ranked=t.result() if t else None)
                if data_checksum(data) != ch["sha"]:
                    raise CheckpointError(f"checksum mismatch on {ch['lfn']}")
                parts.append(data)
            arr = _leaf_from_bytes(b"".join(parts))
            if list(arr.shape) != rec["shape"]:
                raise CheckpointError(f"shape mismatch on leaf {li}")
            out_leaves.append(arr)
        restored = jax.tree.unflatten(treedef, out_leaves)

        if mesh is not None and spec_fn is not None:
            from jax.sharding import NamedSharding

            from repro.parallel.sharding import _path_str

            restored = jax.tree_util.tree_map_with_path(
                lambda path, leaf: jax.device_put(
                    leaf, NamedSharding(mesh, spec_fn(_path_str(path), tuple(leaf.shape)))
                ),
                restored,
            )
        self.stats["restores"] += 1
        return restored

    # ----------------------------------------------------------------- repair
    def repair(self, step: int) -> int:
        """Re-replicate chunks whose live replica count dropped below K."""
        manifest = self.load_manifest(step)
        repaired = 0
        for rec in manifest["leaves"]:
            for ch in rec["chunks"]:
                lfn = ch["lfn"]
                live = [
                    r
                    for r in self.grid.catalog.lookup(lfn)
                    if self.grid.endpoints.get(r.endpoint)
                    and self.grid.endpoints[r.endpoint].alive
                ]
                if len(live) >= self.replication:
                    continue
                if not live:
                    raise CheckpointError(f"chunk {lfn} lost all replicas")
                data = self._fetch(lfn)
                have = {r.endpoint for r in live}
                plan = plan_placement(self.broker, self.grid, len(data), k=len(self.grid.alive_endpoints()))
                for ep in plan.targets:
                    if ep in have:
                        continue
                    path = f"/ckpt/{lfn}"
                    self.transfer.write(ep, path, data, self.broker.client_url)
                    self.grid.catalog.register_replica(
                        lfn, PhysicalFile(ep, path, len(data), data_checksum(data))
                    )
                    repaired += 1
                    have.add(ep)
                    if len(have) >= self.replication:
                        break
        self.stats["repaired_chunks"] += repaired
        return repaired

    # --------------------------------------------------------------------- gc
    def _gc(self) -> None:
        prefix = f"ckpt/{self.run_name}/"
        steps = sorted(
            int(c[len(prefix) :])
            for c in self.grid.catalog.collections()
            if c.startswith(prefix) and c[len(prefix) :].isdigit()
        )
        for old in steps[: -self.keep] if len(steps) > self.keep else []:
            coll = self._collection(old)
            for lfn in self.grid.catalog.collection(coll):
                for pfn in list(self.grid.catalog.lookup(lfn)):
                    ep = self.grid.endpoints.get(pfn.endpoint)
                    if ep is not None and ep.alive and ep.has(pfn.path):
                        ep.delete(pfn.path)
                    self.grid.catalog.unregister_replica(lfn, pfn.endpoint, pfn.path)
            self.grid.catalog.drop_collection(coll)
            self.stats["gc_steps"] += 1
