"""Pallas kernel lint: BlockSpec shape/alignment checks (KRNxx rules).

TPU vector memory tiles float32 as (8, 128) — sublane × lane. A BlockSpec
whose lane (last) dimension is not a multiple of 128, or whose sublane
(second-to-last) dimension is not a multiple of 8, forces relayouts or
fails to lower on real hardware even though ``interpret=True`` hides it.
The matchrank/bwstats wrappers guarantee this by construction (``block_s
= 512``, ``A_PAD % 128 == 0``); these rules keep future edits honest.

Rules (files under ``kernels/`` only):

  KRN001  lane-misaligned       resolvable last block dim is neither 1
                                nor a multiple of 128
  KRN002  sublane-misaligned    resolvable second-to-last block dim is
                                neither 1 nor a multiple of 8
  KRN003  index-map-arity       BlockSpec index_map lambda arity differs
                                from the rank of the ``grid`` tuple in
                                scope

Dims are resolved from integer literals, enclosing-function keyword
defaults (``block_s: int = 512``) and module-level integer constants;
runtime-shaped dims (``a_pad``) are deliberately skipped — the wrappers
assert those at call time. Suppress with ``# lint: allow-kernel``.
"""

from __future__ import annotations

import ast
from typing import Dict, List, Optional

from .codelint import LintContext
from .diagnostics import Diagnostic, Severity

__all__ = ["check_source", "check_file"]

_LANE = 128
_SUBLANE = 8

_BINOPS = {
    ast.Add: lambda a, b: a + b,
    ast.Sub: lambda a, b: a - b,
    ast.Mult: lambda a, b: a * b,
    ast.FloorDiv: lambda a, b: a // b if b else None,
    ast.Mod: lambda a, b: a % b if b else None,
}


def _resolve(expr: ast.AST, env: Dict[str, int]) -> Optional[int]:
    """Best-effort static value of a block dimension; None when dynamic."""
    if isinstance(expr, ast.Constant) and isinstance(expr.value, int) \
            and not isinstance(expr.value, bool):
        return expr.value
    if isinstance(expr, ast.Name):
        return env.get(expr.id)
    if isinstance(expr, ast.BinOp) and type(expr.op) in _BINOPS:
        left = _resolve(expr.left, env)
        right = _resolve(expr.right, env)
        if left is None or right is None:
            return None
        return _BINOPS[type(expr.op)](left, right)
    if isinstance(expr, ast.UnaryOp) and isinstance(expr.op, ast.USub):
        v = _resolve(expr.operand, env)
        return -v if v is not None else None
    return None


def _int_defaults(fn: ast.FunctionDef) -> Dict[str, int]:
    """Parameter → value for int-literal defaults (positional + kw-only)."""
    out: Dict[str, int] = {}
    args = fn.args
    pos = args.posonlyargs + args.args
    for arg, default in zip(pos[len(pos) - len(args.defaults):], args.defaults):
        v = _resolve(default, {})
        if v is not None:
            out[arg.arg] = v
    for arg, default in zip(args.kwonlyargs, args.kw_defaults):
        if default is not None:
            v = _resolve(default, {})
            if v is not None:
                out[arg.arg] = v
    return out


def _module_consts(tree: ast.Module) -> Dict[str, int]:
    out: Dict[str, int] = {}
    for node in tree.body:
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name):
            v = _resolve(node.value, {})
            if v is not None:
                out[node.targets[0].id] = v
    return out


def _is_blockspec(call: ast.Call) -> bool:
    f = call.func
    if isinstance(f, ast.Attribute):
        return f.attr == "BlockSpec"
    return isinstance(f, ast.Name) and f.id == "BlockSpec"


def _grid_assignments(fn: ast.FunctionDef) -> List[tuple]:
    """(lineno, rank) for each ``grid = (...)`` in the function body."""
    out = []
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name) \
                and node.targets[0].id == "grid" \
                and isinstance(node.value, ast.Tuple):
            out.append((node.lineno, len(node.value.elts)))
    return out


def check_source(text: str, relpath: str) -> List[Diagnostic]:
    """Run the KRN rules over one kernel module's source."""
    try:
        tree = ast.parse(text)
    except SyntaxError:
        return []  # codelint already reports GEN001 for this file
    ctx = LintContext(relpath=relpath, text=text, tree=tree)
    consts = _module_consts(tree)

    for fn in [n for n in ast.walk(tree)
               if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]:
        env = {**consts, **_int_defaults(fn)}
        grids = _grid_assignments(fn)
        for node in ast.walk(fn):
            if not (isinstance(node, ast.Call) and _is_blockspec(node)):
                continue
            if not node.args or not isinstance(node.args[0], ast.Tuple):
                continue
            dims = node.args[0].elts
            resolved = [_resolve(d, env) for d in dims]
            lane = resolved[-1]
            if lane is not None and lane != 1 and lane % _LANE != 0:
                ctx.emit(
                    "KRN001", Severity.ERROR,
                    f"BlockSpec lane (last) dimension {lane} is not a "
                    f"multiple of {_LANE} — float32 min tile is "
                    f"({_SUBLANE}, {_LANE})", node, "kernel",
                )
            if len(resolved) >= 2:
                sub = resolved[-2]
                if sub is not None and sub != 1 and sub % _SUBLANE != 0:
                    ctx.emit(
                        "KRN002", Severity.ERROR,
                        f"BlockSpec sublane dimension {sub} is not a "
                        f"multiple of {_SUBLANE} — float32 min tile is "
                        f"({_SUBLANE}, {_LANE})", node, "kernel",
                    )
            if len(node.args) >= 2 and isinstance(node.args[1], ast.Lambda):
                arity = len(node.args[1].args.args)
                prior = [g for g in grids if g[0] <= node.lineno]
                grid_rank = (prior[-1] if prior else grids[0])[1] if grids else None
                if grid_rank is not None and arity != grid_rank:
                    ctx.emit(
                        "KRN003", Severity.ERROR,
                        f"BlockSpec index_map takes {arity} argument(s) but "
                        f"the grid in scope has rank {grid_rank}",
                        node, "kernel",
                    )
    ctx.diags.sort(key=lambda d: (d.span.line if d.span else 0, d.rule))
    return ctx.diags


def check_file(path: str, relpath: Optional[str] = None) -> List[Diagnostic]:
    with open(path) as f:
        text = f.read()
    return check_source(text, relpath or path)
