"""Repo lint: ``ast``-based rules for the repo's hardest-won invariants.

The transfer/resilience stack is deterministic **by construction**: every
timestamp flows from an injected :class:`~repro.core.gris.Clock` and every
random draw from an explicitly seeded generator. One ``time.time()`` in a
sim path silently breaks replayability. These rules keep that invariant —
plus a few robustness/hygiene properties — machine-checked.

Rules:

  SIM001  wallclock-leak        ``time.time()``/``perf_counter``/
                                ``datetime.now()``… used directly. Error in
                                sim paths (storage/, core/, serve/), warning
                                elsewhere. Where wall time is genuinely
                                intended (obs tracing defaults, launch
                                CLIs), mark the line ``# lint: allow-wallclock``.
  SIM002  unseeded-random       stdlib ``random`` module functions or
                                global-state ``numpy.random`` samplers
                                (``np.random.default_rng(seed)`` and
                                ``jax.random`` are fine — both are
                                explicitly seeded).
  TRF001  unbounded-retry       a ``while True`` loop in a transfer path
                                with no ``break``/``return``/``raise`` —
                                a retry loop that can never give up.
  TRF002  bare-except           ``except:`` (error in transfer paths,
                                warning elsewhere); also flags
                                swallow-all ``except Exception: pass``
                                in transfer paths.
  OBS001  unbounded-metric-labels  a metric registered with a label drawn
                                from an unbounded domain (endpoint/url/
                                lfn/…) with a non-literal value.
  DEP001  deprecated-tuple-read call to the deprecated tuple-returning
                                ``read(replica, client_url)`` /
                                ``read_chunks(...)`` shims; use
                                ``transfer(TransferRequest(...))``.

Suppression: append ``# lint: allow-<tag>`` to the offending line (tags:
``wallclock``, ``random``, ``retry``, ``bare-except``, ``metric-labels``,
``deprecated``, ``kernel``, or ``all``). Suppressions are deliberate and
reviewable — they are the "explicit allowlist" of the determinism policy.
"""

from __future__ import annotations

import ast
import re
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Set, Tuple

from .diagnostics import Diagnostic, Severity, Span

__all__ = ["LintContext", "lint_source", "lint_file", "RULES"]


_ALLOW_RE = re.compile(r"#\s*lint:\s*(allow-[a-z0-9_,\s-]+)")

#: wall-clock functions of the ``time`` module
_TIME_WALLCLOCK = frozenset(
    {"time", "time_ns", "perf_counter", "perf_counter_ns", "monotonic",
     "monotonic_ns", "process_time", "process_time_ns", "sleep",
     "localtime", "gmtime", "ctime"}
)
#: nondeterministic constructors on ``datetime``/``date``
_DATETIME_WALLCLOCK = frozenset({"now", "utcnow", "today"})
#: global-state samplers of the stdlib ``random`` module
_RANDOM_FNS = frozenset(
    {"random", "randint", "randrange", "uniform", "choice", "choices",
     "shuffle", "sample", "gauss", "normalvariate", "expovariate",
     "betavariate", "triangular", "seed", "getrandbits", "randbytes"}
)
#: ``numpy.random`` attributes that are explicitly seeded constructions
_NP_RANDOM_SAFE = frozenset(
    {"default_rng", "Generator", "SeedSequence", "PCG64", "Philox",
     "MT19937", "BitGenerator", "RandomState"}
)
#: label names whose value domain is unbounded (URLs, files, requests)
_HIGH_CARDINALITY_LABELS = frozenset(
    {"endpoint", "client", "client_url", "url", "lfn", "path",
     "request_id", "source", "dn", "replica", "query"}
)
_METRIC_FACTORIES = frozenset({"counter", "gauge", "histogram"})
_METRIC_NON_LABEL_KWARGS = frozenset({"help", "buckets"})


@dataclass
class LintContext:
    """Per-file state shared by every rule."""

    relpath: str
    text: str
    tree: ast.AST
    lines: List[str] = field(default_factory=list)
    diags: List[Diagnostic] = field(default_factory=list)

    def __post_init__(self) -> None:
        if not self.lines:
            self.lines = self.text.splitlines()
        parts = set(self.relpath.replace("\\", "/").split("/"))
        self.is_sim_path = bool(parts & {"storage", "core", "serve"})
        self.is_transfer_path = "storage" in parts
        self.is_kernel_path = "kernels" in parts

    # ------------------------------------------------------------ allowlist
    def allowed(self, lineno: int, tag: str) -> bool:
        if not (1 <= lineno <= len(self.lines)):
            return False
        m = _ALLOW_RE.search(self.lines[lineno - 1])
        if not m:
            return False
        tags = {t.strip()[len("allow-"):] for t in m.group(1).split(",")
                if t.strip().startswith("allow-")}
        return tag in tags or "all" in tags

    # ------------------------------------------------------------- emission
    def emit(
        self,
        rule: str,
        severity: Severity,
        message: str,
        node: ast.AST,
        tag: str,
        lineno: Optional[int] = None,
    ) -> None:
        line = lineno or getattr(node, "lineno", 1)
        if self.allowed(line, tag):
            return
        col = getattr(node, "col_offset", 0) + 1 if lineno is None else 1
        snippet = self.lines[line - 1].strip() if line <= len(self.lines) else None
        self.diags.append(
            Diagnostic(rule, severity, message, file=self.relpath,
                       span=Span(line, col), source=snippet)
        )


# ---------------------------------------------------------------------------
# Import alias maps (computed once, used by both SIM rules)
# ---------------------------------------------------------------------------


def _alias_maps(tree: ast.AST) -> Dict[str, Dict[str, str]]:
    """module → {bound-name: original-name} for the modules we care about."""
    mods = {"time": {}, "datetime": {}, "random": {}, "numpy": {}}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                root = alias.name.split(".")[0]
                if root in mods:
                    mods[root][alias.asname or root] = "__module__"
        elif isinstance(node, ast.ImportFrom) and node.module:
            root = node.module.split(".")[0]
            if root in mods:
                for alias in node.names:
                    mods[root][alias.asname or alias.name] = alias.name
    return mods


def _attr_on_module(
    node: ast.AST, module_aliases: Dict[str, str]
) -> Optional[str]:
    """``alias.attr`` where alias is a tracked module binding → attr name."""
    if (
        isinstance(node, ast.Attribute)
        and isinstance(node.value, ast.Name)
        and module_aliases.get(node.value.id) == "__module__"
    ):
        return node.attr
    return None


# ---------------------------------------------------------------------------
# Rules
# ---------------------------------------------------------------------------


def rule_sim001_wallclock(ctx: LintContext) -> None:
    mods = _alias_maps(ctx.tree)
    sev = Severity.ERROR if ctx.is_sim_path else Severity.WARNING
    hint = (
        "route through the injected Clock / tracer time_fn, or mark the "
        "line '# lint: allow-wallclock' if wall time is intended"
    )
    from_time = {n: orig for n, orig in mods["time"].items()
                 if orig in _TIME_WALLCLOCK}
    from_dt = {n: orig for n, orig in mods["datetime"].items()
               if orig in ("datetime", "date")}
    for node in ast.walk(ctx.tree):
        attr = _attr_on_module(node, mods["time"])
        if attr in _TIME_WALLCLOCK:
            ctx.emit("SIM001", sev,
                     f"wall-clock call time.{attr} — {hint}", node, "wallclock")
            continue
        if isinstance(node, ast.Name) and node.id in from_time:
            ctx.emit("SIM001", sev,
                     f"wall-clock call time.{from_time[node.id]} — {hint}",
                     node, "wallclock")
            continue
        if isinstance(node, ast.Attribute) and node.attr in _DATETIME_WALLCLOCK:
            base = node.value
            if isinstance(base, ast.Name) and base.id in from_dt:
                ctx.emit("SIM001", sev,
                         f"wall-clock call {from_dt[base.id]}.{node.attr}() — "
                         f"{hint}", node, "wallclock")
            elif _attr_on_module(base, mods["datetime"]) in ("datetime", "date"):
                ctx.emit("SIM001", sev,
                         f"wall-clock call datetime.{node.attr}() — {hint}",
                         node, "wallclock")


def rule_sim002_random(ctx: LintContext) -> None:
    mods = _alias_maps(ctx.tree)
    sev = Severity.ERROR if ctx.is_sim_path else Severity.WARNING
    hint = (
        "use an explicitly seeded generator (np.random.default_rng(seed), "
        "random.Random(seed)) or mark '# lint: allow-random'"
    )
    from_random = {n: orig for n, orig in mods["random"].items()
                   if orig in _RANDOM_FNS}
    for node in ast.walk(ctx.tree):
        attr = _attr_on_module(node, mods["random"])
        if attr in _RANDOM_FNS:
            ctx.emit("SIM002", sev,
                     f"global-state random.{attr} — {hint}", node, "random")
            continue
        if isinstance(node, ast.Name) and node.id in from_random:
            ctx.emit("SIM002", sev,
                     f"global-state random.{from_random[node.id]} — {hint}",
                     node, "random")
            continue
        # np.random.<sampler> — global MT19937 state
        if (
            isinstance(node, ast.Attribute)
            and node.attr not in _NP_RANDOM_SAFE
            and isinstance(node.value, ast.Attribute)
            and node.value.attr == "random"
            and _attr_on_module(node.value, mods["numpy"]) == "random"
        ):
            ctx.emit("SIM002", sev,
                     f"global-state numpy.random.{node.attr} — {hint}",
                     node, "random")


def _loop_can_exit(loop: ast.While) -> bool:
    """Does the loop body contain a break (of *this* loop), return or raise?"""

    def scan(nodes, in_nested_loop: bool) -> bool:
        for n in nodes:
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
                continue  # a nested def's return doesn't exit the loop
            if isinstance(n, ast.Break) and not in_nested_loop:
                return True
            if isinstance(n, (ast.Return, ast.Raise)):
                return True
            nested = in_nested_loop or isinstance(n, (ast.While, ast.For))
            if scan(ast.iter_child_nodes(n), nested):
                return True
        return False

    return scan(loop.body, False)


def rule_trf001_unbounded_retry(ctx: LintContext) -> None:
    if not ctx.is_transfer_path:
        return
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.While):
            continue
        test = node.test
        is_true = isinstance(test, ast.Constant) and bool(test.value)
        if is_true and not _loop_can_exit(node):
            ctx.emit(
                "TRF001", Severity.ERROR,
                "unbounded 'while True' retry loop with no break/return/"
                "raise — bound the attempts (see ResilientTransferService "
                "retry budget)", node, "retry",
            )


def rule_trf002_bare_except(ctx: LintContext) -> None:
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.ExceptHandler):
            continue
        if node.type is None:
            sev = Severity.ERROR if ctx.is_transfer_path else Severity.WARNING
            ctx.emit(
                "TRF002", sev,
                "bare 'except:' swallows KeyboardInterrupt/SystemExit and "
                "masks transfer faults — catch a concrete exception",
                node, "bare-except",
            )
        elif (
            ctx.is_transfer_path
            and isinstance(node.type, ast.Name)
            and node.type.id in ("Exception", "BaseException")
            and len(node.body) == 1
            and isinstance(node.body[0], ast.Pass)
        ):
            ctx.emit(
                "TRF002", Severity.WARNING,
                f"'except {node.type.id}: pass' in a transfer path silently "
                "drops faults the resilience layer should see",
                node, "bare-except",
            )


def rule_obs001_metric_labels(ctx: LintContext) -> None:
    for node in ast.walk(ctx.tree):
        if not (
            isinstance(node, ast.Call)
            and isinstance(node.func, ast.Attribute)
            and node.func.attr in _METRIC_FACTORIES
        ):
            continue
        for kw in node.keywords:
            if kw.arg is None or kw.arg in _METRIC_NON_LABEL_KWARGS:
                continue
            if kw.arg.lower() not in _HIGH_CARDINALITY_LABELS:
                continue
            if isinstance(kw.value, ast.Constant):
                continue
            ctx.emit(
                "OBS001", Severity.ERROR,
                f"metric label {kw.arg!r} takes values from an unbounded "
                "domain with a non-literal value — cardinality grows with "
                "the grid; aggregate or mark '# lint: allow-metric-labels' "
                "if the domain is provably bounded",
                kw.value, "metric-labels", lineno=kw.value.lineno,
            )


def rule_dep001_tuple_read(ctx: LintContext) -> None:
    for node in ast.walk(ctx.tree):
        if not (isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute)):
            continue
        recv = node.func.value
        recv_name = recv.id if isinstance(recv, ast.Name) else None
        if node.func.attr == "read_chunks":
            ctx.emit(
                "DEP001", Severity.ERROR,
                "deprecated tuple read_chunks() shim — use "
                "transfer(TransferRequest(...)) / TransferResult.chunks",
                node, "deprecated",
            )
        elif (
            node.func.attr == "read"
            and len(node.args) == 2
            and not node.keywords
            and recv_name != "os"
        ):
            ctx.emit(
                "DEP001", Severity.ERROR,
                "deprecated tuple read(replica, client_url) shim — use "
                "transfer(TransferRequest(...))",
                node, "deprecated",
            )


#: (rule id, implementation) in report order
RULES: List[Tuple[str, Callable[[LintContext], None]]] = [
    ("SIM001", rule_sim001_wallclock),
    ("SIM002", rule_sim002_random),
    ("TRF001", rule_trf001_unbounded_retry),
    ("TRF002", rule_trf002_bare_except),
    ("OBS001", rule_obs001_metric_labels),
    ("DEP001", rule_dep001_tuple_read),
]


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------


def lint_source(text: str, relpath: str) -> List[Diagnostic]:
    """Run every code rule over one module's source."""
    try:
        tree = ast.parse(text)
    except SyntaxError as e:
        return [
            Diagnostic(
                "GEN001", Severity.ERROR, f"file does not parse: {e.msg}",
                file=relpath, span=Span(e.lineno or 1, (e.offset or 1)),
            )
        ]
    ctx = LintContext(relpath=relpath, text=text, tree=tree)
    for _rule_id, fn in RULES:
        fn(ctx)
    ctx.diags.sort(key=lambda d: (d.span.line if d.span else 0, d.rule))
    return ctx.diags


def lint_file(path: str, relpath: Optional[str] = None) -> List[Diagnostic]:
    with open(path) as f:
        text = f.read()
    return lint_source(text, relpath or path)
