"""The one diagnostic model every analysis layer shares.

Both analyzers — the ClassAd/schema checker (:mod:`.adlint`) and the
Python repo lint (:mod:`.codelint` / :mod:`.kernelcheck`) — emit the same
:class:`Diagnostic` shape: a stable rule id, a severity, a message, and a
location that is either a file span (line/col) or an ad attribute. A
:class:`Report` aggregates them, renders the human-readable listing, and
round-trips through the JSON format the CI gate uploads as an artifact.

Rule ids are namespaced by layer:

  ``AD1xx``  ClassAd expression analysis     (adlint)
  ``ADSxx``  ad ↔ DIT schema consistency      (adlint)
  ``SIMxx``  sim-determinism (wallclock/rng)  (codelint)
  ``TRFxx``  transfer-path robustness         (codelint)
  ``OBSxx``  observability hygiene            (codelint)
  ``DEPxx``  deprecated in-repo APIs          (codelint)
  ``KRNxx``  Pallas kernel BlockSpec checks   (kernelcheck)
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field
from enum import Enum
from typing import Any, Dict, IO, Iterable, Iterator, List, Optional, Union

__all__ = ["Severity", "Span", "Diagnostic", "Report", "REPORT_VERSION"]

REPORT_VERSION = 1


class Severity(str, Enum):
    """Ordered severity: ERROR fails the CI gate, WARNING/INFO do not."""

    ERROR = "error"
    WARNING = "warning"
    INFO = "info"

    @property
    def level(self) -> int:
        return {"error": 2, "warning": 1, "info": 0}[self.value]

    def __lt__(self, other: "Severity") -> bool:  # type: ignore[override]
        return self.level < other.level


@dataclass(frozen=True)
class Span:
    """A source location: 1-based line/col, inclusive-exclusive columns."""

    line: int
    col: int = 0
    end_line: Optional[int] = None
    end_col: Optional[int] = None

    def to_dict(self) -> Dict[str, Any]:
        d: Dict[str, Any] = {"line": self.line, "col": self.col}
        if self.end_line is not None:
            d["end_line"] = self.end_line
        if self.end_col is not None:
            d["end_col"] = self.end_col
        return d


@dataclass
class Diagnostic:
    """One finding: rule id + severity + message + location."""

    rule: str  # stable id, e.g. "AD101", "SIM001"
    severity: Severity
    message: str
    file: Optional[str] = None  # repo-relative path or ad name
    span: Optional[Span] = None  # file location, when known
    attr: Optional[str] = None  # ClassAd attribute the finding is about
    source: Optional[str] = None  # offending source snippet (one line)

    def to_dict(self) -> Dict[str, Any]:
        d: Dict[str, Any] = {
            "rule": self.rule,
            "severity": self.severity.value,
            "message": self.message,
        }
        if self.file is not None:
            d["file"] = self.file
        if self.span is not None:
            d.update(self.span.to_dict())
        if self.attr is not None:
            d["attr"] = self.attr
        if self.source is not None:
            d["source"] = self.source
        return d

    def render(self) -> str:
        """``path:line:col: severity RULE message [attr]`` — one line."""
        loc = self.file or "<ad>"
        if self.span is not None:
            loc += f":{self.span.line}:{self.span.col}"
        if self.attr is not None:
            loc += f" ({self.attr})"
        return f"{loc}: {self.severity.value} {self.rule} {self.message}"


class Report:
    """An ordered collection of diagnostics with counts and JSON I/O."""

    def __init__(self, diagnostics: Optional[Iterable[Diagnostic]] = None):
        self.diagnostics: List[Diagnostic] = list(diagnostics or [])
        self.checked_files = 0
        self.checked_ads = 0

    # ------------------------------------------------------------ building
    def extend(self, diags: Iterable[Diagnostic]) -> None:
        self.diagnostics.extend(diags)

    def add(self, diag: Diagnostic) -> None:
        self.diagnostics.append(diag)

    def __iter__(self) -> Iterator[Diagnostic]:
        return iter(self.diagnostics)

    def __len__(self) -> int:
        return len(self.diagnostics)

    # ------------------------------------------------------------- queries
    def counts(self) -> Dict[str, int]:
        out = {s.value: 0 for s in Severity}
        for d in self.diagnostics:
            out[d.severity.value] += 1
        return out

    def by_rule(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for d in self.diagnostics:
            out[d.rule] = out.get(d.rule, 0) + 1
        return out

    @property
    def errors(self) -> List[Diagnostic]:
        return [d for d in self.diagnostics if d.severity is Severity.ERROR]

    @property
    def ok(self) -> bool:
        """True when the CI gate passes (no error-severity findings)."""
        return not self.errors

    # -------------------------------------------------------------- output
    def render(self) -> str:
        lines = [d.render() for d in self.diagnostics]
        c = self.counts()
        lines.append(
            f"analysis: {self.checked_files} file(s), {self.checked_ads} ad(s) "
            f"checked — {c['error']} error(s), {c['warning']} warning(s), "
            f"{c['info']} info"
        )
        return "\n".join(lines)

    def to_dict(self) -> Dict[str, Any]:
        return {
            "version": REPORT_VERSION,
            "tool": "repro.analysis",
            "checked_files": self.checked_files,
            "checked_ads": self.checked_ads,
            "counts": self.counts(),
            "by_rule": self.by_rule(),
            "ok": self.ok,
            "diagnostics": [d.to_dict() for d in self.diagnostics],
        }

    def dump_json(self, path_or_file: Union[str, IO[str]]) -> None:
        payload = json.dumps(self.to_dict(), indent=2) + "\n"
        if isinstance(path_or_file, str):
            with open(path_or_file, "w") as f:
                f.write(payload)
        else:
            path_or_file.write(payload)
