"""CLI driver: ``python -m repro.analysis`` — the CI analysis gate.

Walks Python sources through the repo lint (:mod:`.codelint`) and — for
files under ``kernels/`` — the Pallas BlockSpec checks
(:mod:`.kernelcheck`); validates ClassAd files (``*.ad``) through the
ad/schema analyzer (:mod:`.adlint`). Emits the shared one-line-per-finding
listing and, with ``--json``, the versioned report CI uploads as an
artifact. Exit status 1 when any error-severity diagnostic exists.

Usage::

    python -m repro.analysis src/repro --ads examples/ads --json report.json
    python -m repro.analysis src/repro/core/broker.py
    python -m repro.analysis --ads examples/ads/request_read.ad
"""

from __future__ import annotations

import argparse
import os
import sys
from typing import Iterable, List, Optional

from . import adlint, codelint, kernelcheck
from .diagnostics import Report

__all__ = ["main", "build_report"]


def _iter_py_files(path: str) -> Iterable[str]:
    if os.path.isfile(path):
        yield path
        return
    for dirpath, dirnames, filenames in os.walk(path):
        dirnames.sort()
        dirnames[:] = [d for d in dirnames if d not in ("__pycache__", ".git")]
        for fname in sorted(filenames):
            if fname.endswith(".py"):
                yield os.path.join(dirpath, fname)


def _iter_ad_files(path: str) -> Iterable[str]:
    if os.path.isfile(path):
        yield path
        return
    for dirpath, dirnames, filenames in os.walk(path):
        dirnames.sort()
        for fname in sorted(filenames):
            if fname.endswith(".ad"):
                yield os.path.join(dirpath, fname)


def _relpath(path: str) -> str:
    try:
        rel = os.path.relpath(path)
    except ValueError:  # pragma: no cover - cross-drive on win32
        rel = path
    return rel.replace(os.sep, "/")


def build_report(
    paths: Iterable[str] = (), ad_paths: Iterable[str] = ()
) -> Report:
    """Run every analyzer over the given trees; shared by CLI and tests."""
    report = Report()
    for root in paths:
        for path in _iter_py_files(root):
            rel = _relpath(path)
            report.extend(codelint.lint_file(path, rel))
            if "kernels" in rel.split("/"):
                report.extend(kernelcheck.check_file(path, rel))
            report.checked_files += 1
    for root in ad_paths:
        for path in _iter_ad_files(root):
            report.extend(adlint.check_ad_file(path, name=_relpath(path)))
            report.checked_ads += 1
    return report


def main(argv: Optional[List[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="ClassAd/schema analyzer + repo lint (the CI analysis gate)",
    )
    parser.add_argument(
        "paths", nargs="*",
        help="Python files or directories to lint (default: none)",
    )
    parser.add_argument(
        "--ads", action="append", default=[], metavar="PATH",
        help="ClassAd file or directory of *.ad files to validate "
             "(repeatable)",
    )
    parser.add_argument(
        "--json", metavar="FILE", default=None,
        help="also write the versioned JSON report here",
    )
    parser.add_argument(
        "--quiet", action="store_true",
        help="suppress the per-finding listing; print only the summary line",
    )
    args = parser.parse_args(argv)

    if not args.paths and not args.ads:
        parser.error("nothing to analyze: give source paths and/or --ads")

    report = build_report(args.paths, args.ads)

    if args.quiet:
        out = report.render().splitlines()[-1]
    else:
        out = report.render()
    print(out)
    if args.json:
        report.dump_json(args.json)
    return 0 if report.ok else 1


if __name__ == "__main__":  # pragma: no cover
    sys.exit(main())
