"""Static analysis subsystem: ClassAd/schema checking + repo lint.

Two layers over one diagnostic model (:mod:`.diagnostics`):

* :mod:`.adlint` — type/schema analysis of ClassAd ``requirements``/
  ``rank`` expressions against the §3 DIT object classes and the
  attributes GRIS publishes. The broker runs it at select time
  (``DataBroker(ad_check=...)``) and GRIS at policy registration.
* :mod:`.codelint` / :mod:`.kernelcheck` — ``ast``-based repo lint:
  sim-clock determinism, transfer-path robustness, metric cardinality,
  deprecated APIs, and Pallas BlockSpec alignment.

CLI: ``python -m repro.analysis src/repro --ads examples/ads --json out.json``.
"""

from .adlint import (
    check_ad_file,
    check_ad_text,
    check_policy_source,
    check_request_ad,
    check_resource_ad,
)
from .codelint import lint_file, lint_source
from .diagnostics import Diagnostic, Report, Severity, Span
from .kernelcheck import check_file as check_kernel_file
from .kernelcheck import check_source as check_kernel_source
from .runner import build_report, main

__all__ = [
    "Diagnostic",
    "Report",
    "Severity",
    "Span",
    "check_ad_file",
    "check_ad_text",
    "check_policy_source",
    "check_request_ad",
    "check_resource_ad",
    "check_kernel_file",
    "check_kernel_source",
    "lint_file",
    "lint_source",
    "build_report",
    "main",
]
