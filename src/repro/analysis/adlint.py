"""ClassAd static analyzer: type/schema checking for requirements & rank.

The broker matches application request ads against replica capability ads
built from the GRIS storage schema (paper §4–5). A malformed ad — a typo'd
attribute, a ``cis`` string compared as a number, an unsatisfiable
``requirements`` — surfaces at match time only as a silent non-match or a
0.0 rank. This module catches those *before* they distort selection, by
checking expressions against the DIT object classes in
:mod:`repro.core.schema` plus the attributes GRIS actually publishes.

Rules (all diagnostics carry the rule id, severity and location):

  AD101  undefined-attribute      reference to an attribute neither side
                                  defines or publishes (error for request
                                  ads; warning when isUndefined-guarded or
                                  on the resource side, where request
                                  attributes vary by application)
  AD102  type-mismatch            a ``cis`` string attribute compared or
                                  combined as a number (and kin)
  AD103  unknown-function         call to a function the evaluator lacks
                                  (evaluates to ``error`` at match time)
  AD104  unsatisfiable-requirements  requirements can never be True:
                                  trivially false/undefined, or numeric
                                  constraints on one attribute contradict
  AD105  tautological-requirements   requirements is constant True — the
                                  gate admits everything (often intended;
                                  warning)
  AD106  non-discriminating-rank  rank references no resource attribute,
                                  so every candidate ties at the same value
  AD107  missing-requirements     request ad has no requirements at all
  AD108  non-numeric-rank         rank has string/bool/list type — ranks
                                  as 0.0 for every candidate
  ADS01  schema-violation         resource ad violates its DIT object
                                  class (missing MUST attr, wrong syntax)
  ADS02  syntax-error             ad source text does not parse
  ADS03  unknown-object-class     objectClass is not a §3 storage class

Entry points: :func:`check_request_ad`, :func:`check_resource_ad`,
:func:`check_policy_source`, :func:`check_ad_text` (adds line spans), and
:func:`check_ad_file`.
"""

from __future__ import annotations

import re
from typing import Any, Dict, List, Mapping, Optional, Sequence, Set, Tuple

from repro.core.classads import (
    AttrRef,
    BinOp,
    ClassAd,
    ClassAdSyntaxError,
    Expr,
    FuncCall,
    Index,
    ListExpr,
    Literal,
    Select,
    Ternary,
    UnaryOp,
    Undefined,
    Error,
    evaluate,
    parse_classad,
)
from repro.core.schema import OBJECT_CLASSES, SchemaError, validate_entry

from .diagnostics import Diagnostic, Severity, Span

__all__ = [
    "RESOURCE_SCHEMA",
    "REQUEST_SCHEMA",
    "check_request_ad",
    "check_resource_ad",
    "check_policy_source",
    "check_ad_text",
    "check_ad_file",
    "detect_perspective",
]


# ---------------------------------------------------------------------------
# Attribute universes
# ---------------------------------------------------------------------------

_SYNTAX_TYPE = {"cisfloat": "number", "cis": "string"}


def _schema_attrs() -> Dict[str, str]:
    """lowercase attribute → inferred type, over every §3 object class."""
    out: Dict[str, str] = {}
    for oc in OBJECT_CLASSES.values():
        for spec in oc.must + oc.may:
            out[spec.name.lower()] = _SYNTAX_TYPE[spec.syntax]
    return out


#: Everything a replica-side ad can define: the §3 DIT object classes plus
#: the attributes the broker's Search Phase and the resilient layer attach
#: to the flattened GRIS view (endpoint/replica identity, breaker health).
RESOURCE_SCHEMA: Dict[str, str] = {
    **_schema_attrs(),
    "dn": "string",
    "objectclass": "any",  # string or list of strings in flattened views
    "endpoint": "string",
    "name": "string",
    "url": "string",
    "type": "string",
    "replicapath": "string",
    "replicasize": "number",
    "breakeropentosource": "number",
    "requirements": "bool",
    "rank": "number",
}

#: Request-side attributes the shipped request builders publish — what a
#: site ``requirements`` policy can reference through ``other.``.
REQUEST_SCHEMA: Dict[str, str] = {
    "clienturl": "string",
    "requrl": "string",
    "reqdspace": "number",
    "reqdrdbandwidth": "number",
    "reqdwrbandwidth": "number",
    "requirements": "bool",
    "rank": "number",
}

#: Builtin → result type (see classads.BUILTINS; all deterministic).
_FN_RESULT: Dict[str, str] = {}
for _n in ("abs", "floor", "ceiling", "ceil", "round", "pow", "sqrt", "log",
           "exp", "int", "real", "strlen", "size", "time", "min", "max",
           "sum", "avg"):
    _FN_RESULT[_n] = "number"
for _n in ("string", "strcat", "substr", "tolower", "toupper"):
    _FN_RESULT[_n] = "string"
for _n in ("regexp", "member", "isundefined", "iserror", "isboolean",
           "isinteger", "isreal", "isstring", "islist"):
    _FN_RESULT[_n] = "bool"
_FN_RESULT["ifthenelse"] = "branch"

_NUMERIC_ARG_FNS = frozenset(
    {"abs", "floor", "ceiling", "ceil", "round", "pow", "sqrt", "log", "exp"}
)
_STRING_ARG_FNS = frozenset({"strlen", "tolower", "toupper"})
_GUARD_FNS = frozenset({"isundefined", "iserror"})

_CMP = {"==", "!=", "<", "<=", ">", ">="}
_ARITH = {"+", "-", "*", "/", "%"}
_FLIP = {"<": ">", "<=": ">=", ">": "<", ">=": "<=", "==": "==", "!=": "!="}


def _type_of_value(v: Any) -> str:
    if isinstance(v, bool):
        return "bool"
    if isinstance(v, (int, float)):
        return "number"
    if isinstance(v, str):
        return "string"
    if isinstance(v, list):
        return "list"
    if isinstance(v, ClassAd):
        return "ad"
    return "any"  # Undefined / Error sentinels


def _has_refs(expr: Expr) -> bool:
    if isinstance(expr, AttrRef):
        return True
    if isinstance(expr, UnaryOp):
        return _has_refs(expr.operand)
    if isinstance(expr, BinOp):
        return _has_refs(expr.left) or _has_refs(expr.right)
    if isinstance(expr, Ternary):
        return any(_has_refs(e) for e in (expr.cond, expr.then, expr.other))
    if isinstance(expr, FuncCall):
        return any(_has_refs(a) for a in expr.args)
    if isinstance(expr, ListExpr):
        return any(_has_refs(e) for e in expr.items)
    if isinstance(expr, (Select, Index)):
        return True  # conservatively dynamic
    return False


def _fold(expr: Expr) -> Optional[Any]:
    """Constant-fold a ref-free expression; None when not foldable."""
    if _has_refs(expr):
        return None
    try:
        return evaluate(expr, ClassAd(), None, {"now": 0.0})
    except Exception:  # pragma: no cover - evaluator never raises
        return None


def _conjuncts(expr: Expr) -> List[Expr]:
    if isinstance(expr, BinOp) and expr.op == "&&":
        return _conjuncts(expr.left) + _conjuncts(expr.right)
    return [expr]


# ---------------------------------------------------------------------------
# The expression checker
# ---------------------------------------------------------------------------


class _AdChecker:
    """Shared machinery for request- and resource-perspective checks."""

    def __init__(
        self,
        ad: ClassAd,
        *,
        perspective: str,  # 'request' | 'resource'
        name: str,
        self_fallback: Optional[Dict[str, str]] = None,
    ):
        self.ad = ad
        self.perspective = perspective
        self.name = name
        self.other_schema = (
            RESOURCE_SCHEMA if perspective == "request" else REQUEST_SCHEMA
        )
        self.self_fallback = self_fallback or {}
        self.diags: List[Diagnostic] = []
        self.guarded: Set[Tuple[str, str]] = set()
        self._reported_undef: Set[Tuple[str, str]] = set()
        self._self_types: Dict[str, str] = {}
        self._inferring: Set[str] = set()
        self._resource_refs = 0  # refs resolving to the resource side
        self._current_attr: Optional[str] = None

    # ------------------------------------------------------------- helpers
    def _emit(self, rule: str, severity: Severity, message: str,
              source: Optional[str] = None) -> None:
        self.diags.append(
            Diagnostic(rule, severity, message, file=self.name,
                       attr=self._current_attr, source=source)
        )

    def _collect_guards(self, expr: Expr) -> None:
        if isinstance(expr, FuncCall) and expr.name.lower() in _GUARD_FNS:
            for a in expr.args:
                if isinstance(a, AttrRef):
                    self.guarded.add((a.scope or "", a.name.lower()))
            return
        for child in _children(expr):
            self._collect_guards(child)

    def _self_type(self, key: str) -> Optional[str]:
        """Type of one of the ad's own attributes (lazy, cycle-guarded)."""
        if key in self._self_types:
            return self._self_types[key]
        expr = self.ad.lookup_expr(key)
        if expr is None:
            return self.self_fallback.get(key)
        if key in self._inferring:
            return "any"
        self._inferring.add(key)
        try:
            t = self.infer(expr)
        finally:
            self._inferring.discard(key)
        self._self_types[key] = t
        return t

    def _undef(self, ref: AttrRef, side: str) -> None:
        key = (ref.scope or "", ref.name.lower())
        if key in self._reported_undef:
            return
        self._reported_undef.add(key)
        guarded = key in self.guarded or ("", key[1]) in self.guarded
        if self.perspective == "resource" or guarded:
            sev = Severity.WARNING
        else:
            sev = Severity.ERROR
        extra = " (isUndefined-guarded)" if guarded else ""
        self._emit(
            "AD101",
            sev,
            f"reference to undefined attribute {ref!r}: not in the {side} "
            f"schema nor defined by this ad{extra}",
            source=repr(ref),
        )

    # ------------------------------------------------------------ inference
    def infer(self, expr: Expr) -> str:
        """Infer the expression's type, emitting diagnostics on the way."""
        if isinstance(expr, Literal):
            return _type_of_value(expr.value)
        if isinstance(expr, AttrRef):
            return self._infer_ref(expr)
        if isinstance(expr, UnaryOp):
            t = self.infer(expr.operand)
            if expr.op == "!" and t in ("number", "string"):
                self._emit("AD102", Severity.ERROR,
                           f"logical ! applied to a {t} operand", repr(expr))
            elif expr.op in ("-", "+") and t in ("string", "bool"):
                self._emit("AD102", Severity.ERROR,
                           f"arithmetic {expr.op} applied to a {t} operand",
                           repr(expr))
            return "bool" if expr.op == "!" else "number"
        if isinstance(expr, BinOp):
            return self._infer_binop(expr)
        if isinstance(expr, Ternary):
            ct = self.infer(expr.cond)
            if ct in ("number", "string"):
                self._emit("AD102", Severity.ERROR,
                           f"ternary condition has {ct} type", repr(expr.cond))
            return _union(self.infer(expr.then), self.infer(expr.other))
        if isinstance(expr, FuncCall):
            return self._infer_call(expr)
        if isinstance(expr, ListExpr):
            for item in expr.items:
                self.infer(item)
            return "list"
        if isinstance(expr, Select):
            self.infer(expr.base)
            return "any"
        if isinstance(expr, Index):
            self.infer(expr.base)
            self.infer(expr.index)
            return "any"
        return "any"  # pragma: no cover - all node kinds handled

    def _infer_ref(self, ref: AttrRef) -> str:
        key = ref.name.lower()
        if ref.scope == "other":
            t = self.other_schema.get(key)
            if t is None:
                other_side = "resource" if self.perspective == "request" else "request"
                self._undef(ref, other_side)
                return "any"
            if self.perspective == "request":
                self._resource_refs += 1
            return t
        # my./unqualified: self first, then (unqualified only) the far side
        t = self._self_type(key)
        if t is not None:
            return t
        if ref.scope is None:
            t = self.other_schema.get(key)
            if t is not None:
                if self.perspective == "request":
                    self._resource_refs += 1
                return t
        self._undef(ref, "request" if self.perspective == "request" else "resource")
        return "any"

    def _infer_binop(self, expr: BinOp) -> str:
        op = expr.op
        if op in ("&&", "||"):
            for side in (expr.left, expr.right):
                t = self.infer(side)
                if t in ("number", "string"):
                    self._emit(
                        "AD102", Severity.ERROR,
                        f"non-boolean {t} operand to {op} "
                        "(evaluates to error at match time)",
                        repr(side),
                    )
            return "bool"
        if op in ("=?=", "=!="):
            self.infer(expr.left)
            self.infer(expr.right)
            return "bool"
        lt, rt = self.infer(expr.left), self.infer(expr.right)
        if op in _CMP:
            if {lt, rt} == {"number", "string"}:
                sattr = expr.left if lt == "string" else expr.right
                self._emit(
                    "AD102", Severity.ERROR,
                    f"{sattr!r} is a cis string but is compared with a "
                    "number (always evaluates to error)",
                    repr(expr),
                )
            elif "bool" in (lt, rt) and op not in ("==", "!=") and \
                    {lt, rt} <= {"bool", "number", "string"} and lt != rt:
                self._emit("AD102", Severity.ERROR,
                           f"ordered comparison {op} between {lt} and {rt}",
                           repr(expr))
            return "bool"
        if op in _ARITH:
            if op == "+" and lt == "string" and rt == "string":
                return "string"
            for t, side in ((lt, expr.left), (rt, expr.right)):
                if t in ("string", "bool", "list", "ad"):
                    self._emit(
                        "AD102", Severity.ERROR,
                        f"arithmetic {op} on a {t} operand "
                        f"({side!r} is not numeric)",
                        repr(expr),
                    )
            return "number"
        return "any"  # pragma: no cover - parser emits only known ops

    def _infer_call(self, call: FuncCall) -> str:
        fname = call.name.lower()
        if fname in _GUARD_FNS:
            # guard tests are total; their args are deliberately optional
            return "bool"
        result = _FN_RESULT.get(fname)
        if result is None:
            self._emit(
                "AD103", Severity.ERROR,
                f"call to unknown function {call.name!r} "
                "(evaluates to error at match time)",
                repr(call),
            )
            for a in call.args:
                self.infer(a)
            return "any"
        arg_types = [self.infer(a) for a in call.args]
        if fname in _NUMERIC_ARG_FNS:
            for t, a in zip(arg_types, call.args):
                if t in ("string", "bool", "list", "ad"):
                    self._emit("AD102", Severity.ERROR,
                               f"{fname}() expects numeric arguments, got {t}",
                               repr(a))
        elif fname in _STRING_ARG_FNS:
            for t, a in zip(arg_types, call.args):
                if t in ("number", "bool", "list", "ad"):
                    self._emit("AD102", Severity.ERROR,
                               f"{fname}() expects a string argument, got {t}",
                               repr(a))
        if result == "branch":
            return _union(arg_types[1], arg_types[2]) if len(arg_types) == 3 else "any"
        return result

    # --------------------------------------------- requirements-level rules
    def check_requirements(self) -> None:
        self._current_attr = "requirements"
        expr = self.ad.lookup_expr("requirements")
        if expr is None:
            if self.perspective == "request":
                self._emit(
                    "AD107", Severity.WARNING,
                    "request has no requirements expression; every replica "
                    "matches unconditionally",
                )
            self._current_attr = None
            return
        t = self.infer(expr)
        if t in ("number", "string"):
            self._emit("AD102", Severity.ERROR,
                       f"requirements has {t} type; a match needs a boolean")
        folded = _fold(expr)
        if folded is True:
            self._emit(
                "AD105", Severity.WARNING,
                "requirements is constantly True — the gate admits every "
                "candidate",
                source=repr(expr),
            )
        elif folded is False:
            self._emit("AD104", Severity.ERROR,
                       "requirements is constantly False — nothing can ever "
                       "match", source=repr(expr))
        elif folded is Undefined or folded is Error:
            self._emit("AD104", Severity.ERROR,
                       f"requirements constantly evaluates to {folded!r} — "
                       "a match treats that as a failed gate",
                       source=repr(expr))
        else:
            reason = _unsat_reason(expr)
            if reason is not None:
                self._emit("AD104", Severity.ERROR,
                           f"requirements is unsatisfiable: {reason}",
                           source=repr(expr))
        self._current_attr = None

    def check_rank(self) -> None:
        self._current_attr = "rank"
        expr = self.ad.lookup_expr("rank")
        if expr is None:
            self._current_attr = None
            return
        before = self._resource_refs
        t = self.infer(expr)
        if t in ("string", "bool", "list", "ad"):
            self._emit(
                "AD108", Severity.ERROR,
                f"rank has {t} type — every candidate ranks 0.0",
                source=repr(expr),
            )
        elif self.perspective == "request" and self._resource_refs == before:
            self._emit(
                "AD106", Severity.WARNING,
                "rank references no resource attribute — every candidate "
                "ties at the same value (selection falls to the name "
                "tiebreak)",
                source=repr(expr),
            )
        self._current_attr = None

    # -------------------------------------------------------------- driver
    def run(self) -> List[Diagnostic]:
        for key, expr in self.ad.items():
            self._collect_guards(expr)
        self.check_requirements()
        self.check_rank()
        # reference/type-check the remaining attributes too (a typo in a
        # helper attribute propagates Undefined into whoever reads it)
        for key, expr in self.ad.items():
            if key.lower() in ("requirements", "rank"):
                continue
            self._current_attr = key
            self.infer(expr)
            self._current_attr = None
        return self.diags


def _union(a: str, b: str) -> str:
    return a if a == b else "any"


def _children(expr: Expr) -> Sequence[Expr]:
    if isinstance(expr, UnaryOp):
        return (expr.operand,)
    if isinstance(expr, BinOp):
        return (expr.left, expr.right)
    if isinstance(expr, Ternary):
        return (expr.cond, expr.then, expr.other)
    if isinstance(expr, FuncCall):
        return expr.args
    if isinstance(expr, ListExpr):
        return expr.items
    if isinstance(expr, Select):
        return (expr.base,)
    if isinstance(expr, Index):
        return (expr.base, expr.index)
    return ()


# ---------------------------------------------------------------------------
# Unsatisfiability: interval analysis over top-level conjuncts
# ---------------------------------------------------------------------------


def _unsat_reason(expr: Expr) -> Optional[str]:
    """A human-readable reason when the conjunction cannot hold, else None.

    Handles the decidable fragment that actually appears in ads: numeric
    comparisons of one attribute against literals, joined by ``&&``. Two
    conjuncts like ``x > 10G && x < 1G`` intersect to an empty interval.
    """
    bounds: Dict[Tuple[str, str], Dict[str, Any]] = {}
    for conj in _conjuncts(expr):
        folded = _fold(conj)
        if folded is False:
            return f"conjunct {conj!r} is constantly False"
        if folded is Undefined or folded is Error:
            return f"conjunct {conj!r} constantly evaluates to {folded!r}"
        c = _as_constraint(conj)
        if c is None:
            continue
        key, op, val = c
        b = bounds.setdefault(
            key, {"lo": float("-inf"), "lo_strict": False,
                  "hi": float("inf"), "hi_strict": False, "eq": None}
        )
        if op in (">", ">="):
            strict = op == ">"
            if val > b["lo"] or (val == b["lo"] and strict):
                b["lo"], b["lo_strict"] = val, strict
        elif op in ("<", "<="):
            strict = op == "<"
            if val < b["hi"] or (val == b["hi"] and strict):
                b["hi"], b["hi_strict"] = val, strict
        elif op == "==":
            if b["eq"] is not None and b["eq"] != val:
                return (f"{key[1]} must equal both {b['eq']:g} and {val:g}")
            b["eq"] = val
    for (scope, name), b in bounds.items():
        lo, hi = b["lo"], b["hi"]
        if lo > hi or (lo == hi and (b["lo_strict"] or b["hi_strict"])):
            ref = f"{scope}.{name}" if scope else name
            return (
                f"{ref} is constrained to the empty interval "
                f"{'(' if b['lo_strict'] else '['}{lo:g}, {hi:g}"
                f"{')' if b['hi_strict'] else ']'}"
            )
        if b["eq"] is not None:
            v = b["eq"]
            if (v < lo or (v == lo and b["lo_strict"])
                    or v > hi or (v == hi and b["hi_strict"])):
                ref = f"{scope}.{name}" if scope else name
                return f"{ref} == {v:g} contradicts its interval bounds"
    return None


def _as_constraint(conj: Expr) -> Optional[Tuple[Tuple[str, str], str, float]]:
    """``ref op number-literal`` (either order) → ((scope, name), op, val)."""
    if not (isinstance(conj, BinOp) and conj.op in _CMP and conj.op != "!="):
        return None
    left, right, op = conj.left, conj.right, conj.op
    if isinstance(left, Literal) and isinstance(right, AttrRef):
        left, right, op = right, left, _FLIP[op]
    if not (isinstance(left, AttrRef) and isinstance(right, Literal)):
        return None
    v = right.value
    if isinstance(v, bool) or not isinstance(v, (int, float)):
        return None
    return ((left.scope or "", left.name.lower()), op, float(v))


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------


def check_request_ad(ad: ClassAd, *, name: str = "<request>") -> List[Diagnostic]:
    """Analyze an application request ad against the published resource
    schema. ``other.`` references resolve to the §3 DIT attributes (plus
    the broker-attached extras); unqualified references resolve to the
    ad's own attributes first, then the resource side — Condor's lookup
    order inside a MatchClassAd."""
    return _AdChecker(ad, perspective="request", name=name).run()


def check_resource_ad(ad: ClassAd, *, name: str = "<resource>") -> List[Diagnostic]:
    """Analyze a replica capability ad: DIT schema validation of its
    literal attributes plus expression analysis of its site policy
    (``requirements``) from the resource perspective, where ``other.``
    references the request."""
    diags = _schema_check(ad, name=name)
    checker = _AdChecker(
        ad, perspective="resource", name=name, self_fallback=RESOURCE_SCHEMA
    )
    diags.extend(checker.run())
    return diags


def check_policy_source(source: str, *, name: str = "<policy>") -> List[Diagnostic]:
    """Analyze a site ``requirements`` policy string (what an admin puts
    in the GRIS static configuration) without a full ad around it."""
    ad = ClassAd()
    try:
        ad.set_expr("requirements", source)
    except ClassAdSyntaxError as e:
        return [Diagnostic("ADS02", Severity.ERROR,
                           f"policy does not parse: {e}", file=name,
                           attr="requirements", source=source)]
    checker = _AdChecker(
        ad, perspective="resource", name=name, self_fallback=RESOURCE_SCHEMA
    )
    checker.run()
    return checker.diags


def _schema_check(ad: ClassAd, *, name: str) -> List[Diagnostic]:
    """Validate the ad's literal attributes against its DIT object class."""
    entry: Dict[str, Any] = {}
    for key, expr in ad.items():
        if isinstance(expr, Literal) and not isinstance(expr.value, ClassAd):
            entry[key] = expr.value
    oc_val = entry.get("objectClass", entry.get("objectclass"))
    if oc_val is None:
        for key in entry:
            if key.lower() == "objectclass":
                oc_val = entry[key]
                break
    diags: List[Diagnostic] = []
    if oc_val is None:
        return diags  # bare capability ad without a declared class: skip
    oc_names = oc_val if isinstance(oc_val, list) else [oc_val]
    for oc_name in oc_names:
        oc = OBJECT_CLASSES.get(str(oc_name).lower())
        if oc is None:
            diags.append(Diagnostic(
                "ADS03", Severity.WARNING,
                f"objectClass {oc_name!r} is not a §3 storage class",
                file=name, attr="objectClass"))
            continue
        try:
            validate_entry(entry, oc)
        except SchemaError as e:
            diags.append(Diagnostic(
                "ADS01", Severity.ERROR,
                f"schema violation for {oc.name}: {e}", file=name))
    return diags


#: attributes whose presence marks a resource-side (capability) ad
_RESOURCE_MARKERS = frozenset(
    {"objectclass", "totalspace", "availablespace", "mountpoint",
     "disktransferrate", "maxrdbandwidth", "avgrdbandwidth"}
)


def detect_perspective(ad: ClassAd) -> str:
    """'resource' when the ad carries storage-schema attributes, else
    'request'."""
    for key in ad.keys():
        if key.lower() in _RESOURCE_MARKERS:
            return "resource"
    return "request"


_ATTR_LINE_RE = re.compile(r"^\s*([A-Za-z_][A-Za-z0-9_]*)\s*=")


def check_ad_text(
    text: str, *, name: str = "<ad>", perspective: Optional[str] = None
) -> List[Diagnostic]:
    """Analyze ad source text; diagnostics gain line spans located at the
    offending attribute's assignment."""
    try:
        ad = parse_classad(text)
    except ClassAdSyntaxError as e:
        line = text.count("\n", 0, getattr(e, "pos", 0)) + 1
        return [Diagnostic("ADS02", Severity.ERROR,
                           f"ad does not parse: {e}", file=name,
                           span=Span(line, 1))]
    if perspective is None:
        perspective = detect_perspective(ad)
    if perspective == "resource":
        diags = check_resource_ad(ad, name=name)
    else:
        diags = check_request_ad(ad, name=name)
    # locate each flagged attribute's assignment line for the span
    attr_lines: Dict[str, int] = {}
    for i, line_text in enumerate(text.splitlines(), start=1):
        m = _ATTR_LINE_RE.match(line_text)
        if m:
            attr_lines.setdefault(m.group(1).lower(), i)
    for d in diags:
        if d.span is None and d.attr and d.attr.lower() in attr_lines:
            d.span = Span(attr_lines[d.attr.lower()], 1)
    return diags


def check_ad_file(path: str, *, name: Optional[str] = None) -> List[Diagnostic]:
    with open(path) as f:
        text = f.read()
    return check_ad_text(text, name=name or path)
