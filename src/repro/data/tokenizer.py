"""Byte-level tokenizer stub (deterministic, dependency-free).

Real deployments plug a BPE here; the framework only requires the
encode/decode contract. Tokens are bytes offset by the special-token
block, so round-tripping is exact and any ``vocab_size ≥ 260`` works.
"""

from __future__ import annotations

from typing import List, Sequence

import numpy as np

__all__ = ["ByteTokenizer"]


class ByteTokenizer:
    PAD, BOS, EOS, SEP = 0, 1, 2, 3
    OFFSET = 4

    def __init__(self, vocab_size: int = 50257):
        if vocab_size < 256 + self.OFFSET:
            raise ValueError("vocab_size too small for byte tokenizer")
        self.vocab_size = vocab_size

    def encode(self, text: str, *, bos: bool = True, eos: bool = True) -> List[int]:
        ids = [b + self.OFFSET for b in text.encode("utf-8")]
        if bos:
            ids = [self.BOS] + ids
        if eos:
            ids = ids + [self.EOS]
        return ids

    def decode(self, ids: Sequence[int]) -> str:
        bs = bytes(i - self.OFFSET for i in ids if i >= self.OFFSET and i - self.OFFSET < 256)
        return bs.decode("utf-8", errors="replace")

    def encode_array(self, text: str, **kw) -> np.ndarray:
        return np.asarray(self.encode(text, **kw), dtype=np.int32)
