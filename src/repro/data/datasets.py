"""Synthetic sharded datasets + grid materialization.

A dataset is a collection of *shards*; each shard is a deterministic token
stream (seeded permuted-congruential sequence with document structure, so
a language model has actual statistical signal to learn: repeated n-gram
"phrases" within documents). Shards serialize to bytes, replicate onto
storage endpoints through the grid (replica catalog entries under the
``dataset/<name>`` collection), and the pipeline fetches them back through
each host's broker — the paper's Search/Match/Access loop on every fetch.
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.storage.endpoint import DataGrid

__all__ = ["ShardManifest", "SyntheticCorpus", "materialize_on_grid"]


@dataclass(frozen=True)
class ShardManifest:
    name: str
    n_shards: int
    tokens_per_shard: int
    vocab_size: int
    seed: int
    dtype: str = "int32"

    def lfn(self, shard: int) -> str:
        return f"dataset/{self.name}/shard-{shard:05d}"

    def lfns(self) -> List[str]:
        return [self.lfn(i) for i in range(self.n_shards)]


class SyntheticCorpus:
    """Deterministic token shards with learnable structure."""

    def __init__(self, manifest: ShardManifest):
        self.manifest = manifest

    def _rng(self, shard: int) -> np.random.Generator:
        h = hashlib.sha256(f"{self.manifest.seed}|{self.manifest.name}|{shard}".encode())
        return np.random.default_rng(int.from_bytes(h.digest()[:8], "big"))

    def shard_tokens(self, shard: int) -> np.ndarray:
        """Documents of geometric length made of repeated 'phrases' drawn
        from a shard-local phrase book — compressible, learnable."""
        m = self.manifest
        rng = self._rng(shard)
        v = m.vocab_size
        phrase_book = [
            rng.integers(4, v, size=rng.integers(3, 9)).astype(np.int32)
            for _ in range(64)
        ]
        out = np.empty(m.tokens_per_shard, dtype=np.int32)
        i = 0
        while i < m.tokens_per_shard:
            out[i] = 1  # BOS
            i += 1
            doc_len = int(rng.geometric(1.0 / 256))
            end = min(i + doc_len, m.tokens_per_shard)
            while i < end:
                ph = phrase_book[int(rng.integers(0, 64))]
                take = min(len(ph), end - i)
                out[i : i + take] = ph[:take]
                i += take
            if i < m.tokens_per_shard:
                out[i] = 2  # EOS
                i += 1
        return out

    def shard_bytes(self, shard: int) -> bytes:
        return self.shard_tokens(shard).astype(np.int32).tobytes()

    @staticmethod
    def decode_bytes(data: bytes) -> np.ndarray:
        return np.frombuffer(data, dtype=np.int32).copy()


def materialize_on_grid(
    corpus: SyntheticCorpus,
    grid: DataGrid,
    *,
    replication: int = 2,
    endpoints: Optional[Sequence[str]] = None,
) -> List[str]:
    """Write every shard to ``replication`` endpoints (round-robin spread)
    and register the replicas + the dataset collection in the catalog."""
    m = corpus.manifest
    eps = list(endpoints or sorted(grid.endpoints))
    if len(eps) < replication:
        raise ValueError(f"need ≥{replication} endpoints, have {len(eps)}")
    lfns = []
    for s in range(m.n_shards):
        data = corpus.shard_bytes(s)
        lfn = m.lfn(s)
        targets = [eps[(s + r) % len(eps)] for r in range(replication)]
        grid.replicate(lfn, data, targets)
        lfns.append(lfn)
    grid.catalog.create_collection(f"dataset/{m.name}", lfns)
    return lfns
