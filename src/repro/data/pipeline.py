"""The broker-backed input pipeline — the paper's technique on the hot path.

Each training host runs a :class:`DataPipeline` around its own
:class:`~repro.core.broker.DataBroker` (decentralized, §5.1.1): every
shard fetch runs Search → Match → Access against live GRIS state, so
replica choice adapts as bandwidth history accumulates, endpoints die
(failover) or degrade (mid-transfer straggler re-selection).

Determinism: the shard schedule is a pure function of
(epoch, host_index, n_hosts) — ``parallel.elastic.host_shard_assignment``
— so after an elastic re-mesh every host recomputes its slice with no
coordinator. Fetched shards are LRU-cached; a prefetch depth of 1 hides
transfer time behind the previous batch's step in a real deployment (here
it keeps accounting: ``stats['prefetch_hits']``).
"""

from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

from repro.core.broker import DataBroker, default_read_request
from repro.parallel.elastic import host_shard_assignment
from repro.storage.endpoint import DataGrid

from .datasets import ShardManifest, SyntheticCorpus

__all__ = ["BatchSpec", "DataPipeline"]


@dataclass(frozen=True)
class BatchSpec:
    batch: int  # sequences per batch on this host
    seq_len: int

    @property
    def tokens_per_batch(self) -> int:
        return self.batch * (self.seq_len + 1)  # +1 for the shifted labels


class DataPipeline:
    def __init__(
        self,
        host_url: str,
        host_index: int,
        n_hosts: int,
        grid: DataGrid,
        manifest: ShardManifest,
        spec: BatchSpec,
        *,
        broker: Optional[DataBroker] = None,
        cache_shards: int = 4,
        min_bandwidth: float = 0.0,
        resilient: bool = True,
    ):
        self.host_url = host_url
        self.host_index = host_index
        self.n_hosts = n_hosts
        self.grid = grid
        self.manifest = manifest
        self.spec = spec
        self.broker = broker or grid.broker_for(host_url)
        # shard fetches go through the resilient access layer by default:
        # striped over the top-ranked replicas, hedged when a source runs
        # below prediction, breaker-gated after repeated failures
        self.resilient = resilient
        if resilient:
            self.transfer = grid.resilient_transfer_service(self.broker)
        else:
            self.transfer = grid.transfer_service(metrics=self.broker.metrics)
        self.min_bandwidth = min_bandwidth
        self._cache: "OrderedDict[int, np.ndarray]" = OrderedDict()
        self._cache_max = cache_shards
        self.stats = {
            "fetches": 0, "cache_hits": 0, "bytes": 0, "fetch_seconds": 0.0,
            "stripes": 0, "hedges": 0, "hedge_wins": 0, "retries": 0,
            "failovers": 0,
        }

    # -- shard access -----------------------------------------------------
    def _tokens_for_shard(self, shard: int) -> np.ndarray:
        if shard in self._cache:
            self._cache.move_to_end(shard)
            self.stats["cache_hits"] += 1
            return self._cache[shard]
        req = default_read_request(self.host_url, min_bandwidth=self.min_bandwidth)
        if self.resilient:
            out = self.transfer.fetch(self.manifest.lfn(shard), req)
            for key in ("stripes", "hedges", "hedge_wins", "retries", "failovers"):
                self.stats[key] += getattr(out, key)
        else:
            out = self.broker.fetch(self.manifest.lfn(shard), self.transfer, req)
        tokens = SyntheticCorpus.decode_bytes(out.payload)
        self.stats["fetches"] += 1
        self.stats["bytes"] += out.nbytes
        self.stats["fetch_seconds"] += out.seconds
        self._cache[shard] = tokens
        while len(self._cache) > self._cache_max:
            self._cache.popitem(last=False)
        return tokens

    def my_shards(self, epoch: int) -> List[int]:
        return host_shard_assignment(
            self.manifest.n_shards, self.n_hosts, self.host_index, epoch=epoch
        )

    # -- batching -------------------------------------------------------------
    def batches(self, epoch: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        """Yield {'tokens': [B, S], 'labels': [B, S]} until this host's
        shard slice for the epoch is exhausted."""
        need = self.spec.tokens_per_batch
        buf = np.empty(0, dtype=np.int32)
        for shard in self.my_shards(epoch):
            buf = np.concatenate([buf, self._tokens_for_shard(shard)])
            while len(buf) >= need:
                chunk, buf = buf[:need], buf[need:]
                seqs = chunk.reshape(self.spec.batch, self.spec.seq_len + 1)
                yield {
                    "tokens": np.ascontiguousarray(seqs[:, :-1]) % self.manifest.vocab_size,
                    "labels": np.ascontiguousarray(seqs[:, 1:]) % self.manifest.vocab_size,
                }

    def steps_per_epoch(self, epoch: int = 0) -> int:
        total = len(self.my_shards(epoch)) * self.manifest.tokens_per_shard
        return total // self.spec.tokens_per_batch
