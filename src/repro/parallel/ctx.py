"""Activation-sharding context: explicit constraints inside model code.

The SPMD partitioner sometimes loses the batch sharding of the residual
stream across scan/reshape boundaries and silently *replicates*
activations over the data axes (measured: a [52, 32, 4096, ·] saved
residual stack on granite-20b — 16× the memory it should take). Model
code is policy-agnostic, so the launcher installs a context naming the
data-parallel axes, and the model's hot loops call
:func:`constrain_batch` on the residual carry — a no-op when no context
is installed (unit tests, single-device runs).
"""

from __future__ import annotations

import contextlib
from typing import List, Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

_STATE = {"dp_axes": None}


def set_activation_dp_axes(axes: Optional[Tuple[str, ...]]) -> None:
    _STATE["dp_axes"] = axes


@contextlib.contextmanager
def activation_sharding(axes: Optional[Tuple[str, ...]]):
    prev = _STATE["dp_axes"]
    _STATE["dp_axes"] = axes
    try:
        yield
    finally:
        _STATE["dp_axes"] = prev


def constrain_batch(x, batch_dim: int = 0):
    """Pin ``x``'s batch dim to the data-parallel axes (if context set)."""
    axes = _STATE["dp_axes"]
    if axes is None or x.ndim <= batch_dim or x.shape[batch_dim] == 1:
        return x
    spec: List = [None] * x.ndim
    spec[batch_dim] = axes if len(axes) > 1 else axes[0]
    return jax.lax.with_sharding_constraint(x, P(*spec))


def degather_weight(w, model_dim: int = -1):
    """Pin a weight to model-axis-only sharding (drop any zero3 'data'
    sharding) — used to hoist per-loop-iteration all-gathers of a
    loop-invariant weight out of a scan (the chunked-CE unembedding was
    re-gathered and its gradient all-reduced per chunk: 216 GiB/step on
    granite-20b — §Perf iteration). No-op outside a launcher context."""
    axes = _STATE["dp_axes"]
    if axes is None:
        return w
    spec: List = [None] * w.ndim
    d = w.shape[model_dim]
    # assume a 16-wide model axis only when divisible; else leave replicated
    spec[model_dim] = "model"
    try:
        return jax.lax.with_sharding_constraint(w, P(*spec))
    except Exception:
        return w
