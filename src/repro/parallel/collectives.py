"""Distributed-optimization collectives: gradient compression + helpers.

Int8 blockwise gradient compression with **error feedback** (1-bit-Adam /
PowerSGD lineage): each data-parallel worker quantizes its local gradient
contribution to int8 with per-block scales before the all-reduce, keeps
the quantization residual locally, and adds it back into the next step's
gradient. Error feedback makes the compression *unbiased over time* —
SGD/Adam converge to the same neighbourhood (test: tests/test_collectives.py
trains a quadratic + a tiny LM with/without compression).

Two integration points:

  * ``compress_tree`` / ``decompress_tree`` + ``ErrorFeedbackState`` — used
    inside the pjit train step around the gradient (the all-reduce then
    moves int8, 4× fewer bytes over DCN on the ``pod`` axis),
  * ``ring_allreduce`` — an explicit ``ppermute`` reduce-scatter/all-gather
    ring for ``shard_map`` deployments; the dry-run uses it to demonstrate
    the collective schedule is expressible without torch/NCCL semantics.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "quantize_int8",
    "dequantize_int8",
    "ErrorFeedbackState",
    "init_error_feedback",
    "compress_with_feedback",
    "ring_allreduce",
    "global_norm",
]


def quantize_int8(x: jnp.ndarray, block: int = 256) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Blockwise symmetric int8 quantization. Returns (q, scales)."""
    flat = x.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(
    q: jnp.ndarray, scale: jnp.ndarray, shape: Tuple[int, ...]
) -> jnp.ndarray:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


class ErrorFeedbackState(NamedTuple):
    residual: Any  # pytree matching the gradient


def init_error_feedback(tree: Any) -> ErrorFeedbackState:
    return ErrorFeedbackState(
        jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), tree)
    )


def compress_with_feedback(
    grads: Any, ef: ErrorFeedbackState, *, block: int = 256
) -> Tuple[Any, ErrorFeedbackState, Dict[str, jnp.ndarray]]:
    """grad' = Q(grad + residual); residual' = (grad + residual) - grad'.

    Returns the *dequantized* compressed gradient (what the all-reduce
    moves is the int8 payload; numerically the downstream optimizer sees
    exactly this tree), the new residual state, and compression metrics.
    """

    def one(g, r):
        corrected = g.astype(jnp.float32) + r
        q, s = quantize_int8(corrected, block)
        deq = dequantize_int8(q, s, corrected.shape)
        return deq, corrected - deq

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(ef.residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    new_g = tdef.unflatten([o[0] for o in outs])
    new_r = tdef.unflatten([o[1] for o in outs])
    err = sum(jnp.sum(jnp.abs(o[1])) for o in outs)
    total = sum(jnp.sum(jnp.abs(g)) for g in flat_g) + 1e-12
    return new_g, ErrorFeedbackState(new_r), {"compression_rel_err": err / total}


# ---------------------------------------------------------------------------
# explicit ring all-reduce (shard_map building block)
# ---------------------------------------------------------------------------


def ring_allreduce(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """Reduce-scatter + all-gather ring over ``axis_name`` using ppermute.

    Bandwidth-optimal (2·(n-1)/n · |x| per link), the schedule every
    production all-reduce uses; written out so the collective pattern is
    explicit in the HLO (the dry-run counts its collective-permute bytes).
    Requires leading dim divisible by the axis size.
    """
    n = jax.lax.psum(1, axis_name)
    idx = jax.lax.axis_index(axis_name)
    chunks = jnp.stack(jnp.split(x, n, axis=0))  # [n, ...]

    perm_fwd = [(i, (i + 1) % n) for i in range(n)]

    # reduce-scatter: after n-1 steps, chunk (idx+1) holds the full sum
    def rs_body(i, acc):
        # send the chunk we just accumulated to the right neighbour
        send = jax.lax.dynamic_index_in_dim(acc, (idx - i) % n, 0, keepdims=False)
        recv = jax.lax.ppermute(send, axis_name, perm_fwd)
        j = (idx - i - 1) % n
        old = jax.lax.dynamic_index_in_dim(acc, j, 0, keepdims=False)
        return jax.lax.dynamic_update_index_in_dim(acc, old + recv, j, 0)

    acc = jax.lax.fori_loop(0, n - 1, rs_body, chunks)

    # all-gather: circulate the finished chunks
    def ag_body(i, acc):
        j = (idx - i + 1) % n
        send = jax.lax.dynamic_index_in_dim(acc, j, 0, keepdims=False)
        recv = jax.lax.ppermute(send, axis_name, perm_fwd)
        return jax.lax.dynamic_update_index_in_dim(acc, recv, (j - 1) % n, 0)

    acc = jax.lax.fori_loop(0, n - 1, ag_body, acc)
    return acc.reshape(x.shape)


def global_norm(tree: Any) -> jnp.ndarray:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )
