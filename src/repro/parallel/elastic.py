"""Elastic scaling: re-mesh on membership change + checkpoint resharding.

When hosts die (or arrive), a 1000-node job must resume on the surviving
set without a full restart from slot 0. The flow GridSelect implements:

  1. the straggler/failure monitor (train/straggler.py) or the launcher
     declares a membership change,
  2. :func:`plan_mesh` picks the largest valid mesh shape for the
     surviving chip count (data axis shrinks in powers of two; the model
     axis is preserved — TP degree is an architectural choice),
  3. the checkpoint manager restores the latest step into the new mesh:
     checkpoints store *logically complete* arrays (chunked, replicated
     across storage endpoints via the broker), so restoring into any mesh
     is just applying the new ShardingPolicy's specs — no reshard pass,
  4. the data pipeline recomputes its shard→host assignment from the new
     mesh (deterministic in (step, host) — no coordinator).

``plan_mesh`` + ``revalidate_batch`` are pure functions, unit-tested;
the end-to-end save→shrink→restore path is tests/test_elastic.py.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

__all__ = ["MeshPlan", "plan_mesh", "revalidate_batch", "host_shard_assignment"]


@dataclass(frozen=True)
class MeshPlan:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]
    chips: int
    dropped_chips: int
    per_device_batch_scale: float  # how much per-device batch grows


def _pow2_floor(n: int) -> int:
    return 1 << (n.bit_length() - 1) if n > 0 else 0


def plan_mesh(
    alive_chips: int,
    *,
    model_parallel: int,
    prefer_pods: bool = True,
    pod_size: int = 256,
) -> MeshPlan:
    """Largest usable mesh for ``alive_chips``: keep TP = ``model_parallel``,
    shrink the data axis to the largest power of two that fits, and use a
    pod axis when at least two full pods survive."""
    if alive_chips < model_parallel:
        raise ValueError(
            f"cannot keep model_parallel={model_parallel} with {alive_chips} chips"
        )
    usable_data = _pow2_floor(alive_chips // model_parallel)
    chips = usable_data * model_parallel
    if prefer_pods and chips >= 2 * pod_size and chips % pod_size == 0:
        pods = _pow2_floor(chips // pod_size)
        chips = pods * pod_size
        data = chips // (pods * model_parallel)
        shape: Tuple[int, ...] = (pods, data, model_parallel)
        axes: Tuple[str, ...] = ("pod", "data", "model")
    else:
        shape = (usable_data, model_parallel)
        axes = ("data", "model")
        chips = usable_data * model_parallel
    return MeshPlan(
        shape=shape,
        axes=axes,
        chips=chips,
        dropped_chips=alive_chips - chips,
        per_device_batch_scale=1.0,
    )


def revalidate_batch(global_batch: int, plan: MeshPlan) -> Tuple[int, int]:
    """Keep the global batch (optimization semantics!) and recompute the
    per-data-shard microbatch. Returns (global_batch, per_shard)."""
    data = 1
    for s, a in zip(plan.shape, plan.axes):
        if a in ("pod", "data"):
            data *= s
    if global_batch % data != 0:
        # shrink to the largest multiple that divides — logged by caller
        global_batch = (global_batch // data) * data
        if global_batch == 0:
            raise ValueError("global batch smaller than data-parallel degree")
    return global_batch, global_batch // data


def host_shard_assignment(
    n_shards: int, n_hosts: int, host_index: int, *, epoch: int = 0
) -> List[int]:
    """Deterministic shard→host assignment (round-robin rotated by epoch).
    Every host computes the same answer with no coordinator — the same
    decentralization argument the paper makes for broker placement."""
    if not 0 <= host_index < n_hosts:
        raise ValueError((host_index, n_hosts))
    return [
        s
        for s in range(n_shards)
        if (s + epoch) % n_hosts == host_index
    ]
