"""Divisibility-aware sharding policy: every leaf gets a PartitionSpec.

The policy maps (tree path, logical shape) → PartitionSpec for parameters,
optimizer state, activations and caches, on meshes with axes drawn from
``('pod', 'data', 'model')``:

  * vocab / d_ff / attention heads shard on ``model`` (tensor parallel),
  * batch shards on ``(pod, data)`` (data parallel),
  * optimizer master/moments additionally shard on ``data`` (ZeRO-1) and
    optionally parameters too (ZeRO-3 / FSDP) — the flag that makes
    nemotron-340b training fit,
  * MoE experts shard on ``model`` when ``E % model == 0`` and
    ``expert_parallel=True`` (the perf-hillclimb axis), else every
    expert's d_ff TP-shards,
  * decode KV caches shard heads on ``model``; for ``long_500k`` (batch 1)
    the cache *sequence* dimension shards on ``data`` — sequence-parallel
    attention over the cached context,
  * any dimension that does not divide its axis is replicated on it
    (never crash): whisper-base's 8 heads on a 16-way model axis replicate
    attention but still shard its d_ff=2048. This degradation is reported,
    not hidden — ``explain()`` returns every fallback the policy took.

Stacked-layer parameters (leading ``n_periods`` axis from scan-over-layers)
are detected by path (``slots``) and get a leading ``None``.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "ShardingPolicy",
    "distribute_shards",
    "shard_axis_mesh",
    "tree_shardings",
    "tree_specs",
]


def _axis_size(mesh: Mesh, name: str) -> int:
    try:
        return mesh.shape[name]
    except (KeyError, TypeError):
        return 1


@dataclass
class ShardingPolicy:
    """Sharding rules for one (arch config, mesh) pair."""

    mesh: Mesh
    expert_parallel: bool = False  # EP vs per-expert TP for MoE weights
    zero3: bool = False  # shard params over 'data' as well (FSDP)
    zero1: bool = True  # shard optimizer state over 'data'
    seq_shard_cache: bool = False  # long_500k: KV-cache sequence over 'data'
    cache_kv_heads: Optional[int] = None  # arch's n_kv_heads (cache sharding)
    fallbacks: List[str] = field(default_factory=list)

    # ---- helpers ---------------------------------------------------------
    @property
    def dp_axes(self) -> Tuple[str, ...]:
        return tuple(a for a in self.mesh.axis_names if a in ("pod", "data"))

    @property
    def model_size(self) -> int:
        return _axis_size(self.mesh, "model")

    @property
    def data_size(self) -> int:
        n = 1
        for a in self.dp_axes:
            n *= _axis_size(self.mesh, a)
        return n

    def _shard_if(self, dim: int, axis: str, what: str) -> Optional[str]:
        size = _axis_size(self.mesh, axis)
        if size <= 1:
            return None
        if dim % size == 0:
            return axis
        self.fallbacks.append(f"{what}: dim {dim} !% {axis}({size}) — replicated")
        return None

    def _data_axis_for(self, dim: int, what: str):
        """Largest data-axis combination that divides ``dim``."""
        axes = self.dp_axes
        if not axes:
            return None
        full = 1
        for a in axes:
            full *= _axis_size(self.mesh, a)
        if dim % full == 0:
            return axes if len(axes) > 1 else axes[0]
        # try just 'data'
        if "data" in axes and dim % _axis_size(self.mesh, "data") == 0:
            return "data"
        self.fallbacks.append(f"{what}: dim {dim} !% data axes — replicated")
        return None

    # ---- parameters ---------------------------------------------------------
    def param_spec(self, path: str, shape: Tuple[int, ...]) -> P:
        """PartitionSpec for a parameter leaf. ``path`` is the '/'-joined
        tree path; ``shape`` the *full* array shape (incl. stacking)."""
        stacked = "/slots/" in path or path.startswith("slots/") or "/enc/" in path
        logical = shape[1:] if stacked and len(shape) > 1 else shape
        spec = self._param_spec_logical(path, logical)
        out = (None,) + tuple(spec) if stacked and len(shape) > 1 else tuple(spec)
        # ZeRO-3: additionally shard the largest replicated dim over data
        if self.zero3 and len(shape) >= 2:
            out = self._add_data_sharding(out, shape, path)
        return P(*out)

    def _param_spec_logical(self, path: str, s: Tuple[int, ...]) -> Tuple:
        name = path.rsplit("/", 1)[-1]
        m = "model"

        if name in ("embedding",):  # [V, D] — vocab on model
            return (self._shard_if(s[0], m, path), None)
        if name in ("head",):  # [D, V]
            return (None, self._shard_if(s[1], m, path))
        if name in ("pos_embed",):
            return (None, None)
        if name in ("wq", "wk", "wv"):  # [D, H*hd] — heads on model
            return (None, self._shard_if(s[1], m, path))
        if name in ("wo",) and "attn" in path or name == "wo" and "cross" in path:
            return (self._shard_if(s[0], m, path), None)
        if name in ("bq", "bk", "bv"):
            return (self._shard_if(s[0], m, path),)
        if name in ("wi", "wg") and "moe" in path:  # [E, D, F]
            if self.expert_parallel:
                e = self._shard_if(s[0], m, path + "(EP)")
                if e:
                    return (e, None, None)
            return (None, None, self._shard_if(s[2], m, path))
        if name == "wo" and "moe" in path:  # [E, F, D]
            if self.expert_parallel:
                e = self._shard_if(s[0], m, path + "(EP)")
                if e:
                    return (e, None, None)
            return (None, self._shard_if(s[1], m, path), None)
        if name in ("wi", "wg"):  # mlp [D, F]
            return (None, self._shard_if(s[1], m, path))
        if name == "wo":  # mlp [F, D]
            return (self._shard_if(s[0], m, path), None)
        if name == "router":  # [D, E] — replicated (tiny, avoids a2a on logits)
            return (None, None)
        if name in ("z_proj", "x_proj"):  # ssm [D, di] — heads on model
            return (None, self._shard_if(s[1], m, path))
        if name == "dt_proj":  # [D, nh] — aligned with head sharding
            return (None, self._shard_if(s[1], m, path))
        if name == "bc_proj":  # [D, 2·G·N] — tiny, group-broadcast: replicate
            return (None, None)
        if name == "out_proj":  # ssm [di, D]
            return (self._shard_if(s[0], m, path), None)
        if name in ("conv_x_w",):  # [K, di]
            return (None, self._shard_if(s[1], m, path))
        if name in ("conv_x_b",):
            return (self._shard_if(s[0], m, path),)
        if name in ("conv_bc_w", "conv_bc_b"):
            return tuple(None for _ in s)
        if name == "scale" and "ssm/norm" in path:  # gated norm over di
            return (self._shard_if(s[0], m, path),)
        # norms, scalars (A_log, D, dt_bias), biases: replicated
        return tuple(None for _ in s)

    def _add_data_sharding(self, spec: Tuple, shape: Tuple[int, ...], path: str) -> Tuple:
        """ZeRO-3: shard the largest not-yet-sharded dim over 'data'."""
        if "data" not in self.mesh.axis_names:
            return spec
        # a mesh axis may appear at most once per spec (zero3 params already
        # consumed 'data' ⇒ zero1 opt sharding is a no-op on top)
        for entry in spec:
            if entry == "data" or (isinstance(entry, tuple) and "data" in entry):
                return spec
        d = _axis_size(self.mesh, "data")
        order = sorted(range(len(shape)), key=lambda i: -shape[i])
        for i in order:
            if spec[i] is None and shape[i] % d == 0 and shape[i] >= d:
                out = list(spec)
                out[i] = "data"
                return tuple(out)
        if len(shape) >= 2:  # scalars/1-D replicating is expected, not a fallback
            self.fallbacks.append(f"{path}: zero3 found no dim % data({d})")
        return spec

    # ---- optimizer state -------------------------------------------------------
    def opt_spec(self, path: str, shape: Tuple[int, ...]) -> P:
        """Optimizer master/moments: param spec + ZeRO-1 data sharding.

        Blockwise-int8 moments (QTensor leaves ``.../q``, ``.../scale`` of
        shape [Nblk, B]) lose the param's dimensionality, so they shard
        their block dim over *all* axes that divide — for a 340B model the
        difference is 42 GB vs 2.7 GB of moments per device."""
        name = path.rsplit("/", 1)[-1]
        if name in ("q", "scale") and len(shape) in (2, 3):
            # QTensor leaves: spread every axis that divides across the
            # block dim first, then the lead dim (q [L, nblk, B] or
            # [nblk, B]) — the embedding's blocks-per-row may be small
            # (D/256) while its vocab lead dim shards fine.
            dims = [1, 0] if len(shape) == 3 else [0]
            spec: List = [None] * len(shape)
            used: Dict[int, List[str]] = {d: [] for d in dims}
            divisor: Dict[int, int] = {d: 1 for d in dims}
            for a in sorted(self.mesh.axis_names, key=lambda a: -_axis_size(self.mesh, a)):
                sz = _axis_size(self.mesh, a)
                if sz <= 1:
                    continue
                for d in dims:
                    if shape[d] % (divisor[d] * sz) == 0:
                        used[d].append(a)
                        divisor[d] *= sz
                        break
            for d in dims:
                if used[d]:
                    spec[d] = tuple(used[d]) if len(used[d]) > 1 else used[d][0]
            return P(*spec)
        base = tuple(self.param_spec(path, shape))
        if not self.zero1:
            return P(*base)
        return P(*self._add_data_sharding(base, shape, path + "(opt)"))

    # ---- activations / batches ---------------------------------------------------
    def batch_spec(self, shape: Tuple[int, ...], *, batch_dim: int = 0) -> P:
        spec: List = [None] * len(shape)
        axes = self._data_axis_for(shape[batch_dim], "batch")
        spec[batch_dim] = axes
        return P(*spec)

    def activation_spec(self, shape: Tuple[int, ...]) -> P:
        # [B, S, D] — batch over dp, D over model when divisible
        spec: List = [None] * len(shape)
        spec[0] = self._data_axis_for(shape[0], "act-batch")
        return P(*spec)

    def logits_spec(self, shape: Tuple[int, ...]) -> P:
        spec: List = [None] * len(shape)
        spec[0] = self._data_axis_for(shape[0], "logits-batch")
        spec[-1] = self._shard_if(shape[-1], "model", "logits-vocab")
        return P(*spec)

    # ---- KV caches ------------------------------------------------------------
    def cache_spec(self, path: str, shape: Tuple[int, ...]) -> P:
        """KVCache leaves: [n_periods, B, W, Hkv, hd] (k/v) or
        [n_periods, B, W] (pos); SSM states [n_periods, B, H, P, N]."""
        name = path.rsplit("/", 1)[-1]
        n = len(shape)
        spec: List = [None] * n
        is_kv = name in ("k", "v") and n == 5  # [np, B, W, Hkv, hd]
        is_pos = name == "pos" and n == 3  # [np, B, W]
        if n >= 2:
            bdim = 1  # batch dim (after the stacked periods dim)
            if shape[bdim] > 1:
                spec[bdim] = self._data_axis_for(shape[bdim], "cache-batch")
            elif self.seq_shard_cache and (is_kv or is_pos):
                # long_500k: batch 1 ⇒ shard the cache *sequence* over data
                spec[2] = self._data_axis_for(shape[2], "cache-seq")
        if is_kv or is_pos:
            heads_ok = (
                self.cache_kv_heads is not None
                and self.model_size > 1
                and self.cache_kv_heads % self.model_size == 0
            )
            if is_kv and heads_ok:
                spec[3] = "model"
            elif spec[2] is None and shape[2] % max(self.model_size, 1) == 0 and self.model_size > 1:
                # KV heads don't divide the model axis (e.g. 8 heads / 16-way
                # TP): split the cache *sequence* over 'model' instead —
                # flash-decoding-style split-K attention (partial softmax
                # combined by collectives the partitioner inserts). Applied
                # to k/v AND pos so the masking stays aligned.
                spec[2] = "model"
                self.fallbacks.append(
                    f"{path}: kv heads !% model — cache seq sharded on model"
                )
        if name == "h" and n == 5:  # SSM state [np, B, H, P, N]
            spec[2] = self._shard_if(shape[2], "model", "ssm-state-heads")
        if name == "conv_x" and n == 4:  # [np, B, K-1, di]
            spec[3] = self._shard_if(shape[3], "model", "conv-tail")
        return P(*spec)

    def explain(self) -> List[str]:
        return list(dict.fromkeys(self.fallbacks))


# ---------------------------------------------------------------------------
# shard-axis meshes (sharded matchmaking, DESIGN.md §9)
# ---------------------------------------------------------------------------


def shard_axis_mesh(n_shards: int, *, axis: str = "shard") -> Mesh:
    """A 1-D mesh over the snapshot's shard axis.

    Uses the largest device count ≤ ``n_shards`` that *divides*
    ``n_shards``, so the vmapped per-shard matchrank partitions evenly
    (each device ranks n_shards/devices shards). On a single device
    (CPU test rigs) this degenerates to a 1-device mesh — same results,
    batched loop instead of parallel execution.
    """
    devices = jax.devices()
    use = 1
    for d in range(min(len(devices), max(1, int(n_shards))), 0, -1):
        if n_shards % d == 0:
            use = d
            break
    return Mesh(np.asarray(devices[:use]), (axis,))


def distribute_shards(*arrays, mesh: Mesh, axis: str = "shard"):
    """Lay stacked ``[G, ...]`` per-shard blocks out along ``mesh``'s
    shard axis (leading dim sharded, rest replicated). Returns the
    arrays in input order (a single array when one is passed)."""
    sharding = NamedSharding(mesh, P(axis))
    out = tuple(jax.device_put(a, sharding) for a in arrays)
    return out[0] if len(out) == 1 else out


# ---------------------------------------------------------------------------
# tree application
# ---------------------------------------------------------------------------


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def tree_specs(tree, spec_fn) -> Any:
    """Map (path, shape) → PartitionSpec over a pytree of arrays or
    ShapeDtypeStructs."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: spec_fn(_path_str(path), tuple(leaf.shape)), tree
    )


def tree_shardings(tree, mesh: Mesh, spec_fn) -> Any:
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, spec_fn(_path_str(path), tuple(leaf.shape))),
        tree,
    )
