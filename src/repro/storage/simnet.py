"""Deterministic network/topology model for the simulated data grid.

The paper's grid spans sites with very different end-to-end paths; the
whole point of per-source bandwidth history (§3.2) is that *the same
server looks different from different clients*. This model produces that
structure deterministically:

  * every node (storage endpoint or client host) lives in a **zone**
    (≙ site / pod / region),
  * a base bandwidth matrix assigns intra-zone / inter-zone link rates,
  * each (src, dst) pair gets a stable multiplicative fingerprint drawn
    from a seeded hash (some paths are just bad),
  * a diurnal load wave + lognormal noise modulate each observation, so
    history is informative but not constant (predictors have work to do),
  * endpoints have a load factor that grows with concurrent transfers.

Everything is a pure function of (seed, names, time) — two brokers
simulating the same grid see the same world, which the decentralized-
consistency tests rely on.
"""

from __future__ import annotations

import hashlib
import math
from dataclasses import dataclass, field
from typing import Dict, Mapping, Optional, Tuple

__all__ = ["ZoneTopology", "NetModel"]


def _stable_unit(seed: int, *keys: str) -> float:
    """Deterministic uniform [0,1) from a seed and string keys."""
    h = hashlib.sha256(("%d|" % seed + "|".join(keys)).encode()).digest()
    return int.from_bytes(h[:8], "big") / 2**64


@dataclass
class ZoneTopology:
    """Zone assignment plus the inter-zone base bandwidth matrix (B/s)."""

    zones: Dict[str, str] = field(default_factory=dict)  # node url -> zone
    intra_zone_bw: float = 2.0e9  # same zone: pod-local network
    inter_zone_bw: float = 200.0e6  # cross zone: WAN-ish
    cross_region_bw: float = 25.0e6  # zones in different regions
    zone_region: Dict[str, str] = field(default_factory=dict)  # zone -> region

    def assign(self, url: str, zone: str, region: Optional[str] = None) -> None:
        self.zones[url] = zone
        if region is not None:
            self.zone_region[zone] = region

    def zone_of(self, url: str) -> str:
        return self.zones.get(url, "default")

    def base_bandwidth(self, src: str, dst: str) -> float:
        zs, zd = self.zone_of(src), self.zone_of(dst)
        if zs == zd:
            return self.intra_zone_bw
        rs = self.zone_region.get(zs, zs)
        rd = self.zone_region.get(zd, zd)
        if rs == rd:
            return self.inter_zone_bw
        return self.cross_region_bw


class NetModel:
    """Effective bandwidth as a deterministic function of (pair, time, load)."""

    def __init__(
        self,
        topology: ZoneTopology,
        *,
        seed: int = 0,
        diurnal_amplitude: float = 0.35,
        diurnal_period: float = 86400.0,
        noise_sigma: float = 0.20,
        pair_spread: float = 0.5,
    ):
        self.topo = topology
        self.seed = seed
        self.diurnal_amplitude = diurnal_amplitude
        self.diurnal_period = diurnal_period
        self.noise_sigma = noise_sigma
        self.pair_spread = pair_spread
        self._obs_counter: Dict[Tuple[str, str], int] = {}

    # -- stable per-pair fingerprint ------------------------------------------
    def pair_factor(self, src: str, dst: str) -> float:
        """Stable multiplier in [1-spread, 1+spread*0.5]: some paths are
        simply worse, and history is the only way to learn it."""
        u = _stable_unit(self.seed, "pair", src, dst)
        return 1.0 - self.pair_spread * u + 0.25 * self.pair_spread * (1 - u)

    def diurnal(self, src: str, t: float) -> float:
        phase = 2 * math.pi * _stable_unit(self.seed, "phase", src)
        return 1.0 - self.diurnal_amplitude * 0.5 * (
            1.0 + math.sin(2 * math.pi * t / self.diurnal_period + phase)
        )

    def noise(self, src: str, dst: str, k: int) -> float:
        """Lognormal-ish multiplicative noise, deterministic in draw index."""
        u = _stable_unit(self.seed, "noise", src, dst, str(k))
        # Box-Muller-lite: map uniform → approx normal via inverse-ish sum
        u2 = _stable_unit(self.seed, "noise2", src, dst, str(k))
        z = math.sqrt(-2.0 * math.log(max(u, 1e-12))) * math.cos(2 * math.pi * u2)
        return math.exp(self.noise_sigma * z - 0.5 * self.noise_sigma**2)

    # -- the headline function ---------------------------------------------------
    def effective_bandwidth(
        self,
        src: str,
        dst: str,
        t: float,
        *,
        load_factor: float = 0.0,
        disk_rate: Optional[float] = None,
        advance: bool = True,
    ) -> float:
        """End-to-end B/s for one transfer starting at time ``t``.

        min(network path, disk) × diurnal × pair fingerprint × noise,
        divided by (1 + load). ``advance`` increments the per-pair noise
        draw index (each transfer sees fresh noise, deterministically).
        """
        base = self.topo.base_bandwidth(src, dst)
        if disk_rate is not None:
            base = min(base, disk_rate)
        k = self._obs_counter.get((src, dst), 0)
        if advance:
            self._obs_counter[(src, dst)] = k + 1
        bw = (
            base
            * self.pair_factor(src, dst)
            * self.diurnal(src, t)
            * self.noise(src, dst, k)
            / (1.0 + max(load_factor, 0.0))
        )
        return max(bw, 1.0)

    def expected_bandwidth(self, src: str, dst: str, t: float, **kw) -> float:
        """Noise-free expectation — the oracle the quality benchmarks use."""
        base = self.topo.base_bandwidth(src, dst)
        disk_rate = kw.get("disk_rate")
        if disk_rate is not None:
            base = min(base, disk_rate)
        return max(
            base
            * self.pair_factor(src, dst)
            * self.diurnal(src, t)
            / (1.0 + max(kw.get("load_factor", 0.0), 0.0)),
            1.0,
        )
