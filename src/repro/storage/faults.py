"""Fault injection for the simulated grid.

Large-scale runnability demands that node death, degradation and flapping
be *routine*, not exceptional. The injector drives endpoint fault state
deterministically (seeded schedule) so fault-tolerance tests are exact:
the broker must failover, the checkpoint restorer must find a surviving
replica, the repair daemon must restore the replication factor.
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from .endpoint import DataGrid

__all__ = ["FaultEvent", "FaultInjector"]


@dataclass(frozen=True)
class FaultEvent:
    at: float  # clock time
    kind: str  # 'kill' | 'heal' | 'degrade' | 'flaky'
    endpoint: str
    factor: float = 1.0  # degrade multiplier or flaky probability


class FaultInjector:
    """Applies scheduled or immediate faults to grid endpoints."""

    def __init__(self, grid: DataGrid):
        self.grid = grid
        self.schedule: List[FaultEvent] = []
        self.applied: List[FaultEvent] = []

    # -- immediate faults ----------------------------------------------------
    def kill(self, endpoint: str) -> None:
        self.grid.endpoints[endpoint].kill()
        self.applied.append(FaultEvent(self.grid.clock.now(), "kill", endpoint))

    def heal(self, endpoint: str) -> None:
        self.grid.endpoints[endpoint].heal()
        self.applied.append(FaultEvent(self.grid.clock.now(), "heal", endpoint))

    def degrade(self, endpoint: str, factor: float) -> None:
        """Multiply the endpoint's effective bandwidth by ``factor`` (<1).
        This is the straggler scenario: alive but slow."""
        self.grid.endpoints[endpoint].degradation = factor
        self.applied.append(
            FaultEvent(self.grid.clock.now(), "degrade", endpoint, factor)
        )

    def flaky(self, endpoint: str, probability: float) -> None:
        self.grid.endpoints[endpoint].flaky_rate = probability
        self.applied.append(
            FaultEvent(self.grid.clock.now(), "flaky", endpoint, probability)
        )

    # -- scheduled faults ---------------------------------------------------
    def schedule_event(self, event: FaultEvent) -> None:
        self.schedule.append(event)
        self.schedule.sort(key=lambda e: e.at)

    def tick(self) -> List[FaultEvent]:
        """Apply every scheduled event whose time has come."""
        now = self.grid.clock.now()
        due = [e for e in self.schedule if e.at <= now]
        self.schedule = [e for e in self.schedule if e.at > now]
        for e in due:
            if e.kind == "kill":
                self.kill(e.endpoint)
            elif e.kind == "heal":
                self.heal(e.endpoint)
            elif e.kind == "degrade":
                self.degrade(e.endpoint, e.factor)
            elif e.kind == "flaky":
                self.flaky(e.endpoint, e.factor)
        return due

    # -- chaos schedule ---------------------------------------------------------
    def chaos(
        self,
        *,
        horizon: float,
        mtbf: float,
        mttr: float,
        seed: int = 0,
        kinds: Sequence[str] = ("kill", "degrade"),
    ) -> int:
        """Generate a deterministic kill/heal schedule over ``horizon``
        seconds with the given mean-time-between-failures per endpoint."""
        n = 0
        for url in sorted(self.grid.endpoints):
            t = 0.0
            k = 0
            while True:
                u = _unit(seed, url, "gap", str(k))
                t += -mtbf * _ln(u)
                if t >= horizon:
                    break
                kind = kinds[int(_unit(seed, url, "kind", str(k)) * len(kinds)) % len(kinds)]
                factor = 0.05 + 0.2 * _unit(seed, url, "factor", str(k))
                self.schedule_event(FaultEvent(t, kind, url, factor))
                heal_at = t + max(mttr * (-_ln(_unit(seed, url, "heal", str(k)))), 1.0)
                if heal_at < horizon:
                    self.schedule_event(FaultEvent(heal_at, "heal", url))
                t = heal_at
                k += 1
                n += 1
        return n


def _unit(seed: int, *keys: str) -> float:
    h = hashlib.sha256(("%d|" % seed + "|".join(keys)).encode()).digest()
    return max(int.from_bytes(h[:8], "big") / 2**64, 1e-12)


def _ln(x: float) -> float:
    import math

    return math.log(x)
