"""Resilient multi-replica access: striped + hedged TransferPlan execution.

The paper's Access Phase picks one winner and hopes; production grids
(GridFTP striping, the EU DataGrid failure reports) learned to spread one
file over several replicas and to assume any of them can die or crawl
mid-transfer. :class:`ResilientTransferService` executes the broker's
:class:`~repro.core.transferplan.TransferPlan` that way, against the
simulated clock:

  * **striping** — chunk ranges fan out over the top-k ranked replicas in
    parallel simulated time (the wall time charged is the stripe
    *makespan*, not the sum), apportioned by predicted bandwidth,
  * **hedging** — a stripe whose observed chunk bandwidth falls below
    ``hedge_factor ×`` the broker's prediction (or, for a cold source
    with no history, the fastest peer stripe's observed rate) for
    ``hedge_patience`` consecutive chunks gets its remaining chunks
    *duplicated* onto the best unused backup; the two race, first claim
    wins per chunk,
  * **retry/backoff** — transient faults (flaky endpoints) retry in
    place with jittered exponential backoff, resuming from the last
    completed chunk (restart markers: completed chunks are never
    re-fetched),
  * **failover** — a dead or retry-exhausted stripe hands its pending
    chunks to a fresh backup replica, or to the surviving stripes,
  * **work stealing** — a stripe that drains its queue takes a
    bandwidth-weighted share of the largest pending queue, so a slow
    backup that inherited a dead stripe's chunks cannot drag the
    makespan while fast stripes sit idle,
  * **circuit breakers** — per-endpoint closed → open → half-open state
    (:mod:`.breaker`) gates which replicas a plan may touch, and every
    state change is published back into the endpoint's GRIS as the
    per-source ``breakerOpenToSource`` attribute, which the broker's
    default read request *requires* to be ``< 1`` — matchmaking itself
    learns to avoid tripped endpoints.

Everything is deterministic: stripe scheduling is a min-heap walk over
per-stripe virtual clocks, jitter comes from seeded hashes, and the
shared grid clock only ever moves to the current stripe frontier (so a
scheduled fault injector hooked on :attr:`on_advance` can kill an
endpoint *mid-transfer* and the executor observes it exactly then).
"""

from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Set, Tuple

from repro.core.catalog import PhysicalFile
from repro.core.transferplan import (
    TransferFailure,
    TransferPlan,
    TransferRequest,
    TransferResult,
)

from .breaker import BreakerBoard
from .transfer import SimulatedTransferService, TransferConfig, _stable_unit

__all__ = ["ResilienceConfig", "ResilientTransferService"]


@dataclass
class ResilienceConfig:
    stripe_k: int = 3  # max replicas striped across
    hedge_factor: float = 0.4  # hedge when observed < factor × predicted
    hedge_patience: int = 2  # consecutive slow chunks before hedging
    max_hedges: int = 2  # hedge launches per plan execution
    max_retries: int = 2  # transient retries per stripe before failover
    backoff_base_s: float = 0.25  # first retry delay
    backoff_max_s: float = 4.0  # delay cap
    backoff_jitter: float = 0.5  # ± fraction of the delay, seeded hash
    breaker_failures: int = 3  # consecutive failures to trip open
    breaker_reset_s: float = 60.0  # open → half-open probe window


class _Stripe:
    """One in-flight stripe: a replica, its pending chunks, and a virtual
    clock that only the executor advances."""

    __slots__ = (
        "idx", "pfn", "ep", "data", "queue", "t", "streams", "slow",
        "retries", "hedge_of", "hedged", "alive", "bytes_done", "started_at",
        "last_bw",
    )

    def __init__(self, idx, pfn, ep, data, queue, t, streams):
        self.idx = idx
        self.pfn = pfn
        self.ep = ep
        self.data = data
        self.queue = queue  # deque of chunk indices
        self.t = t  # virtual time cursor
        self.streams = streams
        self.slow = 0  # consecutive below-prediction chunks
        self.last_bw = 0.0  # most recent observed chunk bandwidth
        self.retries = 0  # consecutive transient retries
        self.hedge_of: Optional[int] = None  # stripe idx this hedges
        self.hedged = False  # already spawned a hedge
        self.alive = True
        self.bytes_done = 0
        self.started_at = t


class ResilientTransferService(SimulatedTransferService):
    """Striped/hedged/retrying executor over the base simulated engine.

    Inherits the single-source ``transfer``/``transfer_chunks`` surface
    (so it satisfies the broker's TransferService protocol anywhere),
    and adds :meth:`execute` (run a TransferPlan) and :meth:`fetch`
    (select → execute, annotating the broker's decision record).
    """

    def __init__(
        self,
        grid,
        broker,
        *,
        config: Optional[TransferConfig] = None,
        resilience: Optional[ResilienceConfig] = None,
    ):
        super().__init__(grid, config, metrics=broker.metrics)
        self.broker = broker
        self.resilience = resilience or ResilienceConfig()
        self.breakers = BreakerBoard(
            failure_threshold=self.resilience.breaker_failures,
            reset_s=self.resilience.breaker_reset_s,
            publish=self._publish_breaker,
            metrics=broker.metrics,
        )
        #: optional hook called whenever the executor advances the shared
        #: clock to a stripe frontier — wire a FaultInjector's ``tick``
        #: here to make scheduled faults land mid-transfer.
        self.on_advance: Optional[Callable[[], Any]] = None
        m = broker.metrics
        self._c_stripes = m.counter(
            "resilient_stripes_total", "stripes launched across plan executions"
        )
        self._c_hedges = m.counter(
            "resilient_hedges_total", "hedge stripes launched against slow sources"
        )
        self._c_hedge_wins = m.counter(
            "resilient_hedge_wins_total", "chunks claimed by a hedge stripe first"
        )
        self._c_retries = m.counter(
            "resilient_retries_total", "transient chunk failures retried with backoff"
        )
        self._c_stripe_failovers = m.counter(
            "resilient_stripe_failovers_total",
            "stripes abandoned (dead/exhausted) with chunks reassigned",
        )
        self._c_breaker_skips = m.counter(
            "resilient_breaker_skips_total", "replicas excluded by an open breaker"
        )
        self._c_steals = m.counter(
            "resilient_steals_total",
            "chunk batches stolen by idle stripes from laggards",
        )
        self._h_retries = m.histogram(
            "resilient_retries_per_transfer",
            "retry count distribution per plan execution",
            buckets=(0, 1, 2, 3, 5, 8, 13, float("inf")),
        )
        self._h_backoff = m.histogram(
            "resilient_backoff_seconds",
            "jittered backoff delays charged to stripes",
            buckets=(0.1, 0.25, 0.5, 1, 2, 4, 8, float("inf")),
        )
        self._h_stripe_k = m.histogram(
            "resilient_stripes_per_transfer",
            "concurrent stripes at plan launch",
            buckets=(1, 2, 3, 4, 6, 8, float("inf")),
        )

    # ------------------------------------------------------------- feedback
    def _publish_breaker(self, endpoint: str, value: float) -> None:
        """Breaker state → the endpoint's GRIS per-source health attrs —
        the feedback loop the Match Phase reads (``breakerOpenToSource``)."""
        gris = self.grid.gris_for(endpoint)
        if gris is not None:
            gris.publish_source_health(
                self.broker.client_url, {"breakerOpenToSource": value}
            )
        # batched selection works from a TTL snapshot; a breaker flip is
        # exactly the "published world changed" event that invalidates it
        self.broker.invalidate_snapshot()

    def _republish_breakers(self) -> None:
        """Re-push non-closed breaker state for endpoints whose GRIS was
        unreachable at trip time (e.g. tripped by death, then healed)."""
        now = self.grid.clock.now()
        for url, br in self.breakers.breakers.items():
            br.allows(now)  # open → half-open transitions happen lazily
            if br.value > 0:
                gris = self.grid.gris_for(url)
                if gris is not None:
                    gris.publish_source_health(
                        self.broker.client_url, {"breakerOpenToSource": br.value}
                    )

    # ------------------------------------------------------------ top level
    def fetch(
        self,
        lfn: str,
        request=None,
        *,
        top_k: Optional[int] = None,
    ) -> TransferResult:
        """Select → plan → striped execution, end to end.

        The selection's decision record is annotated with the access
        outcome (fetched_from = the endpoint that contributed the most
        bytes) and the client-side history monitor observes the achieved
        end-to-end bandwidth, exactly like ``DataBroker.access``.
        """
        self._republish_breakers()
        sel = self.broker.select(lfn, request, top_k=top_k)
        res = self.execute(sel.plan)
        self.broker.note_access(sel.request_id, res)
        return res

    # ------------------------------------------------------------- executor
    def execute(self, plan: TransferPlan) -> TransferResult:
        """Run a TransferPlan: striped, hedged, retried, breaker-gated."""
        cfg = self.resilience
        clock = self.grid.clock
        t0 = clock.now()
        size = plan.primary.size
        cb = self.config.chunk_bytes
        n_chunks = max(1, math.ceil(size / cb)) if size > 0 else 1

        # breaker gate (half-open admits the probe); if everything is
        # tripped, probe the full ranked list rather than fail outright
        candidates = [
            pfn for pfn in plan.replicas if self.breakers.allows(pfn.endpoint, t0)
        ]
        skipped = len(plan.replicas) - len(candidates)
        if skipped:
            self._c_breaker_skips.inc(skipped)
        if not candidates:
            candidates = list(plan.replicas)

        k = max(1, min(plan.stripe_k, cfg.stripe_k, len(candidates)))
        smap = plan.stripe_map(n_chunks, k)
        queues: List[deque] = [deque() for _ in range(k)]
        for ci, s in enumerate(smap):
            queues[s].append(ci)

        done: List[Optional[bytes]] = [None] * n_chunks
        claimed: Set[int] = set()
        per_replica: Dict[str, int] = {}
        ep_elapsed: Dict[str, Tuple[float, float]] = {}  # url -> (start, end)
        stats = {
            "retries": 0, "hedges": 0, "hedge_wins": 0, "failovers": 0,
            "steals": 0,
        }
        stripes: List[_Stripe] = []
        used_eps: Set[str] = set()
        max_finish = t0

        def _chunk_range(ci: int) -> Tuple[int, int]:
            lo = ci * cb
            return lo, min(lo + cb, size)

        def _activate(
            pfn: PhysicalFile, queue: deque, at: float, hedge_of: Optional[int]
        ) -> Optional[_Stripe]:
            """Open a stripe on ``pfn``; None if the endpoint refuses."""
            ep = self.grid.endpoints.get(pfn.endpoint)
            if ep is None or not ep.alive:
                self.breakers.record_failure(pfn.endpoint, at)
                return None
            try:
                data = ep.get(pfn.path)
            except FileNotFoundError:
                self.breakers.record_failure(pfn.endpoint, at)
                return None
            st = _Stripe(
                len(stripes), pfn, ep, data,
                deque(queue), at + self.config.latency_s, self.config.n_streams,
            )
            st.hedge_of = hedge_of
            ep.active_transfers += 1
            ep.active_streams += st.streams
            stripes.append(st)
            used_eps.add(pfn.endpoint)
            self._c_stripes.inc()
            return st

        def _deactivate(st: _Stripe) -> None:
            if not st.alive:
                return
            st.alive = False
            st.ep.active_transfers -= 1
            st.ep.active_streams -= st.streams
            nonlocal max_finish
            max_finish = max(max_finish, st.t)
            s0, s1 = ep_elapsed.get(st.ep.url, (st.started_at, st.t))
            ep_elapsed[st.ep.url] = (min(s0, st.started_at), max(s1, st.t))

        def _backup_ok(pfn: PhysicalFile, at: float) -> bool:
            if not self.breakers.allows(pfn.endpoint, at):
                return False
            ep = self.grid.endpoints.get(pfn.endpoint)
            return ep is not None and ep.alive

        def _next_backup(
            at: float, avoid: Sequence[str] = ()
        ) -> Optional[PhysicalFile]:
            # prefer a replica no stripe has touched yet, by rank...
            for pfn in plan.replicas:
                if pfn.endpoint in used_eps or pfn.endpoint in avoid:
                    continue
                if _backup_ok(pfn, at):
                    return pfn
            # ...else re-open a stripe on an endpoint whose stripe already
            # finished (per-endpoint stream accounting shares the pipe, so
            # a second stripe there is safe, just slower than a fresh one)
            active_eps = {s.ep.url for s in stripes if s.alive}
            for pfn in plan.replicas:
                if pfn.endpoint in avoid or pfn.endpoint in active_eps:
                    continue
                if _backup_ok(pfn, at):
                    return pfn
            return None

        def _steal_into(st: _Stripe) -> bool:
            """Work stealing: a stripe that drained its queue takes a
            bandwidth-weighted share of the largest pending queue's tail,
            so one slow replica cannot drag the makespan while faster
            stripes sit finished (failover often dumps a dead stripe's
            chunks on whatever backup existed, however slow)."""
            if not st.ep.alive:
                return False
            victims = [
                s
                for s in stripes
                if s.alive
                and s is not st
                and s.ep.url != st.ep.url
                and len(s.queue) > 1
            ]
            if not victims:
                return False
            victim = max(victims, key=lambda s: (len(s.queue), -s.idx))
            bw_t = st.last_bw or plan.predicted_for(st.ep.url) or 0.0
            bw_v = victim.last_bw or plan.predicted_for(victim.ep.url) or 0.0
            share = bw_t / (bw_t + bw_v) if bw_t > 0 and bw_v > 0 else 0.5
            take = min(len(victim.queue) - 1, int(len(victim.queue) * share))
            if take <= 0:
                return False
            stolen = [victim.queue.pop() for _ in range(take)]
            st.queue.extend(reversed(stolen))
            stats["steals"] += 1
            self._c_steals.inc()
            return True

        def _fail_stripe(st: _Stripe, reason: str) -> None:
            """Breaker bookkeeping + reassign pending chunks (restart
            markers: only chunks not yet claimed move)."""
            at = st.t
            _deactivate(st)
            self.breakers.record_failure(st.ep.url, at)
            stats["failovers"] += 1
            self._c_stripe_failovers.inc()
            pending = [ci for ci in st.queue if ci not in claimed]
            if not pending:
                return
            backup = _next_backup(at, avoid=(st.ep.url,))
            if backup is not None:
                _activate(backup, deque(pending), at, st.hedge_of)
                return
            survivors = [
                s for s in stripes if s.alive and s is not st
            ]
            if survivors:
                for i, ci in enumerate(pending):
                    survivors[i % len(survivors)].queue.append(ci)

        # launch the initial stripe set (failed launches reassign through
        # the same failover path a mid-flight death takes)
        launched = 0
        for s in range(k):
            if not queues[s]:
                continue
            st = _activate(candidates[s], queues[s], t0, None)
            if st is None:
                stats["failovers"] += 1
                self._c_stripe_failovers.inc()
                backup = _next_backup(t0, avoid=(candidates[s].endpoint,))
                st = _activate(backup, queues[s], t0, None) if backup else None
                if st is None:
                    # chunks stay unassigned; the post-launch sweep below
                    # hands them to whichever stripe did come up
                    live = [x for x in stripes if x.alive]
                    for i, ci in enumerate(queues[s]):
                        if live:
                            live[i % len(live)].queue.append(ci)
            if st is not None:
                launched += 1
        if not any(st.alive for st in stripes):
            raise self._fault(
                f"{plan.lfn or plan.primary.path}: no replica admitted a stripe "
                f"({len(plan.replicas)} ranked, {skipped} breaker-open)"
            )
        # chunks whose stripe never launched and found no survivors at the
        # time: hand them to the first live stripe now
        assigned = set()
        for st in stripes:
            assigned.update(st.queue)
        live0 = next(st for st in stripes if st.alive)
        for ci in range(n_chunks):
            if ci not in assigned:
                live0.queue.append(ci)
        self._h_stripe_k.observe(launched)

        # ---- min-frontier event loop over virtual stripe clocks ----
        while len(claimed) < n_chunks:
            active = [st for st in stripes if st.alive]
            # a drained stripe (queue fully claimed / finished) first tries
            # to steal pending work from a laggard, else retires
            for st in active:
                while st.queue and st.queue[0] in claimed:
                    st.queue.popleft()
                if not st.queue and not _steal_into(st):
                    _deactivate(st)
            active = [st for st in stripes if st.alive]
            if not active:
                raise self._fault(
                    f"{plan.lfn or plan.primary.path}: every stripe failed "
                    f"with {n_chunks - len(claimed)} chunk(s) pending"
                )
            st = min(active, key=lambda s: (s.t, s.idx))
            # advance the shared clock to the frontier; scheduled faults
            # (injector.tick on on_advance) land exactly here — this is
            # what makes "endpoint killed mid-transfer" observable
            if st.t > clock.now():
                clock.advance(st.t - clock.now())
                if self.on_advance is not None:
                    self.on_advance()
            if not st.ep.alive:
                _fail_stripe(st, "died mid-transfer")
                continue
            ci = st.queue[0]
            # transient fault? retry in place with jittered backoff
            try:
                self._maybe_flake(st.ep)
            except TransferFailure:
                st.retries += 1
                stats["retries"] += 1
                self._c_retries.inc()
                if st.retries > cfg.max_retries:
                    _fail_stripe(st, "retries exhausted")
                    continue
                delay = min(
                    cfg.backoff_base_s * (2 ** (st.retries - 1)), cfg.backoff_max_s
                )
                jit = cfg.backoff_jitter * (
                    2 * _stable_unit(st.ep.url, plan.lfn or "", str(ci), str(st.retries))
                    - 1
                )
                delay = max(delay * (1 + jit), 1e-3)
                self._h_backoff.observe(delay)
                st.t += delay
                continue
            st.retries = 0
            lo, hi = _chunk_range(ci)
            nb = hi - lo
            csecs = self.chunk_seconds(st.ep, self.broker.client_url, nb, st.t, st.streams)
            if not math.isfinite(csecs):
                _fail_stripe(st, "zero bandwidth")
                continue
            st.t += csecs
            st.queue.popleft()
            claimed.add(ci)
            done[ci] = st.data[lo:hi]
            st.bytes_done += nb
            per_replica[st.ep.url] = per_replica.get(st.ep.url, 0) + nb
            self.breakers.record_success(st.ep.url, st.t)
            if st.hedge_of is not None:
                stats["hedge_wins"] += 1
                self._c_hedge_wins.inc()
            # hedging: observed chunk bandwidth vs the broker's prediction;
            # a stripe the broker had no history for (cold source) is
            # judged against the fastest peer stripe instead
            if nb > 0 and csecs > 0:
                obw = nb / csecs
                pred = plan.predicted_for(st.pfn.endpoint)
                if not pred:
                    # finished peers still count as reference points
                    peers = [
                        s.last_bw for s in stripes if s is not st and s.last_bw > 0
                    ]
                    pred = max(peers) if peers else None
                st.last_bw = obw
                if pred and obw < cfg.hedge_factor * pred:
                    st.slow += 1
                else:
                    st.slow = 0
                if (
                    st.slow >= cfg.hedge_patience
                    and not st.hedged
                    and stats["hedges"] < cfg.max_hedges
                ):
                    backup = _next_backup(st.t, avoid=(st.ep.url,))
                    remaining = [c for c in st.queue if c not in claimed]
                    if backup is not None and remaining:
                        hedge = _activate(backup, deque(remaining), st.t, st.idx)
                        if hedge is not None:
                            st.hedged = True
                            stats["hedges"] += 1
                            self._c_hedges.inc()

        for st in stripes:
            _deactivate(st)
        if max_finish > clock.now():
            clock.advance(max_finish - clock.now())
            if self.on_advance is not None:
                self.on_advance()
        seconds = clock.now() - t0

        # deliver what the servers actually held — a replica whose stored
        # bytes are shorter than the catalog size (corruption) yields a
        # short payload, and the caller's checksum catches it, exactly as
        # with a single-source read
        payload = b"".join(p for p in done if p is not None)
        nbytes = len(payload)
        # server-side instrumentation per contributing endpoint (§3.2)
        for url, contributed in per_replica.items():
            ep = self.grid.endpoints.get(url)
            if ep is None:
                continue
            s0, s1 = ep_elapsed.get(url, (t0, clock.now()))
            ep.monitor.observe_transfer(
                "read", self.broker.client_url, contributed, max(s1 - s0, 1e-9), s0
            )
        self._record("read", nbytes, seconds)
        self._h_retries.observe(stats["retries"])
        return TransferResult(
            payload=payload,
            nbytes=nbytes,
            seconds=seconds,
            per_replica=per_replica,
            retries=stats["retries"],
            hedges=stats["hedges"],
            hedge_wins=stats["hedge_wins"],
            stripes=launched,
            failovers=stats["failovers"],
            lfn=plan.lfn,
        )
