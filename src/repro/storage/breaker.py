"""Per-endpoint circuit breakers for the resilient access layer.

A breaker protects the client from hammering an endpoint that keeps
failing: ``closed`` (normal) → ``open`` after N consecutive failures
(endpoint is skipped entirely) → ``half-open`` after a reset timeout
(one probe transfer is admitted) → ``closed`` on probe success, back to
``open`` on probe failure.

Breakers are client-side state (each client judges endpoints from its own
vantage, like the paper's per-source bandwidth history), but their state
is *published back* into the endpoint's GRIS as a per-source health
attribute (``breakerOpenToSource``) so this client's subsequent
matchmaking — which reads exactly that GRIS view — avoids tripped
endpoints without any new code path in the Match Phase.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, Optional

__all__ = ["BreakerOpen", "CircuitBreaker", "BreakerBoard"]

CLOSED = "closed"
OPEN = "open"
HALF_OPEN = "half-open"

#: numeric encoding published to GRIS (and the obs gauge): requirements
#: gate on ``breakerOpenToSource < 1`` so half-open endpoints stay
#: selectable as probes while open ones are excluded.
STATE_VALUE = {CLOSED: 0.0, HALF_OPEN: 0.5, OPEN: 1.0}


class BreakerOpen(RuntimeError):
    """An operation was refused because the endpoint's breaker is open."""


@dataclass
class CircuitBreaker:
    """One endpoint's failure-trip state machine (deterministic clock)."""

    endpoint: str
    failure_threshold: int = 3
    reset_s: float = 30.0
    state: str = CLOSED
    consecutive_failures: int = 0
    opened_at: float = 0.0
    trips: int = 0  # closed/half-open → open transitions

    def _maybe_half_open(self, now: float) -> None:
        if self.state == OPEN and now - self.opened_at >= self.reset_s:
            self.state = HALF_OPEN

    def allows(self, now: float) -> bool:
        """May a transfer use this endpoint right now? (half-open admits
        the probe)"""
        self._maybe_half_open(now)
        return self.state != OPEN

    def record_success(self, now: float) -> str:
        self._maybe_half_open(now)
        self.consecutive_failures = 0
        self.state = CLOSED
        return self.state

    def record_failure(self, now: float) -> str:
        self._maybe_half_open(now)
        self.consecutive_failures += 1
        if self.state == HALF_OPEN or (
            self.consecutive_failures >= self.failure_threshold
        ):
            if self.state != OPEN:
                self.trips += 1
            self.state = OPEN
            self.opened_at = now
        return self.state

    @property
    def value(self) -> float:
        return STATE_VALUE[self.state]


class BreakerBoard:
    """All of one client's breakers + the GRIS/obs feedback on changes.

    ``publish`` is called as ``publish(endpoint_url, value)`` whenever an
    endpoint's breaker state value changes (0 closed / 0.5 half-open /
    1 open) — the resilient service wires it to
    ``gris.publish_source_health`` so matchmaking sees it.
    """

    def __init__(
        self,
        *,
        failure_threshold: int = 3,
        reset_s: float = 30.0,
        publish: Optional[Callable[[str, float], None]] = None,
        metrics=None,
    ):
        self.failure_threshold = failure_threshold
        self.reset_s = reset_s
        self.publish = publish
        self.breakers: Dict[str, CircuitBreaker] = {}
        self._gauges = {}
        self.metrics = metrics

    def get(self, endpoint: str) -> CircuitBreaker:
        br = self.breakers.get(endpoint)
        if br is None:
            br = CircuitBreaker(endpoint, self.failure_threshold, self.reset_s)
            self.breakers[endpoint] = br
        return br

    def _sync(self, br: CircuitBreaker, before: float) -> None:
        if br.value == before:
            return
        if self.publish is not None:
            self.publish(br.endpoint, br.value)
        if self.metrics is not None:
            g = self._gauges.get(br.endpoint)
            if g is None:
                g = self.metrics.gauge(
                    "resilient_breaker_state",
                    "circuit state per endpoint (0 closed, 0.5 half-open, 1 open)",
                    # one gauge per grid endpoint: the registry's cardinality
                    # cap bounds this even on very large grids
                    endpoint=br.endpoint,  # lint: allow-metric-labels
                )
                self._gauges[br.endpoint] = g
            g.set(br.value)

    def allows(self, endpoint: str, now: float) -> bool:
        br = self.get(endpoint)
        before = br.value
        ok = br.allows(now)
        self._sync(br, before)
        return ok

    def record_success(self, endpoint: str, now: float) -> None:
        br = self.get(endpoint)
        before = br.value
        br.record_success(now)
        self._sync(br, before)

    def record_failure(self, endpoint: str, now: float) -> None:
        br = self.get(endpoint)
        before = br.value
        br.record_failure(now)
        self._sync(br, before)

    def state(self, endpoint: str) -> str:
        return self.get(endpoint).state

    def open_endpoints(self, now: float) -> list:
        return sorted(
            url for url, br in self.breakers.items() if not br.allows(now)
        )
