"""Simulated GridFTP: the transfer engine behind the Access Phase.

"Once a suitable replica has been identified, the file is accessed using a
high-speed file transfer protocol, for example the GridFTP tools" (§5.1.2).

The engine moves *real bytes* between endpoints and clients while charging
simulated wall-time against the shared deterministic clock. Every transfer
is instrumented on the server side (the endpoint's TransferMonitor → GRIS,
§3.2) — which is precisely the feedback loop the broker's history-based
rank expressions read. Transfers are chunked so the broker can watch
in-flight bandwidth for straggler mitigation, and parallel streams model
GridFTP's stream parallelism (diminishing returns past the path's
capacity).

The API speaks :class:`~repro.core.transferplan.TransferRequest` →
:class:`~repro.core.transferplan.TransferResult`; the old positional
``read(replica, client_url)`` tuple surface survives only as deprecation
shims. Stream utilization is accounted **per endpoint**: every open
stripe registers its streams on the endpoint, and each stripe's share of
the path is ``U(total_streams) * mine / total`` — so k stripes hammering
one endpoint saturate the same pipe once instead of k times, and a
single-replica k-stripe plan charges time consistent with a k-replica
striped plan (the utilization curve is one function of per-endpoint
stream count, wherever the streams come from).
"""

from __future__ import annotations

import hashlib
import math
import warnings
from dataclasses import dataclass
from typing import Any, Iterator, List, Optional, Tuple

from repro.core.catalog import PhysicalFile
from repro.core.transferplan import (
    ChunkEvent,
    TransferFailure,
    TransferRequest,
    TransferResult,
)

from .endpoint import DataGrid, StorageEndpoint

__all__ = [
    "TransferFailure",
    "TransferConfig",
    "SimulatedTransferService",
]


def _stable_unit(*keys: str) -> float:
    h = hashlib.sha256("|".join(keys).encode()).digest()
    return int.from_bytes(h[:8], "big") / 2**64


@dataclass
class TransferConfig:
    chunk_bytes: int = 256 << 10  # straggler-monitoring granularity
    latency_s: float = 0.030  # per-transfer setup (TCP+auth handshake)
    n_streams: int = 4  # GridFTP parallel streams per stripe
    stream_efficiency: float = 0.85  # per-extra-stream scaling


def _single_stream_utilization() -> float:
    return 0.4  # one stream fills ~40% of a long fat pipe


def stream_utilization(n_streams: int) -> float:
    """Path utilization with n parallel streams: extra streams saturate
    harmonically (GridFTP's motivation for stream parallelism)."""
    n = max(int(n_streams), 1)
    su = _single_stream_utilization()
    return n * su / (1.0 + (n - 1) * su)


class SimulatedTransferService:
    """Implements the broker's :class:`~repro.core.broker.TransferService`
    protocol against a :class:`DataGrid`."""

    def __init__(
        self,
        grid: DataGrid,
        config: Optional[TransferConfig] = None,
        *,
        metrics: Any = None,
    ):
        self.grid = grid
        self.config = config or TransferConfig()
        self.transfer_count = 0
        self.bytes_moved = 0
        # optional obs registry (usually the owning broker's): per-op
        # transfer/byte counters, fault counters, effective-bandwidth
        # histogram over simulated wall time
        self.metrics = metrics
        if metrics is not None:
            self._c_transfers = {
                op: metrics.counter(
                    "transfer_total", "completed transfers by direction", op=op
                )
                for op in ("read", "write")
            }
            self._c_bytes = {
                op: metrics.counter(
                    "transfer_bytes_total", "payload bytes moved by direction", op=op
                )
                for op in ("read", "write")
            }
            self._c_faults = metrics.counter(
                "transfer_faults_total", "refused/dropped/died transfer attempts"
            )
            self._h_bw = metrics.histogram(
                "transfer_effective_bandwidth_mb_per_s",
                "achieved bandwidth per completed transfer (simulated time)",
                buckets=(0.1, 0.5, 1, 2, 5, 10, 25, 50, 100, 250, 1000, float("inf")),
            )

    def _record(self, op: str, nbytes: int, seconds: float) -> None:
        self.transfer_count += 1
        self.bytes_moved += nbytes
        if self.metrics is not None:
            self._c_transfers[op].inc()
            self._c_bytes[op].inc(nbytes)
            if seconds > 0:
                self._h_bw.observe(nbytes / seconds / 1e6)

    def _fault(self, msg: str) -> "TransferFailure":
        if self.metrics is not None:
            self._c_faults.inc()
        return TransferFailure(msg)

    # -- internal -----------------------------------------------------------
    def _endpoint(self, url: str) -> StorageEndpoint:
        ep = self.grid.endpoints.get(url)
        if ep is None:
            raise self._fault(f"unknown endpoint {url}")
        if not ep.alive:
            raise self._fault(f"endpoint {url} is down")
        return ep

    def _maybe_flake(self, ep: StorageEndpoint) -> None:
        if ep.flaky_rate > 0:
            ep._flaky_counter += 1
            if _stable_unit(ep.url, "flake", str(ep._flaky_counter)) < ep.flaky_rate:
                raise self._fault(f"endpoint {ep.url} dropped the connection")

    def _bandwidth(
        self, ep: StorageEndpoint, client_url: str, t: float, my_streams: int
    ) -> float:
        """This stripe's share of the path at virtual time ``t``.

        Utilization is a function of the endpoint's *total* concurrently
        open streams (``ep.active_streams``), split proportionally — not
        of a per-service constant — so concurrent stripes share one pipe.
        """
        bw = self.grid.net.effective_bandwidth(
            ep.url,
            client_url,
            t,
            load_factor=ep.active_transfers,
            disk_rate=ep.disk_rate,
        )
        total = max(ep.active_streams, my_streams, 1)
        share = stream_utilization(total) * (my_streams / total)
        return bw * ep.degradation * share

    def chunk_seconds(
        self,
        ep: StorageEndpoint,
        client_url: str,
        nbytes: int,
        t: float,
        my_streams: int,
    ) -> float:
        """Simulated seconds to move ``nbytes`` from ``ep`` at virtual
        time ``t`` while holding ``my_streams`` of the endpoint's open
        streams (the striped executor's per-chunk cost model)."""
        bw = self._bandwidth(ep, client_url, t, my_streams)
        return nbytes / bw if bw > 0 else math.inf

    # -- new surface: TransferRequest → TransferResult -----------------------
    def _resolve(self, request: TransferRequest) -> Tuple[StorageEndpoint, bytes, int]:
        """Endpoint + byte range for a request (no clock charged)."""
        ep = self._endpoint(request.replica.endpoint)
        data = ep.get(request.replica.path)
        end = (
            len(data)
            if request.length is None
            else min(request.offset + request.length, len(data))
        )
        return ep, data[request.offset : end], request.offset

    def transfer_chunks(self, request: TransferRequest) -> Iterator[ChunkEvent]:
        """Chunked read of the request's byte range; yields
        :class:`ChunkEvent`s and charges the shared clock as it goes.
        Instrumented server-side on completion (§3.2)."""
        ep, data, base = self._resolve(request)
        self._maybe_flake(ep)
        n_streams = request.n_streams or self.config.n_streams
        t0 = self.grid.clock.now()
        ep.active_transfers += 1
        ep.active_streams += n_streams
        total = len(data)
        sent = 0
        elapsed = self.config.latency_s
        self.grid.clock.advance(self.config.latency_s)
        try:
            while sent < total or total == 0:
                chunk = data[sent : sent + self.config.chunk_bytes]
                csecs = self.chunk_seconds(
                    ep, request.client_url, len(chunk), self.grid.clock.now(), n_streams
                )
                self.grid.clock.advance(csecs)
                elapsed += csecs
                yield ChunkEvent(chunk, len(chunk), csecs, base + sent, ep.url)
                sent += len(chunk)
                if total == 0:
                    break
                # endpoint may die mid-transfer (fault injection)
                if sent < total and not ep.alive:
                    raise self._fault(f"endpoint {ep.url} died mid-transfer")
                if sent < total:
                    self._maybe_flake(ep)
        finally:
            ep.active_transfers -= 1
            ep.active_streams -= n_streams
        # server-side instrumentation (§3.2): read = replica -> client
        ep.monitor.observe_transfer(
            "read", request.client_url, total, max(elapsed, 1e-9), t0
        )
        self._record("read", total, elapsed)

    def transfer(self, request: TransferRequest) -> TransferResult:
        """Whole-range single-source read → :class:`TransferResult`."""
        chunks: List[bytes] = []
        nbytes = 0
        seconds = self.config.latency_s
        for ev in self.transfer_chunks(request):
            chunks.append(ev.payload)
            nbytes += ev.nbytes
            seconds += ev.seconds
        return TransferResult(
            payload=b"".join(chunks),
            nbytes=nbytes,
            seconds=seconds,
            per_replica={request.replica.endpoint: nbytes},
            stripes=1,
            lfn=None,
        )

    # -- writes ----------------------------------------------------------------
    def write(
        self, endpoint_url: str, path: str, data: bytes, client_url: str
    ) -> TransferResult:
        """Client → endpoint write (checkpoint placement). Registers
        nothing — callers own the catalog."""
        ep = self._endpoint(endpoint_url)
        self._maybe_flake(ep)
        n_streams = self.config.n_streams
        t0 = self.grid.clock.now()
        ep.active_transfers += 1
        ep.active_streams += n_streams
        try:
            bw = self._bandwidth(ep, client_url, t0, n_streams)
            seconds = self.config.latency_s + (len(data) / bw if bw > 0 else math.inf)
            self.grid.clock.advance(seconds)
            ep.put(path, data)
        finally:
            ep.active_transfers -= 1
            ep.active_streams -= n_streams
        ep.monitor.observe_transfer("write", client_url, len(data), max(seconds, 1e-9), t0)
        self._record("write", len(data), seconds)
        return TransferResult(
            payload=None,
            nbytes=len(data),
            seconds=seconds,
            per_replica={endpoint_url: len(data)},
        )

    # -- deprecated tuple surface (shims only; no in-repo callers) -----------
    def read(self, replica: PhysicalFile, client_url: str) -> Tuple[bytes, int, float]:
        """Deprecated: use ``transfer(TransferRequest(replica, client_url))``."""
        warnings.warn(
            "SimulatedTransferService.read(replica, client_url) is deprecated; "
            "use transfer(TransferRequest(...)) -> TransferResult",
            DeprecationWarning,
            stacklevel=2,
        )
        res = self.transfer(TransferRequest(replica, client_url))
        return res.payload, res.nbytes, res.seconds

    def read_chunks(
        self, replica: PhysicalFile, client_url: str
    ) -> Iterator[Tuple[bytes, int, float]]:
        """Deprecated: use ``transfer_chunks(TransferRequest(...))``."""
        warnings.warn(
            "SimulatedTransferService.read_chunks(replica, client_url) is "
            "deprecated; use transfer_chunks(TransferRequest(...))",
            DeprecationWarning,
            stacklevel=2,
        )
        for ev in self.transfer_chunks(TransferRequest(replica, client_url)):
            yield ev.payload, ev.nbytes, ev.seconds
