"""Simulated GridFTP: the transfer engine behind the Access Phase.

"Once a suitable replica has been identified, the file is accessed using a
high-speed file transfer protocol, for example the GridFTP tools" (§5.1.2).

The engine moves *real bytes* between endpoints and clients while charging
simulated wall-time against the shared deterministic clock. Every transfer
is instrumented on the server side (the endpoint's TransferMonitor → GRIS,
§3.2) — which is precisely the feedback loop the broker's history-based
rank expressions read. Transfers are chunked so the broker can watch
in-flight bandwidth for straggler mitigation, and parallel streams model
GridFTP's stream parallelism (diminishing returns past the path's
capacity).
"""

from __future__ import annotations

import hashlib
import math
from dataclasses import dataclass
from typing import Any, Iterator, List, Optional, Tuple

from repro.core.catalog import PhysicalFile

from .endpoint import DataGrid, StorageEndpoint

__all__ = ["TransferFailure", "SimulatedTransferService"]


class TransferFailure(IOError):
    """Endpoint dead / refused / mid-transfer fault."""


def _stable_unit(*keys: str) -> float:
    h = hashlib.sha256("|".join(keys).encode()).digest()
    return int.from_bytes(h[:8], "big") / 2**64


@dataclass
class TransferConfig:
    chunk_bytes: int = 256 << 10  # straggler-monitoring granularity
    latency_s: float = 0.030  # per-transfer setup (TCP+auth handshake)
    n_streams: int = 4  # GridFTP parallel streams
    stream_efficiency: float = 0.85  # per-extra-stream scaling


class SimulatedTransferService:
    """Implements the broker's :class:`~repro.core.broker.TransferService`
    protocol against a :class:`DataGrid`."""

    def __init__(
        self,
        grid: DataGrid,
        config: Optional[TransferConfig] = None,
        *,
        metrics: Any = None,
    ):
        self.grid = grid
        self.config = config or TransferConfig()
        self.transfer_count = 0
        self.bytes_moved = 0
        # optional obs registry (usually the owning broker's): per-op
        # transfer/byte counters, fault counters, effective-bandwidth
        # histogram over simulated wall time
        self.metrics = metrics
        if metrics is not None:
            self._c_transfers = {
                op: metrics.counter(
                    "transfer_total", "completed transfers by direction", op=op
                )
                for op in ("read", "write")
            }
            self._c_bytes = {
                op: metrics.counter(
                    "transfer_bytes_total", "payload bytes moved by direction", op=op
                )
                for op in ("read", "write")
            }
            self._c_faults = metrics.counter(
                "transfer_faults_total", "refused/dropped/died transfer attempts"
            )
            self._h_bw = metrics.histogram(
                "transfer_effective_bandwidth_mb_per_s",
                "achieved bandwidth per completed transfer (simulated time)",
                buckets=(0.1, 0.5, 1, 2, 5, 10, 25, 50, 100, 250, 1000, float("inf")),
            )

    def _record(self, op: str, nbytes: int, seconds: float) -> None:
        self.transfer_count += 1
        self.bytes_moved += nbytes
        if self.metrics is not None:
            self._c_transfers[op].inc()
            self._c_bytes[op].inc(nbytes)
            if seconds > 0:
                self._h_bw.observe(nbytes / seconds / 1e6)

    def _fault(self, msg: str) -> "TransferFailure":
        if self.metrics is not None:
            self._c_faults.inc()
        return TransferFailure(msg)

    # -- internal -----------------------------------------------------------
    def _endpoint(self, url: str) -> StorageEndpoint:
        ep = self.grid.endpoints.get(url)
        if ep is None:
            raise self._fault(f"unknown endpoint {url}")
        if not ep.alive:
            raise self._fault(f"endpoint {url} is down")
        return ep

    def _maybe_flake(self, ep: StorageEndpoint) -> None:
        if ep.flaky_rate > 0:
            ep._flaky_counter += 1
            if _stable_unit(ep.url, "flake", str(ep._flaky_counter)) < ep.flaky_rate:
                raise self._fault(f"endpoint {ep.url} dropped the connection")

    def _stream_utilization(self) -> float:
        """Path utilization with n parallel streams: a single stream only
        fills ~40% of a long fat pipe; extra streams saturate harmonically
        (GridFTP's motivation for stream parallelism)."""
        n = max(self.config.n_streams, 1)
        su = 0.4  # single-stream utilization
        return n * su / (1.0 + (n - 1) * su)

    def _bandwidth(self, ep: StorageEndpoint, client_url: str, t: float) -> float:
        bw = self.grid.net.effective_bandwidth(
            ep.url,
            client_url,
            t,
            load_factor=ep.active_transfers,
            disk_rate=ep.disk_rate,
        )
        return bw * ep.degradation * self._stream_utilization()

    # -- reads ----------------------------------------------------------------
    def read(self, replica: PhysicalFile, client_url: str) -> Tuple[bytes, int, float]:
        """Whole-file read. Returns (payload, nbytes, seconds)."""
        chunks: List[bytes] = []
        nbytes = 0
        seconds = 0.0
        for payload, cbytes, csecs in self.read_chunks(replica, client_url):
            chunks.append(payload)
            nbytes += cbytes
            seconds += csecs
        return b"".join(chunks), nbytes, seconds

    def read_chunks(
        self, replica: PhysicalFile, client_url: str
    ) -> Iterator[Tuple[bytes, int, float]]:
        """Chunked read; yields (chunk, nbytes, seconds) and charges the
        clock as it goes. Instrumented server-side on completion."""
        ep = self._endpoint(replica.endpoint)
        self._maybe_flake(ep)
        data = ep.get(replica.path)
        t0 = self.grid.clock.now()
        ep.active_transfers += 1
        total = len(data)
        sent = 0
        elapsed = self.config.latency_s
        self.grid.clock.advance(self.config.latency_s)
        try:
            while sent < total or total == 0:
                chunk = data[sent : sent + self.config.chunk_bytes]
                bw = self._bandwidth(ep, client_url, self.grid.clock.now())
                csecs = len(chunk) / bw if bw > 0 else math.inf
                self.grid.clock.advance(csecs)
                elapsed += csecs
                sent += len(chunk)
                yield chunk, len(chunk), csecs
                if total == 0:
                    break
                # endpoint may die mid-transfer (fault injection)
                if not ep.alive:
                    raise self._fault(f"endpoint {ep.url} died mid-transfer")
                self._maybe_flake(ep)
        finally:
            ep.active_transfers -= 1
        # server-side instrumentation (§3.2): read = replica -> client
        ep.monitor.observe_transfer("read", client_url, total, max(elapsed, 1e-9), t0)
        self._record("read", total, elapsed)

    # -- writes ----------------------------------------------------------------
    def write(
        self, endpoint_url: str, path: str, data: bytes, client_url: str
    ) -> Tuple[int, float]:
        """Client → endpoint write (checkpoint placement). Returns
        (nbytes, seconds); registers nothing — callers own the catalog."""
        ep = self._endpoint(endpoint_url)
        self._maybe_flake(ep)
        t0 = self.grid.clock.now()
        ep.active_transfers += 1
        try:
            bw = self._bandwidth(ep, client_url, t0)
            seconds = self.config.latency_s + (len(data) / bw if bw > 0 else math.inf)
            self.grid.clock.advance(seconds)
            ep.put(path, data)
        finally:
            ep.active_transfers -= 1
        ep.monitor.observe_transfer("write", client_url, len(data), max(seconds, 1e-9), t0)
        self._record("write", len(data), seconds)
        return len(data), seconds
