"""Simulated storage endpoints and the DataGrid facade.

A :class:`StorageEndpoint` is the stand-in for one GridFTP server + volume:
it *stores real bytes* (checkpoint integrity tests read them back), tracks
capacity, exposes a Storage GRIS whose dynamic attributes are provider
callbacks over live endpoint state (≙ shell-backends), and owns the
TransferMonitor that instruments every transfer through it (≙ the paper's
tuned FTP server).

:class:`DataGrid` assembles endpoints + topology + GIIS + replica catalog
into one simulated grid and hands out per-client brokers — the unit every
example, test and the training data pipeline builds on.
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

from repro.core.bandwidth import TransferMonitor
from repro.core.broker import DataBroker
from repro.core.catalog import PhysicalFile, ReplicaCatalog
from repro.core.giis import GIIS
from repro.core.gris import Clock, StorageGRIS

from .simnet import NetModel, ZoneTopology

__all__ = ["StorageEndpoint", "DataGrid", "checksum"]


def checksum(data: bytes) -> str:
    return hashlib.sha256(data).hexdigest()[:16]


class StorageEndpoint:
    """One storage resource: volume + GRIS + transfer instrumentation."""

    def __init__(
        self,
        url: str,
        *,
        capacity: int = 1 << 40,  # 1 TiB
        disk_rate: float = 800e6,  # B/s
        drd_time: float = 4e-3,  # seek times (Figure 2)
        dwr_time: float = 5e-3,
        mount_point: str = "/data",
        zone: str = "default",
        policy: Optional[str] = None,  # admin `requirements` ClassAd source
        clock: Optional[Clock] = None,
        gris_ttl: float = 5.0,
    ):
        self.url = url
        self.capacity = int(capacity)
        self.disk_rate = float(disk_rate)
        self.zone = zone
        self.clock = clock or Clock()
        self._store: Dict[str, bytes] = {}
        self._used = 0
        self.alive = True
        self.degradation = 1.0  # multiplicative bandwidth penalty (1 = none)
        self.flaky_rate = 0.0  # probability a transfer fails outright
        self._flaky_counter = 0
        self.active_transfers = 0
        # total parallel streams currently open to this endpoint, across
        # every in-flight transfer/stripe: path utilization is a function
        # of this total (per-endpoint accounting, not per-service)
        self.active_streams = 0

        static = {
            "hostname": url,
            "mountPoint": mount_point,
            "diskTransferRate": self.disk_rate,
            "drdTime": drd_time,
            "dwrTime": dwr_time,
            "zone": zone,
        }
        if policy:
            static["requirements"] = policy
        self.gris = StorageGRIS(f"gss={url}, o=grid", static, clock=self.clock)
        # Dynamic attributes — provider callbacks over live state, the
        # in-process analogue of the paper's shell-backend scripts.
        self.gris.register_dynamic("totalSpace", lambda: float(self.capacity), ttl=gris_ttl)
        self.gris.register_dynamic("availableSpace", lambda: float(self.available), ttl=gris_ttl)
        self.gris.register_dynamic("loadFactor", lambda: float(self.active_transfers), ttl=gris_ttl)
        self.monitor = TransferMonitor(self.gris)

    # -- volume ------------------------------------------------------------
    @property
    def available(self) -> int:
        return self.capacity - self._used

    @property
    def used(self) -> int:
        return self._used

    def put(self, path: str, data: bytes) -> None:
        old = len(self._store.get(path, b""))
        new_used = self._used - old + len(data)
        if new_used > self.capacity:
            raise IOError(f"{self.url}: volume full ({new_used} > {self.capacity})")
        self._store[path] = bytes(data)
        self._used = new_used
        self.gris.invalidate("availableSpace")

    def get(self, path: str) -> bytes:
        if path not in self._store:
            raise FileNotFoundError(f"{self.url}:{path}")
        return self._store[path]

    def delete(self, path: str) -> None:
        data = self._store.pop(path, None)
        if data is not None:
            self._used -= len(data)
            self.gris.invalidate("availableSpace")

    def has(self, path: str) -> bool:
        return path in self._store

    def paths(self) -> List[str]:
        return sorted(self._store)

    # -- fault state (driven by faults.FaultInjector) -----------------------
    def kill(self) -> None:
        self.alive = False

    def heal(self) -> None:
        self.alive = True
        self.degradation = 1.0
        self.flaky_rate = 0.0


class DataGrid:
    """The whole simulated grid: endpoints, topology, catalog, index.

    One instance per test/benchmark/training-job; per-client brokers come
    from :meth:`broker_for` and share nothing mutable except the published
    world state (catalog + GRIS), exactly as §5.1.1 prescribes.
    """

    def __init__(self, *, seed: int = 0, clock: Optional[Clock] = None):
        self.clock = clock or Clock()
        self.topology = ZoneTopology()
        self.net = NetModel(self.topology, seed=seed)
        self.catalog = ReplicaCatalog()
        self.giis = GIIS("o=grid", clock=self.clock)
        self.endpoints: Dict[str, StorageEndpoint] = {}
        self.seed = seed

    # -- construction ------------------------------------------------------
    def add_endpoint(
        self,
        url: str,
        *,
        zone: str = "default",
        region: Optional[str] = None,
        **kwargs,
    ) -> StorageEndpoint:
        ep = StorageEndpoint(url, zone=zone, clock=self.clock, **kwargs)
        self.endpoints[url] = ep
        self.topology.assign(url, zone, region)
        self.giis.register(url, ep.gris)
        return ep

    def add_client(self, url: str, zone: str = "default", region: Optional[str] = None) -> None:
        self.topology.assign(url, zone, region)

    def gris_for(self, endpoint_url: str) -> Optional[StorageGRIS]:
        ep = self.endpoints.get(endpoint_url)
        if ep is None or not ep.alive:
            return None  # a dead endpoint's GRIS is unreachable
        return ep.gris

    def broker_for(self, client_url: str, **kwargs) -> DataBroker:
        return DataBroker(
            client_url, self.catalog, self.gris_for, clock=self.clock, **kwargs
        )

    def transfer_service(self, *, metrics=None, config=None):
        from .transfer import SimulatedTransferService

        return SimulatedTransferService(self, config, metrics=metrics)

    def resilient_transfer_service(self, broker, *, config=None, resilience=None):
        """A :class:`~repro.storage.resilient.ResilientTransferService`
        bound to one client's broker: striped/hedged plan execution with
        retry, restart markers, and breaker → GRIS feedback."""
        from .resilient import ResilientTransferService

        return ResilientTransferService(
            self, broker, config=config, resilience=resilience
        )

    # -- replication helpers ------------------------------------------------
    def store_replica(self, lfn: str, endpoint_url: str, data: bytes, path: Optional[str] = None) -> PhysicalFile:
        """Write bytes to an endpoint and register the replica."""
        ep = self.endpoints[endpoint_url]
        path = path or f"/data/{lfn}"
        ep.put(path, data)
        pfn = PhysicalFile(endpoint_url, path, len(data), checksum(data))
        self.catalog.register_replica(lfn, pfn)
        return pfn

    def replicate(self, lfn: str, data: bytes, endpoint_urls: Sequence[str]) -> List[PhysicalFile]:
        return [self.store_replica(lfn, ep, data) for ep in endpoint_urls]

    def drop_endpoint(self, url: str) -> None:
        """Declare an endpoint dead: GRIS unreachable, transfers fail.
        Catalog entries are left in place — brokers must failover, and the
        repair daemon (checkpoint/placement) re-replicates."""
        self.endpoints[url].kill()

    def alive_endpoints(self) -> List[str]:
        return sorted(u for u, e in self.endpoints.items() if e.alive)


def build_demo_grid(
    n_endpoints: int = 8,
    n_zones: int = 4,
    *,
    seed: int = 0,
    capacity: int = 1 << 34,
    clock: Optional[Clock] = None,
    policy_every: int = 3,
    policy: str = "other.reqdSpace <= 10G",
) -> DataGrid:
    """A small heterogeneous grid used by tests/examples: endpoints spread
    over zones, every ``policy_every``-th endpoint publishing a usage
    policy like the paper's hugo.mcs.anl.gov ad."""
    grid = DataGrid(seed=seed, clock=clock)
    for i in range(n_endpoints):
        zone = f"zone{i % n_zones}"
        grid.add_endpoint(
            f"gsiftp://ep{i:03d}",
            zone=zone,
            region="region0" if (i % n_zones) < max(n_zones // 2, 1) else "region1",
            capacity=capacity,
            disk_rate=200e6 * (1 + (i % 5)),
            policy=policy if (policy_every and i % policy_every == 0) else None,
        )
    return grid
