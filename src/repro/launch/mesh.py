"""Production mesh construction.

Single pod: ``(data=16, model=16)`` = 256 TPU v5e chips.
Multi-pod:  ``(pod=2, data=16, model=16)`` = 512 chips; the ``pod`` axis is
an additional pure-DP axis crossing the inter-pod DCN links (its
collectives are the expensive ones — see EXPERIMENTS.md §Roofline).

``make_production_mesh`` is a function, not a module constant: importing
this module must never touch jax device state (the dry-run sets
``XLA_FLAGS`` before first jax init; tests must keep seeing 1 device).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax

#: v5e hardware constants used by the roofline analysis
TPU_V5E = {
    "peak_bf16_flops": 197e12,  # per chip
    "hbm_bw": 819e9,  # B/s per chip
    "ici_link_bw": 50e9,  # B/s per link
    "hbm_bytes": 16 * 1024**3,
    "vmem_bytes": 128 * 1024**2,
    "dcn_bw": 25e9,  # per host, inter-pod
}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Arbitrary mesh (elastic re-shapes, tests)."""
    return jax.make_mesh(shape, axes)


def data_axes(mesh) -> Tuple[str, ...]:
    """The data-parallel axes of a mesh (everything that isn't 'model')."""
    return tuple(a for a in mesh.axis_names if a != "model")


def mesh_chips(mesh) -> int:
    n = 1
    for s in mesh.devices.shape:
        n *= s
    return n
