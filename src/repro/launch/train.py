"""Production training launcher.

Assembles the full stack for one host of a (multi-pod) training job:

  simulated data grid (or a real one behind the same interfaces)
  → replicated dataset shards (broker-selected on every fetch)
  → fault-tolerant TrainLoop (checkpoint/restart, straggler monitor,
    chaos schedule if requested)
  → per-arch config from the registry, reduced or full.

On this CPU container the full production meshes only *lower* (see
dryrun.py); ``--reduced`` runs a real training loop end to end. The same
launcher drives both, which is the point: config, data plane and loop are
identical, only the mesh axis sizes change.

  PYTHONPATH=src python -m repro.launch.train --arch granite-moe-3b-a800m \
      --reduced --steps 100 --batch 8 --seq 128 --chaos
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Optional

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import get_arch, list_archs
from repro.data.datasets import ShardManifest, SyntheticCorpus, materialize_on_grid
from repro.data.pipeline import BatchSpec, DataPipeline
from repro.storage.endpoint import build_demo_grid
from repro.storage.faults import FaultInjector
from repro.train.loop import LoopConfig, TrainLoop
from repro.train.optim import AdamWConfig
from repro.train.train_step import TrainConfig


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--reduced", action="store_true",
                    help="train the reduced config (CPU-feasible)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--int8-moments", action="store_true")
    ap.add_argument("--endpoints", type=int, default=8)
    ap.add_argument("--replication", type=int, default=2)
    ap.add_argument("--shards", type=int, default=16)
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--async-checkpoint", action="store_true")
    ap.add_argument("--chaos", action="store_true",
                    help="schedule random endpoint kills/degradations")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--metrics-out", default=None)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    elif cfg.param_counts()["total"] > 5e8:
        print(
            f"WARNING: {args.arch} full config on CPU — use --reduced "
            "(full configs are exercised via launch.dryrun)",
            file=sys.stderr,
        )

    # --- the data grid ---
    grid = build_demo_grid(args.endpoints, max(args.endpoints // 2, 2), seed=args.seed)
    host = "client://train-host0"
    grid.add_client(host, zone="zone0")
    manifest = ShardManifest(
        f"{args.arch}-corpus", args.shards, tokens_per_shard=50_000,
        vocab_size=cfg.vocab_size, seed=args.seed,
    )
    materialize_on_grid(SyntheticCorpus(manifest), grid, replication=args.replication)

    pipeline = DataPipeline(
        host, 0, 1, grid, manifest, BatchSpec(args.batch, args.seq)
    )
    broker = grid.broker_for(host)
    ckpt = CheckpointManager(f"run-{args.arch}", grid, broker,
                             replication=args.replication, chunk_bytes=1 << 20)

    faults: Optional[FaultInjector] = None
    if args.chaos:
        faults = FaultInjector(grid)
        n = faults.chaos(horizon=3600.0, mtbf=600.0, mttr=120.0, seed=args.seed)
        print(f"chaos: scheduled {n} fault events")

    tc = TrainConfig(
        optimizer=AdamWConfig(
            lr=args.lr,
            moments_dtype="int8" if args.int8_moments else "float32",
        ),
        n_microbatches=args.microbatches,
        warmup_steps=max(args.steps // 20, 1),
        total_steps=args.steps,
        grad_compression=args.grad_compression,
    )
    lc = LoopConfig(
        total_steps=args.steps,
        checkpoint_every=args.checkpoint_every,
        log_every=max(args.steps // 20, 1),
        async_checkpoint=args.async_checkpoint,
        repair_every=args.checkpoint_every * 2 if args.chaos else 0,
    )
    loop = TrainLoop(cfg, tc, lc, pipeline, ckpt, faults=faults, rng_seed=args.seed)
    loop.run()

    losses = loop.losses()
    summary = {
        "arch": args.arch,
        "steps": len(losses),
        "loss_first": losses[0] if losses else None,
        "loss_last": losses[-1] if losses else None,
        "events": loop.events[-20:],
        "pipeline": pipeline.stats,
        "broker": broker.stats,
        "checkpoint": ckpt.stats,
        "fleet": loop.monitor.fleet_summary(),
    }
    print(json.dumps(summary, indent=2, default=str))
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump({"summary": summary, "losses": losses}, f)
    return 0


if __name__ == "__main__":
    sys.exit(main())
