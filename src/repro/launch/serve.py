"""Serving launcher: broker-selected weight loading + batched generation.

Demonstrates the paper's mechanism on the *model distribution* path: the
checkpointed weights are replicated across the grid; a serving replica
brokers each weight-chunk read (rank = predicted bandwidth to *this*
host), then serves batched greedy generation with the reduced config.

  PYTHONPATH=src python -m repro.launch.serve --arch mistral-nemo-12b \
      --reduced --batch 4 --max-new 32
"""

from __future__ import annotations

import argparse
import json
import sys

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import get_arch, list_archs
from repro.data.tokenizer import ByteTokenizer
from repro.models import transformer
from repro.serve.engine import ServeEngine
from repro.storage.endpoint import build_demo_grid


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--endpoints", type=int, default=6)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="dump the shared metrics registry (JSON + Prometheus "
                         "exposition) after serving")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="dump Chrome trace-event JSON (load in Perfetto)")
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch).reduced()
    rng = jax.random.PRNGKey(args.seed)
    params = transformer.init_params(cfg, rng)

    # publish weights onto the grid, then load them back through the broker
    grid = build_demo_grid(args.endpoints, 3, seed=args.seed)
    host = "client://serve-replica0"
    grid.add_client(host, zone="zone1")
    broker = grid.broker_for(host)
    # attach the broker's registry to every GRIS it polls: query counts and
    # TTL hit rates land in the same exposition as the broker's own series
    for ep in grid.endpoints.values():
        ep.gris.metrics = broker.metrics
    mgr = CheckpointManager(f"weights-{args.arch}", grid, broker,
                            replication=2, chunk_bytes=1 << 20)
    mgr.save(0, params)
    engine = ServeEngine.from_grid(
        cfg, mgr, 0, jax.eval_shape(lambda: params),
        max_seq=args.prompt_len + args.max_new + 8,
    )
    print(f"weights loaded via broker: {broker.stats['fetches']} fetches, "
          f"{broker.stats['failovers']} failovers, "
          f"{engine.selection_stats['batches']} batched selection launches "
          f"({engine.selection_stats['coalescing_ratio']:.1f}x coalescing)")
    tok = ByteTokenizer(cfg.vocab_size)
    rng_np = np.random.default_rng(args.seed)
    prompts = rng_np.integers(4, cfg.vocab_size, (args.batch, args.prompt_len)).astype(np.int32)

    extras = {}
    if cfg.family == "vlm":
        extras["patch_embeds"] = 0.01 * np.ones(
            (args.batch, cfg.n_patches, cfg.d_model), np.float32
        )
    if cfg.enc_dec:
        extras["frames"] = 0.01 * np.ones(
            (args.batch, cfg.enc_seq, cfg.d_model), np.float32
        )
    result = engine.generate(prompts, max_new=args.max_new, extras=extras or None)
    print(json.dumps({
        "arch": args.arch,
        "generated_tokens": int(result.n_generated.sum()),
        "prefill_s": round(result.prefill_s, 3),
        "decode_s": round(result.decode_s, 3),
        "decode_tok_per_s": round(result.decode_tokens_per_s, 1),
    }, indent=2))
    if args.metrics_out:
        broker.metrics.dump_json(args.metrics_out, extra={"arch": args.arch})
        print(f"metrics registry -> {args.metrics_out}")
    if args.trace_out:
        broker.tracer.dump_json(args.trace_out)
        print(f"chrome trace -> {args.trace_out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
