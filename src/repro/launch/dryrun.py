import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines, before ANY other import (jax locks the
#   device count on first init). The dry-run, and only the dry-run, sees
#   512 placeholder devices; tests and benches keep seeing 1.

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Proves the distribution config is coherent without hardware: for each of
the 10 assigned architectures × their 4 input shapes, on the single-pod
``(data=16, model=16)`` and multi-pod ``(pod=2, data=16, model=16)``
meshes, the train / prefill / decode step is ``jit(...).lower(...).
compile()``d from ShapeDtypeStructs (no allocation). Each cell records:

  * ``memory_analysis()`` — per-device bytes (does it fit 16 GB v5e HBM),
  * ``cost_analysis()``   — FLOPs / bytes for the roofline,
  * collective bytes parsed from the partitioned HLO (launch/roofline.py),
  * the sharding-policy fallbacks taken (every divisibility degradation).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch granite-20b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod both -o experiments/dryrun
"""

import argparse
import json
import sys
import time
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import INPUT_SHAPES, ArchConfig, ShapeSpec, get_arch, list_archs
from repro.models import transformer
from repro.parallel.ctx import activation_sharding
from repro.parallel.sharding import ShardingPolicy, _path_str
from repro.train.optim import AdamWConfig
from repro.train.train_step import TrainConfig, init_train_state, make_train_step

from .hlo_analysis import analyze_hlo
from .mesh import TPU_V5E, make_production_mesh
from .roofline import roofline_report

# cells skipped per the long_500k sub-quadratic rule (DESIGN.md §4)
LONG_CTX_ARCHS = {"h2o-danube3-4b", "jamba-v0.1-52b", "mamba2-130m"}


def shape_cells(arch: str):
    for sname, spec in INPUT_SHAPES.items():
        if sname == "long_500k" and arch not in LONG_CTX_ARCHS:
            continue
        yield sname, spec


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins, no allocation)
# ---------------------------------------------------------------------------


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_input_specs(cfg: ArchConfig, shape: ShapeSpec) -> Dict[str, Any]:
    gb, s = shape.global_batch, shape.seq_len
    if cfg.family == "vlm":
        text = s - cfg.n_patches
        return {
            "tokens": _sds((gb, text), jnp.int32),
            "labels": _sds((gb, text), jnp.int32),
            "patch_embeds": _sds((gb, cfg.n_patches, cfg.d_model), jnp.bfloat16),
        }
    if cfg.enc_dec:
        return {
            "tokens": _sds((gb, s), jnp.int32),
            "labels": _sds((gb, s), jnp.int32),
            "frames": _sds((gb, cfg.enc_seq, cfg.d_model), jnp.bfloat16),
        }
    return {
        "tokens": _sds((gb, s), jnp.int32),
        "labels": _sds((gb, s), jnp.int32),
    }


def prefill_input_specs(cfg: ArchConfig, shape: ShapeSpec) -> Dict[str, Any]:
    spec = train_input_specs(cfg, shape)
    spec.pop("labels")
    return spec


def decode_input_specs(cfg: ArchConfig, shape: ShapeSpec):
    """(tokens, caches, step_pos) stand-ins for one decode step with a KV
    cache of seq_len."""
    gb, s = shape.global_batch, shape.seq_len
    caches = jax.eval_shape(
        lambda: transformer.init_caches(cfg, gb, s, jnp.bfloat16)
    )
    return (
        _sds((gb, 1), jnp.int32),
        caches,
        _sds((gb,), jnp.int32),
    )


# ---------------------------------------------------------------------------
# policy / shardings per cell
# ---------------------------------------------------------------------------


def policy_for(cfg: ArchConfig, shape: ShapeSpec, mesh, *, overrides=None) -> ShardingPolicy:
    ov = overrides or {}
    model = 1
    try:
        model = mesh.shape["model"]
    except Exception:
        pass
    params_f32 = 4 * cfg.param_counts()["total_with_emb"]
    zero3 = ov.get("zero3")
    if zero3 is None:
        # FSDP when TP alone leaves >2 GB of fp32 master weights per device
        # (leaves room for grads + accumulators + activations in 16 GB HBM)
        zero3 = params_f32 / max(model, 1) > 2 * 1024**3
    return ShardingPolicy(
        mesh=mesh,
        expert_parallel=ov.get("expert_parallel", False),
        zero3=zero3,
        zero1=ov.get("zero1", True),
        seq_shard_cache=(shape.name == "long_500k"),
        cache_kv_heads=cfg.n_kv_heads,
    )


def shardings_for_tree(tree, mesh, spec_fn):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, spec_fn(_path_str(path), tuple(leaf.shape))),
        tree,
    )


def _rep(mesh):
    return NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# lowering per cell
# ---------------------------------------------------------------------------


def lower_cell(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    overrides: Optional[Dict] = None,
    tc: Optional[TrainConfig] = None,
):
    cfg = get_arch(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    policy = policy_for(cfg, shape, mesh, overrides=overrides)
    t0 = time.time()  # lint: allow-wallclock

    if shape.kind == "train":
        # default microbatching: keep ≈2 sequences per device per microstep
        data_par = mesh.devices.size // mesh.shape.get("model", 1)
        per_dev = max(shape.global_batch // max(data_par, 1), 1)
        default_micro = max(per_dev // 2, 1)
        big = 4 * cfg.param_counts()["total_with_emb"] / max(
            mesh.shape.get("model", 1), 1
        ) > 2 * 1024**3
        tc = tc or TrainConfig(
            optimizer=AdamWConfig(
                moments_dtype="int8" if cfg.param_counts()["total"] > 1e11 else "float32",
                # big archs: bf16 live params + f32 master in opt state —
                # halves FSDP weight-gathers and gradient reductions
                master_dtype="float32" if big else "none",
            ),
            n_microbatches=(overrides or {}).get("n_microbatches", default_micro),
        )
        state_shapes = jax.eval_shape(
            lambda: init_train_state(cfg, tc, jax.random.PRNGKey(0))
        )

        def state_spec(path, shp):
            if path.startswith("params/"):
                return policy.param_spec(path[len("params/"):], shp)
            if path.startswith("opt/"):
                return policy.opt_spec(path.split("/", 2)[-1], shp)
            return P()

        state_sh = shardings_for_tree(state_shapes, mesh, state_spec)
        batch_shapes = train_input_specs(cfg, shape)
        batch_sh = {
            k: NamedSharding(mesh, policy.batch_spec(tuple(v.shape)))
            for k, v in batch_shapes.items()
        }
        step = make_train_step(cfg, tc, param_shardings=state_sh.params)
        jitted = jax.jit(
            step,
            in_shardings=(state_sh, batch_sh),
            out_shardings=(state_sh, None),
            donate_argnums=(0,),
        )
        with mesh, activation_sharding(policy.dp_axes):
            lowered = jitted.lower(state_shapes, batch_shapes)

    elif shape.kind == "prefill":
        params_shapes = jax.eval_shape(lambda: transformer.init_params(cfg, jax.random.PRNGKey(0)))
        params_sh = shardings_for_tree(
            params_shapes, mesh, lambda p, s: policy.param_spec(p, s)
        )
        batch_shapes = prefill_input_specs(cfg, shape)
        batch_sh = {
            k: NamedSharding(mesh, policy.batch_spec(tuple(v.shape)))
            for k, v in batch_shapes.items()
        }
        fn = lambda p, b: transformer.prefill(p, b, cfg, max_seq=shape.seq_len)
        jitted = jax.jit(fn, in_shardings=(params_sh, batch_sh))
        with mesh, activation_sharding(policy.dp_axes):
            lowered = jitted.lower(params_shapes, batch_shapes)

    else:  # decode
        params_shapes = jax.eval_shape(lambda: transformer.init_params(cfg, jax.random.PRNGKey(0)))
        params_sh = shardings_for_tree(
            params_shapes, mesh, lambda p, s: policy.param_spec(p, s)
        )
        tokens, caches, pos = decode_input_specs(cfg, shape)
        caches_sh = shardings_for_tree(
            caches, mesh, lambda p, s: policy.cache_spec(p, s)
        )
        tok_sh = NamedSharding(mesh, policy.batch_spec(tuple(tokens.shape)))
        pos_sh = NamedSharding(mesh, policy.batch_spec(tuple(pos.shape)))
        fn = lambda p, t, c, s: transformer.decode_step(p, t, c, s, cfg)
        jitted = jax.jit(
            fn,
            in_shardings=(params_sh, tok_sh, caches_sh, pos_sh),
            out_shardings=(None, caches_sh),
            donate_argnums=(2,),
        )
        with mesh, activation_sharding(policy.dp_axes):
            lowered = jitted.lower(params_shapes, tokens, caches, pos)

    return lowered, mesh, policy, cfg, shape, time.time() - t0  # lint: allow-wallclock


def run_cell(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    overrides: Optional[Dict] = None,
    hlo_out: Optional[str] = None,
) -> Dict[str, Any]:
    lowered, mesh, policy, cfg, shape, lower_s = lower_cell(
        arch, shape_name, multi_pod=multi_pod, overrides=overrides
    )
    t0 = time.time()  # lint: allow-wallclock
    compiled = lowered.compile()
    compile_s = time.time() - t0  # lint: allow-wallclock

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    hlo = compiled.as_text()
    if hlo_out:
        with open(hlo_out, "w") as f:
            f.write(hlo)
    hc = analyze_hlo(hlo)
    n_chips = mesh.devices.size

    result: Dict[str, Any] = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "multi_pod": multi_pod,
        "chips": int(n_chips),
        "lower_s": round(lower_s, 1),
        "compile_s": round(compile_s, 1),
        "overrides": overrides or {},
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "peak_bytes_per_device": getattr(mem, "temp_size_in_bytes", 0)
            + getattr(mem, "argument_size_in_bytes", 0),
        },
        "xla_cost": {k: cost.get(k, 0.0) for k in ("flops", "bytes accessed") if cost},
        "hlo_cost": hc.as_dict(),
        "policy_fallbacks": policy.explain(),
    }
    result["roofline"] = roofline_report(
        cfg, shape, hc, n_chips=n_chips, xla_cost=result["xla_cost"],
        memory=result["memory"],
    )
    return result


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="architecture id (default: all)")
    ap.add_argument("--shape", default=None, help="input shape name (default: all)")
    ap.add_argument("--all", action="store_true")
    ap.add_argument(
        "--multi-pod", choices=("off", "on", "both"), default="off", dest="multi_pod"
    )
    ap.add_argument("-o", "--out-dir", default=None)
    ap.add_argument("--hlo-dir", default=None, help="dump partitioned HLO per cell")
    ap.add_argument("--override", action="append", default=[],
                    help="policy override key=value (e.g. expert_parallel=1)")
    args = ap.parse_args(argv)

    overrides: Dict[str, Any] = {}
    for ov in args.override:
        k, _, v = ov.partition("=")
        overrides[k] = int(v) if v.isdigit() else v
    archs = [args.arch] if args.arch else list_archs()
    pods = {"off": [False], "on": [True], "both": [False, True]}[args.multi_pod]

    failures = []
    for arch in archs:
        for sname, _spec in shape_cells(arch):
            if args.shape and sname != args.shape:
                continue
            for mp in pods:
                tag = f"{arch}--{sname}--{'pod2' if mp else 'pod1'}"
                hlo_out = f"{args.hlo_dir}/{tag}.hlo" if args.hlo_dir else None
                try:
                    res = run_cell(
                        arch, sname, multi_pod=mp, overrides=overrides or None,
                        hlo_out=hlo_out,
                    )
                    line = (
                        f"{tag}: OK compile={res['compile_s']}s "
                        f"mem/dev={res['memory']['peak_bytes_per_device']/2**30:.2f}GiB "
                        f"bottleneck={res['roofline']['bottleneck']}"
                    )
                    print(line, flush=True)
                    if args.out_dir:
                        os.makedirs(args.out_dir, exist_ok=True)
                        with open(f"{args.out_dir}/{tag}.json", "w") as f:
                            json.dump(res, f, indent=1)
                except Exception as e:  # noqa: BLE001 — report and continue
                    failures.append((tag, repr(e)))
                    print(f"{tag}: FAIL {e!r}", flush=True)
    if failures:
        print(f"\n{len(failures)} failures:")
        for tag, err in failures:
            print(f"  {tag}: {err[:200]}")
        return 1
    print("\nall cells passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
