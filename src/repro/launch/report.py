"""Render EXPERIMENTS.md tables from the dry-run JSON directory.

  PYTHONPATH=src python -m repro.launch.report experiments/dryrun
"""

import json
import os
import sys
from collections import defaultdict

ARCH_ORDER = [
    "granite-20b", "mistral-nemo-12b", "nemotron-4-340b", "h2o-danube3-4b",
    "jamba-v0.1-52b", "granite-moe-3b-a800m", "moonshot-v1-16b-a3b",
    "llava-next-34b", "whisper-base", "mamba2-130m",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
SKIPPED_LONG = [
    "granite-20b", "mistral-nemo-12b", "nemotron-4-340b",
    "granite-moe-3b-a800m", "moonshot-v1-16b-a3b", "llava-next-34b",
    "whisper-base",
]


def fmt_s(x):
    if x >= 1:
        return f"{x:.2f}s"
    return f"{x*1e3:.1f}ms"


def load(dirname):
    cells = {}
    for fn in os.listdir(dirname):
        if not fn.endswith(".json"):
            continue
        r = json.load(open(os.path.join(dirname, fn)))
        cells[(r["arch"], r["shape"], "pod2" if r["multi_pod"] else "pod1")] = r
    return cells


def roofline_table(cells, mesh="pod1"):
    lines = [
        "| arch | shape | compute | memory | collective | bottleneck "
        "| useful | roofline | mem/dev GiB | fits |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = cells.get((arch, shape, mesh))
            if r is None:
                if shape == "long_500k" and arch in SKIPPED_LONG:
                    lines.append(
                        f"| {arch} | {shape} | — | — | — | *skipped: "
                        f"full attention (DESIGN.md §4)* | — | — | — | — |"
                    )
                continue
            rl = r["roofline"]
            mem = r["memory"]["peak_bytes_per_device"] / 2**30
            fits = "yes" if mem <= 16.0 else f"**no**"
            lines.append(
                f"| {arch} | {shape} | {fmt_s(rl['compute_s'])} "
                f"| {fmt_s(rl['memory_s'])} | {fmt_s(rl['collective_s'])} "
                f"| {rl['bottleneck']} | {rl['useful_flops_ratio']:.2f} "
                f"| {rl['roofline_fraction']*100:.1f}% | {mem:.1f} | {fits} |"
            )
    return "\n".join(lines)


def dryrun_table(cells):
    lines = [
        "| arch | shape | mesh | compile s | FLOPs/chip | HBM B/chip "
        "| coll B/chip | dominant collective |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            for mesh in ("pod1", "pod2"):
                r = cells.get((arch, shape, mesh))
                if r is None:
                    continue
                rl = r["roofline"]
                per = rl["per_collective_bytes"]
                dom = max(per, key=per.get) if any(per.values()) else "—"
                lines.append(
                    f"| {arch} | {shape} | {r['mesh']} | {r['compile_s']} "
                    f"| {rl['dot_flops_per_chip']:.2e} "
                    f"| {rl['hbm_bytes_per_chip']:.2e} "
                    f"| {rl['collective_bytes_per_chip']:.2e} | {dom} |"
                )
    return "\n".join(lines)


def main():
    dirname = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun"
    cells = load(dirname)
    print("## Roofline (single pod, 16×16 = 256 chips)\n")
    print(roofline_table(cells, "pod1"))
    print(f"\ncells loaded: {len(cells)}")
    print("\n## Dry-run raw (both meshes)\n")
    print(dryrun_table(cells))


if __name__ == "__main__":
    main()
