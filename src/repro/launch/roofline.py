"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), in seconds (TPU v5e constants):

  compute    = dot_FLOPs_per_chip / 197e12
  memory     = HBM_bytes_per_chip / 819e9
  collective = collective_bytes_per_chip / 50e9 (per-link ICI)

All three come from :mod:`.hlo_analysis`, the loop-aware HLO cost model
(``compiled.cost_analysis()`` counts while-loop bodies once — verified —
so its numbers ride along in the dry-run JSON only as a cross-check).

``MODEL_FLOPS`` (6·N_active·tokens for training, 2·N_active + cache reads
per decoded token) anchors the *useful fraction*:
``useful_ratio = MODEL_FLOPS/chips ÷ dot_FLOPs/chip`` — below 1 means the
compiled step does extra work (remat recompute, masked attention blocks,
replicated compute on the model axis) and exactly how much.

``roofline_fraction`` is the score: time the chip would spend at peak on
useful FLOPs ÷ the dominant roofline term.
"""

from __future__ import annotations

import re
from typing import Any, Dict, Optional

from repro.configs.base import ArchConfig, ShapeSpec

from .hlo_analysis import HloCost, analyze_hlo
from .mesh import TPU_V5E

__all__ = ["roofline_report", "model_flops", "analyze_hlo"]


def model_flops(cfg: ArchConfig, shape: ShapeSpec) -> float:
    """Analytic useful FLOPs for the whole cell (all chips), forward(+bwd).

    Includes the unembedding projection (V·D per token) — for small-active
    / large-vocab models (mamba2, granite-moe, whisper) the CE matmul is a
    dominant, *legitimate* part of the work, and excluding it made the
    useful-FLOPs ratio read as waste (§Perf iteration 3)."""
    n_active = cfg.param_counts()["active"] + cfg.vocab_size * cfg.d_model
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        # prefill emits only last-token logits: unembed once per sequence
        n_body = cfg.param_counts()["active"]
        return 2.0 * n_body * tokens + 2.0 * cfg.vocab_size * cfg.d_model * shape.global_batch
    # decode: one token per sequence; attention over the cache is real work
    n_attn = sum(1 for k in cfg.layer_kinds() if k == "a")
    window = cfg.sliding_window or shape.seq_len
    ctx = min(shape.seq_len, window)
    per_tok_attn = 2.0 * n_attn * 2 * cfg.kv_dim * ctx  # QK^T + PV
    return shape.global_batch * (2.0 * n_active + per_tok_attn)


def roofline_report(
    cfg: ArchConfig,
    shape: ShapeSpec,
    hlo_cost: HloCost,
    *,
    n_chips: int,
    xla_cost: Optional[Dict[str, float]] = None,
    memory: Optional[Dict[str, float]] = None,
) -> Dict[str, Any]:
    peak = TPU_V5E["peak_bf16_flops"]
    hbm = TPU_V5E["hbm_bw"]
    link = TPU_V5E["ici_link_bw"]

    flops = hlo_cost.dot_flops  # per chip (the HLO is the per-device module)
    bytes_ = hlo_cost.hbm_bytes
    coll = hlo_cost.collective_bytes

    compute_s = flops / peak
    memory_s = bytes_ / hbm
    collective_s = coll / link
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bottleneck = max(terms, key=terms.get)

    mf = model_flops(cfg, shape)
    useful_per_chip = mf / n_chips
    bound = max(terms[bottleneck], 1e-30)
    return {
        "dot_flops_per_chip": flops,
        "hbm_bytes_per_chip": bytes_,
        "collective_bytes_per_chip": coll,
        "per_collective_bytes": hlo_cost.per_collective,
        "collective_counts": hlo_cost.collective_counts,
        "model_flops_total": mf,
        "model_flops_per_chip": useful_per_chip,
        "useful_flops_ratio": useful_per_chip / flops if flops else 0.0,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "bottleneck": bottleneck,
        "bound_s": bound,
        "step_time_lower_bound_s": max(compute_s, memory_s, collective_s),
        "roofline_fraction": (useful_per_chip / peak) / bound,
        "xla_cost_reference": dict(xla_cost or {}),
    }


def format_row(res: Dict[str, Any]) -> str:
    r = res["roofline"]
    return (
        f"| {res['arch']} | {res['shape']} | {res['mesh']} "
        f"| {r['compute_s']*1e3:.1f} | {r['memory_s']*1e3:.1f} "
        f"| {r['collective_s']*1e3:.1f} | {r['bottleneck']} "
        f"| {r['useful_flops_ratio']:.2f} | {r['roofline_fraction']*100:.1f}% "
        f"| {res['memory']['peak_bytes_per_device']/2**30:.1f} |"
    )
