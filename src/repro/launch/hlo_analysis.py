"""Post-SPMD HLO cost model: loop-aware FLOPs / HBM bytes / collectives.

``compiled.cost_analysis()`` counts a ``while`` body **once** (verified in
EXPERIMENTS.md §Dry-run methodology), which under scan-over-layers
understates a 96-layer model by ~96×. This module parses the partitioned
HLO text instead and walks the computation call graph:

  * every computation gets a **multiplier** = Σ over callers of
    (caller multiplier × trip count) — ``while`` bodies contribute their
    ``known_trip_count`` (XLA records it in backend_config), fusions and
    ``call``s contribute 1, conditionals contribute 1 per branch
    (upper bound),
  * **FLOPs**: ``dot`` ops contribute 2 × |output| × contracted-size —
    shapes and ``lhs_contracting_dims`` parsed from the op line.
    (convolutions lower to dots or elementwise here; elementwise FLOPs are
    bandwidth-shadowed and excluded, as in standard MXU rooflines),
  * **HBM bytes**: the traffic model charges each *top-level* op in a
    non-fusion computation (operands + outputs); ops inside fusion
    computations are free (fused intermediates never hit HBM). This is
    the fusion-boundary model XLA's own memory analysis uses,
  * **collectives**: operand bytes of all-reduce / all-gather /
    reduce-scatter / all-to-all / collective-permute (async ``-start``
    counted, ``-done`` skipped), × the computation multiplier.

The result feeds launch/roofline.py; raw cost_analysis numbers ride along
in the dry-run JSON for cross-checking.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

__all__ = ["HloCost", "analyze_hlo"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_SHAPE_RE = re.compile(r"\b(\w+)\[([\d,]*)\]")
_COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(")
_CALLSITE_SINGLE_RE = re.compile(r"(body|condition|to_apply|calls)=%([\w.\-]+)")
_CALLSITE_LIST_RE = re.compile(r"(calls|branch_computations)=\{([^}]*)\}")
_TRIP_RE = re.compile(r"known_trip_count[\"':{ ]+n[\"': ]+\"?(\d+)")
_DOT_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

_COLL_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")
_NO_TRAFFIC_OPS = (
    "parameter", "constant", "tuple(", "get-tuple-element", "bitcast",
    "after-all", "iota",
)


def _shape_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _dims_of(shape_text: str) -> List[int]:
    m = _SHAPE_RE.search(shape_text)
    if not m or m.group(1) not in _DTYPE_BYTES:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class _Comp:
    name: str
    lines: List[str] = field(default_factory=list)
    is_fusion_target: bool = False
    trip_if_body: int = 1


@dataclass
class HloCost:
    dot_flops: float
    hbm_bytes: float
    collective_bytes: float
    per_collective: Dict[str, float]
    collective_counts: Dict[str, int]
    multipliers: Dict[str, float]

    def as_dict(self) -> Dict:
        return {
            "dot_flops": self.dot_flops,
            "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes,
            "per_collective": self.per_collective,
            "collective_counts": self.collective_counts,
        }


def _split_computations(hlo: str) -> Tuple[Dict[str, _Comp], Optional[str]]:
    comps: Dict[str, _Comp] = {}
    current: Optional[_Comp] = None
    entry: Optional[str] = None
    for raw in hlo.splitlines():
        line = raw.strip()
        if not line or line.startswith("//") or line.startswith("#"):
            continue
        m = _COMP_HEADER_RE.match(line)
        if m and "->" in line and line.rstrip().endswith("{"):
            name = m.group(1)
            current = comps.setdefault(name, _Comp(name))
            if raw.startswith("ENTRY") or line.startswith("ENTRY"):
                entry = name
            continue
        if line == "}":
            current = None
            continue
        if current is not None and "=" in line:
            current.lines.append(line)
    return comps, entry


def _call_edges(comps: Dict[str, _Comp]) -> Dict[str, List[Tuple[str, float]]]:
    """callee → [(caller, multiplier_per_caller_execution)]"""
    edges: Dict[str, List[Tuple[str, float]]] = {}
    for comp in comps.values():
        for line in comp.lines:
            trip = 1.0
            tm = _TRIP_RE.search(line)
            if tm:
                trip = float(tm.group(1))
            is_while = " while(" in line or "= while(" in line
            is_fusion = " fusion(" in line
            seen = set()
            for cm in _CALLSITE_SINGLE_RE.finditer(line):
                kind, callee = cm.group(1), cm.group(2)
                if callee not in comps or callee in seen:
                    continue
                seen.add(callee)
                mult = trip if (is_while and kind == "body") else 1.0
                edges.setdefault(callee, []).append((comp.name, mult))
                if is_fusion and kind == "calls":
                    comps[callee].is_fusion_target = True
            for cm in _CALLSITE_LIST_RE.finditer(line):
                kind = cm.group(1)
                for raw_name in re.split(r",\s*", cm.group(2)):
                    callee = raw_name.strip().lstrip("%")
                    if callee not in comps or callee in seen:
                        continue
                    seen.add(callee)
                    edges.setdefault(callee, []).append((comp.name, 1.0))
                    if is_fusion and kind == "calls":
                        comps[callee].is_fusion_target = True
    return edges


def _multipliers(comps: Dict[str, _Comp], entry: Optional[str]) -> Dict[str, float]:
    edges = _call_edges(comps)
    mult: Dict[str, float] = {}

    import functools

    @functools.lru_cache(maxsize=None)
    def of(name: str) -> float:
        if name == entry:
            return 1.0
        callers = edges.get(name)
        if not callers:
            # unreachable from entry (e.g. dead comps): count once if entry
            return 1.0 if entry is None else 0.0
        return sum(of(c) * m for c, m in callers)

    for name in comps:
        try:
            mult[name] = of(name)
        except RecursionError:  # pragma: no cover - malformed HLO
            mult[name] = 1.0
    return mult


_LHS_RE = re.compile(r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+)$")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _symbol_table(comps: Dict[str, _Comp]) -> Dict[str, int]:
    """op name → output bytes, from each line's LHS/declared shape.
    Scheduled HLO omits operand shapes, so consumers look producers up."""
    table: Dict[str, int] = {}
    for comp in comps.values():
        for line in comp.lines:
            m = _LHS_RE.match(line)
            if not m:
                continue
            name, rhs = m.group(1), m.group(2)
            # the declared output shape is the first shape on the RHS
            sm = _SHAPE_RE.search(rhs)
            nbytes = 0
            if sm is not None and sm.group(1) in _DTYPE_BYTES:
                n = 1
                for d in sm.group(2).split(","):
                    if d:
                        n *= int(d)
                nbytes = n * _DTYPE_BYTES[sm.group(1)]
            else:
                # tuple outputs: sum every shape before the op name
                head = rhs.split("(", 1)[0]
                nbytes = _shape_bytes(head)
            table[name] = nbytes
    return table


def _out_dims(rhs: str) -> List[int]:
    return _dims_of(rhs)


def _dot_flops_of_line(line: str, shapes: Dict[str, List[int]]) -> float:
    """2 × |out| × contracted_size for a `dot(` line (symbol-table lookup
    for the lhs operand's dims)."""
    m = _LHS_RE.match(line)
    if not m:
        return 0.0
    rhs = m.group(2)
    out_dims = _dims_of(rhs)
    out_n = 1
    for d in out_dims:
        out_n *= d
    cm = _DOT_CONTRACT_RE.search(line)
    args = rhs[rhs.index("dot(") + 4 :]
    ops = _OPERAND_RE.findall(args.split(")", 1)[0])
    csize = 1
    if cm and ops:
        lhs_dims = shapes.get(ops[0], [])
        for ci in cm.group(1).split(","):
            if ci and int(ci) < len(lhs_dims):
                csize *= lhs_dims[int(ci)]
    return 2.0 * out_n * csize


def analyze_hlo(hlo: str) -> HloCost:
    comps, entry = _split_computations(hlo)
    mult = _multipliers(comps, entry)
    byte_table = _symbol_table(comps)

    # dims table for dot contraction lookup
    dims_table: Dict[str, List[int]] = {}
    for comp in comps.values():
        for line in comp.lines:
            m = _LHS_RE.match(line)
            if m:
                dims_table[m.group(1)] = _dims_of(m.group(2))
    # parameters inside computations: `%p = f32[...] parameter(0)` handled
    # by the same LHS scan above.

    dot_flops = 0.0
    hbm_bytes = 0.0
    per_coll: Dict[str, float] = {k: 0.0 for k in _COLL_OPS}
    coll_counts: Dict[str, int] = {k: 0 for k in _COLL_OPS}

    for comp in comps.values():
        m = mult.get(comp.name, 0.0)
        if m <= 0:
            continue
        for line in comp.lines:
            lm = _LHS_RE.match(line)
            if not lm:
                continue
            rhs = lm.group(2)
            # ---- collectives (counted anywhere) ----
            matched_coll = False
            for op in _COLL_OPS:
                if f" {op}(" in rhs or rhs.startswith(f"{op}(") or f" {op}-start(" in rhs:
                    per_coll[op] += byte_table.get(lm.group(1), 0) * m
                    coll_counts[op] += 1
                    matched_coll = True
                    break
                if f" {op}-done(" in rhs:
                    matched_coll = True
                    break
            # ---- dot flops (counted anywhere incl. inside fusions) ----
            if " dot(" in rhs:
                dot_flops += _dot_flops_of_line(line, dims_table) * m
            # ---- HBM traffic at fusion boundaries ----
            if comp.is_fusion_target:
                continue  # fused internals don't touch HBM
            if matched_coll:
                continue  # collective bytes tracked separately
            if any(op in rhs for op in _NO_TRAFFIC_OPS):
                continue
            if " while(" in rhs or " conditional(" in rhs or " call(" in rhs:
                continue  # bodies charged directly
            out_b = byte_table.get(lm.group(1), 0)
            opnames = _OPERAND_RE.findall(rhs.split("(", 1)[1] if "(" in rhs else "")
            in_b = sum(byte_table.get(o, 0) for o in opnames)
            hbm_bytes += (out_b + in_b) * m

    return HloCost(
        dot_flops=dot_flops,
        hbm_bytes=hbm_bytes,
        collective_bytes=sum(per_coll.values()),
        per_collective=per_coll,
        collective_counts=coll_counts,
        multipliers=mult,
    )
