"""Mixture-of-Experts: token-choice top-k routing with static capacity.

GShard/Switch-style dispatch expressed with *static shapes* (pjit-friendly,
no ragged tensors):

  1. router logits → top-k experts per token, renormalized gates,
  2. position-in-expert via a cumulative sum over the flat (token, k)
     assignment list; tokens beyond an expert's capacity
     ``C = ceil(T·k·cf / E)`` are dropped (loss recovers them through the
     residual path),
  3. scatter tokens into the ``[E, C, D]`` expert batch (unique
     destinations ⇒ a pure scatter-set), run all experts as one grouped
     einsum ``ecd,edf->ecf`` (MXU-shaped), gather back with gate weights.

FLOPs are proportional to *active* parameters (E·C·D·F with C ∝ T·k/E),
which keeps the roofline's MODEL_FLOPS/HLO ratio honest. Sharding: the
default policy TP-shards every expert's ``d_ff`` on the ``model`` axis
(always divisible); expert-parallel (experts on ``model``) is a sharding-
policy flag exercised in the perf hillclimb.

Load-balancing auxiliary loss is the Switch formulation
``E · Σ_e f_e · p_e`` (fraction of tokens routed × mean router prob).
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.parallel.ctx import constrain_batch

from .layers import Params, activation_fn, dense_init


def init_moe(key, cfg) -> Params:
    m = cfg.moe
    d, f, e = cfg.d_model, m.d_ff_expert, m.n_experts
    ks = jax.random.split(key, 4)
    std = 1.0 / math.sqrt(d)
    p: Params = {
        "router": dense_init(ks[0], d, e),
        "wi": std * jax.random.truncated_normal(ks[1], -2, 2, (e, d, f), jnp.float32),
        "wo": (1.0 / math.sqrt(f))
        * jax.random.truncated_normal(ks[2], -2, 2, (e, f, d), jnp.float32),
    }
    if cfg.glu:
        p["wg"] = std * jax.random.truncated_normal(ks[3], -2, 2, (e, d, f), jnp.float32)
    return p


def capacity(n_tokens: int, top_k: int, n_experts: int, factor: float) -> int:
    c = int(math.ceil(n_tokens * top_k * factor / n_experts))
    return max(((c + 7) // 8) * 8, 8)  # sublane-aligned


def apply_moe(
    params: Params, x: jnp.ndarray, cfg
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B, S, D] → (y [B, S, D], aux_loss scalar).

    GShard-style **grouped** dispatch: each batch row is its own routing
    group with capacity ``C = ceil(S·k·cf/E)``, so the dispatch buffer is
    ``[B, E, C, D]`` — the batch dim stays data-sharded end to end and the
    whole MoE block partitions with *zero* cross-shard traffic (expert
    weights are TP-sharded on d_ff). A global-capacity buffer has no
    data-shardable dim: measured on granite-moe train_4k, the partitioner
    replicated it and all-reduced 7.7 GB per layer per microbatch
    (useful-FLOPs ratio 0.04, collective term 142 s — §Perf iteration 1).
    """
    m = cfg.moe
    b, s, d = x.shape
    e, k = m.n_experts, m.top_k
    c = capacity(s, k, e, m.capacity_factor)  # per-row capacity

    logits = (x @ params["router"].astype(x.dtype)).astype(jnp.float32)  # [B, S, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_v, gate_i = jax.lax.top_k(probs, k)  # [B, S, k]
    gate_v = gate_v / jnp.maximum(gate_v.sum(-1, keepdims=True), 1e-9)

    # --- load-balancing aux loss (Switch) ---
    me = probs.mean(axis=(0, 1))  # [E] mean router prob
    assign1 = jax.nn.one_hot(gate_i[..., 0], e, dtype=jnp.float32)
    ce = assign1.mean(axis=(0, 1))  # [E] fraction of tokens (primary route)
    aux = e * jnp.sum(me * ce)

    # --- position-in-expert within each row (cumsum over S·k: unsharded) ---
    flat_e = gate_i.reshape(b, s * k)  # [B, S*k] row-major (token, k)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)  # [B, S*k, E]
    pos_all = jnp.cumsum(onehot, axis=1) - 1
    pos = jnp.take_along_axis(pos_all, flat_e[..., None], axis=2)[..., 0]  # [B, S*k]
    keep = pos < c
    dest = jnp.where(keep, flat_e * c + pos, e * c)  # [B, S*k], overflow slot

    # --- dispatch (per-row scatter; unique destinations ⇒ scatter-set) ---
    # token-major k copies: [x0,x0,..,x1,x1,..] aligned with flat_e above
    xt = x[:, jnp.repeat(jnp.arange(s), k), :]  # [B, S*k, D]
    xe = (
        jnp.zeros((b, e * c + 1, d), x.dtype)
        .at[jnp.arange(b)[:, None], dest]
        .set(xt)
    )
    # pin the dispatch buffer's batch dim: scatter output sharding doesn't
    # propagate and the partitioner otherwise replicates the expert matmuls
    # across the data axes (measured 22× useful FLOPs — §Perf iteration 2)
    xe = constrain_batch(xe[:, : e * c].reshape(b, e, c, d))

    # --- grouped expert FFN (MXU-shaped; d_ff TP-sharded) ---
    act = activation_fn(cfg.activation)
    h = jnp.einsum("becd,edf->becf", xe, params["wi"].astype(x.dtype))
    if "wg" in params:
        h = act(jnp.einsum("becd,edf->becf", xe, params["wg"].astype(x.dtype))) * h
    else:
        h = act(h)
    ye = constrain_batch(jnp.einsum("becf,efd->becd", h, params["wo"].astype(x.dtype)))

    # --- combine: gather back per row, gate-weighted, sum over k ---
    ye_flat = ye.reshape(b, e * c, d)
    back = jnp.where(
        keep[..., None],
        jnp.take_along_axis(ye_flat, jnp.clip(dest, 0, e * c - 1)[..., None], axis=1),
        0.0,
    )  # [B, S*k, D]
    contrib = back * gate_v.reshape(b, s * k)[..., None].astype(x.dtype)
    y = contrib.reshape(b, s, k, d).sum(axis=2)

    return y, aux * m.aux_loss_weight
