"""Core model layers: norms, embeddings, MLPs, rotary embeddings.

Functional style throughout: parameters are nested dicts of ``jnp``
arrays, every layer is ``apply(params, x, ...) -> y``. Parameters are
kept in float32 (optimizer master dtype); activations run in the config's
compute dtype (bf16 on TPU) with float32 accumulation where it matters
(softmax, norms, logits).
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, *, scale: Optional[float] = None) -> jnp.ndarray:
    """Truncated-normal fan-in init (the MaxText/T5 default)."""
    std = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return std * jax.random.truncated_normal(key, -2.0, 2.0, (d_in, d_out), jnp.float32)


def embed_init(key, vocab: int, d: int) -> jnp.ndarray:
    # GPT-style 0.02 std — keeps tied-unembedding logits O(1) at init.
    return 0.02 * jax.random.normal(key, (vocab, d), jnp.float32)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def init_norm(kind: str, d: int) -> Params:
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def apply_norm(params: Params, x: jnp.ndarray, kind: str, eps: float = 1e-6) -> jnp.ndarray:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps) * params["scale"]
    else:  # layernorm
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps) * params["scale"] + params["bias"]
    return y.astype(dtype)


# ---------------------------------------------------------------------------
# activations & MLP
# ---------------------------------------------------------------------------


def activation_fn(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    if name == "relu2":  # squared ReLU (nemotron)
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(f"unknown activation {name!r}")


def init_mlp(key, d_model: int, d_ff: int, glu: bool) -> Params:
    ks = jax.random.split(key, 3)
    p: Params = {
        "wi": dense_init(ks[0], d_model, d_ff),
        "wo": dense_init(ks[1], d_ff, d_model),
    }
    if glu:
        p["wg"] = dense_init(ks[2], d_model, d_ff)
    return p


def apply_mlp(params: Params, x: jnp.ndarray, activation: str, glu: bool) -> jnp.ndarray:
    act = activation_fn(activation)
    h = x @ params["wi"].astype(x.dtype)
    if glu:
        h = act(x @ params["wg"].astype(x.dtype)) * h
    else:
        h = act(h)
    return h @ params["wo"].astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [..., S, H, D]; positions: broadcastable to [..., S]."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)  # [D/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, D/2]
    cos = jnp.cos(angles)[..., None, :]  # [..., S, 1, D/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


def sinusoidal_positions(n: int, d: int) -> jnp.ndarray:
    """Whisper-style fixed sinusoidal embeddings [n, d]."""
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    inv = jnp.exp(-math.log(10000.0) * dim / max(d // 2 - 1, 1))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# embedding / unembedding
# ---------------------------------------------------------------------------


def embed_tokens(embedding: jnp.ndarray, tokens: jnp.ndarray, dtype) -> jnp.ndarray:
    return jnp.take(embedding, tokens, axis=0).astype(dtype)


def logits_from_hidden(
    x: jnp.ndarray,
    embedding: jnp.ndarray,
    head: Optional[jnp.ndarray],
    *,
    softcap: Optional[float] = None,
) -> jnp.ndarray:
    """Final projection; fp32 logits (loss numerics)."""
    w = embedding.T if head is None else head
    logits = x.astype(jnp.float32) @ w.astype(jnp.float32)
    if softcap:
        logits = softcap * jnp.tanh(logits / softcap)
    return logits


def cross_entropy(
    logits: jnp.ndarray, labels: jnp.ndarray, *, ignore_index: int = -100
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Mean CE over non-ignored positions. Returns (loss, n_tokens)."""
    mask = labels != ignore_index
    safe = jnp.where(mask, labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    n = jnp.maximum(mask.sum(), 1)
    return nll.sum() / n, n


# Materializing [B, S, V] float32 logits dominates training memory for
# big-vocab models (mamba2: 50k vocab × 4k seq = 13 GB/device). Above this
# element budget the loss is computed chunked over the sequence.
CE_CHUNK_ELEMENTS = 1 << 26  # 64M logits (256 MB f32) per chunk


def chunked_cross_entropy(
    hidden: jnp.ndarray,  # [B, S, D]
    w: jnp.ndarray,  # [D, V] unembedding (head or embedding.T)
    labels: jnp.ndarray,  # [B, S]
    *,
    softcap: Optional[float] = None,
    ignore_index: int = -100,
    chunk: Optional[int] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """CE without materializing full logits: ``lax.scan`` over sequence
    chunks, each chunk's logits rematerialized in the backward
    (``jax.checkpoint``). The unembedding cotangent accumulates across
    chunks inside the scan — one [D, V(shard)] f32 buffer, not S of them."""
    b, s, d = hidden.shape
    v = w.shape[-1]
    if chunk is None:
        chunk = max(min(s, CE_CHUNK_ELEMENTS // max(b * v, 1)), 16)
        while s % chunk:
            chunk -= 1
    n_chunks = s // chunk

    hc = hidden.reshape(b, n_chunks, chunk, d).swapaxes(0, 1)  # [n, B, c, D]
    lc = labels.reshape(b, n_chunks, chunk).swapaxes(0, 1)

    @jax.checkpoint
    def body(carry, xs):
        nll_sum, n_sum = carry
        h, lab = xs
        logits = h.astype(jnp.float32) @ w.astype(jnp.float32)
        if softcap:
            logits = softcap * jnp.tanh(logits / softcap)
        mask = lab != ignore_index
        safe = jnp.where(mask, lab, 0)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
        nll_sum = nll_sum + ((logz - gold) * mask).sum()
        n_sum = n_sum + mask.sum()
        return (nll_sum, n_sum), None

    (nll, n), _ = jax.lax.scan(
        body, (jnp.float32(0.0), jnp.int32(0)), (hc, lc)
    )
    n = jnp.maximum(n, 1)
    return nll / n, n
