"""Model assembly: init / forward / loss / prefill / decode for all families.

One functional stack covers the ten assigned architectures:

  dense   — [norm→attn→res, norm→mlp→res] × L
  moe     — mlp sublayer replaced by token-choice MoE on configured layers
  hybrid  — per-period layer pattern of attention ('a') / SSD ('m') slots
  ssm     — all-'m', no MLP sublayer (mamba2 block layout)
  vlm     — precomputed patch embeddings spliced ahead of text embeddings
  audio   — encoder stack (bidirectional) + decoder stack with cross-attn

**Scan-over-layers**: parameters for each slot of the repeating period are
stacked over periods and the stack is applied with ``lax.scan`` — the HLO
contains one period body regardless of depth (96-layer nemotron compiles
as fast as 2-layer smoke), and remat wraps the scan body (``cfg.remat``).

Decode threads per-slot caches (KV rings / SSD states) through the same
scan, so serving reuses the exact layer code that training lowers.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.parallel.ctx import constrain_batch

from .attention import (
    KVCache,
    attention_forward,
    cache_slots,
    cross_attention_forward,
    decode_attention,
    encode_cross_kv,
    init_attention,
    init_kv_cache,
    prefill_into_cache,
)
from .layers import (
    Params,
    apply_mlp,
    apply_norm,
    cross_entropy,
    dense_init,
    embed_init,
    embed_tokens,
    init_mlp,
    init_norm,
    logits_from_hidden,
    sinusoidal_positions,
)
from .moe import apply_moe, init_moe
from .ssm import (
    SSMState,
    init_ssm,
    init_ssm_state,
    ssm_decode_step,
    ssm_forward,
)

# ---------------------------------------------------------------------------
# slot structure
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class SlotSpec:
    kind: str  # 'a' | 'm'
    moe: bool
    cross: bool = False  # decoder cross-attention (audio)


def build_slots(cfg: ArchConfig) -> Tuple[List[SlotSpec], int]:
    """Per-period slot specs + number of periods."""
    kinds = cfg.layer_kinds()
    period = len(cfg.layer_pattern) if cfg.layer_pattern else 1
    if cfg.moe is not None:
        period = _lcm(period, cfg.moe.every_k_layers)
    period = min(period, cfg.n_layers)
    assert cfg.n_layers % period == 0, (cfg.n_layers, period)
    slots = [
        SlotSpec(kinds[i], cfg.is_moe_layer(i), cross=cfg.enc_dec) for i in range(period)
    ]
    # sanity: the pattern must actually repeat with this period
    for i in range(cfg.n_layers):
        assert kinds[i] == slots[i % period].kind
        assert cfg.is_moe_layer(i) == slots[i % period].moe
    return slots, cfg.n_layers // period


def _lcm(a: int, b: int) -> int:
    import math

    return a * b // math.gcd(a, b)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_slot(key, slot: SlotSpec, cfg: ArchConfig) -> Params:
    ks = jax.random.split(key, 8)
    p: Params = {"norm1": init_norm(cfg.norm, cfg.d_model)}
    if slot.kind == "a":
        p["attn"] = init_attention(ks[0], cfg)
    else:
        p["ssm"] = init_ssm(ks[1], cfg)
    if slot.cross:
        p["norm_x"] = init_norm(cfg.norm, cfg.d_model)
        p["cross"] = init_attention(ks[2], cfg)
    if slot.moe:
        p["norm2"] = init_norm(cfg.norm, cfg.d_model)
        p["moe"] = init_moe(ks[3], cfg)
    elif cfg.d_ff > 0:
        p["norm2"] = init_norm(cfg.norm, cfg.d_model)
        p["mlp"] = init_mlp(ks[4], cfg.d_model, cfg.d_ff, cfg.glu)
    return p


def init_params(cfg: ArchConfig, rng: jax.Array) -> Params:
    slots, n_periods = build_slots(cfg)
    keys = jax.random.split(rng, 8)
    params: Params = {
        "embedding": embed_init(keys[0], cfg.vocab_size, cfg.d_model),
        "final_norm": init_norm(cfg.norm, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["head"] = dense_init(keys[1], cfg.d_model, cfg.vocab_size)
    if cfg.positional == "learned":
        params["pos_embed"] = 0.02 * jax.random.normal(
            keys[2], (max(cfg.max_seq, 4096), cfg.d_model), jnp.float32
        )

    def init_stack(base_key, slot):
        per_period = jax.random.split(base_key, n_periods)
        return jax.vmap(lambda k: _init_slot(k, slot, cfg))(per_period)

    slot_keys = jax.random.split(keys[3], len(slots))
    params["slots"] = [init_stack(slot_keys[i], s) for i, s in enumerate(slots)]

    if cfg.enc_dec:
        enc_slot = SlotSpec("a", False, cross=False)
        enc_keys = jax.random.split(keys[4], cfg.n_enc_layers)
        params["enc"] = {
            "slots": [jax.vmap(lambda k: _init_slot(k, enc_slot, cfg))(enc_keys)],
            "final_norm": init_norm(cfg.norm, cfg.d_model),
        }
    return params


# ---------------------------------------------------------------------------
# forward (train / full-sequence)
# ---------------------------------------------------------------------------


def _slot_forward(slot_params, x, slot: SlotSpec, cfg: ArchConfig, *,
                  causal: bool, enc_kv=None):
    aux = jnp.float32(0.0)
    h = apply_norm(slot_params["norm1"], x, cfg.norm)
    if slot.kind == "a":
        x = x + attention_forward(slot_params["attn"], h, cfg, causal=causal)
    else:
        x = x + ssm_forward(slot_params["ssm"], h, cfg)
    if slot.cross and enc_kv is not None:
        hx = apply_norm(slot_params["norm_x"], x, cfg.norm)
        x = x + cross_attention_forward(slot_params["cross"], hx, enc_kv, cfg)
    if slot.moe:
        h2 = apply_norm(slot_params["norm2"], x, cfg.norm)
        y, a = apply_moe(slot_params["moe"], h2, cfg)
        x = x + y
        aux = aux + a
    elif cfg.d_ff > 0:
        h2 = apply_norm(slot_params["norm2"], x, cfg.norm)
        x = x + apply_mlp(slot_params["mlp"], h2, cfg.activation, cfg.glu)
    return x, aux


def _run_stack(slot_stacks, x, slots: List[SlotSpec], cfg: ArchConfig, *,
               causal: bool, enc_kv=None):
    """Scan the stacked periods; remat the period body per cfg.remat."""

    def period_body(carry, period_params):
        x, aux = carry
        x = constrain_batch(x)
        for i, slot in enumerate(slots):
            x, a = _slot_forward(period_params[i], x, slot, cfg,
                                 causal=causal, enc_kv=enc_kv)
            aux = aux + a
        return (constrain_batch(x), aux), None

    if cfg.remat in ("block", "full"):
        # 'block': full recompute inside each period (saves only the
        # residual-stream carry — 0.1 GB vs 1 GB/layer on granite-20b; the
        # dots-saveable policy was measured at 52 GB/device, EXPERIMENTS
        # §Perf). 'block_dots' trades memory back for recompute FLOPs.
        period_body = jax.checkpoint(period_body)
    elif cfg.remat == "block_dots":
        period_body = jax.checkpoint(
            period_body,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
        )

    n_periods = jax.tree.leaves(slot_stacks[0])[0].shape[0]
    if cfg.remat == "nested" and n_periods >= 4:
        # Two-level (√L) remat: residual saves drop from n_periods×carry to
        # (n_groups + group)×carry — what fits nemotron-340b's 96 layers.
        group = _best_group(n_periods)
        stacks_g = jax.tree.map(
            lambda a: a.reshape(a.shape[0] // group, group, *a.shape[1:]),
            tuple(slot_stacks),
        )
        inner_body = jax.checkpoint(period_body)

        @jax.checkpoint
        def group_body(carry, group_params):
            out, _ = jax.lax.scan(inner_body, carry, group_params)
            return out, None

        (x, aux), _ = jax.lax.scan(group_body, (x, jnp.float32(0.0)), stacks_g)
        return x, aux

    (x, aux), _ = jax.lax.scan(period_body, (x, jnp.float32(0.0)), tuple(slot_stacks))
    return x, aux


def _best_group(n: int) -> int:
    """Divisor of n closest to √n (nested-remat group size)."""
    import math

    target = math.isqrt(n)
    best = 1
    for d in range(1, n + 1):
        if n % d == 0 and abs(d - target) < abs(best - target):
            best = d
    return best


def _compute_dtype(cfg: ArchConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def _embed_inputs(params, batch: Dict[str, jnp.ndarray], cfg: ArchConfig):
    """Family-dependent input embedding. Returns (x, label_offset)."""
    dtype = _compute_dtype(cfg)
    tokens = batch["tokens"]
    x = embed_tokens(params["embedding"], tokens, dtype)
    if cfg.family == "vlm" and "patch_embeds" in batch:
        patches = batch["patch_embeds"].astype(dtype)  # [B, P, D] (stub frontend)
        x = jnp.concatenate([patches, x], axis=1)
    if cfg.positional == "learned":
        s = x.shape[1]
        x = x + params["pos_embed"][:s].astype(dtype)[None]
    elif cfg.positional == "sinusoidal":
        s = x.shape[1]
        x = x + sinusoidal_positions(s, cfg.d_model).astype(dtype)[None]
    return x


def _encode(params, batch, cfg: ArchConfig):
    """Audio encoder: frames [B, T, D] (conv frontend stubbed) → enc_out."""
    dtype = _compute_dtype(cfg)
    frames = batch["frames"].astype(dtype)
    x = frames + sinusoidal_positions(frames.shape[1], cfg.d_model).astype(dtype)[None]
    enc_slots = [SlotSpec("a", False, cross=False)]
    x, _ = _run_stack(params["enc"]["slots"], x, enc_slots, cfg, causal=False)
    return apply_norm(params["enc"]["final_norm"], x, cfg.norm)


def forward(params: Params, batch: Dict[str, jnp.ndarray], cfg: ArchConfig):
    """Full-sequence forward → (logits [B, S, V], aux_loss)."""
    slots, _ = build_slots(cfg)
    enc_kv = None
    if cfg.enc_dec:
        enc_out = _encode(params, batch, cfg)
        # cross K/V shared across decoder layers would be wrong — each layer
        # has its own projections; project inside the slot via stacked params.
        # We instead pass enc_out and let each slot project. To keep the
        # scan body uniform we precompute per-slot K/V lazily inside
        # _slot_forward via encode_cross_kv — but that needs per-layer
        # weights, which ARE per-slot. So pass enc_out through closure:
        enc_kv = enc_out  # sentinel: projected per-slot below
    x = _embed_inputs(params, batch, cfg)

    if cfg.enc_dec:
        x, aux = _run_stack_encdec(params["slots"], x, enc_kv, slots, cfg)
    else:
        x, aux = _run_stack(params["slots"], x, slots, cfg, causal=True)
    x = apply_norm(params["final_norm"], x, cfg.norm)
    logits = logits_from_hidden(
        x, params["embedding"], params.get("head"), softcap=cfg.logit_softcap
    )
    return logits, aux


def _run_stack_encdec(slot_stacks, x, enc_out, slots, cfg):
    def period_body(carry, period_params):
        x, aux = carry
        x = constrain_batch(x)
        for i, slot in enumerate(slots):
            sp = period_params[i]
            kv = encode_cross_kv(sp["cross"], enc_out, cfg)
            x, a = _slot_forward(sp, x, slot, cfg, causal=True, enc_kv=kv)
            aux = aux + a
        return (x, aux), None

    if cfg.remat in ("block", "full"):
        period_body = jax.checkpoint(period_body)
    (x, aux), _ = jax.lax.scan(period_body, (x, jnp.float32(0.0)), tuple(slot_stacks))
    return x, aux


def forward_hidden(params: Params, batch: Dict[str, jnp.ndarray], cfg: ArchConfig):
    """Forward up to the final norm (no unembedding)."""
    slots, _ = build_slots(cfg)
    x = _embed_inputs(params, batch, cfg)
    if cfg.enc_dec:
        enc_out = _encode(params, batch, cfg)
        x, aux = _run_stack_encdec(params["slots"], x, enc_out, slots, cfg)
    else:
        x, aux = _run_stack(params["slots"], x, slots, cfg, causal=True)
    return apply_norm(params["final_norm"], x, cfg.norm), aux


def loss_fn(params: Params, batch: Dict[str, jnp.ndarray], cfg: ArchConfig):
    """Next-token CE (+ MoE aux). For VLM, loss is on text positions only.

    Large-vocab models never materialize [B, S, V] logits — the loss runs
    through the chunked CE (layers.chunked_cross_entropy)."""
    from .layers import CE_CHUNK_ELEMENTS, chunked_cross_entropy

    x, aux = forward_hidden(params, batch, cfg)
    labels = batch["labels"]
    if cfg.family == "vlm" and "patch_embeds" in batch:
        n_patch = batch["patch_embeds"].shape[1]
        x = x[:, n_patch:]
    b, s, _ = x.shape
    w = params["embedding"].T if "head" not in params else params["head"]
    if b * s * cfg.vocab_size > CE_CHUNK_ELEMENTS:
        from repro.parallel.ctx import degather_weight

        if cfg.vocab_size % 16 == 0:  # keep vocab sharding, drop zero3 data
            w = degather_weight(w, model_dim=-1)
        loss, n = chunked_cross_entropy(x, w, labels, softcap=cfg.logit_softcap)
    else:
        logits = logits_from_hidden(
            x, params["embedding"], params.get("head"), softcap=cfg.logit_softcap
        )
        loss, n = cross_entropy(logits, labels)
    return loss + aux, {"ce": loss, "aux": aux, "tokens": n}


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------


class LayerCaches(NamedTuple):
    """Per-slot stacked caches (over periods)."""

    kv: List[Any]  # KVCache or None per slot
    ssm: List[Any]  # SSMState or None per slot
    cross_kv: Optional[List[Any]] = None  # audio: per-slot stacked (k, v)


def init_caches(cfg: ArchConfig, batch: int, max_seq: int, dtype=None) -> LayerCaches:
    dtype = dtype or _compute_dtype(cfg)
    slots, n_periods = build_slots(cfg)
    slots_n = cache_slots(cfg, max_seq)

    def stack(make):
        return jax.tree.map(lambda *xs: jnp.stack(xs), *[make() for _ in range(n_periods)])

    kv, ssm = [], []
    for slot in slots:
        if slot.kind == "a":
            kv.append(stack(lambda: init_kv_cache(batch, slots_n, cfg, dtype)))
            ssm.append(None)
        else:
            kv.append(None)
            ssm.append(stack(lambda: init_ssm_state(batch, cfg, dtype)))
    return LayerCaches(kv=kv, ssm=ssm, cross_kv=None)


def prefill(params: Params, batch: Dict[str, jnp.ndarray], cfg: ArchConfig,
            *, max_seq: Optional[int] = None):
    """Process the prompt, returning last-position logits + decode caches.

    Runs slot-by-slot (python loop over periods via scan with cache
    outputs); the prompt length S is the shape's seq_len.
    """
    slots, n_periods = build_slots(cfg)
    x = _embed_inputs(params, batch, cfg)
    b, s = x.shape[0], x.shape[1]
    max_seq = max_seq or (s + 1024)
    slots_n = cache_slots(cfg, max_seq)
    enc_out = _encode(params, batch, cfg) if cfg.enc_dec else None

    kv_out: List[Any] = []
    ssm_out: List[Any] = []
    cross_out: List[Any] = []

    def period_body(x_aux, period_params):
        x, aux = x_aux
        x = constrain_batch(x)
        new_caches = []
        for i, slot in enumerate(slots):
            sp = period_params[i]
            h = apply_norm(sp["norm1"], x, cfg.norm)
            if slot.kind == "a":
                y, cache = prefill_into_cache(sp["attn"], h, cfg, slots_n)
                x = x + y
                new_caches.append(cache)
            else:
                y, state = ssm_forward(sp["ssm"], h, cfg, return_state=True)
                x = x + y
                new_caches.append(state)
            if slot.cross and enc_out is not None:
                kvx = encode_cross_kv(sp["cross"], enc_out, cfg)
                hx = apply_norm(sp["norm_x"], x, cfg.norm)
                x = x + cross_attention_forward(sp["cross"], hx, kvx, cfg)
                new_caches.append(kvx)
            if slot.moe:
                h2 = apply_norm(sp["norm2"], x, cfg.norm)
                y, a = apply_moe(sp["moe"], h2, cfg)
                x, aux = x + y, aux + a
            elif cfg.d_ff > 0:
                h2 = apply_norm(sp["norm2"], x, cfg.norm)
                x = x + apply_mlp(sp["mlp"], h2, cfg.activation, cfg.glu)
        return (x, aux), tuple(new_caches)

    (x, _aux), caches_stacked = jax.lax.scan(
        period_body, (x, jnp.float32(0.0)), tuple(params["slots"])
    )

    # unpack per-slot cache stacks
    ci = 0
    cross_kv: List[Any] = []
    for slot in slots:
        if slot.kind == "a":
            kv_out.append(caches_stacked[ci])
            ssm_out.append(None)
        else:
            kv_out.append(None)
            ssm_out.append(caches_stacked[ci])
        ci += 1
        if slot.cross:
            cross_kv.append(caches_stacked[ci])
            ci += 1

    x = apply_norm(params["final_norm"], x, cfg.norm)
    last = x[:, -1:]
    logits = logits_from_hidden(
        last, params["embedding"], params.get("head"), softcap=cfg.logit_softcap
    )
    return logits[:, 0], LayerCaches(kv_out, ssm_out, cross_kv or None)


def decode_step(params: Params, tokens: jnp.ndarray, caches: LayerCaches,
                step_pos: jnp.ndarray, cfg: ArchConfig):
    """One decode step. tokens: [B, 1] int32; step_pos: [B] absolute pos.
    Returns (logits [B, V], new caches)."""
    slots, n_periods = build_slots(cfg)
    dtype = _compute_dtype(cfg)
    x = embed_tokens(params["embedding"], tokens, dtype)
    if cfg.positional == "learned":
        x = x + params["pos_embed"].astype(dtype)[step_pos][:, None]

    # xs for the scan: per-slot stacked params + caches
    xs: List[Any] = []
    for i, slot in enumerate(slots):
        entry: Dict[str, Any] = {"params": params["slots"][i]}
        if slot.kind == "a":
            entry["cache"] = caches.kv[i]
        else:
            entry["cache"] = caches.ssm[i]
        if slot.cross and caches.cross_kv is not None:
            entry["cross_kv"] = caches.cross_kv[_cross_index(slots, i)]
        xs.append(entry)

    def period_body(x, slot_inputs):
        new_caches = []
        for i, slot in enumerate(slots):
            sp = slot_inputs[i]["params"]
            cache = slot_inputs[i]["cache"]
            h = apply_norm(sp["norm1"], x, cfg.norm)
            if slot.kind == "a":
                y, cache = decode_attention(sp["attn"], h, cache, step_pos, cfg)
            else:
                y, cache = ssm_decode_step(sp["ssm"], h, cache, cfg)
            x = x + y
            new_caches.append(cache)
            if slot.cross and "cross_kv" in slot_inputs[i]:
                hx = apply_norm(sp["norm_x"], x, cfg.norm)
                x = x + cross_attention_forward(
                    sp["cross"], hx, slot_inputs[i]["cross_kv"], cfg
                )
            if slot.moe:
                h2 = apply_norm(sp["norm2"], x, cfg.norm)
                y, _a = apply_moe(sp["moe"], h2, cfg)
                x = x + y
            elif cfg.d_ff > 0:
                h2 = apply_norm(sp["norm2"], x, cfg.norm)
                x = x + apply_mlp(sp["mlp"], h2, cfg.activation, cfg.glu)
        return x, tuple(new_caches)

    x, caches_stacked = jax.lax.scan(period_body, x, tuple(xs))

    kv_out, ssm_out = [], []
    for i, slot in enumerate(slots):
        if slot.kind == "a":
            kv_out.append(caches_stacked[i])
            ssm_out.append(None)
        else:
            kv_out.append(None)
            ssm_out.append(caches_stacked[i])

    x = apply_norm(params["final_norm"], x, cfg.norm)
    logits = logits_from_hidden(
        x[:, 0:1], params["embedding"], params.get("head"), softcap=cfg.logit_softcap
    )
    return logits[:, 0], LayerCaches(kv_out, ssm_out, caches.cross_kv)


def _cross_index(slots: List[SlotSpec], slot_idx: int) -> int:
    """Index into the cross_kv list for a given slot."""
    return sum(1 for s in slots[:slot_idx] if s.cross)
