"""Attention: GQA/MQA, sliding windows, chunked flash, KV-cache decode.

Three execution paths, all numerically equivalent (tested):

  * **dense** — materialized scores, for short sequences (smoke tests).
  * **chunked flash** — online-softmax over (q-block, kv-block) pairs.
    The pair list is built *statically at trace time* and, for causal or
    sliding-window masks, only the needed pairs are emitted — the HLO
    carries exactly-triangular FLOPs instead of the 2× of mask-everything
    schedules. This is the SplashAttention idea expressed in pure JAX
    (`lax.scan` over the pair list, `dynamic_update_slice` accumulators).
  * **decode** — one query position against a (possibly ring) KV cache
    with explicit per-slot absolute positions, which makes sliding-window
    ring buffers and ragged batches exact.

GQA is computed grouped (``[B, Hkv, G, S, D]``) — KV is never repeated to
Hq, so MQA (granite-20b, G=48) reads each KV head once.
"""

from __future__ import annotations

import functools
import math
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import Params, apply_rope, dense_init

NEG_INF = -1e30


def init_attention(key, cfg) -> Params:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p: Params = {
        "wq": dense_init(ks[0], d, cfg.q_dim),
        "wk": dense_init(ks[1], d, cfg.kv_dim),
        "wv": dense_init(ks[2], d, cfg.kv_dim),
        "wo": dense_init(ks[3], cfg.q_dim, d),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.q_dim,), jnp.float32)
        p["bk"] = jnp.zeros((cfg.kv_dim,), jnp.float32)
        p["bv"] = jnp.zeros((cfg.kv_dim,), jnp.float32)
    return p


def _project_qkv(params, x, cfg, positions, *, rope: bool):
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = x @ params["wq"].astype(x.dtype)
    k = x @ params["wk"].astype(x.dtype)
    v = x @ params["wv"].astype(x.dtype)
    if "bq" in params:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    q = q.reshape(b, s, cfg.n_heads, hd)
    k = k.reshape(b, s, cfg.n_kv_heads, hd)
    v = v.reshape(b, s, cfg.n_kv_heads, hd)
    if rope and cfg.positional == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


# ---------------------------------------------------------------------------
# dense path (short sequences)
# ---------------------------------------------------------------------------


def _dense_attention(q, k, v, *, causal: bool, window: Optional[int], bias=None):
    """q: [B,S,Hq,D]; k,v: [B,Skv,Hkv,D] → [B,S,Hq,D]."""
    b, s, hq, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, s, hkv, g, d)
    scale = 1.0 / math.sqrt(d)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32) * scale
    if causal or window:
        qi = jnp.arange(s)[:, None]
        ki = jnp.arange(skv)[None, :]
        offset = skv - s  # queries are the trailing positions
        mask = jnp.ones((s, skv), bool)
        if causal:
            mask &= ki <= qi + offset
        if window:
            mask &= (qi + offset) - ki < window
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    if bias is not None:
        scores = scores + bias
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
    return out.reshape(b, s, hq, d)


# ---------------------------------------------------------------------------
# chunked flash path (static pair list, exactly-causal FLOPs)
# ---------------------------------------------------------------------------


def _kv_range(i: int, nkv: int, q_chunk: int, kv_chunk: int,
              *, causal: bool, window: Optional[int], offset: int):
    """Static [lo, hi) kv-block range containing unmasked work for q-block i."""
    q_lo = i * q_chunk + offset
    q_hi = (i + 1) * q_chunk - 1 + offset
    hi = nkv
    if causal:
        hi = min(nkv, q_hi // kv_chunk + 1)
    lo = 0
    if window is not None:
        lo = max(0, (q_lo - window + 1) // kv_chunk)
    return lo, max(hi, lo + 1)


def _flash_attention(q, k, v, *, causal: bool, window: Optional[int],
                     q_chunk: int = 512, kv_chunk: int = 1024):
    """Online-softmax attention, blocked for memory and FLOPs.

    Q-blocks are *independent*: a static python loop emits one
    ``jax.checkpoint``-wrapped computation per q-block whose kv-scan covers
    exactly the statically-needed [lo, hi) block range (causal triangle /
    sliding window). The HLO carries exactly-needed FLOPs, and backward
    memory is O(one block) — the scan-carry trajectory of a fused-pairs
    formulation would otherwise store every q-block's accumulator per step
    (measured 61 GiB/device on granite-20b train_4k; see EXPERIMENTS.md).
    """
    b, s, hq, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    q_chunk = min(q_chunk, s)
    kv_chunk = min(kv_chunk, skv)
    assert s % q_chunk == 0 and skv % kv_chunk == 0, (s, q_chunk, skv, kv_chunk)
    nq, nkv = s // q_chunk, skv // kv_chunk
    offset = skv - s
    scale = 1.0 / math.sqrt(d)

    qg = q.reshape(b, nq, q_chunk, hkv, g, d)
    kb = k.reshape(b, nkv, kv_chunk, hkv, d)
    vb = v.reshape(b, nkv, kv_chunk, hkv, d)
    q_pos_base = jnp.arange(q_chunk)
    k_pos_base = jnp.arange(kv_chunk)

    @functools.partial(jax.checkpoint, static_argnums=(3, 4))
    def one_q_block(qi, kjs, vjs, i, lo):
        """qi: [b,qc,hkv,g,d]; kjs/vjs: [b,nj,kc,hkv,d] for blocks lo..hi."""

        def body(carry, xs):
            m_i, l_i, a_i = carry
            kj, vj, jrel = xs
            scores = jnp.einsum("bqhgd,bkhd->bqhgk", qi, kj).astype(jnp.float32) * scale
            qpos = i * q_chunk + q_pos_base + offset
            kpos = (lo + jrel) * kv_chunk + k_pos_base
            mask = jnp.ones((q_chunk, kv_chunk), bool)
            if causal:
                mask &= kpos[None, :] <= qpos[:, None]
            if window is not None:
                mask &= qpos[:, None] - kpos[None, :] < window
            scores = jnp.where(mask[None, :, None, None, :], scores, NEG_INF)
            m_ij = jnp.max(scores, axis=-1)
            m_new = jnp.maximum(m_i, m_ij)
            p = jnp.exp(scores - m_new[..., None])
            alpha = jnp.exp(m_i - m_new)
            l_new = l_i * alpha + p.sum(axis=-1)
            a_new = a_i * alpha[..., None] + jnp.einsum(
                "bqhgk,bkhd->bqhgd", p.astype(vj.dtype), vj
            ).astype(jnp.float32)
            return (m_new, l_new, a_new), None

        nj = kjs.shape[1]
        m0 = jnp.full((b, q_chunk, hkv, g), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, q_chunk, hkv, g), jnp.float32)
        a0 = jnp.zeros((b, q_chunk, hkv, g, d), jnp.float32)
        (m_f, l_f, a_f), _ = jax.lax.scan(
            body,
            (m0, l0, a0),
            (jnp.moveaxis(kjs, 1, 0), jnp.moveaxis(vjs, 1, 0),
             jnp.arange(nj, dtype=jnp.int32)),
        )
        return (a_f / jnp.maximum(l_f[..., None], 1e-30)).astype(q.dtype)

    outs = []
    for i in range(nq):
        lo, hi = _kv_range(i, nkv, q_chunk, kv_chunk,
                           causal=causal, window=window, offset=offset)
        out_i = one_q_block(qg[:, i], kb[:, lo:hi], vb[:, lo:hi], i, lo)
        outs.append(out_i)
    out = jnp.stack(outs, axis=1)  # [b, nq, qc, hkv, g, d]
    return out.reshape(b, s, hq, d)


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------

DENSE_MAX_SEQ = 2048  # beyond this, use the chunked flash path


def attention_forward(
    params: Params,
    x: jnp.ndarray,  # [B, S, D]
    cfg,
    *,
    positions: Optional[jnp.ndarray] = None,
    causal: bool = True,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
) -> jnp.ndarray:
    """Self-attention over a full sequence (train / prefill)."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)[None, :]
    q, k, v = _project_qkv(params, x, cfg, positions, rope=True)
    window = cfg.sliding_window
    if s <= DENSE_MAX_SEQ:
        out = _dense_attention(q, k, v, causal=causal, window=window)
    else:
        out = _flash_attention(q, k, v, causal=causal, window=window,
                               q_chunk=q_chunk, kv_chunk=kv_chunk)
    return out.reshape(b, s, cfg.q_dim) @ params["wo"].astype(x.dtype)


def cross_attention_forward(
    params: Params,
    x: jnp.ndarray,  # [B, S, D] decoder states
    enc_kv: Tuple[jnp.ndarray, jnp.ndarray],  # precomputed (k, v): [B, Senc, Hkv, D]
    cfg,
) -> jnp.ndarray:
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = (x @ params["wq"].astype(x.dtype)).reshape(b, s, cfg.n_heads, hd)
    if "bq" in params:
        q = q + params["bq"].astype(x.dtype).reshape(1, 1, cfg.n_heads, hd)
    k, v = enc_kv
    out = _dense_attention(q, k, v, causal=False, window=None)
    return out.reshape(b, s, cfg.q_dim) @ params["wo"].astype(x.dtype)


def encode_cross_kv(params: Params, enc_out: jnp.ndarray, cfg):
    """Precompute cross-attention K/V from encoder output (cached once)."""
    b, senc, _ = enc_out.shape
    hd = cfg.resolved_head_dim
    k = (enc_out @ params["wk"].astype(enc_out.dtype)).reshape(b, senc, cfg.n_kv_heads, hd)
    v = (enc_out @ params["wv"].astype(enc_out.dtype)).reshape(b, senc, cfg.n_kv_heads, hd)
    if "bk" in params:
        k = k + params["bk"].astype(k.dtype).reshape(1, 1, cfg.n_kv_heads, hd)
        v = v + params["bv"].astype(v.dtype).reshape(1, 1, cfg.n_kv_heads, hd)
    return k, v


# ---------------------------------------------------------------------------
# KV cache + decode
# ---------------------------------------------------------------------------


class KVCache(NamedTuple):
    """Ring-capable KV cache. ``pos`` holds the absolute position stored in
    each slot (-1 = empty), making sliding windows and ragged decode exact."""

    k: jnp.ndarray  # [B, W, Hkv, D]
    v: jnp.ndarray  # [B, W, Hkv, D]
    pos: jnp.ndarray  # [B, W] int32


def init_kv_cache(batch: int, slots: int, cfg, dtype) -> KVCache:
    hd = cfg.resolved_head_dim
    return KVCache(
        k=jnp.zeros((batch, slots, cfg.n_kv_heads, hd), dtype),
        v=jnp.zeros((batch, slots, cfg.n_kv_heads, hd), dtype),
        pos=jnp.full((batch, slots), -1, jnp.int32),
    )


def cache_slots(cfg, max_seq: int) -> int:
    if cfg.sliding_window is not None:
        return min(cfg.sliding_window, max_seq)
    return max_seq


def decode_attention(
    params: Params,
    x: jnp.ndarray,  # [B, 1, D]
    cache: KVCache,
    step_pos: jnp.ndarray,  # [B] absolute position of the new token
    cfg,
) -> Tuple[jnp.ndarray, KVCache]:
    b = x.shape[0]
    hd = cfg.resolved_head_dim
    q, k_new, v_new = _project_qkv(params, x, cfg, step_pos[:, None], rope=True)

    slots = cache.k.shape[1]
    slot = (step_pos % slots).astype(jnp.int32)  # ring write
    bi = jnp.arange(b)
    k = cache.k.at[bi, slot].set(k_new[:, 0])
    v = cache.v.at[bi, slot].set(v_new[:, 0])
    pos = cache.pos.at[bi, slot].set(step_pos.astype(jnp.int32))

    # attention over all slots with validity/window masking via slot pos
    g = cfg.n_heads // cfg.n_kv_heads
    qg = q.reshape(b, 1, cfg.n_kv_heads, g, hd)
    scale = 1.0 / math.sqrt(hd)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32) * scale
    valid = (pos >= 0) & (pos <= step_pos[:, None])
    if cfg.sliding_window is not None:
        valid &= (step_pos[:, None] - pos) < cfg.sliding_window
    scores = jnp.where(valid[:, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v).reshape(b, 1, cfg.q_dim)
    y = out @ params["wo"].astype(x.dtype)
    return y, KVCache(k, v, pos)


def prefill_into_cache(
    params: Params,
    x: jnp.ndarray,  # [B, S, D]
    cfg,
    slots: int,
) -> Tuple[jnp.ndarray, KVCache]:
    """Full-sequence attention that also populates a decode cache."""
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :]
    q, k, v = _project_qkv(params, x, cfg, positions, rope=True)
    window = cfg.sliding_window
    if s <= DENSE_MAX_SEQ:
        out = _dense_attention(q, k, v, causal=True, window=window)
    else:
        out = _flash_attention(q, k, v, causal=True, window=window)
    y = out.reshape(b, s, cfg.q_dim) @ params["wo"].astype(x.dtype)

    # write the trailing `slots` positions into the ring
    take = min(slots, s)
    k_tail = k[:, s - take :]
    v_tail = v[:, s - take :]
    tail_pos = jnp.arange(s - take, s, dtype=jnp.int32)
    cache = init_kv_cache(b, slots, cfg, x.dtype)
    slot_idx = tail_pos % slots
    ck = cache.k.at[:, slot_idx].set(k_tail)
    cv = cache.v.at[:, slot_idx].set(v_tail)
    cp = cache.pos.at[:, slot_idx].set(tail_pos[None, :])
    return y, KVCache(ck, cv, cp)
