"""Mamba-2 SSD (state-space duality) blocks: chunked scan + O(1) decode.

The SSD formulation (Dao & Gu 2024) evaluates the selective state-space
recurrence

    h_t = exp(dt_t·A) · h_{t-1} + dt_t · B_t ⊗ x_t ,   y_t = C_t · h_t + D·x_t

blockwise: within a chunk of Q timesteps the quadratic "attention-like"
form runs on the MXU; across chunks a small [H, P, N] state is carried by
a ``lax.scan``. Decode is the recurrence itself — O(1) state per layer,
which is what qualifies the ssm/hybrid architectures for ``long_500k``.

**TP layout**: projections are kept *separate* (z, x, B|C, dt) rather than
fused, so the tensor-parallel sharding is head-aligned: x/z shard on
``d_inner`` (⇒ heads shard, since head_dim stays intact), dt shards on
heads, the tiny group B/C projections replicate, and ``out_proj``
row-shards back to d_model (one psum). A fused in_proj would slice a
model-sharded dimension at non-boundary offsets and force reshards —
measured and rejected in EXPERIMENTS.md §Perf.

Both the hybrid (Jamba) and pure-SSM (mamba2-130m) architectures lower
through this module (DESIGN.md §5 records the Mamba-1→SSD substitution
for Jamba).
"""

from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import Params, apply_norm, dense_init, init_norm


class SSMState(NamedTuple):
    """Decode-time carry for one SSD block."""

    h: jnp.ndarray  # [B, H, P, N] state
    conv_x: jnp.ndarray  # [B, d_conv-1, di] conv tail (x path)
    conv_bc: jnp.ndarray  # [B, d_conv-1, 2·G·N] conv tail (B|C path)


def _dims(cfg):
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    nh = s.n_heads(d)
    gn = s.n_groups * s.d_state
    return s, d, di, nh, gn


def init_ssm(key, cfg) -> Params:
    s, d, di, nh, gn = _dims(cfg)
    ks = jax.random.split(key, 8)
    p: Params = {
        "z_proj": dense_init(ks[0], d, di),
        "x_proj": dense_init(ks[1], d, di),
        "bc_proj": dense_init(ks[2], d, 2 * gn),
        "dt_proj": dense_init(ks[3], d, nh),
        "conv_x_w": 0.1 * jax.random.normal(ks[4], (s.d_conv, di), jnp.float32),
        "conv_x_b": jnp.zeros((di,), jnp.float32),
        "conv_bc_w": 0.1 * jax.random.normal(ks[5], (s.d_conv, 2 * gn), jnp.float32),
        "conv_bc_b": jnp.zeros((2 * gn,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh, dtype=jnp.float32)),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.log(
            jnp.exp(
                jnp.exp(
                    jax.random.uniform(ks[6], (nh,), jnp.float32)
                    * (math.log(s.dt_max) - math.log(s.dt_min))
                    + math.log(s.dt_min)
                )
            )
            - 1.0
            + 1e-6
        ),  # inverse-softplus of U(dt_min, dt_max)
        "norm": init_norm("rmsnorm", di),
        "out_proj": dense_init(ks[7], di, d),
    }
    return p


def _causal_conv(w, b, x, tail: Optional[jnp.ndarray] = None):
    """Depthwise causal conv over time. x: [B, L, C]; w: [K, C].
    Returns (silu(conv(x)), new tail [B, K-1, C])."""
    k = w.shape[0]
    wd = w.astype(x.dtype)
    if tail is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = tail.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # [B, L+K-1, C]
    out = sum(xp[:, i : i + x.shape[1]] * wd[i] for i in range(k))
    out = out + b.astype(x.dtype)
    new_tail = xp[:, -(k - 1) :] if k > 1 else jnp.zeros((x.shape[0], 0, x.shape[2]), x.dtype)
    return jax.nn.silu(out), new_tail


def _project(params, x, cfg):
    z = x @ params["z_proj"].astype(x.dtype)  # [B, L, di]
    xs = x @ params["x_proj"].astype(x.dtype)  # [B, L, di]
    bc = x @ params["bc_proj"].astype(x.dtype)  # [B, L, 2gn]
    dt = x @ params["dt_proj"].astype(x.dtype)  # [B, L, nh]
    return z, xs, bc, dt


def _ssd_chunked(xh, dt, A, B, C, chunk: int):
    """Chunked SSD scan.

    xh: [B, L, H, P]; dt: [B, L, H]; A: [H] (negative);
    B, C: [B, L, G, N] (G=1 here, broadcast over heads).
    Returns y: [B, L, H, P] and the final state [B, H, P, N].
    """
    b, l, h, p = xh.shape
    n = B.shape[-1]
    assert l % chunk == 0, (l, chunk)
    nc = l // chunk

    xh_c = xh.reshape(b, nc, chunk, h, p)
    dt_c = dt.reshape(b, nc, chunk, h)
    B_c = B.reshape(b, nc, chunk, -1, n)
    C_c = C.reshape(b, nc, chunk, -1, n)

    dA = dt_c * A[None, None, None, :]  # [b,nc,q,h] (negative)
    cum = jnp.cumsum(dA, axis=2)  # within-chunk cumulative

    def body(h_prev, inp):
        xq, dtq, Bq, Cq, cumq = inp  # chunk-local slices (b, q, ...)
        # decay from position j (exclusive) to i (inclusive): exp(cum_i - cum_j)
        seg = cumq[:, :, None, :] - cumq[:, None, :, :]  # [b, i, j, h]
        causal = jnp.tril(jnp.ones((chunk, chunk), bool))
        decay = jnp.where(causal[None, :, :, None], jnp.exp(seg), 0.0)
        # intra-chunk (quadratic, MXU): scores[b,i,j,h] = C_i·B_j · decay · dt_j
        cb = jnp.einsum("bign,bjgn->bijg", Cq, Bq)  # G broadcast → g=1
        scores = cb * decay.astype(cb.dtype) * dtq[:, None, :, :].astype(cb.dtype)
        y_intra = jnp.einsum("bijh,bjhp->bihp", scores.astype(xq.dtype), xq)
        # inter-chunk: contribution of carried state
        y_inter = jnp.einsum(
            "bign,bhpn->bihp", Cq, h_prev.astype(Cq.dtype)
        ) * jnp.exp(cumq)[..., None].astype(xq.dtype)
        # state update: h_new = h·exp(cum_Q) + Σ_j exp(cum_Q-cum_j)·dt_j·B_j⊗x_j
        total = cumq[:, -1:, :]  # [b,1,h]
        w = jnp.exp(total - cumq) * dtq  # [b,q,h]
        h_new = h_prev * jnp.exp(total[:, 0, :, None, None]).astype(h_prev.dtype) + jnp.einsum(
            "bqh,bqgn,bqhp->bhpn", w.astype(xq.dtype), Bq, xq
        ).astype(h_prev.dtype)
        return h_new, y_intra + y_inter

    h0 = jnp.zeros((b, h, p, n), jnp.float32)
    inputs = (
        jnp.moveaxis(xh_c, 1, 0),
        jnp.moveaxis(dt_c, 1, 0),
        jnp.moveaxis(B_c, 1, 0),
        jnp.moveaxis(C_c, 1, 0),
        jnp.moveaxis(cum, 1, 0),
    )
    h_final, ys = jax.lax.scan(body, h0, inputs)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, l, h, p)
    return y, h_final


def ssm_forward(
    params: Params, x: jnp.ndarray, cfg, *, return_state: bool = False
) -> Any:
    """Full-sequence SSD block (train / prefill). x: [B, L, D]."""
    s, d, di, nh, gn = _dims(cfg)
    b, l, _ = x.shape
    z, xs_raw, bc, dtr = _project(params, x, cfg)
    xs_act, tail_x = _causal_conv(params["conv_x_w"], params["conv_x_b"], xs_raw)
    bc_act, tail_bc = _causal_conv(params["conv_bc_w"], params["conv_bc_b"], bc)
    xs = xs_act.reshape(b, l, nh, s.head_dim)
    Bv = bc_act[..., :gn].reshape(b, l, s.n_groups, s.d_state)
    Cv = bc_act[..., gn:].reshape(b, l, s.n_groups, s.d_state)
    dt = jax.nn.softplus(dtr.astype(jnp.float32) + params["dt_bias"])  # [b,l,h]
    A = -jnp.exp(params["A_log"])  # [h]

    chunk = min(s.chunk, l)
    pad = (-l) % chunk
    if pad:
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bv = jnp.pad(Bv, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cv = jnp.pad(Cv, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    y, h_final = _ssd_chunked(xs, dt, A, Bv, Cv, chunk)
    y = y[:, :l]

    y = y + params["D"].astype(y.dtype)[None, None, :, None] * xs[:, :l]
    y = y.reshape(b, l, di)
    y = y * jax.nn.silu(z)
    y = apply_norm(params["norm"], y, "rmsnorm")
    out = y @ params["out_proj"].astype(x.dtype)
    if return_state:
        state = SSMState(h=h_final, conv_x=tail_x, conv_bc=tail_bc)
        return out, state
    return out


def init_ssm_state(batch: int, cfg, dtype) -> SSMState:
    s, d, di, nh, gn = _dims(cfg)
    return SSMState(
        h=jnp.zeros((batch, nh, s.head_dim, s.d_state), jnp.float32),
        conv_x=jnp.zeros((batch, s.d_conv - 1, di), dtype),
        conv_bc=jnp.zeros((batch, s.d_conv - 1, 2 * gn), dtype),
    )


def ssm_decode_step(
    params: Params, x: jnp.ndarray, state: SSMState, cfg
) -> Tuple[jnp.ndarray, SSMState]:
    """One-token recurrence. x: [B, 1, D]."""
    s, d, di, nh, gn = _dims(cfg)
    b = x.shape[0]
    z, xs_raw, bc, dtr = _project(params, x, cfg)
    xs_act, tail_x = _causal_conv(params["conv_x_w"], params["conv_x_b"], xs_raw, state.conv_x)
    bc_act, tail_bc = _causal_conv(params["conv_bc_w"], params["conv_bc_b"], bc, state.conv_bc)
    xs = xs_act[:, 0].reshape(b, nh, s.head_dim)
    Bv = bc_act[:, 0, :gn].reshape(b, s.n_groups, s.d_state)
    Cv = bc_act[:, 0, gn:].reshape(b, s.n_groups, s.d_state)
    dtv = jax.nn.softplus(dtr[:, 0].astype(jnp.float32) + params["dt_bias"])  # [b,h]
    A = -jnp.exp(params["A_log"])

    dA = jnp.exp(dtv * A)  # [b,h]
    Bb = Bv[:, 0]  # [b,n] (G=1 broadcast)
    Cb = Cv[:, 0]
    h_new = state.h * dA[..., None, None] + (
        dtv[..., None, None]
        * xs.astype(jnp.float32)[..., None]
        * Bb.astype(jnp.float32)[:, None, None, :]
    )
    y = jnp.einsum("bhpn,bn->bhp", h_new, Cb.astype(jnp.float32)).astype(x.dtype)
    y = y + params["D"].astype(y.dtype)[None, :, None] * xs
    y = y.reshape(b, 1, di)
    y = y * jax.nn.silu(z)
    y = apply_norm(params["norm"], y, "rmsnorm")
    out = y @ params["out_proj"].astype(x.dtype)
    return out, SSMState(h=h_new, conv_x=tail_x, conv_bc=tail_bc)
